"""Table I reproduction: staged SpMV speedups (base / +selective caching /
+DMA gather), paper: 10.0x / 19.8x / 29.2x vs a 4-socket Xeon.

Two components:
  (a) measured — wall time of the actual implementations on this host
      (CPU; relative ordering + bandwidth discipline, not absolute TPU perf);
  (b) modeled  — core/traffic.py machine model (paper-spec constants), whose
      EMERGENT ratios are compared against the paper's Table I, including the
      cache-everything pathology the paper reports as "slower than base".
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmat, to_bbcsr, to_padded_ell
from repro.core.algorithms import spmv, spmv_ell
from repro.core.traffic import SPMV_PROFILES, XEON, PIUMA_NODE, speedup, time_per_elem
from repro.kernels import ops

PAPER = {"piuma_base": 10.0, "piuma_selective": 19.8, "piuma_dma": 29.2}


def _bench(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(scale=13):
    g = rmat(scale, 16, seed=0)
    x = jnp.asarray(np.random.default_rng(0).random(g.n_cols, np.float32))
    rows = []

    f_base = jax.jit(lambda xx: spmv(g, xx))
    t_base = _bench(f_base, x)
    cols, vals, mask = to_padded_ell(g, 64)
    f_ell = jax.jit(lambda xx: spmv_ell(cols, vals, mask, xx))
    t_ell = _bench(f_ell, x)
    bb = to_bbcsr(g, block_rows=512, block_cols=512, tile_nnz=512)
    f_bb = jax.jit(lambda xx: ops.spmv_dma(bb, xx))
    t_bb = _bench(f_bb, x, reps=2)

    measured = {"piuma_base": t_base, "piuma_selective": t_ell, "piuma_dma": t_bb}
    base_model = speedup(SPMV_PROFILES["piuma_base"])
    for name in ["piuma_base", "piuma_cache_all", "piuma_selective", "piuma_dma"]:
        s = speedup(SPMV_PROFILES[name])
        rows.append({
            "name": f"table1/{name}",
            "us_per_call": round(measured.get(name, float("nan")), 1),
            "derived": (f"modeled_speedup_vs_xeon={s:.1f}x"
                        f";vs_base={s / base_model:.2f}x"
                        + (f";paper={PAPER[name]}x" if name in PAPER else
                           ";paper=slower_than_base")),
        })
    # bandwidth-utilization claim (paper: DMA version >95% of DRAM bw)
    p = SPMV_PROFILES["piuma_dma"]
    mem_bound = p.dram_bytes / (PIUMA_NODE.dram_bw * PIUMA_NODE.bw_efficiency)
    util = mem_bound / time_per_elem(PIUMA_NODE, p)
    rows.append({"name": "table1/dma_bw_utilization",
                 "us_per_call": float("nan"),
                 "derived": f"modeled_fraction_of_achievable_bw={util:.2f};paper=>0.95"})
    return rows
