"""Table II reproduction: per-application speedups, 1 node and 16 nodes.

Measured: wall time of our implementations (CPU, RMAT-scaled).
Modeled: core/traffic.py 1-node and 16-node PIUMA-vs-Xeon projections,
compared against the paper's Table II column per app.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmat
from repro.core.algorithms import (spmv, spmspv, pagerank, bfs, random_walks,
                                   label_propagation, ties_sample)
from repro.core.traffic import APP_PROFILES, XEON, PIUMA_NODE, \
    multinode_time_per_elem, time_per_elem

PAPER = {  # (1 node, 16 nodes)
    "SpMV": (29, 467), "SpMSpV": (111, 1387), "Breadth-first Search": (7.5, 117),
    "Random Walks": (279, 2606), "PageRank": (41, 555),  # PageRank≈Louvain row class
    "Louvain Community": (41, 555), "TIES Sampler": (93, 419),
    "Graph Sage": (3.1, 46),
}


def _t(fn, reps=3):
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(scale=12):
    g = rmat(scale, 16, seed=1)
    x = jnp.asarray(np.random.default_rng(0).random(g.n_cols, np.float32))
    key = jax.random.PRNGKey(0)
    sp_ids = jnp.asarray(np.arange(32, dtype=np.int32))
    sp_vals = jnp.ones((32,), jnp.float32)

    measured = {}
    measured["SpMV"] = _t(jax.jit(lambda: spmv(g, x)))
    measured["SpMSpV"] = _t(jax.jit(lambda: spmspv(g, sp_ids, sp_vals, max_deg=256)))
    measured["Breadth-first Search"] = _t(jax.jit(lambda: bfs(g, 0, max_levels=32)))
    measured["PageRank"] = _t(jax.jit(lambda: pagerank(g, iters=10)))
    measured["Random Walks"] = _t(
        jax.jit(lambda: random_walks(g, jnp.arange(1024), 16, key)))
    measured["Louvain Community"] = _t(
        jax.jit(lambda: label_propagation(g, iters=5)))
    measured["TIES Sampler"] = _t(
        jax.jit(lambda: ties_sample(g, 256, 512, key)[2]))
    measured["Graph Sage"] = float("nan")  # covered by gnn minibatch bench below

    rows = []
    for app, profs in APP_PROFILES.items():
        tx = time_per_elem(XEON, profs["xeon"])
        s1 = tx / multinode_time_per_elem(PIUMA_NODE, profs["piuma"], 1)
        s16 = tx / multinode_time_per_elem(PIUMA_NODE, profs["piuma"], 16)
        p1, p16 = PAPER.get(app, (float("nan"),) * 2)
        rows.append({
            "name": f"table2/{app.replace(' ', '_')}",
            "us_per_call": round(measured.get(app, float("nan")), 1),
            "derived": (f"modeled_1node={s1:.1f}x(paper={p1}x)"
                        f";modeled_16node={s16:.0f}x(paper={p16}x)"
                        f";scaleout={s16 / s1:.1f}x/16"),
        })
    return rows
