"""Generate the EXPERIMENTS.md §Roofline markdown table from the sweep JSONs.

  PYTHONPATH=src python -m benchmarks.report [--append]
"""
import argparse
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load_all():
    recs = {}
    for f in ("results/dryrun.json", "results/dryrun_lm.json"):
        p = os.path.join(ROOT, f)
        if os.path.exists(p):
            for r in json.load(open(p)):
                key = (r["arch"], r["shape"], r["multi_pod"], r.get("variant"))
                recs[key] = r  # later files win
    return recs


def fmt(x):
    return f"{x:.2e}" if isinstance(x, float) else str(x)


def table(recs, *, variant=None):
    lines = ["| arch | shape | mesh | kind | fit | compute s | memory s | "
             "collective s | dominant | roofline frac | useful FLOPs |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    order = sorted(recs.values(), key=lambda r: (r["arch"], r["shape"],
                                                 r["multi_pod"]))
    for r in order:
        if r.get("variant") != variant:
            continue
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | — | — | — |"
                         f" — | — | skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR |"
                         f" — | — | — | — | {r.get('error','')[:40]} | — | — |")
            continue
        roof = r["roofline"]
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['kind']} |"
            f" {'Y' if r['per_device']['fits_16gb'] else 'N'} |"
            f" {roof['compute_s']:.2e} | {roof['memory_s']:.2e} |"
            f" {roof['collective_s']:.2e} | {roof['dominant']} |"
            f" {roof['roofline_fraction']:.2f} |"
            f" {('%.2f' % uf) if uf else '—'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--append", action="store_true",
                    help="append the table to EXPERIMENTS.md")
    args = ap.parse_args()
    recs = load_all()
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    md = (f"\n## §Roofline — full baseline table ({n_ok} compiled cells)\n\n"
          + table(recs) + "\n")
    print(md)
    if args.append:
        with open(os.path.join(ROOT, "EXPERIMENTS.md"), "a") as f:
            f.write(md)


if __name__ == "__main__":
    main()
