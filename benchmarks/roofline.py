"""Roofline report over the measured kernel lane (DESIGN.md §18).

Each ``repro.tune.kernel_rows`` row carries a modeled HBM byte count and a
measured time (hardware-true on TPU, compiled jnp-oracle on CPU); dividing
gives achieved bytes/s, and the STREAM-triad measurement anchors the
memory-roof.  The report prints achieved vs peak per kernel row — the
"fraction of roofline" number the PIUMA paper's bandwidth argument rests
on.  (The old implementation read a ``results/dryrun.json`` sweep that no
launcher writes anymore; the kernel lane is the live data source.)
"""
from __future__ import annotations

SCALE = 12  # probe-graph scale for the standalone CSV harness


def rows_to_report(rows, peak):
    """Roofline rows (CSV-harness shape) from kernel-lane rows + peak B/s."""
    out = []
    for r in rows:
        frac = r["bytes_per_s"] / peak if peak > 0 else float("nan")
        out.append({
            "name": "roofline/" + r["name"].split("/", 1)[1],
            "us_per_call": r["us"],
            "derived": (f"achieved={r['bytes_per_s']:.3e}B/s"
                        f";peak={peak:.3e}B/s;frac={frac:.3f}"
                        f";model_bytes={r['bytes_model']}"
                        f";measured={r['measured']}"),
        })
    return out


def run(scale: int = SCALE, rows=None):
    from repro.tune import kernel_rows, stream_peak_bytes_per_s
    peak = stream_peak_bytes_per_s()
    if rows is None:
        rows = kernel_rows(scale)
    return rows_to_report(rows, peak)
