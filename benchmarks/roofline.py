"""Roofline report: reads the dry-run sweep JSON and prints per-cell terms.

This is the §Roofline deliverable: compute/memory/collective terms (seconds),
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and HBM fit.
"""
import json
import os

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")


def run(path=DEFAULT):
    rows = []
    if not os.path.exists(path):
        return [{"name": "roofline/missing", "us_per_call": float("nan"),
                 "derived": f"run launch.dryrun --sweep first ({path})"}]
    for r in json.load(open(path)):
        if r.get("status") != "ok":
            continue
        roof = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/pods{1 + int(r['multi_pod'])}",
            "us_per_call": round(roof["bound_s"] * 1e6, 1),
            "derived": (f"dom={roof['dominant']}"
                        f";cT={roof['compute_s']:.2e};mT={roof['memory_s']:.2e}"
                        f";nT={roof['collective_s']:.2e}"
                        f";roofline_frac={roof['roofline_fraction']:.2f}"
                        f";useful_flops={'%.2f' % ratio if ratio else 'n/a'}"
                        f";fits={r['per_device']['fits_16gb']}"),
        })
    return rows
