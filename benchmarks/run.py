"""Benchmark harness — one module per paper table. CSV: name,us_per_call,derived."""
import sys


def main() -> None:
    from . import table1_spmv, table2_apps, roofline, bench_kernels
    print("name,us_per_call,derived")
    for mod in (table1_spmv, table2_apps, bench_kernels, roofline):
        for row in mod.run():
            print(f"{row['name']},{row['us_per_call']},{row['derived']}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
