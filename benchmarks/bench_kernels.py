"""Kernel microbenches — thin shim over ``repro.tune.kernel_rows``.

The measurement lane moved into the autotuner (DESIGN.md §18) so the bench,
the roofline report, and the tuning sweep all time the same grid the same
way: hardware-true compiled kernels on TPU, compiled jnp-oracle timings on
CPU (Pallas interpret-mode wall clock is Python execution, not kernel
performance).  This module keeps the CSV-harness row shape.
"""
from __future__ import annotations

SCALE = 12


def run(scale: int = SCALE):
    from repro.tune import kernel_rows
    rows = []
    for r in kernel_rows(scale):
        cfg = r.get("config")
        cfg_s = ("" if not cfg else
                 ";" + ";".join(f"{k}={v}" for k, v in sorted(cfg.items())))
        rows.append({
            "name": r["name"],
            "us_per_call": r["us"],
            "derived": (f"measured={r['measured']}"
                        f";model_bytes={r['bytes_model']}" + cfg_s),
        })
    return rows
