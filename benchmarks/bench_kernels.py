"""Kernel microbenches: jnp reference path timings + kernel traffic notes.

Pallas kernels run in interpret mode on CPU (Python-level execution), so
wall-clock here is NOT kernel performance; we report the jnp-oracle timing
(the XLA-compiled equivalent computation) and the kernels' modeled VMEM
working sets — the dry-run roofline carries the perf argument.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmat, to_bbcsr
from repro.kernels import ref


def _t(fn, reps=5):
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run():
    rows = []
    g = rmat(12, 16, seed=0)
    bb = to_bbcsr(g, block_rows=256, block_cols=512, tile_nnz=512)
    x = jnp.asarray(np.random.default_rng(0).random(g.n_cols, np.float32))
    t = _t(jax.jit(lambda: ref.spmv_bbcsr_ref(bb, x)))
    vmem = (bb.tile_nnz * (bb.block_cols + bb.block_rows) * 4 +
            bb.block_cols * 4 + bb.block_rows * 4 + 3 * bb.tile_nnz * 4)
    rows.append({"name": "kernels/spmv_bbcsr_oracle", "us_per_call": round(t, 1),
                 "derived": f"nnz={g.nnz};kernel_vmem_per_step={vmem}B"})

    q = jnp.asarray(np.random.default_rng(1).standard_normal(
        (4, 8, 1024, 128)).astype(np.float32))
    k = q[:, :4]
    t = _t(jax.jit(lambda: ref.flash_attention_ref(q, k, k)))
    rows.append({"name": "kernels/flash_attn_oracle_b4h8s1024",
                 "us_per_call": round(t, 1),
                 "derived": "kernel_vmem_per_step="
                            f"{(128 * 128 * 3 + 128 * 128) * 4}B"})
    table = jnp.asarray(np.random.default_rng(2).standard_normal(
        (100_000, 16)).astype(np.float32))
    idx = jnp.asarray(np.random.default_rng(3).integers(0, 100_000, 8192,
                                                        ).astype(np.int32))
    bag = jnp.asarray(np.sort(np.random.default_rng(4).integers(0, 512, 8192)
                              ).astype(np.int32))
    t = _t(jax.jit(lambda: ref.embedding_bag_ref(table, idx, bag, 512)))
    rows.append({"name": "kernels/embedding_bag_oracle_8k_lookups",
                 "us_per_call": round(t, 1),
                 "derived": "fine_grained_bytes=8192*64B (vs 8192*4096B page-granular)"})
    return rows
