"""Direction-optimizing engine sweep on RMAT graphs.

Times level-synchronous BFS in three engine modes on the same graph:

* ``push`` — every level expands the frontier sparsely (nonzero-compaction +
  per-active-row gathers, work ∝ frontier edges, padded to max degree);
* ``pull`` — every level is one dense edge-parallel pass (work ∝ |E|);
* ``auto`` — the engine's switch: push while the frontier population count is
  under n/32, pull once it saturates (Beamer's heuristic).

On RMAT the frontier explodes after 2-3 hops, so always-push pays the
max-degree padding on a huge frontier and always-pull pays |E| work on the
tiny first/last levels; the switch takes the cheaper side of each.  SSSP
(delta-stepping buckets), connected components (min-label propagation) and
multi-level Louvain (gain-gated sweeps + contraction, DESIGN.md §11) run on
the same engine to show the abstraction generalizes — one machinery, five
workloads, and Louvain is the first with a *quality* metric (modularity)
rather than output equivalence.

Also reported:

* the distributed push *byte model* (`core/traffic.py`): routed bytes per
  sparse level under full-capacity routing vs the engine's compacted
  frontier-proportional capacity (`engine.frontier_edge_capacity`);
* the compacted-push **overflow fallback rate** on a skewed RMAT graph
  (DESIGN.md §7): per BFS level, would any shard's active-edge count
  overflow the derived capacity;
* with >= 8 devices (the CI bench lane exports
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): distributed
  multi-level Louvain — partition equivalence vs single-device, contraction
  route bytes, and the *measured* fallback count from
  `engine.run_distributed(return_stats=True)`;
* the **streaming** section (PR 8, fixed RMAT-12, DESIGN.md §16):
  incremental repair (``bfs_repair``/``sssp_repair`` warm-started from the
  previous fixpoint) vs from-scratch per ≤1%-of-edges insert epoch — gated
  ≥ 3x for SSSP, never-slower for BFS; update-ingest throughput through
  ``GraphService.apply_updates``; and the partition-scoped cache survival
  fraction across a one-partition update — gated ≥ 0.5;
* the **obs** section (PR 9, fixed RMAT-12, DESIGN.md §17): one B=32 batch
  served through a span/trace/metrics-instrumented service — gated on ≥ 90%
  of the batch wall clock attributed to named spans and on the exported
  Chrome trace validating; with ``--json`` the trace itself is written next
  to the bench document as ``TRACE_*.json``;
* ``--sweep-delta`` — delta-stepping bucket-width sweep on RMAT and
  uniform-weight graphs against the histogram auto-tune (DESIGN.md §8);
* the **graph query service** section (always at RMAT-12, whatever
  ``--scale``): the MS-BFS amortization ratio (per-query time at B=256 vs a
  sequential bfs — the PR-4 acceptance bar is < 0.15) and, per batch budget
  B ∈ {1, 32, 256}, serving queries/sec, batch occupancy, modeled route
  bytes per query, and the cache hit rate on a resubmitted stream
  (DESIGN.md §13);
* the **distributed service** section (PR 5, also fixed RMAT-12, needs >= 8
  devices): the same budgets served through `run_batched_distributed`
  behind the facade, with latency p50/p95 and the deadline-miss rate under
  a 60 s SLO — gated = 0 at B=32 (DESIGN.md §14); since PR 7 the B=1 lane
  serves under ``placement='async'`` (larger budgets stay sync — the dense
  micro-step work dominates there) with the cost EWMA seeded from the last
  bench doc;
* the **async placement** section (PR 7, fixed RMAT-12, needs >= 8
  devices): MS-BFS and batched delta-stepping at B ∈ {1, 32} under the
  level-synchronous vs the bounded-staleness placement (sync_interval=8) —
  latency p50/p95 and the measured global-reduction counts, gated on
  bit-identical results and a >= 4x (sssp) / >= 2x (bfs) reduction ratio.

Run:  PYTHONPATH=src python benchmarks/bench_engine.py [--scale 12]
      PYTHONPATH=src python benchmarks/bench_engine.py --scale 7 --smoke \
          --json BENCH_pr4.json --baseline auto
      PYTHONPATH=src python benchmarks/bench_engine.py --sweep-delta

``--smoke`` (the `scripts/ci.sh bench` lane) checks the outputs for NaN and
for regression markers (modes disagreeing, byte model not shrinking,
modularity not beating a single LPA sweep) and exits nonzero on failure.
``--json`` writes the machine-readable result document (the repo's persisted
``BENCH_*.json`` trajectory); ``--baseline auto`` compares against the
newest committed ``BENCH_*.json`` and fails on NaN or a >25% regression.
"""
import argparse
import glob
import json
import math
import os
import platform
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dgas, engine, rmat, uniform_random_graph, traffic
from repro.core.algorithms import (auto_delta, bfs, bfs_program,
                                   connected_components, label_propagation,
                                   modularity, multilevel, pagerank, sssp)


def _t(fn, reps=3):
    jax.block_until_ready(fn())  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def routed_bytes_report(n, m, pushes, n_shards=8, switch_frac=1 / 32):
    """Byte model for the distributed push levels of this run: full-capacity
    routing vs the engine's compacted frontier-proportional capacity."""
    m_per_shard = -(-m // n_shards)
    edge_cap = engine.frontier_edge_capacity(m_per_shard, switch_frac)
    full = traffic.RouteByteCounter(n_shards)
    compact = traffic.RouteByteCounter(n_shards)
    for _ in range(max(pushes, 1)):
        full.push_level(m_per_shard)
        compact.push_level(edge_cap)
    reduction = full.total_bytes / max(1, compact.total_bytes)
    print(f"\nrouted bytes / sparse level (model, S={n_shards}): "
          f"full={traffic.push_level_route_bytes(n_shards, m_per_shard):,} B  "
          f"compact={traffic.push_level_route_bytes(n_shards, edge_cap):,} B  "
          f"(capacity {m_per_shard} -> {edge_cap})")
    print(f"sparse-phase total over {max(pushes, 1)} push levels: "
          f"{full.total_bytes:,} B -> {compact.total_bytes:,} B "
          f"({reduction:.1f}x less)")
    return {"full_bytes": full.total_bytes, "compact_bytes": compact.total_bytes,
            "reduction": reduction}


def fallback_report(scale, edge_factor=8, n_shards=8, switch_frac=1 / 32):
    """Compacted-push overflow fallback rate on a *skewed* RMAT graph.

    Replays BFS levels under the distributed engine's capacity rule (block
    vertex rule, ``frontier_edge_capacity`` per-peer budget): a push level
    falls back to full-capacity routing when any shard's active-edge count
    overflows.  This is the analytical counterpart of the runtime counter in
    ``run_distributed(return_stats=True)`` — same decision rule, no mesh
    needed — measured on a=0.7 RMAT where degree skew concentrates active
    edges on few shards (DESIGN.md §7 records the number).
    """
    g = rmat(scale, edge_factor, a=0.7, b=0.12, c=0.12, seed=1)
    n, m = g.n_rows, g.nnz
    lv = np.asarray(bfs(g, 0))
    att = dgas.block_rule(n, n_shards)
    rows = np.asarray(g.row_ids())
    owner = np.asarray(att.owner(jnp.asarray(rows)))
    m_per_shard = int(np.bincount(owner, minlength=n_shards).max())
    edge_cap = engine.frontier_edge_capacity(m_per_shard, switch_frac)
    switch_count = max(1, int(n * switch_frac))
    push_levels = fallbacks = 0
    for d in range(int(lv.max()) + 1 if lv.max() >= 0 else 0):
        frontier = lv == d
        if not frontier.any() or int(frontier.sum()) > switch_count:
            continue  # dense regime: the engine pulls, no routing capacity
        push_levels += 1
        active_per_shard = np.bincount(owner[frontier[rows]],
                                       minlength=n_shards)
        if active_per_shard.max() > edge_cap:
            fallbacks += 1
    rate = fallbacks / push_levels if push_levels else 0.0
    print(f"\ncompacted-push fallback on skewed RMAT-{scale} (a=0.7, S={n_shards}): "
          f"{fallbacks}/{push_levels} push levels overflow cap {edge_cap} "
          f"(rate {rate:.2f})")
    return {"scale": scale, "push_levels": push_levels, "fallbacks": fallbacks,
            "rate": rate, "edge_cap": edge_cap}


def louvain_report(g, smoke_failures):
    """Multi-level Louvain quality + wall time (the repo's first quality
    metric: modularity, not output equivalence)."""
    q_single = float(modularity(g, label_propagation(g, iters=1)))
    labels, scores = multilevel(g)  # cold run: correctness + jit warmup
    ms = float("inf")
    # best-of-8, not the usual best-of-3: this section is hundreds of small
    # dispatches, so its min needs more samples to converge under host load
    # (measured: best-of-3 straddles the 25% baseline gate, best-of-8 is
    # stable to a few percent)
    for _ in range(8):
        t0 = time.perf_counter()
        multilevel(g)  # warm: level shapes repeat, so compiles are cached
        ms = min(ms, (time.perf_counter() - t0) * 1e3)
    q_multi = scores[-1] if scores else float(modularity(g, labels))
    n_comm = int(np.unique(np.asarray(labels)).size)
    print(f"\nlouvain: single LPA sweep Q={q_single:.5f}  multilevel "
          f"Q={q_multi:.5f} over {len(scores)} levels ({n_comm} communities, "
          f"{ms:.0f} ms)")
    if not scores:
        smoke_failures.append("REGRESSION: multilevel accepted no level")
    elif not all(b > a for a, b in zip(scores, scores[1:])):
        smoke_failures.append("REGRESSION: multilevel scores not increasing")
    if not np.isfinite(q_multi) or q_multi <= q_single:
        smoke_failures.append(
            "REGRESSION: multilevel Q does not beat a single LPA sweep")
    return {"single_sweep": q_single, "multilevel": q_multi,
            "levels": len(scores), "n_communities": n_comm, "ms": ms}


def distributed_report(scale, smoke_failures, n_shards=8):
    """Distributed lane (runs when the host exposes >= n_shards devices, as
    the CI bench lane does via XLA_FLAGS): distributed multi-level Louvain
    equivalence + contraction route bytes, and the measured compacted-push
    fallback counter from the engine's runtime stats."""
    if len(jax.devices()) < n_shards:
        print(f"\ndistributed lane skipped ({len(jax.devices())} devices < "
              f"{n_shards}; CI sets XLA_FLAGS=--xla_force_host_platform_"
              f"device_count={n_shards})")
        return None
    from repro.core.algorithms import multilevel_distributed
    from repro.core.algorithms.louvain import partition_equal
    from repro.core.algorithms.distgraph import shard_graph, unshard_vertex_array
    from repro.launch.mesh import make_cores_mesh

    mesh = make_cores_mesh(n_shards)
    g = rmat(scale, 8, seed=1)
    lab_l, scores_l = multilevel(g)
    ctr = traffic.RouteByteCounter(n_shards,
                                   payload_bytes=traffic.CONTRACT_PAYLOAD_BYTES)
    # cold run: correctness + route-byte counter + jit warmup; the reported
    # time is best-of-3 warm (louvain_report's idiom) — the cold wall clock
    # is compile-dominated (~20 s at smoke scale) and gated it measured the
    # XLA frontend, not the engine
    lab_d, scores_d = multilevel_distributed(g, mesh, counter=ctr)
    ms = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        multilevel_distributed(g, mesh)
        ms = min(ms, (time.perf_counter() - t0) * 1e3)
    match = partition_equal(lab_l, lab_d)
    # measured fallback counter on a skewed graph (engine runtime stats);
    # mode='auto' so only genuine push-regime levels count, matching
    # fallback_report's analytical replay of the same decision rule
    gs = rmat(scale, 8, a=0.7, b=0.12, c=0.12, seed=1)
    att = dgas.block_rule(gs.n_rows, n_shards)
    gsh, _ = shard_graph(gs, n_shards, row_att=att)
    g_rev = engine.reverse_graph(gs, att)
    o0, l0 = int(att.owner(jnp.asarray(0))), int(att.local(jnp.asarray(0)))
    st0 = {"level": jnp.full((n_shards, att.per_shard), -1,
                             jnp.int32).at[o0, l0].set(0)}
    f0 = jnp.zeros((n_shards, att.per_shard), jnp.int32).at[o0, l0].set(1)
    _, stats = engine.run_distributed(gsh, att, mesh, bfs_program(), st0, f0,
                                      axis="cores", max_iters=gs.n_rows,
                                      mode="auto", g_rev=g_rev,
                                      return_stats=True)
    stats = {k: int(np.asarray(v)[0]) for k, v in stats.items()}
    print(f"\ndistributed louvain (S={n_shards}): Q levels "
          f"{[round(s, 5) for s in scores_d]} ({ms:.0f} ms), partition match "
          f"with single-device: {match}")
    print(f"contraction routing: {ctr.total_bytes:,} B over {ctr.levels} "
          f"levels; measured push fallbacks on skewed RMAT-{scale}: "
          f"{stats['fallbacks']}/{stats['pushes']}")
    if not match:
        smoke_failures.append(
            "REGRESSION: distributed multilevel diverges from single-device")
    if scores_d and scores_l and abs(scores_d[-1] - scores_l[-1]) > 1e-3:
        smoke_failures.append("REGRESSION: distributed multilevel Q diverges")
    return {"q_levels": scores_d, "partition_match": bool(match),
            "contract_bytes": ctr.total_bytes, "contract_levels": ctr.levels,
            "ms": ms, "measured_fallbacks": stats["fallbacks"],
            "measured_pushes": stats["pushes"]}


def service_report(smoke_failures, budgets=(1, 32, 256), scale=12,
                   edge_factor=8):
    """Graph query service throughput + the MS-BFS amortization ratio.

    Runs at a *fixed* RMAT-12 regardless of ``--scale`` so the trajectory
    point (and the PR-4 acceptance ratio: batched per-query time at B=256
    must be < 0.15x one sequential bfs) stays comparable across lanes.
    Per budget B: queries/sec over a fresh random reachability stream
    (compiled runner pre-warmed — qps measures serving, not compilation),
    batch occupancy, route bytes per query (batched §13 byte model), and the
    cache hit rate when the same stream is resubmitted.
    """
    from repro.core import GraphService, Reachability
    from repro.core.algorithms import msbfs

    g = rmat(scale, edge_factor, seed=0)
    n = g.n_rows
    B = 256
    srcs = np.arange(B, dtype=np.int32) % n
    # sequential per-query cost = mean over a sample of the *actual* batch
    # sources (source 0 alone is the densest hub on RMAT — using only it
    # would flatter the ratio); one compile, source as a traced argument
    bfs_one = jax.jit(lambda s: bfs(g, s))
    sample = srcs[:: max(1, B // 16)]
    jax.block_until_ready(bfs_one(int(sample[0])))  # compile
    t0 = time.perf_counter()
    for s in sample:
        jax.block_until_ready(bfs_one(int(s)))
    t1 = (time.perf_counter() - t0) * 1e3 / len(sample)
    tB = _t(jax.jit(lambda: msbfs(g, srcs)))
    ratio = (tB / B) / t1
    print(f"\nservice (RMAT-{scale}): bfs {t1:.2f} ms/query sequential "
          f"(mean of {len(sample)} sources), msbfs B={B} {tB:.2f} ms total "
          f"= {tB / B:.4f} ms/query (amortization ratio {ratio:.4f}, "
          f"target < 0.15)")
    if not np.isfinite(ratio) or ratio >= 0.15:
        smoke_failures.append(
            f"REGRESSION: msbfs amortization ratio {ratio:.3f} >= 0.15")
    doc = {"scale": scale, "bfs_ms_per_query": t1, "msbfs_b256_ms": tB,
           "amortization_ratio": ratio, "budgets": {}}
    rng = np.random.default_rng(0)
    for budget in budgets:
        n_q = min(512, max(64, 2 * budget))
        svc = GraphService(g, batch_budget=budget, cache_capacity=4 * n_q)
        svc.query(Reachability(0, 1))   # compile the (kind, budget) runner
        svc.reset_stats()
        stream = [Reachability(int(s), int(t))
                  for s, t in zip(rng.integers(0, n, n_q),
                                  rng.integers(0, n, n_q))]
        for q in stream:
            svc.submit(q)
        svc.flush()
        cold = svc.stats.as_dict()
        svc.reset_stats()               # isolate the resubmission pass
        for q in stream:                # resubmission: pure cache hits
            svc.submit(q)
        svc.flush()
        warm = svc.stats.as_dict()
        row = {"n_queries": n_q, "qps": cold["qps"],
               "occupancy": cold["occupancy"],
               "route_bytes_per_query": cold["route_bytes_per_query"],
               "hit_rate_resubmit": warm["hit_rate"]}
        doc["budgets"][str(budget)] = row
        print(f"  B={budget:<4d} {cold['qps']:>9.1f} q/s  occupancy "
              f"{cold['occupancy']:.2f}  {cold['route_bytes_per_query']:>9.0f}"
              f" route B/q  resubmit hit rate {warm['hit_rate']:.2f}")
        if not (np.isfinite(cold["qps"]) and cold["qps"] > 0):
            smoke_failures.append(f"REGRESSION: service qps at B={budget} "
                                  "not positive")
        if not 0 < cold["occupancy"] <= 1:
            smoke_failures.append(f"REGRESSION: service occupancy at "
                                  f"B={budget} out of range")
        # second pass re-submits the identical stream: every query must hit
        # (capacity 4 * n_q rules out evictions)
        if warm["hit_rate"] < 0.999:
            smoke_failures.append(f"REGRESSION: resubmitted stream hit rate "
                                  f"{warm['hit_rate']:.2f} < 1.0 at B={budget}")
    return doc


def service_distributed_report(smoke_failures, budgets=(1, 32, 256), scale=12,
                               edge_factor=8, n_shards=8):
    """The query service on the *sharded* engine (PR 5, DESIGN §14): with a
    mesh the service serves reach/dist through `run_batched_distributed`, so
    this section measures end-to-end distributed serving — qps, occupancy,
    route bytes/query now priced from the *measured* level trace (incl.
    capacity-overflow fallbacks), and the deadline SLO accounting.  Runs when
    the host exposes >= n_shards devices (the CI bench lane forces 8); fixed
    RMAT-12 like `service_report` so the trajectory stays comparable.

    Gates: qps positive at every budget, and the PR-5 acceptance bar —
    **deadline-miss rate = 0 at B=32** under a generous (60 s) SLO on the
    pre-warmed runners.

    Since PR 7 the **B=1 lane serves under ``placement='async'``**
    (sync_interval=8 — identical results, one buffered flush + one
    termination psum per global check instead of five collectives per
    level), with the deadline cost EWMA seeded from the last committed
    bench doc (``cost_seed='auto'``); the baseline gate compares p50
    same-host.  Larger budgets stay level-synchronous: at B>=32 the dense
    per-lane micro-step work dominates the saved barriers on the forced
    host mesh (async p50 measured ~1.4-2x sync there — see the `async`
    section), so async is the small-batch latency lever, not a throughput
    one.  Each budget row records its placement.
    """
    if len(jax.devices()) < n_shards:
        print(f"\ndistributed service lane skipped ({len(jax.devices())} "
              f"devices < {n_shards})")
        return None
    from repro.core import GraphService, Reachability
    from repro.launch.mesh import make_cores_mesh

    mesh = make_cores_mesh(n_shards)
    g = rmat(scale, edge_factor, seed=0)
    n = g.n_rows
    rng = np.random.default_rng(1)
    doc = {"scale": scale, "n_shards": n_shards,
           "placement": "async@B=1, sync@B>=32", "sync_interval": 8,
           "budgets": {}}
    print(f"\ndistributed service (RMAT-{scale}, S={n_shards}, "
          f"run_batched_distributed behind the facade, async at B=1):")
    for budget in budgets:
        n_q = min(512, max(32, 2 * budget))
        placement = "async" if budget == 1 else "sync"
        svc = GraphService(g, batch_budget=budget, mesh=mesh,
                           cache_capacity=4 * n_q, placement=placement,
                           sync_interval=8, cost_seed="auto")
        svc.query(Reachability(0, 1))   # compile the (kind, budget) runner
        svc.reset_stats()
        stream = [Reachability(int(s), int(t))
                  for s, t in zip(rng.integers(0, n, n_q),
                                  rng.integers(0, n, n_q))]
        for q in stream:                # 60 s SLO: misses mean a real stall
            svc.submit(q, deadline=60.0)
        svc.flush()
        st = svc.stats.as_dict()
        row = {"n_queries": n_q, "placement": placement, "qps": st["qps"],
               "occupancy": st["occupancy"],
               "route_bytes_per_query": st["route_bytes_per_query"],
               "latency_p50_ms": st["latency_p50_ms"],
               "latency_p95_ms": st["latency_p95_ms"],
               "deadline_miss_rate": st["deadline_miss_rate"]}
        doc["budgets"][str(budget)] = row
        print(f"  B={budget:<4d} [{placement:>5s}] {st['qps']:>9.1f} q/s  occupancy "
              f"{st['occupancy']:.2f}  {st['route_bytes_per_query']:>11.0f}"
              f" route B/q  p50/p95 {st['latency_p50_ms']:.0f}/"
              f"{st['latency_p95_ms']:.0f} ms  miss rate "
              f"{st['deadline_miss_rate']:.3f}")
        if not (np.isfinite(st["qps"]) and st["qps"] > 0):
            smoke_failures.append(f"REGRESSION: distributed service qps at "
                                  f"B={budget} not positive")
        if budget == 32 and st["deadline_miss_rate"] != 0.0:
            smoke_failures.append(
                f"REGRESSION: deadline-miss rate "
                f"{st['deadline_miss_rate']:.3f} != 0 at B=32 (acceptance "
                "bar: the idle sharded engine must meet a 60 s SLO)")
    return doc


def async_report(smoke_failures, scale=12, edge_factor=8, n_shards=8,
                 budgets=(1, 32), sync_interval=8, reps=5):
    """Bounded-staleness placement vs the level-synchronous baseline (PR 7).

    Fixed RMAT-12 (like the service sections) on the >= 8-device lane: for
    B ∈ ``budgets`` lanes, runs multi-source BFS and batched delta-stepping
    under placement='sync' and placement='async' (``sync_interval`` local
    micro-steps per global check), reporting per-run latency p50/p95 over
    ``reps`` warm repetitions and the **global-reduction count** — the
    engine's measured level/flush trace priced by
    `traffic.level_collectives` (sync: overflow psum + 3 routing exchanges +
    termination psum per compacted push level, + 2 bucket pmins for sssp;
    async: one buffered flush + one termination psum per global check).

    Gates: async must return bit-identical results to sync (the programs are
    monotone — staleness cannot change the fixpoint), and at
    ``sync_interval=8`` the sssp reduction ratio must stay >= 4x (the PR-7
    acceptance bar: 7 collectives per delta-stepping level vs 2 per check,
    with local bucket-bound advances absorbing expansions between flushes)
    while bfs must stay >= 2x (a frontier hop crosses shards only at a
    flush, so its ratio comes from the per-check collective count, 5 -> 2;
    measured ~3x on RMAT).
    """
    if len(jax.devices()) < n_shards:
        print(f"\nasync placement lane skipped ({len(jax.devices())} "
              f"devices < {n_shards})")
        return None
    from repro.core.algorithms import msbfs_distributed, sssp_batched_distributed
    from repro.core.algorithms.distgraph import shard_graph
    from repro.launch.mesh import make_cores_mesh

    mesh = make_cores_mesh(n_shards)
    g = rmat(scale, edge_factor, seed=0)
    n = g.n_rows
    att = dgas.block_rule(n, n_shards)
    gsh, _ = shard_graph(g, n_shards, row_att=att)
    delta = auto_delta(g)
    doc = {"scale": scale, "n_shards": n_shards,
           "sync_interval": sync_interval, "budgets": {}}
    print(f"\nasync placement (RMAT-{scale}, S={n_shards}, "
          f"sync_interval={sync_interval}; reductions = measured trace x "
          f"traffic.level_collectives):")
    for budget in budgets:
        srcs = np.arange(budget, dtype=np.int32) % n
        row = {}
        results = {}
        for name, coll_sync, make in (
            ("bfs", traffic.level_collectives(placement="sync"),
             lambda p: jax.jit(lambda s: msbfs_distributed(
                 gsh, att, s, mesh, max_levels=n, return_stats=True,
                 placement=p, sync_interval=sync_interval))),
            ("sssp", traffic.level_collectives(placement="sync",
                                               program_collectives=2),
             lambda p: jax.jit(lambda s: sssp_batched_distributed(
                 gsh, att, s, mesh, delta=delta, max_iters=4 * n,
                 return_stats=True, placement=p,
                 sync_interval=sync_interval))),
        ):
            for placement in ("sync", "async"):
                fn = make(placement)
                out, stats = jax.block_until_ready(fn(srcs))  # compile
                results[(name, placement)] = np.asarray(out)
                lats = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(srcs))
                    lats.append((time.perf_counter() - t0) * 1e3)
                first = lambda x: int(np.asarray(x).reshape(-1)[0])
                if placement == "async":
                    checks = first(stats["pushes"])  # flushes
                    reductions = checks * traffic.level_collectives(
                        placement="async")
                else:
                    checks = first(stats["iters"])   # levels
                    reductions = checks * coll_sync
                row[f"{name}_{placement}"] = {
                    "p50_ms": float(np.percentile(lats, 50)),
                    "p95_ms": float(np.percentile(lats, 95)),
                    "global_checks": checks,
                    "global_reductions": reductions,
                }
            sy, an = row[f"{name}_sync"], row[f"{name}_async"]
            ratio = sy["global_reductions"] / max(1, an["global_reductions"])
            row[f"{name}_reduction_ratio"] = ratio
            match = np.array_equal(results[(name, "sync")],
                                   results[(name, "async")])
            print(f"  B={budget:<3d} {name:<5} sync  p50 {sy['p50_ms']:8.1f} "
                  f"ms  {sy['global_reductions']:4d} reductions "
                  f"({sy['global_checks']} levels)")
            print(f"  B={budget:<3d} {name:<5} async p50 {an['p50_ms']:8.1f} "
                  f"ms  {an['global_reductions']:4d} reductions "
                  f"({an['global_checks']} flushes)  {ratio:.1f}x fewer, "
                  f"identical: {match}")
            if not match:
                smoke_failures.append(
                    f"REGRESSION: async {name} diverges from sync at "
                    f"B={budget}")
            bar = 4.0 if name == "sssp" else 2.0
            if ratio < bar:
                smoke_failures.append(
                    f"REGRESSION: async {name} reduction ratio {ratio:.1f}x "
                    f"< {bar:.0f}x at B={budget}, "
                    f"sync_interval={sync_interval}")
        doc["budgets"][str(budget)] = row
    return doc


def streaming_report(smoke_failures, scale=12, edge_factor=8, n_epochs=5):
    """Streaming-graph section (PR 8, DESIGN.md §16), fixed RMAT-12 like the
    service sections so the trajectory point stays comparable:

    * **repair vs scratch**: per epoch of a ≤1%-of-edges insert batch, warm
      best-of-3 time of incremental ``bfs_repair`` / ``sssp_repair`` (old
      fixpoint + changed-endpoint frontier) against the from-scratch run on
      the updated graph — gated ≥ 3x for SSSP (the acceptance bar: scratch
      delta-stepping pays ~15 bucket expansions, the repair wave converges
      in a couple; results are bit-identical, pinned by
      tests/test_streaming.py).  BFS is reported but gated only at
      "never slower than scratch": at RMAT-12 its wall clock is
      dispatch-floor-bound (~4 ms for even a one-level run vs ~12 ms for
      the full six), so the iteration ratio caps it near 2x no matter how
      small the repair cone is;
    * **ingest throughput**: edges/s through ``GraphService.apply_updates``
      (splice + runner reset + partition-scoped invalidation + ledger);
    * **cache survival**: fraction of cached entries still live after an
      update touching ONE partition — gated ≥ 0.5 (partition-scoped
      invalidation; an epoch-keyed cache would score 0 here).

    The update stream is pure edge growth: endpoint pairs are rejection-
    sampled against the current edge set so every insert is a genuinely new
    edge (always monotone-safe), with weights from the generator's own
    U[0,1) — near-zero weights would make every insert a global shortcut
    and turn "repair" into a worst-case full rewrite.
    """
    from repro.core import (GraphHandle, GraphService, NeighborSample,
                            Reachability)
    from repro.core.algorithms import bfs_repair, sssp_repair

    g = rmat(scale, edge_factor, seed=0)
    n, m = g.n_rows, g.nnz
    rng = np.random.default_rng(2)
    batch = max(1, min(m // 100, 256))          # <= 1% of edges per epoch

    def make_batch(cur):
        # new-only endpoints: reject pairs already present in `cur` (and
        # in-batch duplicates) so the batch is inserts, never upserts
        have = np.repeat(np.arange(n, dtype=np.int64),
                         np.diff(np.asarray(cur.indptr))) * n \
            + np.asarray(cur.indices, np.int64)
        keys = np.empty(0, np.int64)
        while keys.size < batch:
            cand = rng.integers(0, n, 2 * batch) * n + rng.integers(0, n, 2 * batch)
            cand = cand[~np.isin(cand, have)]
            keys = np.unique(np.concatenate([keys, cand]))
        keys = rng.permutation(keys)[:batch]
        return (keys // n, keys % n, rng.random(batch).astype(np.float32))

    # --- ingest throughput through the service -----------------------------
    svc = GraphService(g, batch_budget=8)
    ingest_s = 0.0                      # batch generation stays off the clock
    for _ in range(n_epochs):
        ins = make_batch(svc.csr)
        t0 = time.perf_counter()
        svc.apply_updates(inserts=ins)
        ingest_s += time.perf_counter() - t0
    ingest_eps = n_epochs * batch / ingest_s

    # --- repair vs scratch, warm best-of-3 per epoch -----------------------
    handle = GraphHandle.wrap(g, n_partitions=8)
    prev_bfs = bfs(handle.csr, 0)
    # scratch runs pin the UNSCALED histogram delta: this section gates the
    # repair machinery's speedup, and the 3x bar was calibrated against
    # delta_scale=1 scratch — letting the tuned multiplier (DESIGN.md §18)
    # speed up the denominator would flap the gate without any repair change
    # (sssp_repair itself is delta-free: bound = inf)
    prev_sssp = sssp(handle.csr, 0,
                     delta=auto_delta(handle.csr, scaled=False))
    speedups = {"bfs": [], "sssp": []}
    print(f"\nstreaming (RMAT-{scale}, batch={batch} edges "
          f"= {100 * batch / m:.2f}% of m):")
    for e in range(n_epochs):
        handle, rep = handle.apply(make_batch(handle.csr))
        if not rep.monotone_safe:
            smoke_failures.append(
                "REGRESSION: new-edge insert batch classified unsafe")
        csr, ch = handle.csr, rep.changed_sources
        ms = {}
        for name, scratch_fn, repair_fn, prev in (
            ("bfs", lambda: bfs(csr, 0),
             lambda: bfs_repair(csr, prev_bfs, ch), prev_bfs),
            ("sssp", lambda: sssp(csr, 0,
                                  delta=auto_delta(csr, scaled=False)),
             lambda: sssp_repair(csr, prev_sssp, ch), prev_sssp),
        ):
            s_ms = _t(jax.jit(scratch_fn))
            r_ms = _t(jax.jit(repair_fn))
            speedups[name].append(s_ms / r_ms)
            ms[name] = (s_ms, r_ms)
        prev_bfs = bfs_repair(csr, prev_bfs, ch)
        prev_sssp = sssp_repair(csr, prev_sssp, ch)
        print(f"  epoch {e + 1}: bfs scratch {ms['bfs'][0]:7.2f} ms  repair "
              f"{ms['bfs'][1]:7.2f} ms ({speedups['bfs'][-1]:5.1f}x)   sssp "
              f"scratch {ms['sssp'][0]:7.2f} ms  repair {ms['sssp'][1]:7.2f} "
              f"ms ({speedups['sssp'][-1]:5.1f}x)")
    med = {k: float(np.median(v)) for k, v in speedups.items()}
    if med["sssp"] < 3.0:
        smoke_failures.append(
            f"REGRESSION: sssp repair speedup {med['sssp']:.1f}x < 3x for "
            f"{100 * batch / m:.2f}%-of-edges batches")
    if med["bfs"] < 1.0:
        smoke_failures.append(
            f"REGRESSION: bfs repair {med['bfs']:.1f}x — slower than scratch")

    # --- partition-scoped cache survival -----------------------------------
    svc2 = GraphService(g, batch_budget=8, cache_capacity=256)
    per = svc2.handle.per_partition
    for p in range(8):                  # 4 sample + 1 reach query / partition
        for off in (0, 7, 19, 31):
            svc2.query(NeighborSample((p * per + off) % n, fanout=2))
        svc2.query(Reachability((p * per + 3) % n, (p * per + 5) % n))
    before = len(svc2._cache)
    rep = svc2.apply_updates(inserts=(np.array([1]), np.array([2]),
                                      np.array([1e-4], np.float32)))
    survival = len(svc2._cache) / max(1, before)
    print(f"  ingest {ingest_eps:,.0f} edges/s through apply_updates; "
          f"repair speedup median bfs {med['bfs']:.1f}x sssp "
          f"{med['sssp']:.1f}x; cache survival {len(svc2._cache)}/{before} "
          f"= {survival:.2f} (update touched partitions "
          f"{rep.touched_partitions.tolist()})")
    if survival < 0.5:
        smoke_failures.append(
            f"REGRESSION: cache survival {survival:.2f} < 0.5 across a "
            "one-partition update")
    if not (np.isfinite(ingest_eps) and ingest_eps > 0):
        smoke_failures.append("REGRESSION: ingest throughput not positive")
    return {"scale": scale, "batch_edges": batch,
            "batch_frac": batch / m, "epochs": n_epochs,
            "repair_speedup_bfs": med["bfs"],
            "repair_speedup_sssp": med["sssp"],
            "ingest_edges_per_s": ingest_eps,
            "cache_survival": survival}


def obs_report(smoke_failures, scale=12, edge_factor=8, budget=32,
               trace_path=None):
    """Observability attribution + trace validity (PR 9, DESIGN.md §17).

    Serves one B=``budget`` reachability batch through a fully instrumented
    :class:`GraphService` (spans + per-level engine traces + an isolated
    metrics registry) on the same fixed RMAT-12 as `service_report`, then
    gates two acceptance bars: >= 90% of the batch's wall clock must land in
    the named service spans (flush_wait/engine/readback tile the service
    lane), and the exported Chrome ``trace_event`` document must be
    structurally valid (every event has pid/tid/ts/dur/name; spans nest
    without partial overlap per tid).  With ``trace_path`` the trace JSON is
    written next to the bench document — the CI bench lane uploads it as an
    artifact alongside ``BENCH_*.json`` (it is named ``TRACE_*`` so the
    baseline glob never picks it up).
    """
    from repro.core import GraphService, Reachability
    from repro.obs import (MetricsRegistry, Observability, format_summary,
                           summarize, validate_chrome_trace)

    g = rmat(scale, edge_factor, seed=0)
    n = g.n_rows
    ob = Observability(metrics=MetricsRegistry())
    svc = GraphService(g, batch_budget=budget, obs=ob)
    svc.query(Reachability(0, 1))   # compile the runner outside the window
    svc.reset_stats()
    ob.clear()                      # attribution measures serving only
    rng = np.random.default_rng(0)
    stream = [Reachability(int(s), int(t))
              for s, t in zip(rng.integers(0, n, budget),
                              rng.integers(0, n, budget))]
    tickets = [svc.submit(q) for q in stream]
    svc.flush()
    for t in tickets:
        svc.result(t)

    spans = ob.spans.spans()
    wall0 = min(sp.ts for sp in spans)
    wall1 = max(sp.ts + sp.dur for sp in spans)
    service_s = sum(sp.dur for sp in spans
                    if sp.tid == Observability.TID_SERVICE)
    frac = service_s / max(wall1 - wall0, 1e-12)
    trace = ob.build_trace()
    errors = validate_chrome_trace(trace)
    summ = summarize(trace)
    print(f"\nobs (RMAT-{scale}, B={budget}): {len(spans)} spans, "
          f"{len(ob.level_runs)} traced runs, attribution {frac:.3f} "
          f"(target >= 0.90), {len(errors)} structural errors")
    print(format_summary(summ))
    if not frac >= 0.90:
        smoke_failures.append(
            f"REGRESSION: span attribution {frac:.3f} < 0.90 of batch wall")
    for e in errors:
        smoke_failures.append(f"REGRESSION: chrome trace invalid: {e}")
    if trace_path:
        with open(trace_path, "w") as f:
            json.dump(trace, f, indent=1)
            f.write("\n")
        print(f"wrote {trace_path}")
    return {"scale": scale, "budget": budget,
            "attribution_frac": frac,
            "trace_events": len(trace.get("traceEvents", ())),
            "trace_errors": len(errors),
            "wall_ms": summ["wall_ms"],
            "phases": summ["phases"],
            "metrics": ob.metrics.snapshot()}


def kernels_report(smoke_failures, scale: int):
    """Kernel lane (DESIGN.md §18): the tuned-vs-default BBCSR grid plus the
    folded jnp-oracle microbenches, with achieved-vs-roofline-peak fractions.

    The gate matches what the autotuner optimizes on this backend: measured
    time on a real device, the deterministic HBM byte model on CPU (where
    wall clock times the jnp oracle, not the interpreted kernel) — a tuned
    config must never score worse than the hand-picked default."""
    from repro import tune
    try:
        from benchmarks import roofline as _roofline
    except ImportError:
        import roofline as _roofline

    rows = tune.kernel_rows(scale)
    peak = tune.stream_peak_bytes_per_s()
    print(f"\nkernel lane (scale={scale}; peak={peak:.3e} B/s)")
    for r in _roofline.rows_to_report(rows, peak):
        print(f"  {r['name']:<40}{r['us_per_call']:>10.1f} us  {r['derived']}")

    by = {r["name"]: r for r in rows}
    for kern in ("bbcsr_add", "bbcsr_min"):
        d = by[f"kernels/{kern}/default"]
        t = by[f"kernels/{kern}/tuned"]
        metric = "us" if d["measured"] == "device" else "bytes_model"
        if t[metric] > d[metric] * 1.05:
            smoke_failures.append(
                f"REGRESSION: tuned {kern} {metric}={t[metric]:.1f} worse "
                f"than default {d[metric]:.1f}")
    if not all(np.isfinite(r["bytes_per_s"]) and r["bytes_per_s"] > 0
               for r in rows):
        smoke_failures.append("REGRESSION: non-finite kernel-lane throughput")

    out_rows = {}
    for r in rows:
        row = {k: r[k] for k in ("us", "bytes_model", "bytes_per_s",
                                 "measured")}
        if "config" in r:
            row["config"] = r["config"]
        out_rows[r["name"]] = row
    return {"peak_bytes_per_s": peak, "rows": out_rows}


def sweep_delta(scale: int = 10, edge_factor: int = 8):
    """Delta sweep (satellite): RMAT + uniform weights vs the histogram rule."""
    print("\ndelta-stepping sweep (iters = bucket expansions; ms best-of-3)")
    for name, g in [("rmat", rmat(scale, edge_factor, seed=0)),
                    ("uniform", uniform_random_graph(1 << scale, edge_factor,
                                                     seed=0))]:
        auto = auto_delta(g)
        deltas = [0.25 * auto, 0.5 * auto, auto, 2 * auto, 4 * auto, 1e9]
        tags = ["auto/4", "auto/2", "auto", "2*auto", "4*auto", "inf(BF)"]
        print(f"  {name}: n={g.n_rows} m={g.nnz} auto_delta={auto:.4f}")
        for tag, d in zip(tags, deltas):
            _, stats = sssp(g, 0, delta=d, return_stats=True)
            ms = _t(jax.jit(lambda d=d: sssp(g, 0, delta=d)))
            print(f"    delta={tag:<8} ({d:9.4f})  iters={int(stats['iters']):4d}"
                  f"  {ms:8.2f} ms")


def run(scale: int = 12, edge_factor: int = 8, smoke: bool = False,
        trace_path=None):
    failures = []
    g = rmat(scale, edge_factor, seed=0)
    n, m = g.n_rows, g.nnz
    kmax = int(np.asarray(g.degrees()).max())
    print(f"RMAT scale={scale}  n={n}  m={m}  max_deg={kmax}")

    rows = []
    stats_by_mode = {}
    levels_by_mode = {}
    for mode in ("push", "pull", "auto"):
        fn = jax.jit(lambda mode=mode: bfs(g, 0, mode=mode))
        ms = _t(fn)
        state0 = {"level": jnp.full((n,), -1, jnp.int32).at[0].set(0)}
        f0 = jnp.zeros((n,), jnp.int32).at[0].set(1)
        st, stats = engine.run(g, bfs_program(), state0, f0, max_iters=n,
                               mode=mode, return_stats=True)
        stats_by_mode[mode] = {k: int(v) for k, v in stats.items()}
        levels_by_mode[mode] = np.asarray(st["level"])
        rows.append((f"bfs/{mode}", ms, stats_by_mode[mode]))

    d_auto, s_stats = sssp(g, 0, return_stats=True)
    ms_sssp = _t(jax.jit(lambda: sssp(g, 0)))
    rows.append((f"sssp/auto(delta={auto_delta(g):.3f})", ms_sssp,
                 {k: int(v) for k, v in s_stats.items()}))
    from repro.core.algorithms import symmetrize
    gs = symmetrize(g)  # host-side prep, outside the jitted region
    ms_cc = _t(jax.jit(lambda: connected_components(gs, symmetrize_input=False)))
    rows.append(("cc/auto", ms_cc, {}))
    pr = pagerank(g, iters=10)
    ms_pr = _t(jax.jit(lambda: pagerank(g, iters=10)))
    rows.append(("pagerank/dense x10", ms_pr, {}))

    print(f"\n{'workload':<28}{'ms':>10}   iters/push/pull")
    for name, ms, st in rows:
        detail = (f"{st['iters']}/{st['pushes']}/{st['pulls']}" if st else "-")
        print(f"{name:<28}{ms:>10.2f}   {detail}")

    push_ms = dict((r[0], r[1]) for r in rows)["bfs/push"]
    auto_ms = dict((r[0], r[1]) for r in rows)["bfs/auto"]
    print(f"\nauto vs always-push: {push_ms / auto_ms:.2f}x "
          f"({stats_by_mode['auto']['pushes']} push + "
          f"{stats_by_mode['auto']['pulls']} pull levels)")

    bytes_doc = routed_bytes_report(n, m, stats_by_mode["auto"]["pushes"])
    reduction = bytes_doc["reduction"]
    louvain_doc = louvain_report(g, failures)
    fallback_doc = fallback_report(scale)
    dist_doc = distributed_report(min(scale, 8), failures)
    service_doc = service_report(failures)
    service_dist_doc = service_distributed_report(failures)
    async_doc = async_report(failures)
    streaming_doc = streaming_report(failures)
    obs_doc = obs_report(failures, trace_path=trace_path)
    kernels_doc = kernels_report(failures, scale)

    # --- smoke checks (ci.sh bench): NaN + regression markers ---------------
    for mode in ("push", "pull"):
        if not np.array_equal(levels_by_mode[mode], levels_by_mode["auto"]):
            failures.append(f"REGRESSION: bfs/{mode} disagrees with bfs/auto")
    d_np = np.asarray(d_auto)
    if np.isnan(d_np).any():
        failures.append("REGRESSION: NaN in sssp distances")
    if not np.isfinite(d_np[np.asarray(levels_by_mode['auto']) >= 0]).all():
        failures.append("REGRESSION: unreachable sssp distance on a reached vertex")
    pr_np = np.asarray(pr)
    if np.isnan(pr_np).any() or abs(float(pr_np.sum()) - 1.0) > 1e-2:
        failures.append("REGRESSION: pagerank is NaN or not a distribution")
    # the reduction is a model-level number; the meaningful guard is that the
    # capacity derivation still enables compaction at this scale (edge_cap
    # strictly below the full partition => run_distributed's compact path on)
    m_per_shard = -(-m // 8)
    if not (0 < engine.frontier_edge_capacity(m_per_shard, 1 / 32) < m_per_shard):
        failures.append("REGRESSION: derived push capacity no longer compacts")
    if reduction < 1.0:
        failures.append("REGRESSION: compacted routing moves MORE bytes than full")
    if not all(np.isfinite(r[1]) and r[1] > 0 for r in rows):
        failures.append("REGRESSION: non-finite timing")

    doc = {
        "meta": {"scale": scale, "edge_factor": edge_factor, "n": n, "m": m,
                 "n_shards": 8, "host": platform.node(),
                 # same-run STREAM peak: lets future baseline comparisons
                 # normalize wall clocks for host-speed drift between runs
                 "host_speed_bytes_per_s": kernels_doc["peak_bytes_per_s"]},
        "timings_ms": {name: ms for name, ms, _ in rows},
        "bytes": bytes_doc,
        "modularity": louvain_doc,
        "fallback": fallback_doc,
        "service": service_doc,
        "streaming": streaming_doc,
        "obs_report": obs_doc,
        "kernels": kernels_doc,
    }
    doc["timings_ms"]["louvain/multilevel"] = louvain_doc["ms"]
    # msbfs_b256_ms stays inside doc["service"] (not timings_ms): wall-clock
    # of a 100-300 ms batch swings well past the 25% gate run-to-run; the
    # gated form is the amortization ratio
    if dist_doc is not None:
        doc["distributed"] = dist_doc
    if service_dist_doc is not None:
        doc["service_distributed"] = service_dist_doc
    if async_doc is not None:
        doc["async"] = async_doc

    for f in failures:
        print(f)
    if smoke:
        print("SMOKE " + ("FAIL" if failures else "PASS"))
    return doc, failures


# ---------------------------------------------------------------------------
# Persisted bench trajectory (BENCH_*.json artifact + baseline comparison)
# ---------------------------------------------------------------------------

def _walk_numbers(node, path=""):
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk_numbers(v, f"{path}/{k}")
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk_numbers(v, f"{path}[{i}]")
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, float(node)


def find_baseline():
    """Newest committed BENCH_*.json (by numeric suffix, then name).  The
    output file itself counts if it already exists — it is read *before* the
    new run overwrites it, so re-runs in one checkout still compare."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cands = sorted(
        glob.glob(os.path.join(root, "BENCH_*.json")),
        key=lambda p: (int((re.search(r"(\d+)", os.path.basename(p)) or
                            [0, 0])[1]), p))
    return cands[-1] if cands else None


def compare_to_baseline(doc, base, rel=0.25, ms_floor=2.0):
    """Regression gate for the bench lane: a timing more than ``rel`` slower
    (plus an absolute floor — tiny-scale timings are noisy), modularity more
    than ``rel`` lower, or the byte-model reduction more than ``rel`` smaller
    than the committed baseline.  Wall-clock timings are only compared when
    the baseline came from the *same host* (meta.host) — a baseline committed
    from the authoring machine must not fail heterogeneous CI runners; the
    machine-independent metrics (modularity, bytes) always gate.

    Same host is not same *speed*: on shared runners the achievable clock
    drifts between runs (measured here: the STREAM peak probe swinging
    2.0e10<->2.8e10 B/s minutes apart, louvain/multilevel 69<->96 ms with
    byte-identical code).  Since PR 10 every doc records that same-run probe
    (meta.host_speed_bytes_per_s), and the wall-clock allowance stretches by
    the baseline/current speed ratio, capped at DRIFT_CAP so a real 2x
    regression still fails even against a lucky-epoch baseline.  A faster
    current host never tightens the gate below ``rel``; a baseline predating
    the probe gets the full cap (its epoch speed is unknowable)."""
    failures = []
    for k in ("scale", "edge_factor", "n_shards"):
        if doc.get("meta", {}).get(k) != base.get("meta", {}).get(k):
            print(f"baseline meta mismatch ({k}: "
                  f"{base.get('meta', {}).get(k)} vs "
                  f"{doc.get('meta', {}).get(k)}): runs are not comparable, "
                  f"skipping baseline gate")
            return failures
    same_host = (doc.get("meta", {}).get("host")
                 and doc.get("meta", {}).get("host")
                 == base.get("meta", {}).get("host"))
    if not same_host:
        print("baseline from a different host: skipping wall-clock "
              "comparison (quality/byte metrics still gate)")
    DRIFT_CAP = 1.6  # measured worst epoch-to-epoch swing ~1.4x, plus margin
    speed_new = doc.get("meta", {}).get("host_speed_bytes_per_s")
    speed_old = base.get("meta", {}).get("host_speed_bytes_per_s")
    if speed_new and speed_old:
        drift = max(1.0, min(speed_old / speed_new, DRIFT_CAP))
    else:
        drift = DRIFT_CAP
    if same_host and drift > 1.0:
        print(f"host-speed drift allowance: wall-clock gates widened "
              f"x{drift:.2f}"
              + ("" if speed_new and speed_old else " (baseline has no probe)"))
    for k, new in (doc.get("timings_ms", {}) if same_host else {}).items():
        old = base.get("timings_ms", {}).get(k)
        if old is not None and new > old * (1 + rel) * drift + ms_floor:
            failures.append(f"REGRESSION: {k} {new:.2f} ms vs baseline "
                            f"{old:.2f} ms (> {100 * rel:.0f}% slower)")
    q_new = doc.get("modularity", {}).get("multilevel")
    q_old = base.get("modularity", {}).get("multilevel")
    if q_new is not None and q_old is not None:
        if q_new < q_old - rel * max(abs(q_old), 0.02):
            failures.append(f"REGRESSION: multilevel modularity {q_new:.5f} "
                            f"vs baseline {q_old:.5f}")
    r_new = doc.get("bytes", {}).get("reduction")
    r_old = base.get("bytes", {}).get("reduction")
    if r_new is not None and r_old is not None and r_new < r_old * (1 - rel):
        failures.append(f"REGRESSION: byte reduction {r_new:.1f}x vs "
                        f"baseline {r_old:.1f}x")
    # service: only the amortization *ratio* gates vs baseline — both of its
    # sides are measured within one run, so it is robust to host *load*,
    # unlike raw qps (observed ~1.7x swings between otherwise-identical
    # runs; qps stays a reported trajectory number, service_report's own
    # smoke checks gate positivity/occupancy/hit-rate and the absolute 0.15
    # bar).  It is still hardware-*shape* dependent (batched vs sequential
    # amortize differently per core count), so like the wall-clock timings
    # it only compares same-host.
    a_new = doc.get("service", {}).get("amortization_ratio")
    a_old = base.get("service", {}).get("amortization_ratio")
    if (same_host and a_new is not None and a_old is not None
            and a_new > a_old * (1 + rel) + 0.01):
        failures.append(f"REGRESSION: msbfs amortization ratio {a_new:.3f} "
                        f"vs baseline {a_old:.3f}")
    # streaming (PR 8): like the amortization ratio, both sides of the
    # repair speedup are measured within one run (robust to host load, still
    # hardware-shape dependent -> same-host); cache survival is a counted
    # fraction and always gates
    s_new = doc.get("streaming", {}).get("repair_speedup_sssp")
    s_old = base.get("streaming", {}).get("repair_speedup_sssp")
    if (same_host and s_new is not None and s_old is not None
            and s_new < s_old * (1 - rel)):
        failures.append(f"REGRESSION: sssp repair speedup {s_new:.1f}x vs "
                        f"baseline {s_old:.1f}x")
    c_new = doc.get("streaming", {}).get("cache_survival")
    c_old = base.get("streaming", {}).get("cache_survival")
    if (c_new is not None and c_old is not None
            and c_new < c_old * (1 - rel)):
        failures.append(f"REGRESSION: cache survival {c_new:.2f} vs "
                        f"baseline {c_old:.2f}")
    # async placement (PR 7): the reduction ratio is machine-independent
    # (counted collectives, not wall clock) so it always gates; latency p50
    # compares same-host like the other wall-clock numbers
    for bkey, brow in doc.get("async", {}).get("budgets", {}).items():
        orow = base.get("async", {}).get("budgets", {}).get(bkey, {})
        for name in ("bfs", "sssp"):
            r_new = brow.get(f"{name}_reduction_ratio")
            r_old = orow.get(f"{name}_reduction_ratio")
            if (r_new is not None and r_old is not None
                    and r_new < r_old * (1 - rel)):
                failures.append(
                    f"REGRESSION: async {name} reduction ratio {r_new:.1f}x "
                    f"vs baseline {r_old:.1f}x at B={bkey}")
            p_new = brow.get(f"{name}_async", {}).get("p50_ms")
            p_old = orow.get(f"{name}_async", {}).get("p50_ms")
            if (same_host and p_new is not None and p_old is not None
                    and p_new > p_old * (1 + rel) * drift + ms_floor):
                failures.append(
                    f"REGRESSION: async {name} p50 {p_new:.1f} ms vs "
                    f"baseline {p_old:.1f} ms at B={bkey}")
    # kernel lane (PR 10): the HBM byte model and tuned config are machine-
    # independent, so modeled bytes always gate; the oracle/device wall
    # clocks compare same-host like the other timings (µs floor instead of
    # ms_floor — single-kernel calls, not whole-algorithm runs)
    us_floor = 500.0
    for name, row in doc.get("kernels", {}).get("rows", {}).items():
        orow = base.get("kernels", {}).get("rows", {}).get(name)
        if orow is None:
            continue
        b_new, b_old = row.get("bytes_model"), orow.get("bytes_model")
        if b_new is not None and b_old is not None and b_new > b_old * (1 + rel):
            failures.append(f"REGRESSION: {name} modeled bytes {b_new} vs "
                            f"baseline {b_old} (> {100 * rel:.0f}% more)")
        u_new, u_old = row.get("us"), orow.get("us")
        if (same_host and u_new is not None and u_old is not None
                and u_new > u_old * (1 + rel) * drift + us_floor):
            failures.append(f"REGRESSION: {name} {u_new:.1f} us vs baseline "
                            f"{u_old:.1f} us (> {100 * rel:.0f}% slower)")
    # distributed-service latency (same-host): the PR-7 async serving path
    # must not drift back toward the per-level-barrier p50.  Since PR 9
    # ServiceStats percentiles are log-histogram bucket *upper edges*
    # (DESIGN.md §17): two runs can sit one bucket ratio apart with no real
    # movement (and the pr8 baseline recorded exact percentiles), so the
    # gate widens by one bucket growth factor on top of ``rel``
    hist_growth = 1.12
    for bkey, brow in doc.get("service_distributed", {}).get("budgets",
                                                             {}).items():
        p_new = brow.get("latency_p50_ms")
        p_old = base.get("service_distributed", {}).get("budgets", {}) \
                    .get(bkey, {}).get("latency_p50_ms")
        if (same_host and p_new is not None and p_old is not None
                and p_new > p_old * (1 + rel) * hist_growth * drift + ms_floor):
            failures.append(
                f"REGRESSION: distributed service p50 {p_new:.1f} ms vs "
                f"baseline {p_old:.1f} ms at B={bkey}")
    return failures


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale CI lane: exit nonzero on NaN/regression")
    ap.add_argument("--sweep-delta", action="store_true")
    ap.add_argument("--json", metavar="PATH",
                    help="write the machine-readable result document")
    ap.add_argument("--baseline", default="none", metavar="PATH|auto|none",
                    help="compare against a previous BENCH_*.json and fail "
                         "on NaN or >25%% regression ('auto' = newest "
                         "committed file)")
    args = ap.parse_args()
    if args.sweep_delta:
        sweep_delta(min(args.scale, 10), args.edge_factor)
        sys.exit(0)
    base = None
    if args.baseline == "auto":
        path = find_baseline()
        if path is not None:
            with open(path) as f:
                base = (path, json.load(f))
    elif args.baseline != "none":
        with open(args.baseline) as f:
            base = (args.baseline, json.load(f))
    # Chrome trace rides next to the bench document; the TRACE_ prefix keeps
    # it out of find_baseline's BENCH_*.json glob (and load_cost_priors')
    trace_path = None
    if args.json:
        trace_path = os.path.join(
            os.path.dirname(args.json) or ".",
            re.sub(r"^BENCH", "TRACE", os.path.basename(args.json))
            if os.path.basename(args.json).startswith("BENCH")
            else "TRACE_" + os.path.basename(args.json))
    doc, failures = run(args.scale, args.edge_factor, smoke=args.smoke,
                        trace_path=trace_path)
    for path, v in _walk_numbers(doc):
        if math.isnan(v):
            failures.append(f"REGRESSION: NaN at {path}")
    if base is not None:
        base_path, base_doc = base
        cmp_failures = compare_to_baseline(doc, base_doc)
        print(f"\nbaseline {os.path.basename(base_path)}: "
              + ("OK" if not cmp_failures else f"{len(cmp_failures)} regressions"))
        for f in cmp_failures:
            print(f)
        failures += cmp_failures
    elif args.baseline == "auto":
        print("\nbaseline: none committed yet (first trajectory point)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    # --smoke and --baseline are both gates: any failure (smoke regression
    # marker, NaN, or baseline regression) exits nonzero under either flag
    if failures and (args.smoke or args.baseline != "none"):
        sys.exit(1)
