"""Direction-optimizing engine sweep on RMAT graphs.

Times level-synchronous BFS in three engine modes on the same graph:

* ``push`` — every level expands the frontier sparsely (nonzero-compaction +
  per-active-row gathers, work ∝ frontier edges, padded to max degree);
* ``pull`` — every level is one dense edge-parallel pass (work ∝ |E|);
* ``auto`` — the engine's switch: push while the frontier population count is
  under n/32, pull once it saturates (Beamer's heuristic).

On RMAT the frontier explodes after 2-3 hops, so always-push pays the
max-degree padding on a huge frontier and always-pull pays |E| work on the
tiny first/last levels; the switch takes the cheaper side of each.  SSSP
(delta-stepping buckets) and connected components (min-label propagation) run
on the same engine to show the abstraction generalizes — one machinery, four
workloads.

Run:  PYTHONPATH=src python benchmarks/bench_engine.py [--scale 12]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, rmat
from repro.core.algorithms import (bfs, bfs_program, connected_components,
                                   pagerank, sssp)


def _t(fn, reps=3):
    jax.block_until_ready(fn())  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def run(scale: int = 12, edge_factor: int = 8):
    g = rmat(scale, edge_factor, seed=0)
    n, m = g.n_rows, g.nnz
    kmax = int(np.asarray(g.degrees()).max())
    print(f"RMAT scale={scale}  n={n}  m={m}  max_deg={kmax}")

    rows = []
    stats_by_mode = {}
    for mode in ("push", "pull", "auto"):
        fn = jax.jit(lambda mode=mode: bfs(g, 0, mode=mode))
        ms = _t(fn)
        state0 = {"level": jnp.full((n,), -1, jnp.int32).at[0].set(0)}
        f0 = jnp.zeros((n,), jnp.int32).at[0].set(1)
        _, stats = engine.run(g, bfs_program(), state0, f0, max_iters=n,
                              mode=mode, return_stats=True)
        stats_by_mode[mode] = {k: int(v) for k, v in stats.items()}
        rows.append((f"bfs/{mode}", ms, stats_by_mode[mode]))

    ms_sssp = _t(jax.jit(lambda: sssp(g, 0)))
    rows.append(("sssp/auto(delta)", ms_sssp, {}))
    from repro.core.algorithms import symmetrize
    gs = symmetrize(g)  # host-side prep, outside the jitted region
    ms_cc = _t(jax.jit(lambda: connected_components(gs, symmetrize_input=False)))
    rows.append(("cc/auto", ms_cc, {}))
    ms_pr = _t(jax.jit(lambda: pagerank(g, iters=10)))
    rows.append(("pagerank/dense x10", ms_pr, {}))

    print(f"\n{'workload':<22}{'ms':>10}   iters/push/pull")
    for name, ms, st in rows:
        detail = (f"{st['iters']}/{st['pushes']}/{st['pulls']}" if st else "-")
        print(f"{name:<22}{ms:>10.2f}   {detail}")

    push_ms = dict((r[0], r[1]) for r in rows)["bfs/push"]
    auto_ms = dict((r[0], r[1]) for r in rows)["bfs/auto"]
    print(f"\nauto vs always-push: {push_ms / auto_ms:.2f}x "
          f"({stats_by_mode['auto']['pushes']} push + "
          f"{stats_by_mode['auto']['pulls']} pull levels)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=8)
    args = ap.parse_args()
    run(args.scale, args.edge_factor)
