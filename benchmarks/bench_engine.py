"""Direction-optimizing engine sweep on RMAT graphs.

Times level-synchronous BFS in three engine modes on the same graph:

* ``push`` — every level expands the frontier sparsely (nonzero-compaction +
  per-active-row gathers, work ∝ frontier edges, padded to max degree);
* ``pull`` — every level is one dense edge-parallel pass (work ∝ |E|);
* ``auto`` — the engine's switch: push while the frontier population count is
  under n/32, pull once it saturates (Beamer's heuristic).

On RMAT the frontier explodes after 2-3 hops, so always-push pays the
max-degree padding on a huge frontier and always-pull pays |E| work on the
tiny first/last levels; the switch takes the cheaper side of each.  SSSP
(delta-stepping buckets) and connected components (min-label propagation) run
on the same engine to show the abstraction generalizes — one machinery, four
workloads.

Also reported:

* the distributed push *byte model* (`core/traffic.py`): routed bytes per
  sparse level under full-capacity routing vs the engine's compacted
  frontier-proportional capacity (`engine.frontier_edge_capacity`);
* ``--sweep-delta`` — delta-stepping bucket-width sweep on RMAT and
  uniform-weight graphs against the histogram auto-tune (DESIGN.md §8).

Run:  PYTHONPATH=src python benchmarks/bench_engine.py [--scale 12]
      PYTHONPATH=src python benchmarks/bench_engine.py --scale 7 --smoke
      PYTHONPATH=src python benchmarks/bench_engine.py --sweep-delta

``--smoke`` (the `scripts/ci.sh bench` lane) checks the outputs for NaN and
for regression markers (modes disagreeing, byte model not shrinking) and
exits nonzero on failure.
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, rmat, uniform_random_graph, traffic
from repro.core.algorithms import (auto_delta, bfs, bfs_program,
                                   connected_components, pagerank, sssp)


def _t(fn, reps=3):
    jax.block_until_ready(fn())  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def routed_bytes_report(n, m, pushes, n_shards=8, switch_frac=1 / 32):
    """Byte model for the distributed push levels of this run: full-capacity
    routing vs the engine's compacted frontier-proportional capacity."""
    m_per_shard = -(-m // n_shards)
    edge_cap = engine.frontier_edge_capacity(m_per_shard, switch_frac)
    full = traffic.RouteByteCounter(n_shards)
    compact = traffic.RouteByteCounter(n_shards)
    for _ in range(max(pushes, 1)):
        full.push_level(m_per_shard)
        compact.push_level(edge_cap)
    reduction = full.total_bytes / max(1, compact.total_bytes)
    print(f"\nrouted bytes / sparse level (model, S={n_shards}): "
          f"full={traffic.push_level_route_bytes(n_shards, m_per_shard):,} B  "
          f"compact={traffic.push_level_route_bytes(n_shards, edge_cap):,} B  "
          f"(capacity {m_per_shard} -> {edge_cap})")
    print(f"sparse-phase total over {max(pushes, 1)} push levels: "
          f"{full.total_bytes:,} B -> {compact.total_bytes:,} B "
          f"({reduction:.1f}x less)")
    return reduction


def sweep_delta(scale: int = 10, edge_factor: int = 8):
    """Delta sweep (satellite): RMAT + uniform weights vs the histogram rule."""
    print("\ndelta-stepping sweep (iters = bucket expansions; ms best-of-3)")
    for name, g in [("rmat", rmat(scale, edge_factor, seed=0)),
                    ("uniform", uniform_random_graph(1 << scale, edge_factor,
                                                     seed=0))]:
        auto = auto_delta(g)
        deltas = [0.25 * auto, 0.5 * auto, auto, 2 * auto, 4 * auto, 1e9]
        tags = ["auto/4", "auto/2", "auto", "2*auto", "4*auto", "inf(BF)"]
        print(f"  {name}: n={g.n_rows} m={g.nnz} auto_delta={auto:.4f}")
        for tag, d in zip(tags, deltas):
            _, stats = sssp(g, 0, delta=d, return_stats=True)
            ms = _t(jax.jit(lambda d=d: sssp(g, 0, delta=d)))
            print(f"    delta={tag:<8} ({d:9.4f})  iters={int(stats['iters']):4d}"
                  f"  {ms:8.2f} ms")


def run(scale: int = 12, edge_factor: int = 8, smoke: bool = False):
    failures = []
    g = rmat(scale, edge_factor, seed=0)
    n, m = g.n_rows, g.nnz
    kmax = int(np.asarray(g.degrees()).max())
    print(f"RMAT scale={scale}  n={n}  m={m}  max_deg={kmax}")

    rows = []
    stats_by_mode = {}
    levels_by_mode = {}
    for mode in ("push", "pull", "auto"):
        fn = jax.jit(lambda mode=mode: bfs(g, 0, mode=mode))
        ms = _t(fn)
        state0 = {"level": jnp.full((n,), -1, jnp.int32).at[0].set(0)}
        f0 = jnp.zeros((n,), jnp.int32).at[0].set(1)
        st, stats = engine.run(g, bfs_program(), state0, f0, max_iters=n,
                               mode=mode, return_stats=True)
        stats_by_mode[mode] = {k: int(v) for k, v in stats.items()}
        levels_by_mode[mode] = np.asarray(st["level"])
        rows.append((f"bfs/{mode}", ms, stats_by_mode[mode]))

    d_auto, s_stats = sssp(g, 0, return_stats=True)
    ms_sssp = _t(jax.jit(lambda: sssp(g, 0)))
    rows.append((f"sssp/auto(delta={auto_delta(g):.3f})", ms_sssp,
                 {k: int(v) for k, v in s_stats.items()}))
    from repro.core.algorithms import symmetrize
    gs = symmetrize(g)  # host-side prep, outside the jitted region
    ms_cc = _t(jax.jit(lambda: connected_components(gs, symmetrize_input=False)))
    rows.append(("cc/auto", ms_cc, {}))
    pr = pagerank(g, iters=10)
    ms_pr = _t(jax.jit(lambda: pagerank(g, iters=10)))
    rows.append(("pagerank/dense x10", ms_pr, {}))

    print(f"\n{'workload':<28}{'ms':>10}   iters/push/pull")
    for name, ms, st in rows:
        detail = (f"{st['iters']}/{st['pushes']}/{st['pulls']}" if st else "-")
        print(f"{name:<28}{ms:>10.2f}   {detail}")

    push_ms = dict((r[0], r[1]) for r in rows)["bfs/push"]
    auto_ms = dict((r[0], r[1]) for r in rows)["bfs/auto"]
    print(f"\nauto vs always-push: {push_ms / auto_ms:.2f}x "
          f"({stats_by_mode['auto']['pushes']} push + "
          f"{stats_by_mode['auto']['pulls']} pull levels)")

    reduction = routed_bytes_report(n, m, stats_by_mode["auto"]["pushes"])

    # --- smoke checks (ci.sh bench): NaN + regression markers ---------------
    for mode in ("push", "pull"):
        if not np.array_equal(levels_by_mode[mode], levels_by_mode["auto"]):
            failures.append(f"REGRESSION: bfs/{mode} disagrees with bfs/auto")
    d_np = np.asarray(d_auto)
    if np.isnan(d_np).any():
        failures.append("REGRESSION: NaN in sssp distances")
    if not np.isfinite(d_np[np.asarray(levels_by_mode['auto']) >= 0]).all():
        failures.append("REGRESSION: unreachable sssp distance on a reached vertex")
    pr_np = np.asarray(pr)
    if np.isnan(pr_np).any() or abs(float(pr_np.sum()) - 1.0) > 1e-2:
        failures.append("REGRESSION: pagerank is NaN or not a distribution")
    # the reduction is a model-level number; the meaningful guard is that the
    # capacity derivation still enables compaction at this scale (edge_cap
    # strictly below the full partition => run_distributed's compact path on)
    m_per_shard = -(-m // 8)
    if not (0 < engine.frontier_edge_capacity(m_per_shard, 1 / 32) < m_per_shard):
        failures.append("REGRESSION: derived push capacity no longer compacts")
    if reduction < 1.0:
        failures.append("REGRESSION: compacted routing moves MORE bytes than full")
    if not all(np.isfinite(r[1]) and r[1] > 0 for r in rows):
        failures.append("REGRESSION: non-finite timing")

    for f in failures:
        print(f)
    if smoke:
        print("SMOKE " + ("FAIL" if failures else "PASS"))
    return rows, failures


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale CI lane: exit nonzero on NaN/regression")
    ap.add_argument("--sweep-delta", action="store_true")
    args = ap.parse_args()
    if args.sweep_delta:
        sweep_delta(min(args.scale, 10), args.edge_factor)
        sys.exit(0)
    _, failures = run(args.scale, args.edge_factor, smoke=args.smoke)
    if args.smoke and failures:
        sys.exit(1)
