"""End-to-end LM training driver (deliverable (b)): a ~100M-parameter
qwen3-family model trained for a few hundred steps on synthetic token
streams, with checkpointing + fault-tolerant loop.

Full run (~100M params; several hours on this 1-core CPU container):
  PYTHONPATH=src python examples/train_lm.py --d-model 640 --layers 10 \
      --steps 300

CPU-sized demo (finishes in ~15-30 min; same code path):
  PYTHONPATH=src python examples/train_lm.py --demo
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LMConfig, init_params, count_params
from repro.optim import adamw
from repro.checkpoint.ckpt import CheckpointManager
from repro.distributed.fault_tolerance import FTConfig, run_training
from repro.data.synthetic import lm_batches, prefetch
from repro.configs.common import SpecBundle, make_step
from repro.configs import get_config
from repro.distributed.sharding import make_rules

ap = argparse.ArgumentParser()
ap.add_argument("--d-model", type=int, default=640)
ap.add_argument("--layers", type=int, default=10)
ap.add_argument("--vocab", type=int, default=32000)
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--lr", type=float, default=6e-4)
ap.add_argument("--demo", action="store_true", help="CPU-sized (~25M params)")
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
ap.add_argument("--metrics-out", default=None)
args = ap.parse_args()

if args.demo:
    args.d_model, args.layers, args.vocab = 384, 6, 16000

cfg = LMConfig(
    name="train-lm-example", n_layers=args.layers, d_model=args.d_model,
    n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 128, 1),
    head_dim=64, d_ff=4 * args.d_model, vocab=args.vocab, qk_norm=True,
    dtype=jnp.float32, q_chunk=128, k_chunk=128)
params = init_params(cfg, jax.random.PRNGKey(0))
print(f"model: {count_params(params) / 1e6:.1f}M params "
      f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab})")

opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 10),
                        total_steps=args.steps, weight_decay=0.1)
ac = get_config("qwen3-14b")   # same family; step builder only needs kind
bundle = SpecBundle("train", cfg, {}, {})
step = jax.jit(make_step(ac, bundle, make_rules(None), opt), donate_argnums=(0,))

state = adamw.init_state(params)
batches = ({"tokens": jnp.asarray(b["tokens"])}
           for b in prefetch(lm_batches(args.batch, args.seq, cfg.vocab)))
ckpt = CheckpointManager(args.ckpt_dir, every=100, keep=2)
logs = []


def on_metrics(i, m):
    if i % 10 == 0 or i == args.steps:
        rec = {"step": i, "loss": float(m["loss"])}
        logs.append(rec)
        print(json.dumps(rec), flush=True)


t0 = time.time()
state, report = run_training(step, state, batches, ckpt, args.steps,
                             FTConfig(ckpt_every=100), on_metrics=on_metrics)
dt = time.time() - t0
print(f"trained {report['steps_run']} steps in {dt / 60:.1f} min "
      f"({dt / max(report['steps_run'], 1):.2f}s/step); "
      f"loss {logs[0]['loss']:.3f} -> {logs[-1]['loss']:.3f}")
if args.metrics_out:
    json.dump(logs, open(args.metrics_out, "w"))
