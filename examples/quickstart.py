"""Quickstart: PIUMA-style graph analytics in 30 lines.

Builds an RMAT graph, runs the paper's core workloads through the offload
engines, and prints the Table I staging from the analytical machine model.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmat, to_bbcsr
from repro.core.algorithms import spmv, pagerank, bfs, random_walks
from repro.core.traffic import SPMV_PROFILES, speedup
from repro.kernels import ops

g = rmat(10, 16, seed=0)     # 1024 vertices, ~16k edges (RMAT, Graph500 params)
print(f"graph: {g.n_rows} vertices, {g.nnz} edges")

# SpMV three ways: fine-grained gather, and the DMA-gather Pallas kernel
x = jnp.asarray(np.random.default_rng(0).random(g.n_cols, np.float32))
y = spmv(g, x)
bb = to_bbcsr(g, block_rows=256, block_cols=256, tile_nnz=256)
y_kernel = ops.spmv_dma(bb, x)
print(f"SpMV max |base - DMA kernel| = {float(jnp.max(jnp.abs(y - y_kernel))):.2e}")

pr = pagerank(g, iters=20)
print(f"PageRank: sum={float(pr.sum()):.4f}, top vertex={int(jnp.argmax(pr))}")

lv = bfs(g, 0)
print(f"BFS from 0: reached {int((lv >= 0).sum())} vertices, "
      f"max level {int(lv.max())}")

walks = random_walks(g, jnp.arange(8), 5, jax.random.PRNGKey(0))
print(f"random walk[0]: {np.asarray(walks[0]).tolist()}")

print("\nTable I machine model (PIUMA node vs 4-socket Xeon):")
for name in ("piuma_base", "piuma_selective", "piuma_dma", "piuma_cache_all"):
    print(f"  {name:18s} {speedup(SPMV_PROFILES[name]):5.1f}x")
