"""Batched LM serving (deliverable (b)): prefill + greedy decode against the
mixtral smoke config (MoE + sliding-window attention + ring KV cache).

  PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys
import os

os.environ.setdefault("PYTHONPATH", "src")
sys.exit(subprocess.call(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "mixtral-8x7b",
     "--smoke", "--batch", "4", "--prompt-len", "48", "--gen", "24"],
    env={**os.environ}))
