"""The paper's Table II workloads end-to-end on one RMAT graph.

  PYTHONPATH=src python examples/graph_analytics.py [--scale 12]
                                                    [--placement sync|async]
                                                    [--stream N]

`--placement async` runs the >= 8-device distributed demo with
bounded-staleness shard pacing (DESIGN §14, PR 7) and prints a sync-vs-async
traversal latency comparison alongside the served stream.

`--stream N` runs the streaming-graph demo (DESIGN §16, PR 8): N edge-update
batches ingested through `GraphService.apply_updates` while the service keeps
answering queries, printing per-epoch repair-vs-scratch latency and the
partition-scoped cache survival.

`--trace out.json` (DESIGN §17, PR 9) attaches a telemetry bundle to the
served stream — the deadline mix when >= 8 devices, the local mixed stream
otherwise — writes the Chrome ``trace_event`` JSON (load it at
chrome://tracing or ui.perfetto.dev) and prints the per-phase summary table.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rmat
from repro.core.algorithms import (spmv, spmspv, pagerank, bfs, random_walks,
                                   label_propagation, modularity, multilevel,
                                   ties_sample, sssp, connected_components,
                                   symmetrize)

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=12)
ap.add_argument("--placement", choices=("sync", "async"), default="sync",
                help="distributed demo placement; async = bounded-staleness "
                     "shard pacing (DESIGN §14)")
ap.add_argument("--sync-interval", type=int, default=8,
                help="micro-steps per global check when --placement async")
ap.add_argument("--stream", type=int, default=0, metavar="N",
                help="streaming demo: ingest N update batches and print "
                     "repair-vs-scratch latency per epoch (DESIGN §16)")
ap.add_argument("--trace", metavar="PATH",
                help="record the served stream (spans + per-level engine "
                     "traces) and write a Chrome trace_event JSON (DESIGN "
                     "§17)")
args = ap.parse_args()

g = rmat(args.scale, 16, seed=7)
print(f"RMAT-{args.scale}: {g.n_rows} vertices, {g.nnz} edges")
x = jnp.asarray(np.random.default_rng(0).random(g.n_cols, np.float32))
key = jax.random.PRNGKey(0)


def timed(name, fn):
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    print(f"  {name:24s} {1e3 * (time.perf_counter() - t0):8.1f} ms")
    return out


y = timed("SpMV", jax.jit(lambda: spmv(g, x)))
ys = timed("SpMSpV (32 active)", jax.jit(lambda: spmspv(
    g, jnp.arange(32, dtype=jnp.int32), jnp.ones(32), max_deg=256)))
pr = timed("PageRank (20 it)", jax.jit(lambda: pagerank(g, iters=20)))
lv = timed("BFS", jax.jit(lambda: bfs(g, 0, max_levels=48)))
wk = timed("Random walks (4096x16)", jax.jit(lambda: random_walks(
    g, jnp.arange(4096) % g.n_rows, 16, key)))
lab = timed("Louvain (LPA, 8 it)", jax.jit(lambda: label_propagation(g, iters=8)))
mlab, mscores = timed("Louvain (multi-level)", lambda: multilevel(g))
dist = timed("SSSP (delta-stepping)", jax.jit(lambda: sssp(g, 0)))
gsym = symmetrize(g)  # host-side prep for components
comp = timed("Connected components", jax.jit(lambda: connected_components(
    gsym, symmetrize_input=False)))
nodes, n_nodes, mask = timed("TIES sampler", jax.jit(lambda: ties_sample(
    g, 512, 1024, key)))

# --- graph query service: micro-batched multi-source serving (DESIGN §13) ---
from repro.core import (GraphService, Reachability, Distance, PPRTopK,
                        NeighborSample)

# --trace: one telemetry bundle for the served stream (DESIGN §17) — it
# rides the deadline-mix service when the distributed demo runs, else the
# local mixed stream, and is exported + summarized after serving
obs = None
if args.trace:
    from repro.obs import MetricsRegistry, Observability, format_summary
    obs = Observability(metrics=MetricsRegistry())
use_dist = len(jax.devices()) >= 8

svc = GraphService(g, batch_budget=32, cache_capacity=1024,
                   obs=None if use_dist else obs)
for warm in (Reachability(0, 1), Distance(0, 1), PPRTopK(0, k=4),
             NeighborSample(0, fanout=2)):
    svc.query(warm)  # compile each kind's runner before timing the stream
svc.reset_stats()
if obs is not None and not use_dist:
    obs.clear()      # the trace shows serving, not the warmup compiles
rng = np.random.default_rng(3)
stream = []
for i in range(96):  # a mixed query stream, as a client would submit it
    s, t = int(rng.integers(0, g.n_rows)), int(rng.integers(0, g.n_rows))
    stream.append([Reachability(s, t), Distance(s, t), PPRTopK(s, k=4),
                   NeighborSample(s, fanout=2)][i % 4])
tickets = [svc.submit(q) for q in stream]
timed("Query service (96 q)", svc.flush)
for q in stream[:16]:  # resubmit a prefix: the LRU cache serves these
    svc.submit(q)
timed("Query service (16 cached)", svc.flush)
reach = svc.result(tickets[0])
print(f"\n  service stats          {svc.stats}")
print(f"  first query            {stream[0]} -> {reach}")

# --- distributed serving with deadlines (DESIGN §14): the same facade on the
# sharded engine — reach/dist ride run_batched_distributed, every query
# carries a latency SLO, and the stats report p50/p95 + deadline-miss rate.
if use_dist:
    from repro.launch.mesh import make_cores_mesh

    mesh = make_cores_mesh(8)

    if args.placement == "async":
        # head-to-head traversal latency: the same sharded graph, sync level
        # barrier vs bounded-staleness pacing (warm runs; first call compiles)
        from repro.core import dgas
        from repro.core.algorithms import sssp_batched_distributed
        from repro.core.algorithms.distgraph import shard_graph

        gsh, att = shard_graph(g, 8, row_att=dgas.block_rule(g.n_rows, 8))
        srcs = jnp.asarray(rng.integers(0, g.n_rows, 8), jnp.int32)
        for pl in ("sync", "async"):
            fn = (lambda pl=pl: sssp_batched_distributed(
                gsh, att, srcs, mesh, placement=pl,
                sync_interval=args.sync_interval))
            fn()  # compile
            timed(f"SSSP x8 shards ({pl})", fn)

    dsvc = GraphService(g, batch_budget=32, mesh=mesh, cache_capacity=1024,
                        placement=args.placement,
                        sync_interval=args.sync_interval,
                        cost_seed="auto", obs=obs)
    for warm in (Reachability(0, 1), PPRTopK(0, k=4)):
        dsvc.query(warm)  # compile before the timed stream
    dsvc.reset_stats()
    if obs is not None:
        obs.clear()      # trace the deadline mix, not the warmup compiles
    dstream = []
    for i in range(64):  # a deadline mix: reachability + PPR top-k
        s = int(rng.integers(0, g.n_rows))
        q = (Reachability(s, int(rng.integers(0, g.n_rows)))
             if i % 2 == 0 else PPRTopK(s, k=4))
        dstream.append(dsvc.submit(q, deadline=30.0))
        dsvc.poll()      # the client-driven admission tick
    timed("Distributed service (64 q)", dsvc.flush)
    st = dsvc.stats
    print(f"  distributed stats      {st}")
    print(f"  latency p50/p95        {st.latency_p50_ms:.1f} / "
          f"{st.latency_p95_ms:.1f} ms")
    print(f"  deadline miss rate     {st.deadline_miss_rate:.3f} "
          f"({st.deadline_misses}/{st.deadline_queries})")
else:
    print(f"\n  distributed serving demo skipped ({len(jax.devices())} "
          "devices < 8; run under "
          "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

if obs is not None:
    tdoc = obs.export_chrome_trace(args.trace)
    print(f"\n  trace: wrote {args.trace} ({len(tdoc['traceEvents'])} events;"
          " load at chrome://tracing or ui.perfetto.dev)")
    for line in format_summary(obs.summary()).splitlines():
        print("  " + line)

# --- streaming graphs (DESIGN §16): epoch-versioned serving under updates ---
if args.stream > 0:
    from repro.core import GraphHandle
    from repro.core.algorithms import auto_delta, bfs_repair, sssp_repair

    print(f"\n  streaming: {args.stream} update batches "
          f"({max(1, g.nnz // 200)} edges each) while serving")
    ssvc = GraphService(g, batch_budget=32, cache_capacity=1024)
    per = ssvc.handle.per_partition
    probe = [NeighborSample((p * per + 3) % g.n_rows, fanout=2)
             for p in range(8)]
    for q in probe:
        ssvc.query(q)                 # one cached entry per partition
    handle = GraphHandle.wrap(g, n_partitions=8)
    prev_lv = bfs(handle.csr, 0)
    prev_d = sssp(handle.csr, 0, delta=auto_delta(handle.csr))
    srng = np.random.default_rng(11)
    k = max(1, g.nnz // 200)          # 0.5% of edges per batch

    def new_edges(csr):
        # genuinely new edges (rejection-sampled, weights at the graph's own
        # U[0,1) scale): pure growth, so every batch is monotone-safe
        have = np.repeat(np.arange(csr.n_rows, dtype=np.int64),
                         np.diff(np.asarray(csr.indptr))) * csr.n_cols \
            + np.asarray(csr.indices, np.int64)
        keys = np.empty(0, np.int64)
        while keys.size < k:
            cand = (srng.integers(0, csr.n_rows, 2 * k) * csr.n_cols
                    + srng.integers(0, csr.n_cols, 2 * k))
            keys = np.unique(np.concatenate([keys, cand[~np.isin(cand, have)]]))
        keys = srng.permutation(keys)[:k]
        return keys // csr.n_cols, keys % csr.n_cols, \
            srng.random(k).astype(np.float32)

    for epoch in range(1, args.stream + 1):
        ins = new_edges(handle.csr)
        cached_before = len(ssvc._cache)
        rep = ssvc.apply_updates(inserts=ins)
        handle, hrep = handle.apply(ins)
        csr, ch = handle.csr, hrep.changed_sources
        delta = auto_delta(csr)
        # each epoch changes nnz, so both paths recompile: jit + warm first,
        # then time, like every other demo in this file
        scratch_fn = jax.jit(lambda: sssp(csr, 0, delta=delta))
        repair_fn = jax.jit(lambda: sssp_repair(csr, prev_d, ch))
        jax.block_until_ready(scratch_fn())
        jax.block_until_ready(repair_fn())
        t0 = time.perf_counter()
        jax.block_until_ready(scratch_fn())
        scratch_ms = 1e3 * (time.perf_counter() - t0)
        t0 = time.perf_counter()
        prev_d = jax.block_until_ready(repair_fn())
        repair_ms = 1e3 * (time.perf_counter() - t0)
        prev_lv = bfs_repair(csr, prev_lv, ch)
        served = ssvc.query(probe[7])  # the stream keeps serving mid-ingest
        print(f"  epoch {epoch:3d}: sssp scratch {scratch_ms:8.1f} ms  repair "
              f"{repair_ms:8.1f} ms ({scratch_ms / repair_ms:5.1f}x)  "
              f"cache {len(ssvc._cache)}/{cached_before} live  "
              f"touched={rep.touched_partitions.tolist()}")
    print(f"  final epoch            {ssvc.epoch} (service) / "
          f"{handle.epoch} (handle)")
    print(f"  sssp reached (stream)  "
          f"{int(np.isfinite(np.asarray(prev_d)).sum())}/{g.n_rows}")

print(f"\n  pagerank mass          {float(pr.sum()):.4f}")
print(f"  bfs reached            {int((lv >= 0).sum())}/{g.n_rows}")
print(f"  sssp reached           {int(np.isfinite(np.asarray(dist)).sum())}"
      f"/{g.n_rows}")
print(f"  components             {len(np.unique(np.asarray(comp)))}")
print(f"  communities            {len(np.unique(np.asarray(lab)))}")
print(f"  modularity             {float(modularity(g, lab)):.4f}")
print(f"  multilevel communities {len(np.unique(np.asarray(mlab)))}")
print(f"  multilevel modularity  {(mscores[-1] if mscores else 0.0):.4f} "
      f"over {len(mscores)} levels")
print(f"  TIES nodes/edges       {int(n_nodes)}/{int(mask.sum())}")
