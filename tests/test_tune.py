"""Autotuner + tuned-config resolution (DESIGN.md §18).

Pins the four ISSUE-10 contracts: the CPU sweep is deterministic (committed
TUNED.json is CI-diffable), lookup precedence is explicit kwarg > tuned
entry > default, the `tune.autotune_fallback` counter fires exactly on a
miss, and a tuned kernel config's outputs are bit-identical to the default
config's on the golden core grid.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import engine, rmat
from repro.kernels import ops
from repro.obs import get_registry

G = rmat(7, 8, seed=11)  # the golden core grid graph

_TILE = ("block_cols", "block_rows", "tile_nnz")


@pytest.fixture(autouse=True)
def _fresh_doc_cache():
    tune.clear_cache()
    yield
    tune.clear_cache()


def _write_tuned(path, entries):
    path.write_text(json.dumps(
        {"version": 1, "tool": "test", "entries": entries}))
    return str(path)


# ---------------------------------------------------------------------------
# Determinism of the CPU sweep
# ---------------------------------------------------------------------------

def test_autotune_cpu_deterministic():
    e1 = tune.autotune(6, backend="cpu", reps=1)
    e2 = tune.autotune(6, backend="cpu", reps=2)  # reps must not matter on cpu
    assert e1 == e2
    assert e1["backend"] == "cpu" and e1["scale"] == 6
    # entries are complete: every tunable present, so a matched entry never
    # has holes (the fallback counter means "no entry", not "missing param")
    assert set(e1["params"]) == set(tune.space.DEFAULTS)


def test_committed_tuned_json_matches_regeneration():
    """The committed file must be what `python -m repro.tune` would write —
    a stale TUNED.json silently pins yesterday's winners."""
    doc = tune.load_tuned()
    assert doc is not None, "TUNED.json missing at repo root"
    committed = {(e["backend"], e["scale"]): e["params"]
                 for e in doc["entries"]}
    if ("cpu", 7) in committed:
        fresh = tune.autotune(7, backend="cpu", reps=1)
        assert committed[("cpu", 7)] == fresh["params"]


# ---------------------------------------------------------------------------
# Lookup precedence: explicit kwarg > TUNED.json > default
# ---------------------------------------------------------------------------

def test_resolve_precedence(tmp_path):
    p = _write_tuned(tmp_path / "TUNED.json", [
        {"backend": "cpu", "scale": 7,
         "params": {"engine.switch_frac": 0.25}},
        {"backend": "cpu", "scale": 12,
         "params": {"engine.switch_frac": 0.125}},
    ])
    # explicit kwarg always wins, even over a matching entry
    assert tune.resolve("engine.switch_frac", explicit=0.5, n=128,
                        backend="cpu", path=p) == 0.5
    # tuned entry: nearest scale within the window
    assert tune.resolve("engine.switch_frac", n=128, backend="cpu",
                        path=p) == 0.25
    assert tune.resolve("engine.switch_frac", scale=11, backend="cpu",
                        path=p) == 0.125
    # outside SCALE_WINDOW of every entry -> hand-picked default
    assert tune.resolve("engine.switch_frac", scale=30, backend="cpu",
                        path=p) == tune.space.DEFAULTS["engine.switch_frac"]
    # unknown tunables are a programming error, not a silent default
    with pytest.raises(KeyError):
        tune.resolve("engine.no_such_knob", path=p)


def test_resolve_scale_tie_breaks_small_and_backend_filters(tmp_path):
    p = _write_tuned(tmp_path / "TUNED.json", [
        {"backend": "cpu", "scale": 6, "params": {"sssp.delta_scale": 6.0}},
        {"backend": "cpu", "scale": 10, "params": {"sssp.delta_scale": 10.0}},
        {"backend": "tpu", "scale": 8, "params": {"sssp.delta_scale": 99.0}},
    ])
    # scale 8 is equidistant from 6 and 10: the smaller scale wins the tie
    assert tune.resolve("sssp.delta_scale", scale=8, backend="cpu",
                        path=p) == 6.0
    # entries for another backend never leak across
    assert tune.resolve("sssp.delta_scale", scale=8, backend="rocm",
                        path=p) == tune.space.DEFAULTS["sssp.delta_scale"]


# ---------------------------------------------------------------------------
# Fallback counter (standing guardrail: degradation must be countable)
# ---------------------------------------------------------------------------

def test_autotune_fallback_counter_fires_on_miss(tmp_path):
    counter = get_registry().counter("tune.autotune_fallback")
    p = _write_tuned(tmp_path / "TUNED.json", [
        {"backend": "cpu", "scale": 7,
         "params": {"engine.switch_frac": 0.25}},
    ])
    before = counter.value
    # hit: no fire
    assert tune.resolve("engine.switch_frac", n=G.n_rows, backend="cpu",
                        path=p) == 0.25
    assert counter.value == before
    # miss (no entry for this backend): default + exactly one fire
    assert tune.resolve("engine.switch_frac", n=G.n_rows, backend="tpu",
                        path=p) == tune.space.DEFAULTS["engine.switch_frac"]
    assert counter.value == before + 1
    # miss (file absent): same degradation path
    tune.resolve("engine.switch_frac", n=G.n_rows, backend="cpu",
                 path=str(tmp_path / "nope.json"))
    assert counter.value == before + 2
    # explicit kwarg is an opt-out, not a degradation: no fire
    tune.resolve("engine.switch_frac", explicit=0.5,
                 path=str(tmp_path / "nope.json"))
    assert counter.value == before + 2


# ---------------------------------------------------------------------------
# Bit-identity of tuned vs default kernel configs on the golden grid
# ---------------------------------------------------------------------------

def test_tuned_kernel_configs_bit_identical_on_golden_grid():
    from repro.tune.sweep import _bit_identical
    for section, combine in (("kernels.bbcsr_add", "add"),
                             ("kernels.bbcsr_min", "min")):
        default = {k: tune.space.DEFAULTS[f"{section}.{k}"] for k in _TILE}
        tuned = {k: tune.resolve(f"{section}.{k}", n=G.n_rows)
                 for k in _TILE}
        assert _bit_identical(G, tuned, default, combine), \
            f"tuned {section} config {tuned} not bit-identical to default"


def test_build_pull_operand_routes_through_resolver(tmp_path):
    p = _write_tuned(tmp_path / "TUNED.json", [])
    tuned = {k: tune.resolve(f"kernels.bbcsr_add.{k}", n=G.n_rows)
             for k in _TILE}
    bb = engine.build_pull_operand(G, combine="add")
    assert (bb.block_cols, bb.block_rows, bb.tile_nnz) == \
        (tuned["block_cols"], tuned["block_rows"], tuned["tile_nnz"])
    # explicit tile kwargs still win over the tuned entry
    bb_d = engine.build_pull_operand(G, combine="add", block_rows=256,
                                     block_cols=512, tile_nnz=512)
    assert (bb_d.block_rows, bb_d.block_cols, bb_d.tile_nnz) == (256, 512, 512)
    # and the two operands compute the same spmv bit-for-bit (golden grid)
    x = jnp.asarray(np.random.default_rng(0).random(G.n_rows, np.float32))
    np.testing.assert_array_equal(np.asarray(ops.spmv_dma(bb, x)),
                                  np.asarray(ops.spmv_dma(bb_d, x)))


# ---------------------------------------------------------------------------
# The bench measurement lane
# ---------------------------------------------------------------------------

def test_kernel_rows_shape_and_cpu_gate_metric():
    rows = tune.kernel_rows(7, reps=1)
    by = {r["name"]: r for r in rows}
    assert {"kernels/bbcsr_add/default", "kernels/bbcsr_add/tuned",
            "kernels/bbcsr_min/default", "kernels/bbcsr_min/tuned",
            "kernels/flash_attn_oracle_b4h8s1024",
            "kernels/embedding_bag_oracle_8k_lookups"} <= set(by)
    for r in rows:
        assert np.isfinite(r["bytes_per_s"]) and r["bytes_per_s"] > 0
        assert r["bytes_model"] > 0 and r["us"] > 0
    # what the bench gates on cpu: the tuned config's modeled traffic is
    # never worse than the hand-picked default's (hysteresis guarantees it)
    for kern in ("bbcsr_add", "bbcsr_min"):
        assert by[f"kernels/{kern}/tuned"]["bytes_model"] <= \
            by[f"kernels/{kern}/default"]["bytes_model"]
