"""Transformer feature tests: SWA ring cache, MLA latent cache, MoE dispatch,
fused projections, vocab padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=97, q_chunk=8, k_chunk=8, dtype=jnp.float32)
RNG = np.random.default_rng(0)


def _decode_all(cfg, params, toks, cache_len):
    cache = T.init_cache(cfg, toks.shape[0], cache_len)
    lg = None
    for t in range(toks.shape[1]):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1])
    return lg


def test_swa_ring_cache_matches_full_cache():
    """Decoding with a window-sized ring buffer == full cache + window mask."""
    cfg = T.LMConfig(name="swa", window=8, **BASE)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, 97, (2, 20)).astype(np.int32))
    lg_ring = _decode_all(cfg, params, toks, cache_len=8)    # ring (wraps 2.5x)
    lg_full = _decode_all(cfg, params, toks, cache_len=20)   # no wrap
    np.testing.assert_allclose(np.asarray(lg_ring), np.asarray(lg_full),
                               rtol=1e-4, atol=1e-4)


def test_mla_latent_cache_is_small_and_consistent():
    mla = T.MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16)
    cfg = T.LMConfig(name="mla", mla=mla, **BASE)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, 2, 16)
    # latent cache stores kv_lora + rope dims, NOT H*(nope+rope+v)
    assert cache["ckv"].shape[-1] == 16
    assert cache["krope"].shape[-1] == 8
    assert "k" not in cache
    toks = jnp.asarray(RNG.integers(0, 97, (2, 16)).astype(np.int32))
    logits, _ = T.forward(cfg, params, toks)
    lg = _decode_all(cfg, params, toks, 16)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=1e-3, atol=1e-3)


def test_moe_capacity_drops_are_bounded_and_finite():
    cfg = T.LMConfig(name="moe", moe=T.MoEConfig(
        n_experts=4, top_k=2, d_ff=64, capacity_factor=0.5), **BASE)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, 97, (2, 32)).astype(np.int32))
    logits, aux = T.forward(cfg, params, toks)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) > 0  # load-balance + z losses present


def test_fused_qkv_same_structure_loss():
    cfg_f = T.LMConfig(name="fused", fused_qkv=True, **BASE)
    p = T.init_params(cfg_f, jax.random.PRNGKey(0))
    assert "wqkv" in p["layers"]["sub0"]["attn"]
    assert "w13" in p["layers"]["sub0"]["mlp"]
    toks = jnp.asarray(RNG.integers(0, 97, (2, 16)).astype(np.int32))
    loss, _ = T.loss_fn(cfg_f, p, {"tokens": toks})
    assert np.isfinite(float(loss))
    # param count matches unfused layout
    cfg_u = T.LMConfig(name="unfused", **BASE)
    assert (T.count_params(p)
            == T.count_params(T.init_params(cfg_u, jax.random.PRNGKey(0))))


def test_vocab_padding_sliced_from_logits():
    cfg = T.LMConfig(name="pad", **{**BASE, "vocab": 97})
    assert cfg.vocab_padded == 256
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    assert params["embed"].shape[0] == 256
    toks = jnp.asarray(RNG.integers(0, 97, (1, 8)).astype(np.int32))
    logits, _ = T.forward(cfg, params, toks)
    assert logits.shape[-1] == 97


def test_prefill_then_decode_continuity():
    cfg = T.LMConfig(name="gqa", **BASE)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, 97, (2, 16)).astype(np.int32))
    logits, _ = T.forward(cfg, params, toks)
    _, cache = T.prefill(cfg, params, toks[:, :12])
    cache = {k: (jnp.pad(v, ((0, 0),) * 3 + ((0, 4),) + ((0, 0),) * (v.ndim - 4))
                 if getattr(v, "ndim", 0) >= 4 else v)
             for k, v in cache.items()}
    lg = None
    for t in range(12, 16):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=1e-4, atol=1e-4)
