"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dgas, uniform_random_graph, to_bbcsr
from repro.core.algorithms import spmv
from repro.kernels import ops, ref
from repro.core.traffic import (SPMV_PROFILES, XEON, PIUMA_NODE, time_per_elem,
                                speedup)

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(1, 10_000), s=st.integers(1, 64),
       kind=st.sampled_from(["interleave", "block"]))
@settings(**SETTINGS)
def test_att_roundtrip(n, s, kind):
    att = (dgas.interleave_rule if kind == "interleave" else dgas.block_rule)(n, s)
    gid = jnp.arange(n, dtype=jnp.int32)
    owner, local = att.owner(gid), att.local(gid)
    assert int(owner.max()) < s
    assert int(local.max()) < att.per_shard
    back = att.to_global(owner, local)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(gid))


@given(st.lists(st.integers(0, 50), min_size=2, max_size=40),
       st.integers(1, 8))
@settings(**SETTINGS)
def test_degree_balanced_rule_covers(degs, s):
    indptr = np.concatenate([[0], np.cumsum(degs)])
    att = dgas.degree_balanced_rule(indptr, s)
    n = len(degs)
    gid = jnp.arange(n, dtype=jnp.int32)
    owner = np.asarray(att.owner(gid))
    # owners are monotone (contiguous partition) and cover each vertex once
    assert (np.diff(owner) >= 0).all()
    back = np.asarray(att.to_global(att.owner(gid), att.local(gid)))
    np.testing.assert_array_equal(back, np.arange(n))


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_spmv_linearity(seed):
    rng = np.random.default_rng(seed)
    g = uniform_random_graph(64, 4, seed=seed % 17)
    x = jnp.asarray(rng.random(64, np.float32))
    y = jnp.asarray(rng.random(64, np.float32))
    a = float(rng.random() * 3)
    lhs = spmv(g, a * x + y)
    rhs = a * spmv(g, x) + spmv(g, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3,
                               atol=1e-4)


@given(seed=st.integers(0, 10_000), scale=st.integers(4, 7))
@settings(max_examples=10, deadline=None)
def test_spmv_kernel_vs_oracle_property(seed, scale):
    from repro.core import rmat
    g = rmat(scale, 4, seed=seed % 100)
    bb = to_bbcsr(g, block_rows=32, block_cols=32, tile_nnz=64)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(g.n_cols, np.float32))
    np.testing.assert_allclose(np.asarray(ops.spmv_dma(bb, x)),
                               np.asarray(ref.spmv_bbcsr_ref(bb, x)),
                               rtol=1e-4, atol=1e-4)


@given(n=st.integers(1, 200), v=st.integers(1, 100), seed=st.integers(0, 9999))
@settings(**SETTINGS)
def test_gather_matches_take(n, v, seed):
    from repro.core.offload import dma_gather
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((v, 3)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-1, v, n).astype(np.int32))
    out = np.asarray(dma_gather(table, idx))
    for i, ix in enumerate(np.asarray(idx)):
        if ix >= 0:
            np.testing.assert_allclose(out[i], np.asarray(table)[ix])
        else:
            np.testing.assert_allclose(out[i], 0.0)


@given(seed=st.integers(0, 9999))
@settings(**SETTINGS)
def test_scatter_add_matches_dense(seed):
    from repro.core.offload import dma_scatter_add
    rng = np.random.default_rng(seed)
    dest = jnp.zeros((20, 2), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, 20, 30).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((30, 2)).astype(np.float32))
    out = np.asarray(dma_scatter_add(dest, idx, vals))
    expect = np.zeros((20, 2), np.float32)
    for i, ix in enumerate(np.asarray(idx)):
        if ix >= 0:
            expect[ix] += np.asarray(vals)[i]
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_traffic_model_orderings():
    """Structural invariants of the Table I analytical model."""
    t = {k: time_per_elem(PIUMA_NODE, p) for k, p in SPMV_PROFILES.items()
         if k != "xeon"}
    # staged optimizations monotonically improve...
    assert t["piuma_base"] > t["piuma_selective"] > t["piuma_dma"]
    # ...and cache-everything is WORSE than base (the paper's pathology)
    assert t["piuma_cache_all"] > t["piuma_base"]
    # PIUMA node beats the Xeon node on every version
    assert speedup(SPMV_PROFILES["piuma_base"]) > 1


# ---------------------------------------------------------------------------
# batched multi-source traversal == per-source loops (PR 4)
# ---------------------------------------------------------------------------

from repro.core.algorithms import (auto_delta, bfs, msbfs, ppr, ppr_batched,
                                   sssp, sssp_batched)
from repro.core import uniform_random_graph as _urg


@given(seed=st.integers(0, 1000), n=st.integers(2, 80), deg=st.integers(1, 5),
       nsrc=st.integers(1, 6), mode=st.sampled_from(["push", "pull", "auto"]))
@settings(**SETTINGS)
def test_property_msbfs_equals_bfs_loop(seed, n, deg, nsrc, mode):
    g = _urg(n, deg, seed=seed)
    srcs = np.random.default_rng(seed).integers(0, n, nsrc)
    lv = np.asarray(msbfs(g, srcs, mode=mode))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(lv[b], np.asarray(bfs(g, int(s),
                                                            mode=mode)))


@given(seed=st.integers(0, 1000), n=st.integers(2, 60), deg=st.integers(1, 4),
       nsrc=st.integers(1, 4))
@settings(**SETTINGS)
def test_property_sssp_batched_equals_sssp_loop(seed, n, deg, nsrc):
    g = _urg(n, deg, seed=seed)
    d = auto_delta(g)
    srcs = np.random.default_rng(seed + 1).integers(0, n, nsrc)
    db = np.asarray(sssp_batched(g, srcs, delta=d))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(db[b], np.asarray(sssp(g, int(s),
                                                             delta=d)))


@given(seed=st.integers(0, 1000), n=st.integers(2, 50), deg=st.integers(1, 4),
       nsrc=st.integers(1, 4))
@settings(**SETTINGS)
def test_property_ppr_batched_equals_ppr_loop(seed, n, deg, nsrc):
    g = _urg(n, deg, seed=seed)
    srcs = np.random.default_rng(seed + 2).integers(0, n, nsrc)
    pb = np.asarray(ppr_batched(g, srcs, iters=8))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(pb[b], np.asarray(ppr(g, int(s),
                                                            iters=8)))


# ---------------------------------------------------------------------------
# async placement: staleness cannot change a monotone fixpoint (PR 7, §14)
# ---------------------------------------------------------------------------

from repro.core.algorithms import (bfs_distributed, sssp_distributed,
                                   connected_components_distributed,
                                   symmetrize)
from repro.core.algorithms.distgraph import shard_graph
from repro.launch.mesh import make_cores_mesh

_MESH1 = make_cores_mesh(1)


@given(seed=st.integers(0, 1000), n=st.sampled_from([13, 24, 40]),
       deg=st.integers(1, 3), interval=st.sampled_from([1, 2, 8]))
@settings(max_examples=25, deadline=None)
def test_property_async_fixpoint_independent_of_interval(seed, n, deg,
                                                         interval):
    """Bounded staleness is invisible in the result: for the monotone
    traversal programs (min-level BFS, (min, +) delta-stepping, min-label
    CC), placement='async' lands on the bit-identical fixpoint as the
    level-synchronous placement at EVERY ``sync_interval`` — deferred and
    stale messages only delay when a relaxation is seen, never what the
    order-independent combine converges to.  interval=1 in particular must
    reproduce the sync schedule exactly (one global check per step)."""
    g = _urg(n, deg, seed=seed)
    gsh, att = shard_graph(g, 1, row_att=dgas.block_rule(n, 1))
    src = int(np.random.default_rng(seed).integers(0, n))

    lv_sync = np.asarray(bfs_distributed(gsh, att, src, _MESH1))
    lv_async = np.asarray(bfs_distributed(gsh, att, src, _MESH1,
                                          placement="async",
                                          sync_interval=interval))
    np.testing.assert_array_equal(lv_async, lv_sync)

    d = auto_delta(g)
    d_sync = np.asarray(sssp_distributed(gsh, att, src, _MESH1, delta=d,
                                         max_iters=4 * n))
    d_async = np.asarray(sssp_distributed(gsh, att, src, _MESH1, delta=d,
                                          max_iters=4 * n, placement="async",
                                          sync_interval=interval))
    np.testing.assert_array_equal(d_async, d_sync)

    gs = symmetrize(g)
    gsh_s, att_s = shard_graph(gs, 1, row_att=dgas.block_rule(gs.n_rows, 1))
    c_sync = np.asarray(connected_components_distributed(gsh_s, att_s, _MESH1))
    c_async = np.asarray(connected_components_distributed(
        gsh_s, att_s, _MESH1, placement="async", sync_interval=interval))
    np.testing.assert_array_equal(c_async, c_sync)


# ---------------------------------------------------------------------------
# deadline-aware admission never serves late on an idle engine (PR 5, §14)
# ---------------------------------------------------------------------------

from repro.core import GraphService, Reachability

_DEADLINE_G = _urg(40, 3, seed=0)
_SAFETY = 0.5


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@given(deadlines=st.lists(st.floats(1.0, 10.0), min_size=1, max_size=8),
       steps=st.lists(st.floats(0.05, _SAFETY), min_size=5, max_size=40),
       seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_deadline_flush_never_serves_late(deadlines, steps, seed):
    """With the engine idle and the client polling at least once per
    ``deadline_safety`` window, deadline-aware flushing serves every query at
    or before its absolute deadline: the admission queue flushes at the first
    tick whose slack (deadline - now - estimated cost) is within the margin,
    so no interleaving of submissions and clock advances can strand a query
    past its SLO.  (Under the fake clock execution is instantaneous, which is
    exactly the 'engine idle' premise.)"""
    clk = _FakeClock()
    svc = GraphService(_DEADLINE_G, batch_budget=4, cache_capacity=0,
                       clock=clk, deadline_safety=_SAFETY)
    rng = np.random.default_rng(seed)
    n = _DEADLINE_G.n_rows
    abs_deadline, served_at = {}, {}

    def note_served():
        # a flush (from submit's full-batch/expired-slack trigger or poll)
        # may serve ANY pending ticket — record first-seen serve times
        for t in svc._results:
            served_at.setdefault(t, clk.t)

    pending = list(deadlines)
    for dt in steps:
        if pending and rng.random() < 0.5:
            q = Reachability(int(rng.integers(0, n)), int(rng.integers(0, n)))
            d = pending.pop()
            t = svc.submit(q, deadline=d)
            abs_deadline[t] = clk.t + d
            note_served()
        clk.t += dt
        svc.poll()
        note_served()
    # drive the clock forward, polling within the safety window, until done
    while svc._queue:
        clk.t += _SAFETY
        svc.poll()
        note_served()
    for t, dl in abs_deadline.items():
        assert t in served_at, f"ticket {t} never served"
        assert served_at[t] <= dl, (served_at[t], dl)
    assert svc.stats.deadline_miss_rate == 0.0


# ---------------------------------------------------------------------------
# log-bucketed latency sketch (PR 9): percentiles within one bucket width
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(1e-6, 1e3, allow_nan=False, allow_infinity=False),
                min_size=1, max_size=400),
       pct=st.sampled_from([50.0, 95.0]))
@settings(**SETTINGS)
def test_histogram_percentile_within_one_bucket(xs, pct):
    """The sketch reports the owning bucket's upper edge at the nearest rank,
    so it can only overshoot the exact percentile — and never by more than
    one growth factor per bucket-boundary crossing (the deterministic twin
    lives in tests/test_obs.py for hypothesis-free environments)."""
    from repro.obs import Histogram
    h = Histogram("lat")
    for x in xs:
        h.observe(x)
    got = h.percentile(pct)
    # the sketch's nearest-rank order statistic sits between the 'lower' and
    # 'higher' exact order statistics; the bucket rounds it up by < growth
    exact_lo = float(np.percentile(np.asarray(xs), pct, method="lower"))
    exact_hi = float(np.percentile(np.asarray(xs), pct, method="higher"))
    assert got >= exact_lo * (1 - 1e-9)
    assert got <= max(exact_hi, h.lo) * h.growth * (1 + 1e-9)
