"""Graph algorithms vs independent numpy references + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rmat, uniform_random_graph, to_padded_ell
from repro.core.graph import CSR
from repro.core.algorithms import (spmv, spmv_ell, spmspv, pagerank, bfs,
                                   random_walks, label_propagation, modularity,
                                   ties_sample, neighbor_sample)

RNG = np.random.default_rng(7)


def _np_bfs(indptr, indices, src):
    n = indptr.shape[0] - 1
    level = -np.ones(n, np.int64)
    level[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in indices[indptr[u]:indptr[u + 1]]:
                if level[v] < 0:
                    level[v] = d + 1
                    nxt.append(v)
        frontier = nxt
        d += 1
    return level


@pytest.mark.parametrize("scale", [6, 8])
def test_spmv_matches_dense(scale):
    g = rmat(scale, 8, seed=scale)
    x = jnp.asarray(RNG.random(g.n_cols, np.float32))
    np.testing.assert_allclose(np.asarray(spmv(g, x)),
                               np.asarray(g.to_dense() @ x), rtol=1e-4, atol=1e-4)


def test_spmv_ell_matches():
    g = rmat(7, 8, seed=1)
    cols, vals, mask = to_padded_ell(g)
    x = jnp.asarray(RNG.random(g.n_cols, np.float32))
    np.testing.assert_allclose(np.asarray(spmv_ell(cols, vals, mask, x)),
                               np.asarray(spmv(g, x)), rtol=1e-4, atol=1e-4)


def test_spmspv_matches_dense_rows():
    g = rmat(7, 8, seed=2)
    ids = jnp.asarray(np.array([3, 17, 42, -1], np.int32))
    vals = jnp.asarray(np.array([1.0, -2.0, 0.5, 9.9], np.float32))
    y = spmspv(g, ids, vals)
    dense = np.asarray(g.to_dense())
    refv = 1.0 * dense[3] - 2.0 * dense[17] + 0.5 * dense[42]
    np.testing.assert_allclose(np.asarray(y), refv, rtol=1e-4, atol=1e-4)


def test_pagerank_is_distribution_and_converges():
    g = rmat(8, 8, seed=3)
    pr = pagerank(g, iters=50)
    assert abs(float(pr.sum()) - 1.0) < 1e-3
    assert float(pr.min()) >= 0
    pr2 = pagerank(g, iters=51)
    assert float(jnp.max(jnp.abs(pr - pr2))) < 1e-5  # converged


def test_pagerank_ring_uniform():
    n = 64
    g = CSR.from_coo(np.arange(n), (np.arange(n) + 1) % n,
                     np.ones(n, np.float32), n, n)
    pr = pagerank(g, iters=100)
    np.testing.assert_allclose(np.asarray(pr), np.full(n, 1.0 / n), atol=1e-6)


@pytest.mark.parametrize("seed", [0, 1])
def test_bfs_matches_numpy(seed):
    g = uniform_random_graph(200, 4, seed=seed)
    lv = np.asarray(bfs(g, 0))
    ref = _np_bfs(np.asarray(g.indptr), np.asarray(g.indices), 0)
    np.testing.assert_array_equal(lv, ref)


def test_random_walks_follow_edges():
    g = uniform_random_graph(100, 4, seed=4)
    walks = np.asarray(random_walks(g, jnp.arange(20), 10, jax.random.PRNGKey(0)))
    indptr, indices = np.asarray(g.indptr), np.asarray(g.indices)
    for w in walks:
        for a, b in zip(w[:-1], w[1:]):
            nbrs = indices[indptr[a]:indptr[a + 1]]
            assert (b in nbrs) or (b == a and nbrs.size == 0)


def test_label_propagation_two_cliques():
    rows, cols = [], []
    for c in range(2):
        for i in range(8):
            for j in range(8):
                if i != j:
                    rows.append(c * 8 + i); cols.append(c * 8 + j)
    rows += [0, 8]; cols += [8, 0]
    g = CSR.from_coo(rows, cols, np.ones(len(rows), np.float32), 16, 16)
    lab = np.asarray(label_propagation(g, iters=10))
    assert len(set(lab[:8])) == 1 and len(set(lab[8:])) == 1
    assert lab[0] != lab[8]
    assert float(modularity(g, jnp.asarray(lab))) > 0.4


def test_ties_sampler_induced():
    g = rmat(7, 8, seed=5)
    nodes, n_nodes, mask = ties_sample(g, 32, 64, jax.random.PRNGKey(1))
    nodes = np.asarray(nodes)
    valid = set(nodes[nodes >= 0].tolist())
    rows = np.asarray(g.row_ids()); cols = np.asarray(g.indices)
    m = np.asarray(mask)
    # every induced edge has both endpoints in the node set
    assert all(r in valid and c in valid for r, c in zip(rows[m], cols[m]))


def test_neighbor_sample_shapes_and_validity():
    g = uniform_random_graph(100, 4, seed=6)
    layers = neighbor_sample(g, jnp.arange(8), [3, 2], jax.random.PRNGKey(2))
    assert [tuple(l.shape) for l in layers] == [(8,), (8, 3), (8, 3, 2)]
    indptr, indices = np.asarray(g.indptr), np.asarray(g.indices)
    l0, l1 = np.asarray(layers[0]), np.asarray(layers[1])
    for i, s in enumerate(l0):
        nbrs = indices[indptr[s]:indptr[s + 1]]
        for v in l1[i]:
            assert v in nbrs or (v == s and nbrs.size == 0)
