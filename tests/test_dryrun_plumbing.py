"""Dry-run integration: one small cell end-to-end in a subprocess (the dry-run
pins 512 host devices, so it cannot share the test process)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_single_cell():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gin-tu",
         "--shape", "molecule"],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    sys.stdout.write(proc.stdout[-2000:])
    sys.stderr.write(proc.stderr[-1000:])
    assert proc.returncode == 0
    assert "ok" in proc.stdout
    assert "fit=True" in proc.stdout
