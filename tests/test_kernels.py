"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rmat, uniform_random_graph, to_bbcsr
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("scale,ef", [(6, 4), (7, 8), (8, 16)])
@pytest.mark.parametrize("block", [(32, 64, 64), (64, 32, 128), (128, 128, 256)])
def test_spmv_dma_sweep(scale, ef, block):
    br, bc, tn = block
    g = rmat(scale, ef, seed=scale * 10 + ef)
    bb = to_bbcsr(g, block_rows=br, block_cols=bc, tile_nnz=tn)
    x = jnp.asarray(RNG.random(g.n_cols, np.float32))
    y_k = ops.spmv_dma(bb, x)
    y_r = ref.spmv_bbcsr_ref(bb, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)
    y_d = np.asarray(g.to_dense() @ x)
    np.testing.assert_allclose(np.asarray(y_k), y_d, rtol=1e-4, atol=1e-4)


def test_spmspv_collapsed_index_sequence():
    """SpMSpV must not DMA the x block of inactive tiles: the collapsed cb
    schedule re-uses the previous active tile's block (no index transition
    => the Pallas pipeline issues no new copy), and the kernel output with
    the collapsed schedule still matches the dense reference."""
    from repro.core import engine
    from repro.kernels.spmv_dma import collapse_inactive_blocks

    # hand-checked pattern: leading inactive tiles pin block 0
    cb = jnp.asarray(np.array([3, 1, 4, 4, 2, 5], np.int32))
    act = jnp.asarray(np.array([0, 1, 0, 1, 0, 1], np.int32))
    got = np.asarray(collapse_inactive_blocks(cb, act))
    np.testing.assert_array_equal(got, [0, 1, 1, 4, 4, 5])

    g = rmat(7, 6, seed=21)
    bb = to_bbcsr(g.transpose(), block_rows=32, block_cols=32, tile_nnz=64)
    n = g.n_rows
    frontier = jnp.zeros((n,), jnp.int32).at[jnp.asarray([5, 40])].set(1)
    x = jnp.where(frontier > 0, jnp.asarray(RNG.random(n, np.float32)), 0.0)
    tact = engine.tile_active(bb, frontier)
    sched = np.asarray(collapse_inactive_blocks(bb.tile_cb, tact))
    a = np.asarray(tact)
    # every index transition (= a new x DMA) happens at an active tile, and
    # active tiles keep their true block
    trans = np.nonzero(sched[1:] != sched[:-1])[0] + 1
    assert (a[trans] == 1).all()
    np.testing.assert_array_equal(sched[a == 1], np.asarray(bb.tile_cb)[a == 1])
    assert len(trans) <= int(a.sum())  # never more DMAs than active tiles
    y = np.asarray(ops.spmspv_dma(bb, x, tact))
    np.testing.assert_allclose(y, np.asarray(ref.spmv_bbcsr_ref(bb, x)),
                               rtol=1e-4, atol=1e-5)


def test_spmv_dma_empty_rows():
    # matrix with fully empty row blocks must still zero its output
    from repro.core.graph import CSR
    g = CSR.from_coo([0, 511], [1, 2], np.ones(2, np.float32), 512, 512)
    bb = to_bbcsr(g, block_rows=64, block_cols=64, tile_nnz=64)
    x = jnp.asarray(RNG.random(512).astype(np.float32))
    y = np.asarray(ops.spmv_dma(bb, x))
    assert y.shape == (512,)
    assert np.count_nonzero(y) <= 2
    np.testing.assert_allclose(y, np.asarray(g.to_dense() @ x), rtol=1e-5)


@pytest.mark.parametrize("n,d,m,bn", [(100, 8, 13, 32), (500, 32, 64, 128),
                                      (1000, 1, 7, 256)])
def test_segment_sum_sweep(n, d, m, bn):
    seg = np.sort(RNG.integers(0, m, n)).astype(np.int32)
    data = RNG.standard_normal((n, d)).astype(np.float32)
    out_k = ops.segment_sum_sorted(jnp.asarray(data), jnp.asarray(seg), m, block_n=bn)
    out_r = ref.segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), m)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5,
                               atol=1e-5)


def test_segment_sum_padding_dropped():
    seg = jnp.asarray(np.array([0, 0, 1, -1, -1], np.int32))
    data = jnp.asarray(np.ones((5, 4), np.float32))
    out = ops.segment_sum_sorted(data, seg, 2, block_n=4)
    np.testing.assert_allclose(np.asarray(out), [[2] * 4, [1] * 4])


@pytest.mark.parametrize("v,d,n,b,weighted,mode", [
    (50, 8, 40, 10, False, "sum"), (100, 16, 64, 7, True, "sum"),
    (30, 4, 25, 5, False, "mean"), (200, 32, 128, 16, True, "mean")])
def test_embedding_bag_sweep(v, d, n, b, weighted, mode):
    table = jnp.asarray(RNG.standard_normal((v, d)).astype(np.float32))
    idx = RNG.integers(0, v, n).astype(np.int32)
    idx[RNG.random(n) < 0.1] = -1  # padding
    bag = np.sort(RNG.integers(0, b, n)).astype(np.int32)
    w = jnp.asarray(RNG.random(n).astype(np.float32)) if weighted else None
    out_k = ops.embedding_bag(table, jnp.asarray(idx), jnp.asarray(bag), b, w, mode)
    out_r = ref.embedding_bag_ref(table, jnp.asarray(idx), jnp.asarray(bag), b, w, mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 2, 2, 64, 64, 16), (2, 4, 2, 128, 128, 32), (1, 8, 1, 64, 64, 64),
    (2, 4, 4, 1, 128, 32),   # decode shape
])
@pytest.mark.parametrize("window", [None, 33])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, window):
    q = jnp.asarray(RNG.standard_normal((B, Hq, Sq, D)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Skv, D)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Skv, D)).astype(np.float32))
    bq = 1 if Sq == 1 else 32
    out_k = ops.flash_attention(q, k, v, causal=True, window=window,
                                block_q=bq, block_k=32)
    out_r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-4,
                               atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    out_k = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    out_r = ref.flash_attention_ref(q, k, v)
    assert out_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), rtol=5e-2, atol=5e-2)
