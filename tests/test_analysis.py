"""repro-lint analyzer tests (DESIGN.md §15).

Every rule gets at least one true-positive fixture (bad source → finding)
and one true-negative fixture (good source → clean), plus framework tests
for pragmas and the baseline, and the keystone check: the real repo is
analyzer-clean.  Pure stdlib under test — none of these fixtures import
jax at runtime.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, RULES_BY_ID, Analyzer, collect_files
from repro.analysis.core import load_baseline, write_baseline
from repro.analysis.rules import (CacheKeyRule, CompatBoundaryRule,
                                  HostSyncRule, MutableHandleRule,
                                  ShardSafetyRule, SingleCoreRule,
                                  TunedConstantsRule)

ROOT = Path(__file__).resolve().parent.parent


def run_rule(rule, source, path="src/repro/somemod.py"):
    return Analyzer([rule]).run_source(textwrap.dedent(source), path)


def rule_ids(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# single-core
# ---------------------------------------------------------------------------

GOOD_ENGINE = """
    from jax import lax

    def _core_loop(core, state):
        # lax.while_loop( in a comment must not count
        return lax.while_loop(lambda c: c[1], lambda c: c, state)

    def _run_local(prog, state):
        core = object()
        return _core_loop(core, state)

    def _run_distributed(prog, state):
        core = object()
        return _core_loop(core, state)

    def run(prog, state):
        return _run_local(prog, state)

    def run_batched(prog, state):
        return _run_local(prog, state)

    def run_distributed(prog, state):
        return _run_distributed(prog, state)

    def run_batched_distributed(prog, state):
        return _run_distributed(prog, state)

    def run_queue(prog, state):
        return lax.scan(lambda c, x: (c, x), state, None)
"""


def test_single_core_true_negative():
    findings = run_rule(SingleCoreRule(), GOOD_ENGINE,
                        "src/repro/core/engine.py")
    assert findings == []


def test_single_core_flags_second_loop():
    bad = textwrap.dedent(GOOD_ENGINE) + (
        "\ndef run_again(prog, state):\n    from jax import lax\n"
        "    return lax.while_loop(lambda c: c[1], lambda c: c, state)\n")
    findings = run_rule(SingleCoreRule(), bad, "src/repro/core/engine.py")
    assert any("while_loop" in f.message for f in findings)


def test_single_core_flags_fori_and_lost_runner():
    bad = textwrap.dedent(GOOD_ENGINE).replace(
        "def run_queue", "def run_queue_x") + \
        "\ndef helper(n, f, x):\n    from jax import lax\n" \
        "    return lax.fori_loop(0, n, f, x)\n"
    findings = run_rule(SingleCoreRule(), bad, "src/repro/core/engine.py")
    msgs = " | ".join(f.message for f in findings)
    assert "fori_loop" in msgs and "run_queue" in msgs


def test_single_core_ignores_other_files():
    bad = "from jax import lax\n" \
          "def two(a):\n    lax.while_loop(a, a, a)\n    lax.while_loop(a, a, a)\n"
    assert run_rule(SingleCoreRule(), bad, "src/repro/core/other.py") == []


def test_check_single_core_script_passes_on_real_engine():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_single_core.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK (one stepping loop)" in proc.stdout


def test_check_single_core_check_fn_flags_regrowth():
    sys.path.insert(0, str(ROOT / "scripts"))
    try:
        import check_single_core
    finally:
        sys.path.pop(0)
    bad = textwrap.dedent(GOOD_ENGINE) + (
        "\ndef rogue(state):\n    from jax import lax\n"
        "    return lax.while_loop(lambda c: c[1], lambda c: c, state)\n")
    assert check_single_core.check(bad) != []
    assert check_single_core.check(textwrap.dedent(GOOD_ENGINE)) == []


# ---------------------------------------------------------------------------
# compat-boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    "from jax.experimental.shard_map import shard_map\n",
    "from jax.experimental import shard_map\n",
    "import jax\nf = jax.shard_map\n",
    "import jax\npairs, treedef = jax.tree_util.tree_flatten_with_path(t)\n",
    "from jax.tree_util import tree_flatten_with_path\n",
    "import jax\nn = jax.lax.axis_size('x')\n",
    "c = fn.lower().compile()\ncost = c.cost_analysis()\n",
    "import jax\ny = jax.lax.with_sharding_constraint(x, s)\n",
    "from jax.experimental.pjit import with_sharding_constraint\n",
])
def test_compat_boundary_true_positives(bad):
    findings = run_rule(CompatBoundaryRule(), bad)
    assert rule_ids(findings) == ["compat-boundary"], bad


@pytest.mark.parametrize("good", [
    # the sanctioned spellings
    "from repro.compat import shard_map, with_sharding_constraint\n",
    "from ..compat import shard_map\n",
    "from repro import compat\ncost = compat.cost_analysis_dict(c)\n",
    # a host-side helper that merely shares a drifted name (sharding.py's
    # MeshRules._axis_size) must NOT be flagged
    "class R:\n"
    "    def _axis_size(self, a):\n        return 1\n"
    "    def dp(self):\n        return self._axis_size('x')\n",
    # docstring mentions are not uses (dryrun.py's case)
    'def f():\n    """uses cost_analysis() under the hood"""\n    return 1\n',
])
def test_compat_boundary_true_negatives(good):
    assert run_rule(CompatBoundaryRule(), good) == [], good


def test_compat_boundary_exempts_compat_py():
    bad = "from jax.experimental.shard_map import shard_map\n"
    assert Analyzer([CompatBoundaryRule()]).run_source(
        bad, "src/repro/compat.py") == []


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_flags_item_in_jitted_fn():
    src = """
        import jax

        @jax.jit
        def step(x):
            return x + x.sum().item()
    """
    findings = run_rule(HostSyncRule(), src)
    assert any(".item()" in f.message for f in findings)


def test_host_sync_flags_asarray_in_while_loop_body():
    src = """
        import numpy as np
        from jax import lax

        def go(x):
            def body(c):
                return np.asarray(c) + 1
            return lax.while_loop(lambda c: c < 3, body, x)
    """
    findings = run_rule(HostSyncRule(), src)
    assert any("np.asarray" in f.message for f in findings)


def test_host_sync_flags_nonzero_without_size():
    src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def live(x):
            return jnp.nonzero(x > 0)
    """
    findings = run_rule(HostSyncRule(), src)
    assert any("size=" in f.message for f in findings)


def test_host_sync_true_negatives():
    # host-only module: same calls, no tracing anywhere -> clean
    host_only = """
        import numpy as np

        def summarize(result):
            a = np.asarray(result)
            return float(a.mean()), a.sum().item()
    """
    assert run_rule(HostSyncRule(), host_only) == []
    # traced, but only safe constructs: jnp ops, int() of a plain argument,
    # nonzero with size=
    traced_safe = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x, budget):
            k = int(budget)
            idx, = jnp.nonzero(x > 0, size=8, fill_value=-1)
            return jnp.asarray(idx)[:k]
    """
    assert run_rule(HostSyncRule(), traced_safe) == []


def test_host_sync_pragma_allowlists_pre_trace_pull():
    src = """
        import jax
        import numpy as np

        # trace-safe: concrete graph structure, pulled before any trace —
        # repro-lint: disable=host-sync
        def budget(indptr):
            d = np.asarray(indptr)
            return int((d[1:] - d[:-1]).max())

        @jax.jit
        def step(x):
            return x * 2
    """
    assert run_rule(HostSyncRule(), src) == []


# ---------------------------------------------------------------------------
# shard-safety
# ---------------------------------------------------------------------------

def test_shard_safety_flags_axisless_collective():
    src = """
        from jax import lax
        from repro.compat import shard_map

        def build(mesh, spec):
            def shard_fn(x):
                return lax.psum(x)
            return shard_map(shard_fn, mesh=mesh, in_specs=spec,
                             out_specs=spec)
    """
    findings = run_rule(ShardSafetyRule(), src)
    assert any("without a bound mesh axis" in f.message for f in findings)


def test_shard_safety_flags_none_axis_and_raw_routing():
    src = """
        from jax import lax
        from repro.compat import shard_map

        def build(mesh, spec):
            def shard_fn(x, idx):
                y = lax.pmax(x, None)
                return lax.ppermute(y, "x", [(0, 1)])
            return shard_map(shard_fn, mesh=mesh, in_specs=spec,
                             out_specs=spec)
    """
    findings = run_rule(ShardSafetyRule(), src)
    msgs = " | ".join(f.message for f in findings)
    assert "without a bound mesh axis" in msgs and "routing" in msgs


def test_shard_safety_true_negatives():
    good = """
        from jax import lax
        from repro import offload
        from repro.compat import shard_map

        def build(mesh, spec, axis):
            def shard_fn(x, idx):
                got = offload.dgas_gather(x, idx, axis)
                n = offload.hierarchical_psum(got, axis)
                return lax.psum(n, axis_name=axis)
            return shard_map(shard_fn, mesh=mesh, in_specs=spec,
                             out_specs=spec)

        def host_helper(x):
            # not shard_map-mapped: collective rules don't apply here
            return x
    """
    assert run_rule(ShardSafetyRule(), good) == []
    # ppermute is legal inside offload.py itself
    routing = """
        from jax import lax
        from repro.compat import shard_map

        def build(mesh, spec):
            def shard_fn(x):
                return lax.ppermute(x, "x", [(0, 1)])
            return shard_map(shard_fn, mesh=mesh, in_specs=spec,
                             out_specs=spec)
    """
    assert Analyzer([ShardSafetyRule()]).run_source(
        textwrap.dedent(routing), "src/repro/core/offload.py") == []


def test_shard_safety_covers_shard_apply_wrapper():
    src = """
        from jax import lax

        def plan(engine, operands, spec):
            def shard_fn(x):
                return lax.psum(x)
            return engine._shard_apply(shard_fn, operands, spec)
    """
    findings = run_rule(ShardSafetyRule(), src)
    assert any("without a bound mesh axis" in f.message for f in findings)


# ---------------------------------------------------------------------------
# cache-key
# ---------------------------------------------------------------------------

def test_cache_key_flags_list_key():
    src = """
        from repro.core import engine

        def plan(mesh, axes, build):
            return engine.cached_mapped([mesh, tuple(axes)], build)
    """
    findings = run_rule(CacheKeyRule(), src)
    assert any("cache key" in f.message for f in findings)


def test_cache_key_flags_assigned_list_and_kwarg():
    src = """
        def plan(engine, operands, spec, build):
            key = ["core", spec]
            return engine._shard_apply(build, operands, spec, cache_key=key)
    """
    findings = run_rule(CacheKeyRule(), src)
    assert any("cache_key" in f.message for f in findings)


def test_cache_key_flags_mutable_default_on_caller():
    src = """
        from repro.core import engine

        def plan(mesh, build, axes=[]):
            return engine.cached_mapped((mesh, tuple(axes)), build)
    """
    findings = run_rule(CacheKeyRule(), src)
    assert any("mutable default" in f.message for f in findings)


def test_cache_key_true_negatives():
    good = """
        from repro.core import engine

        def plan(mesh, axes, att, build, extras=None):
            key = ("core", mesh, tuple(axes), att)
            return engine.cached_mapped(key, build, ident=(mesh, att))

        def no_cache(axes=[]):
            # mutable default is fine on functions that never touch the cache
            return list(axes)
    """
    assert run_rule(CacheKeyRule(), good) == []


# ---------------------------------------------------------------------------
# tuned-constants
# ---------------------------------------------------------------------------

def test_tuned_constants_flags_literal_signature_default():
    src = """
        def run_distributed(g, att, mesh, prog, *, switch_frac=1 / 32):
            return switch_frac
    """
    findings = run_rule(TunedConstantsRule(), src,
                        path="src/repro/core/engine.py")
    assert any("switch_frac" in f.message and "hard-codes" in f.message
               for f in findings)


def test_tuned_constants_flags_literal_funnel_call_args():
    src = """
        from .graph import to_bbcsr

        def build(csr):
            return to_bbcsr(csr, block_rows=256, tile_nnz=512)

        def cap(m):
            return frontier_edge_capacity(m, 1 / 32)
    """
    findings = run_rule(TunedConstantsRule(), src,
                        path="src/repro/kernels/ops.py")
    assert sum("to_bbcsr" in f.message for f in findings) == 2
    assert any("frontier_edge_capacity" in f.message for f in findings)


def test_tuned_constants_true_negatives():
    good = """
        from .. import tune as _tune
        from .graph import to_bbcsr

        def build(csr, block_rows=None, combine="add"):
            block_rows = _tune.resolve("kernels.bbcsr_add.block_rows",
                                       explicit=block_rows, n=csr.n_rows)
            return to_bbcsr(csr, block_rows=block_rows)

        def cap(m, switch_frac):
            return frontier_edge_capacity(m, switch_frac)
    """
    assert run_rule(TunedConstantsRule(), good,
                    path="src/repro/core/service.py") == []
    # literals outside the three funnel modules are none of this rule's
    # business (tests, benchmarks, kernel-internal defaults)
    bad_elsewhere = """
        def build(csr):
            return to_bbcsr(csr, block_rows=256)
    """
    assert run_rule(TunedConstantsRule(), bad_elsewhere,
                    path="src/repro/core/graph.py") == []
    assert run_rule(TunedConstantsRule(), bad_elsewhere,
                    path="tests/test_x.py") == []


# ---------------------------------------------------------------------------
# mutable-handle
# ---------------------------------------------------------------------------

def test_mutable_handle_flags_epoch_assignment():
    src = """
        class GraphService:
            def bump(self):
                self.epoch += 1
    """
    findings = run_rule(MutableHandleRule(), src,
                        "src/repro/core/service.py")
    assert any(".epoch" in f.message for f in findings)


def test_mutable_handle_flags_csr_and_tuple_targets():
    src = """
        def swap(svc, new_csr, new_stamps):
            svc.csr = new_csr
            svc.other, svc.stamps = 1, new_stamps
    """
    findings = run_rule(MutableHandleRule(), src,
                        "src/repro/core/service.py")
    msgs = " | ".join(f.message for f in findings)
    assert ".csr" in msgs and ".stamps" in msgs
    # `.other` is not a handle field
    assert ".other" not in msgs


def test_mutable_handle_flags_frozen_backdoor():
    src = """
        def sneak(handle, e):
            object.__setattr__(handle, "epoch", e)
    """
    findings = run_rule(MutableHandleRule(), src,
                        "src/repro/core/service.py")
    assert any("__setattr__" in f.message for f in findings)


def test_mutable_handle_true_negatives():
    # reads are the API; unrelated attributes are fine; graph.py is home turf
    good = """
        def snapshot(svc):
            e = svc.epoch
            c = svc.csr
            svc.stats = e
            return e, c
    """
    assert run_rule(MutableHandleRule(), good,
                    "src/repro/core/service.py") == []
    home = """
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class GraphHandle:
            epoch: int = 0

            def _bump(self):
                object.__setattr__(self, "epoch", self.epoch + 1)
    """
    assert run_rule(MutableHandleRule(), home,
                    "src/repro/core/graph.py") == []


# ---------------------------------------------------------------------------
# framework: pragmas, baseline, CLI
# ---------------------------------------------------------------------------

BAD_IMPORT = "from jax.experimental.shard_map import shard_map\n"


def test_line_pragma_suppresses_only_named_rule():
    src = ("from jax.experimental.shard_map import shard_map"
           "  # repro-lint: disable=compat-boundary\n")
    assert Analyzer(ALL_RULES).run_source(src, "src/repro/x.py") == []
    wrong = ("from jax.experimental.shard_map import shard_map"
             "  # repro-lint: disable=host-sync\n")
    assert Analyzer(ALL_RULES).run_source(wrong, "src/repro/x.py") != []


def test_file_pragma_and_disable_all():
    src = "# repro-lint: disable-file=compat-boundary\n" + BAD_IMPORT
    assert Analyzer(ALL_RULES).run_source(src, "src/repro/x.py") == []
    src_all = BAD_IMPORT + "x = 1  # repro-lint: disable=all\n"
    # disable=all on an unrelated line does not cover line 1
    assert Analyzer(ALL_RULES).run_source(src_all, "src/repro/x.py") != []


def test_function_scope_pragma_covers_body():
    src = """
        import jax

        @jax.jit
        def step(x):  # repro-lint: disable=host-sync
            return x + x.sum().item()
    """
    assert Analyzer(ALL_RULES).run_source(
        textwrap.dedent(src), "src/repro/x.py") == []


def test_baseline_grandfathers_by_content_not_line(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(BAD_IMPORT)
    analyzer = Analyzer(ALL_RULES)
    report = analyzer.run_files([f])
    assert len(report.findings) == 1
    bl = tmp_path / "baseline.json"
    write_baseline(bl, report.findings, report.modules)
    # same content, moved two lines down -> still baselined
    f.write_text("# a comment\nX = 1\n" + BAD_IMPORT)
    report2 = Analyzer(ALL_RULES, load_baseline(bl)).run_files([f])
    assert report2.findings == [] and report2.baseline_suppressed == 1
    # an *edited* offending line surfaces again
    f.write_text(BAD_IMPORT.replace("shard_map\n", "shard_map as sm\n"))
    report3 = Analyzer(ALL_RULES, load_baseline(bl)).run_files([f])
    assert len(report3.findings) == 1


def test_cli_exit_codes_and_no_jax_import(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_IMPORT)
    env_path = str(ROOT / "src")
    # findings -> exit 1, and the report names the rule
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad), "--no-baseline"],
        capture_output=True, text=True, cwd=tmp_path,
        env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "compat-boundary" in proc.stdout
    # clean tree -> exit 0, even with jax made unimportable: the analyzer
    # must never import the runtime it inspects
    good = tmp_path / "good.py"
    good.write_text("X = 1\n")
    bad.unlink()
    guard = tmp_path / "jax.py"
    guard.write_text("raise ImportError('lint lane must not import jax')\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(good), "--no-baseline"],
        capture_output=True, text=True, cwd=tmp_path,
        env={"PYTHONPATH": f"{tmp_path}:{env_path}",
             "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_rule_registry_complete():
    assert set(RULES_BY_ID) == {"single-core", "compat-boundary",
                                "host-sync", "shard-safety", "cache-key",
                                "mutable-handle", "tuned-constants"}
    for rule in ALL_RULES:
        assert rule.doc, rule.id


# ---------------------------------------------------------------------------
# the keystone: the real repo is analyzer-clean
# ---------------------------------------------------------------------------

def test_repo_is_analyzer_clean():
    files = collect_files([str(ROOT / "src"), str(ROOT / "tests")])
    assert len(files) > 50
    baseline = load_baseline(ROOT / "lint_baseline.json")
    # baseline entries are recorded relative to the repo root; findings on
    # absolute paths must match, so rebase the keys
    rebased = {(str(ROOT / p).replace("\\", "/"), r, c): n
               for (p, r, c), n in baseline.items()}
    report = Analyzer(ALL_RULES, rebased).run_files(files)
    assert report.findings == [], "\n".join(
        f.format() for f in report.findings)
    # the pragma allowlist is real and in use (engine/louvain/service)
    assert report.pragma_suppressed > 0
