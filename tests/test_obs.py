"""Unified telemetry suite (DESIGN.md §17).

Four contracts, each pinned here:

* **bit transparency** — the per-level engine trace is a side buffer: every
  traced run replays the committed golden grid (``tests/golden/core_grid.npz``)
  bit-identically, and trace on/off results agree on the local placement and
  the async placement at every staleness bound;
* **span structure** — an instrumented ``GraphService`` exports a Chrome
  ``trace_event`` JSON that is structurally valid (pid/tid/ts/dur/name on
  every event, per-tid nesting without partial overlap) and attributes ≥90%
  of a served batch's wall time to the named enqueue / flush-wait / engine /
  readback spans;
* **degradation counters** — the ROADMAP guardrail: push-capacity fallback,
  cache invalidation, compaction and EWMA updates are observable as registry
  counters, with a test pinning each firing (the streaming deletion fallback
  fires in tests/test_streaming.py's mixed-stream replay);
* **sketch accuracy** — the log-bucketed latency histogram's p50/p95 land
  within one bucket width of the exact percentiles (the fixed example here;
  the hypothesis property lives in tests/test_property.py).
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core import dgas, rmat
from repro.core.algorithms import msbfs, msbfs_distributed, sssp_batched
from repro.core.algorithms.distgraph import shard_graph
from repro.core.graph import CSR, GraphHandle
from repro.core.service import (Distance, GraphService, NeighborSample,
                                PPRTopK, Reachability)
from repro.launch.mesh import make_cores_mesh
from repro.obs import (Histogram, LevelTrace, MetricsRegistry, Observability,
                       SpanRecorder, build_chrome_trace, decode_level_trace,
                       format_summary, get_registry, summarize,
                       validate_chrome_trace)
from repro.obs.__main__ import main as obs_cli

GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden",
                            "core_grid.npz"))
G = rmat(7, 8, seed=11)
DELTA = float(GOLD["meta_delta_g"])
SOURCES = np.array([0, 3, 17, 64, 0], dtype=np.int32)
MODES = ("push", "pull", "auto")
INTERVALS = (1, 2, 8)

_MESH1 = make_cores_mesh(1)
_GSH1, _ATT1 = shard_graph(G, 1, row_att=dgas.block_rule(G.n_rows, 1))


# ---------------------------------------------------------------------------
# metrics: histogram sketch accuracy (fixed example), counters, registry
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_one_bucket():
    """The deterministic twin of the hypothesis property: the sketch's
    nearest-rank percentile is the owning bucket's upper edge, so it may
    exceed the exact percentile by at most one growth factor."""
    h = Histogram("lat")
    rng = np.random.default_rng(7)
    xs = np.concatenate([rng.uniform(1e-4, 5e-3, 300),
                         rng.uniform(0.05, 2.0, 60), [40.0, 120.0]])
    for x in xs:
        h.observe(float(x))
    for pct in (50.0, 95.0, 99.0):
        exact_lo = float(np.percentile(xs, pct, method="lower"))
        exact_hi = float(np.percentile(xs, pct, method="higher"))
        got = h.percentile(pct)
        assert exact_lo <= got <= exact_hi * h.growth, (pct, exact_lo, got)
    assert h.snapshot()["count"] == len(xs)
    assert h.mean == pytest.approx(float(xs.mean()), rel=1e-6)


def test_histogram_edge_cases():
    h = Histogram("x")
    assert h.percentile(50) == 0.0          # empty -> 0.0, not NaN
    h.observe(float("nan"))                 # skipped, not a bucket
    assert h.snapshot()["count"] == 0
    h.observe(0.0)                          # clamps into the lowest bucket
    h.observe(1e9)                          # clamps into the highest bucket
    assert h.snapshot()["count"] == 2
    assert h.percentile(0) <= h.percentile(100)


def test_registry_counters_and_gauges():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(4)
    assert reg.counter("a").value == 5
    with pytest.raises(ValueError):
        reg.counter("a").inc(-1)            # counters are monotone
    reg.gauge("g").set(2.5)
    assert reg.gauge("g").value == 2.5
    snap = reg.snapshot()
    assert snap["a"] == 5 and snap["g"] == 2.5
    reg.reset()
    assert reg.counter("a").value == 0
    assert get_registry() is get_registry()  # the process-wide singleton


# ---------------------------------------------------------------------------
# spans: nesting, retroactive clip, export structure
# ---------------------------------------------------------------------------

def test_span_recorder_nests_and_clips():
    t = [0.0]
    clk = lambda: t[0]
    rec = SpanRecorder(clock=clk)
    with rec.span("outer", tid=1) as args:
        t[0] = 1.0
        with rec.span("inner", tid=1):
            t[0] = 2.0
        args["route_bytes"] = 64
        t[0] = 3.0
    # a queue-wait measured from before the previous span must clip forward
    sp = rec.record("wait", 1.5, 4.0, tid=1)
    assert sp.ts == pytest.approx(3.0) and sp.dur == pytest.approx(1.0)
    doc = build_chrome_trace(rec.spans())
    assert validate_chrome_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert names == ["outer", "inner", "wait"]  # sorted by (tid, ts, -dur)
    outer = doc["traceEvents"][0]
    assert outer["args"]["route_bytes"] == 64   # args augmentable in-block


def test_validator_rejects_partial_overlap_and_missing_fields():
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 0, "tid": 1, "ts": 0.0, "dur": 10.0},
        {"ph": "X", "name": "b", "pid": 0, "tid": 1, "ts": 5.0, "dur": 10.0},
        {"ph": "X", "name": "c", "pid": 0, "tid": 2, "ts": 0.0},  # no dur
    ]}
    errs = validate_chrome_trace(bad)
    assert any("partially overlaps" in e for e in errs)
    assert any("missing 'dur'" in e for e in errs)


# ---------------------------------------------------------------------------
# engine tracing: bit transparency against the golden grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_msbfs_traced_replays_golden(mode):
    lv, st = msbfs(G, SOURCES, mode=mode, return_stats=True, trace=True)
    np.testing.assert_array_equal(np.asarray(lv), GOLD[f"bfs/packed/{mode}"])
    recs = decode_level_trace(st)
    assert len(recs) == int(st["pushes"] + st["pulls"])
    assert sum(r.direction == "push" for r in recs) == int(st["pushes"])
    assert sum(r.direction == "pull" for r in recs) == int(st["pulls"])
    assert all(r.frontier > 0 for r in recs)   # a level with no work is done


@pytest.mark.parametrize("mode", MODES)
def test_sssp_traced_replays_golden(mode):
    d, st = sssp_batched(G, SOURCES, delta=DELTA, mode=mode,
                         return_stats=True, trace=True)
    np.testing.assert_array_equal(np.asarray(d), GOLD[f"sssp/valued/{mode}"])
    assert len(decode_level_trace(st)) == int(st["pushes"] + st["pulls"])


def test_trace_on_off_identity_sync_and_async():
    ref = np.asarray(msbfs_distributed(_GSH1, _ATT1, SOURCES, _MESH1))
    for placement, ks in (("sync", (None,)), ("async", INTERVALS)):
        for k in ks:
            lv, st = msbfs_distributed(
                _GSH1, _ATT1, SOURCES, _MESH1, placement=placement,
                sync_interval=k, return_stats=True, trace=True)
            np.testing.assert_array_equal(np.asarray(lv), ref)
            recs = decode_level_trace(st)
            assert recs, (placement, k)
            if placement == "async":
                # each row is one global check; the outbox flush fired there
                assert all(r.flush and r.direction == "flush" for r in recs)
            else:
                assert not any(r.flush for r in recs)


def test_trace_len_truncates_by_dropping():
    full = decode_level_trace(
        msbfs(G, SOURCES, return_stats=True, trace=True)[1])
    assert len(full) >= 3
    short = decode_level_trace(
        msbfs(G, SOURCES, return_stats=True, trace=True, trace_len=2)[1])
    # rows past trace_len drop on device — never clamp-overwrite the last row
    assert [r.as_dict() for r in short] == [r.as_dict() for r in full[:2]]


def test_trace_argument_validation():
    with pytest.raises(ValueError, match="return_stats"):
        msbfs(G, SOURCES, trace=True)
    with pytest.raises(ValueError, match="trace"):
        msbfs(G, SOURCES, return_stats=True, trace_len=4)
    with pytest.raises(KeyError):
        decode_level_trace(msbfs(G, SOURCES, return_stats=True)[1])


# ---------------------------------------------------------------------------
# service spans: structural validity + wall-time attribution
# ---------------------------------------------------------------------------

def _served_service(budget=8, **kw):
    obs = Observability(metrics=MetricsRegistry())
    svc = GraphService(rmat(8, 8, seed=3), batch_budget=budget,
                       obs=obs, **kw)
    n = svc.csr.n_rows
    tickets = [svc.submit(Reachability(source=i, target=(i + 13) % n))
               for i in range(6)]
    tickets += [svc.submit(Distance(source=0, target=9)),
                svc.submit(PPRTopK(source=2, k=4)),
                svc.submit(NeighborSample(vertex=5, fanout=3))]
    svc.flush()
    for t in tickets:
        svc.result(t)
    return svc, obs


def test_service_chrome_trace_structurally_valid(tmp_path):
    svc, obs = _served_service()
    path = os.fspath(tmp_path / "trace.json")
    doc = obs.export_chrome_trace(path)
    assert validate_chrome_trace(doc) == []
    with open(path) as f:
        assert json.load(f) == doc
    names = {e["name"] for e in doc["traceEvents"]
             if e.get("cat") == "service"}
    assert {"enqueue", "flush_wait", "engine", "readback"} <= names
    # traversal kinds ran traced: their level lanes are in the export
    assert any(e.get("cat") == "level" for e in doc["traceEvents"])
    # the CLI renders and exits 0 on a structurally valid trace
    assert obs_cli(["summarize", path]) == 0
    assert obs_cli(["summarize", path, "--json"]) == 0


def test_service_span_attribution_covers_wall():
    """≥90% of the served batch's wall clock lands in named spans: the
    flush_wait/engine/readback sequence tiles the service lane (record()
    clips each round's wait to the previous round's end)."""
    svc, obs = _served_service()
    spans = obs.spans.spans()
    wall0 = min(sp.ts for sp in spans)
    wall1 = max(sp.ts + sp.dur for sp in spans)
    service_s = sum(sp.dur for sp in spans
                    if sp.tid == Observability.TID_SERVICE)
    assert service_s >= 0.9 * (wall1 - wall0)
    summ = summarize(obs.build_trace())
    frac = sum(row["wall_frac"] for name, row in summ["phases"].items()
               if name in ("flush_wait", "engine", "readback"))
    assert frac >= 0.9
    assert "wall time" in format_summary(summ)


def test_service_trace_off_records_nothing():
    svc = GraphService(rmat(7, 8, seed=3), batch_budget=4)
    svc.query(Reachability(source=0, target=5))
    assert svc.obs is None                  # no spans, no level runs


def test_service_engine_span_carries_batch_args():
    svc, obs = _served_service()
    eng = [sp for sp in obs.spans.spans() if sp.name == "engine"]
    assert eng
    for sp in eng:
        assert sp.args["kind"] in ("reach", "dist", "ppr", "sample")
        assert sp.args["budget"] == 8 and sp.args["epoch"] == 0
        assert sp.args["route_bytes"] > 0
    assert {r["name"].split("@")[0] for r in obs.level_runs} == \
        {"reach", "dist", "ppr"}            # sampling has no level loop


# ---------------------------------------------------------------------------
# degradation counters: each firing pinned (the ROADMAP guardrail)
# ---------------------------------------------------------------------------

def test_push_capacity_fallback_counter_fires():
    """A star graph overflows the compacted push capacity at the hub level:
    capacity = m * switch_frac * slack = m/8 < m active edges."""
    n = 64
    rows = np.zeros(n - 1, np.int64)
    cols = np.arange(1, n, dtype=np.int64)
    star = CSR.from_coo(rows, cols, None, n, n)
    reg = MetricsRegistry()
    svc = GraphService(star, batch_budget=4, mesh=_MESH1,
                       obs=Observability(metrics=reg))
    assert svc.query(Reachability(source=0, target=n - 1)) is True
    assert reg.counter("service.push_capacity_fallback").value >= 1


def test_cache_invalidation_counter_fires():
    reg = MetricsRegistry()
    svc = GraphService(rmat(7, 8, seed=3), batch_budget=4,
                       obs=Observability(metrics=reg))
    svc.query(Reachability(source=0, target=5))
    assert reg.counter("service.cache_invalidations").value == 0
    svc.apply_updates(inserts=(np.array([0]), np.array([5])))
    evicted = svc.stats.cache_evicted
    assert evicted >= 1
    assert reg.counter("service.cache_invalidations").value == evicted


def test_cost_ewma_counter_counts_batches():
    reg = MetricsRegistry()
    svc = GraphService(rmat(7, 8, seed=3), batch_budget=4,
                       obs=Observability(metrics=reg))
    svc.query(Reachability(source=0, target=5))
    svc.query(Distance(source=0, target=5))
    assert reg.counter("service.cost_ewma_updates").value == 2
    svc.query(Reachability(source=0, target=5))   # cache hit: no batch ran
    assert reg.counter("service.cost_ewma_updates").value == 2


def test_graph_compaction_counter_fires():
    """Delta-log overflow compaction increments the process-wide counter
    (graph.py has no per-service context — library events are global)."""
    h = GraphHandle.wrap(rmat(6, 8, seed=2), compact_threshold=0.001)
    before = get_registry().counter("graph.compactions").value
    h2, rep = h.apply((np.array([1, 2, 3]), np.array([4, 5, 6])), None)
    assert rep.compacted
    assert get_registry().counter("graph.compactions").value == before + 1
