"""Per-assigned-architecture smoke tests: reduced config, one forward/train
step on CPU, asserting output shapes + no NaNs (the FULL configs are exercised
only via the dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, ARCH_NAMES, input_specs, shape_names
from repro.configs.common import init_params, make_step, SpecBundle
from repro.distributed.sharding import make_rules
from repro.data import synthetic
from repro.core.graph import uniform_random_graph
from repro.models import transformer as TF
from repro.models import gnn as GNN
from repro.models import recsys as RS
from repro.optim import adamw

RULES = make_rules(None)
RNG = np.random.default_rng(0)


def _smoke_batch(ac, cfg):
    if ac.family == "lm":
        toks = RNG.integers(0, cfg.vocab, (2, 32)).astype(np.int32)
        return {"tokens": jnp.asarray(toks)}
    if ac.family == "recsys":
        b = next(synthetic.recsys_batches(16, cfg.n_fields, cfg.rows_per_field))
        return {k: jnp.asarray(v) for k, v in b.items()}
    g = uniform_random_graph(48, 3, seed=1)
    b = synthetic.gnn_batch(cfg.arch, g, cfg.d_feat, cfg.n_classes,
                            l_max=cfg.l_max)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    ac = get_config(arch)
    cfg = ac.smoke
    params = init_params(ac, cfg, jax.random.PRNGKey(0))
    state = adamw.init_state_with_dtype(params, ac.moment_dtype)
    bundle = SpecBundle("train", cfg, {}, {})
    step = make_step(ac, bundle, RULES,
                     adamw.AdamWConfig(warmup_steps=0, total_steps=10))
    batch = _smoke_batch(ac, cfg)
    state2, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch} loss NaN/inf"
    assert int(state2.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).family == "lm"])
def test_smoke_lm_decode_matches_forward(arch):
    ac = get_config(arch)
    cfg = ac.smoke
    params = TF.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    logits, _ = TF.forward(cfg, params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    cache = TF.init_cache(cfg, B, S)
    lg = None
    for t in range(S):
        lg, cache = TF.decode_step(cfg, params, cache, toks[:, t:t + 1])
    err = float(jnp.max(jnp.abs(lg - logits[:, -1].astype(jnp.float32))))
    assert err < 1e-3, f"{arch} decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if get_config(a).family == "lm"])
def test_smoke_lm_losses_decrease(arch):
    """A few steps on a repeated batch must reduce the loss (training works)."""
    ac = get_config(arch)
    cfg = ac.smoke
    params = init_params(ac, cfg, jax.random.PRNGKey(0))
    state = adamw.init_state_with_dtype(params, ac.moment_dtype)
    step = jax.jit(make_step(ac, SpecBundle("train", cfg, {}, {}), RULES,
                             adamw.AdamWConfig(lr=1e-2, warmup_steps=0,
                                               total_steps=30,
                                               weight_decay=0.0)))
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 32)),
                                   jnp.int32)}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{arch}: {losses}"


def test_all_cells_resolve():
    """Every non-skipped (arch x shape) cell yields well-formed specs."""
    total = 0
    for a in ARCH_NAMES:
        ac = get_config(a)
        for s in shape_names(ac):
            if s in ac.skips:
                continue
            b = input_specs(ac, s)
            assert b.kind in ("train", "prefill", "decode", "serve", "retrieval")
            for name, sds in b.batch.items():
                assert name in b.batch_axes
                assert all(d > 0 for d in sds.shape)
            total += 1
    assert total == 36  # 40 cells - 4 documented long_500k skips


def test_mixtral_long500k_uses_ring_cache():
    ac = get_config("mixtral-8x7b")
    b = input_specs(ac, "long_500k")
    # physical cache is window-sized (ring), logical context 524288
    assert b.cache["k"].shape[3] == ac.model.window
