"""Checkpoint roundtrip, elastic resharding, fault-tolerant restart."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import save, restore, latest_step, CheckpointManager
from repro.distributed.fault_tolerance import (FTConfig, SimulatedFailure,
                                               run_training)
from repro.optim import adamw


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)]}}


def test_save_restore_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, t)
        assert latest_step(d) == 3
        out = restore(d, 3, jax.eval_shape(lambda: t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))


def test_async_save_and_retention():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, every=1, keep=2)
        for s in range(1, 6):
            mgr.maybe_save(s, t)
        mgr.wait()
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [4, 5]


def test_restore_mismatched_shape_raises():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save(d, 0, t)
        bad = dict(t)
        bad["a"] = jnp.zeros((4, 4))
        try:
            restore(d, 0, jax.eval_shape(lambda: bad))
            assert False, "should raise"
        except ValueError:
            pass


def test_ft_restart_resumes_and_converges():
    params = {"w": jnp.full((4,), 5.0)}
    opt = adamw.AdamWConfig(lr=0.2, warmup_steps=0, total_steps=60,
                            weight_decay=0.0)
    state = adamw.init_state(params)

    @jax.jit
    def step_fn(state, batch):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - batch["t"]) ** 2))(state.params)
        return adamw.apply_update(opt, state, g), {"loss": loss}

    def batches():
        while True:
            yield {"t": jnp.zeros((4,))}

    fails = {9, 23}

    def injector(step):
        if step in fails:
            fails.discard(step)
            raise SimulatedFailure()

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, every=5, keep=3)
        state, report = run_training(step_fn, state, batches(), ckpt, 40,
                                     FTConfig(ckpt_every=5),
                                     fail_injector=injector)
    assert report["restarts"] == 2
    assert int(state.step) == 40
    assert abs(float(state.params["w"][0])) < 1.0  # converged toward 0


def test_elastic_restore_across_meshes():
    """Checkpoint written from one sharding restores onto another mesh size.

    (Single real device here: shardings on 1-device meshes with different
    axis splits exercise the device_put resharding path.)"""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16, dtype=jnp.float32)}
    m1 = jax.make_mesh((1,), ("data",))
    with tempfile.TemporaryDirectory() as d:
        save(d, 0, jax.device_put(t, NamedSharding(m1, P())))
        sh = {"w": NamedSharding(m1, P("data"))}
        out = restore(d, 0, jax.eval_shape(lambda: t), shardings=sh)
        np.testing.assert_allclose(np.asarray(out["w"]), np.arange(16))
        assert out["w"].sharding.spec == P("data")
