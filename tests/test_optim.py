"""AdamW + schedule + compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.optim.compression import _quant_int8


def test_adamw_matches_reference_impl():
    """One step vs a hand-rolled AdamW."""
    cfg = adamw.AdamWConfig(lr=1e-2, beta1=0.9, beta2=0.99, eps=1e-8,
                            weight_decay=0.1, clip_norm=None, warmup_steps=0,
                            total_steps=1, min_lr_frac=1.0)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st = adamw.init_state(p)
    st2 = adamw.apply_update(cfg, st, g)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.99)
    expect = (np.asarray(p["w"])
              - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(st2.params["w"]), expect, rtol=1e-5)


def test_clip_norm_applied():
    cfg = adamw.AdamWConfig(lr=1e-2, clip_norm=0.1, warmup_steps=0,
                            total_steps=1, weight_decay=0.0, min_lr_frac=1.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([300.0, 400.0, 0.0])}  # norm 500 -> scaled by 2e-4
    st2 = adamw.apply_update(cfg, adamw.init_state(p), g)
    # effective grad = [0.06, 0.08, 0]; m-hat/(sqrt(v-hat)) ~ sign
    assert np.isfinite(np.asarray(st2.params["w"])).all()
    assert float(jnp.abs(st2.params["w"][2])) < 1e-9


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    lrs = [float(adamw.cosine_schedule(cfg, jnp.asarray(s)))
           for s in [0, 5, 10, 60, 110]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-3


def test_bf16_moments_state():
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st = adamw.init_state_with_dtype(p, jnp.bfloat16)
    assert st.m["w"].dtype == jnp.bfloat16
    cfg = adamw.AdamWConfig(warmup_steps=0, total_steps=2)
    st2 = adamw.apply_update(cfg, st, {"w": jnp.ones((4,), jnp.bfloat16)})
    assert st2.m["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(st2.params["w"], np.float32)).all()


def test_int8_quant_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q, s = _quant_int8(x)
    err = float(jnp.max(jnp.abs(q.astype(jnp.float32) * s - x)))
    assert err <= float(s) / 2 + 1e-7
