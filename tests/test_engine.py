"""Frontier engine: output equivalence vs reference implementations,
direction switching, the SpMSpV kernel path, the structured combines
(argmax / sample), and the new algorithms."""
import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, offload, rmat, uniform_random_graph, to_padded_ell
from repro.core.graph import CSR
from repro.core.algorithms import (bfs, bfs_program, pagerank, sssp, auto_delta,
                                   connected_components, symmetrize, spmv,
                                   label_propagation, lpa_program, random_walks)
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# references
# ---------------------------------------------------------------------------

def _np_bfs(indptr, indices, src):
    n = indptr.shape[0] - 1
    level = -np.ones(n, np.int64)
    level[src] = 0
    frontier, d = [src], 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in indices[indptr[u]:indptr[u + 1]]:
                if level[v] < 0:
                    level[v] = d + 1
                    nxt.append(v)
        frontier, d = nxt, d + 1
    return level


def _np_pagerank(csr, damping=0.85, iters=20):
    n = csr.n_rows
    indptr = np.asarray(csr.indptr)
    rows = np.asarray(csr.row_ids())
    cols = np.asarray(csr.indices)
    deg = (indptr[1:] - indptr[:-1]).astype(np.float64)
    x = np.full(n, 1.0 / n)
    for _ in range(iters):
        push = np.where(deg[rows] > 0, x[rows] / np.maximum(deg[rows], 1), 0.0)
        y = np.zeros(n)
        np.add.at(y, cols, push)
        dangling = x[deg == 0].sum()
        x = (1 - damping) / n + damping * (y + dangling / n)
    return x


def _np_dijkstra(csr, src):
    indptr = np.asarray(csr.indptr)
    indices = np.asarray(csr.indices)
    w = (np.asarray(csr.values) if csr.values is not None
         else np.ones_like(indices, np.float64))
    n = indptr.shape[0] - 1
    dist = np.full(n, np.inf)
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for e in range(indptr[u], indptr[u + 1]):
            v, nd = indices[e], d + w[e]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist


def _np_components(n, rows, cols):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for r, c in zip(rows, cols):
        a, b = find(int(r)), find(int(c))
        if a != b:
            parent[max(a, b)] = min(a, b)
    return np.array([find(i) for i in range(n)])


def _same_partition(a, b):
    m1, m2 = {}, {}
    for x, y in zip(a.tolist(), b.tolist()):
        if m1.setdefault(x, y) != y or m2.setdefault(y, x) != x:
            return False
    return True


# ---------------------------------------------------------------------------
# output equivalence: engine-backed ports vs references
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["push", "pull", "auto"])
def test_engine_bfs_matches_numpy_all_modes(mode):
    g = uniform_random_graph(250, 4, seed=1)
    lv = np.asarray(bfs(g, 0, mode=mode))
    ref_lv = _np_bfs(np.asarray(g.indptr), np.asarray(g.indices), 0)
    np.testing.assert_array_equal(lv, ref_lv)


def test_engine_bfs_matches_on_rmat():
    g = rmat(8, 8, seed=4)
    lv = np.asarray(bfs(g, 0))
    ref_lv = _np_bfs(np.asarray(g.indptr), np.asarray(g.indices), 0)
    np.testing.assert_array_equal(lv, ref_lv)


def test_engine_pagerank_matches_numpy():
    g = rmat(7, 8, seed=2)
    pr = np.asarray(pagerank(g, iters=25))
    ref_pr = _np_pagerank(g, iters=25)
    np.testing.assert_allclose(pr, ref_pr, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# direction switching
# ---------------------------------------------------------------------------

def test_push_pull_steps_agree():
    """The two directions compute the same acc for the same frontier."""
    g = uniform_random_graph(120, 5, seed=7)
    prog = bfs_program()
    n = g.n_rows
    frontier = jnp.zeros((n,), jnp.int32).at[jnp.arange(0, n, 7)].set(1)
    msg = prog.msg_fn({}, frontier)
    dense = engine._dense_step(g.row_ids(), g.indices, None, msg, n, prog)
    k = int(np.asarray(g.degrees()).max())
    sparse = engine._sparse_step(g.indptr, g.indices, None, msg, frontier,
                                 n, n, k, prog)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


def test_auto_mode_actually_switches():
    """A long path pushes (frontier of 1); a star pulls after one hop."""
    n = 200
    path = CSR.from_coo(np.arange(n - 1), np.arange(1, n), None, n, n)
    state0 = {"level": jnp.full((n,), -1, jnp.int32).at[0].set(0)}
    f0 = jnp.zeros((n,), jnp.int32).at[0].set(1)
    _, stats = engine.run(path, bfs_program(), state0, f0, max_iters=n,
                          mode="auto", return_stats=True)
    assert int(stats["pulls"]) == 0 and int(stats["pushes"]) >= n - 1

    star = CSR.from_coo(np.zeros(n - 1, np.int64), np.arange(1, n), None, n, n)
    # hub -> all: frontier jumps from 1 to n-1, over any n/32 threshold
    _, stats = engine.run(star, bfs_program(), state0, f0, max_iters=n,
                          mode="auto", return_stats=True)
    assert int(stats["pushes"]) >= 1 and int(stats["pulls"]) >= 1


def test_engine_rejects_bad_programs():
    with pytest.raises(ValueError):
        engine.VertexProgram(edge_op="div", combine="add",
                             msg_fn=None, update_fn=None)
    with pytest.raises(ValueError):
        engine.VertexProgram(edge_op="mul", combine="median",
                             msg_fn=None, update_fn=None)
    g = uniform_random_graph(50, 3, seed=2)
    with pytest.raises(ValueError):
        bfs(g, 0, mode="psuh")
    # a weighted kernel operand under an edge_op='copy' program would
    # silently multiply by edge weights — must be rejected
    bb_weighted = engine.build_pull_operand(g, block_rows=32, block_cols=32,
                                            tile_nnz=64)
    with pytest.raises(ValueError):
        bfs(g, 0, kernel_bb=bb_weighted)


def test_push_capacity_overflow_falls_back_to_dense():
    """mode='push' with a small capacity must not truncate the frontier."""
    g = uniform_random_graph(200, 4, seed=1)
    ref_lv = np.asarray(bfs(g, 0))
    n = g.n_rows
    state0 = {"level": jnp.full((n,), -1, jnp.int32).at[0].set(0)}
    f0 = jnp.zeros((n,), jnp.int32).at[0].set(1)
    st, stats = engine.run(g, bfs_program(), state0, f0, max_iters=n,
                           mode="push", push_capacity=4, return_stats=True)
    np.testing.assert_array_equal(np.asarray(st["level"]), ref_lv)
    assert int(stats["pulls"]) > 0  # oversized levels took the dense path


# ---------------------------------------------------------------------------
# SpMSpV kernel path
# ---------------------------------------------------------------------------

def test_spmspv_kernel_matches_masked_spmv():
    g = rmat(7, 6, seed=9)
    bb = engine.build_pull_operand(g, block_rows=32, block_cols=32,
                                   tile_nnz=64)
    n = g.n_rows
    frontier = jnp.zeros((n,), jnp.int32).at[jnp.asarray([3, 50, 77])].set(1)
    x = jnp.where(frontier > 0, jnp.asarray(RNG.random(n, np.float32)), 0.0)
    got = np.asarray(ops.spmspv_dma(bb, x, engine.tile_active(bb, frontier)))
    want = np.asarray(ref.spmv_bbcsr_ref(bb, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_bfs_kernel_path_matches():
    g = uniform_random_graph(200, 4, seed=12)
    bb = engine.build_pull_operand(g, unit_values=True, block_rows=32,
                                   block_cols=32, tile_nnz=64)
    lv_k = np.asarray(bfs(g, 0, kernel_bb=bb))
    lv = np.asarray(bfs(g, 0))
    np.testing.assert_array_equal(lv_k, lv)


# ---------------------------------------------------------------------------
# new engine-backed algorithms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["push", "pull", "auto"])
def test_sssp_matches_dijkstra(mode):
    g = uniform_random_graph(220, 4, seed=5)
    got = np.asarray(sssp(g, 0, mode=mode))
    want = _np_dijkstra(g, 0)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_sssp_unweighted_equals_bfs_levels():
    g = uniform_random_graph(150, 3, seed=6, weighted=False)
    d = np.asarray(sssp(g, 0))
    lv = np.asarray(bfs(g, 0)).astype(np.float64)
    lv[lv < 0] = np.inf
    np.testing.assert_allclose(d, lv)


def test_sssp_delta_insensitive():
    g = uniform_random_graph(150, 4, seed=8)
    a = np.asarray(sssp(g, 0, delta=0.05))
    b = np.asarray(sssp(g, 0, delta=10.0))  # ~Bellman-Ford
    np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("mode", ["push", "pull", "auto"])
def test_connected_components_match_union_find(mode):
    g = uniform_random_graph(300, 1, seed=10)
    lab = np.asarray(connected_components(g, mode=mode))
    rows, cols = np.asarray(g.row_ids()), np.asarray(g.indices)
    want = _np_components(300, rows, cols)
    assert _same_partition(lab, want)


def test_connected_components_two_cliques():
    rows, cols = [], []
    for c in range(2):
        for i in range(6):
            for j in range(6):
                if i != j:
                    rows.append(c * 6 + i)
                    cols.append(c * 6 + j)
    g = CSR.from_coo(rows, cols, None, 12, 12)
    lab = np.asarray(connected_components(g))
    assert len(set(lab[:6])) == 1 and len(set(lab[6:])) == 1
    assert lab[0] != lab[6]


def test_symmetrize_is_symmetric():
    g = rmat(6, 4, seed=3)
    s = symmetrize(g)
    d = np.asarray(s.to_dense()) > 0
    assert (d == d.T).all()


# ---------------------------------------------------------------------------
# structured combines: argmax_weighted (LPA) and sample
# ---------------------------------------------------------------------------

_PAD = jnp.int32(2 ** 30)


def _weighted_mode_ell(labels, weights, fallback):
    """The pre-refactor per-row weighted mode (reference for equivalence)."""
    n, k = labels.shape
    order = jnp.argsort(labels, axis=1)
    sl = jnp.take_along_axis(labels, order, 1)
    sw = jnp.take_along_axis(weights, order, 1)
    is_start = jnp.concatenate(
        [jnp.ones((n, 1), bool), sl[:, 1:] != sl[:, :-1]], axis=1)
    run_id = jnp.cumsum(is_start, axis=1) - 1
    seg = (jnp.arange(n)[:, None] * k + run_id).reshape(-1)
    run_w = jax.ops.segment_sum(sw.reshape(-1), seg, num_segments=n * k).reshape(n, k)
    run_l = jnp.full((n * k,), _PAD, jnp.int32).at[seg].min(sl.reshape(-1)).reshape(n, k)
    run_w = jnp.where(run_l == _PAD, -1.0, run_w)
    best = jnp.argmax(run_w, axis=1)
    lab = jnp.take_along_axis(run_l, best[:, None], 1)[:, 0]
    has_any = jnp.max(run_w, axis=1) > 0
    return jnp.where(has_any, lab, fallback)


def _lpa_reference(csr, iters):
    """The pre-refactor label_propagation (ELL gather + per-row mode)."""
    cols, vals, mask = to_padded_ell(csr)
    n = csr.n_rows

    def body(_, labels):
        nl = offload.dma_gather(labels, jnp.where(mask, cols, -1), fill=0)
        nl = jnp.where(mask, nl, _PAD).astype(jnp.int32)
        w = jnp.where(mask, vals, 0.0)
        return _weighted_mode_ell(nl, w, labels)

    return jax.lax.fori_loop(0, iters, body, jnp.arange(n, dtype=jnp.int32))


@pytest.mark.parametrize("seed", [4, 5])
def test_lpa_engine_matches_prerefactor_exactly(seed):
    """Engine-backed LPA == the bespoke implementation, label for label
    (fixed smaller-label tie-breaking)."""
    g = rmat(8, 8, seed=seed)
    got = np.asarray(label_propagation(g, iters=6))
    want = np.asarray(_lpa_reference(g, iters=6))
    np.testing.assert_array_equal(got, want)


def test_argmax_push_pull_steps_agree():
    """Both directions compute the same (weight, label) acc for a partial
    frontier — the structured-combine analogue of test_push_pull_steps_agree."""
    g = uniform_random_graph(150, 5, seed=3)
    n = g.n_rows
    prog = lpa_program()
    labels = jnp.asarray(RNG.integers(0, 12, n).astype(np.int32))
    frontier = jnp.zeros((n,), jnp.int32).at[jnp.arange(0, n, 3)].set(1)
    msg = prog.msg_fn({"label": labels}, frontier)
    dw, dl = engine._dense_step(g.row_ids(), g.indices, g.values, msg, n, prog)
    k = int(np.asarray(g.degrees()).max())
    sw, sl = engine._sparse_step(g.indptr, g.indices, g.values, msg, frontier,
                                 n, n, k, prog)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(sw), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(dl), np.asarray(sl))


def test_sample_combine_is_uniform_pick():
    """combine='sample' through engine.run: each destination picks uniformly
    among its active in-neighbors."""
    n, hub = 9, 0
    srcs = np.arange(1, n)
    g = CSR.from_coo(srcs, np.full(n - 1, hub), None, n, n)

    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, jnp.arange(n, dtype=jnp.int32), -1)

    def update_fn(state, acc, frontier, it):
        _, pick = acc
        return {"pick": pick}, jnp.zeros_like(frontier)  # one step

    prog = engine.VertexProgram(edge_op="copy", combine="sample",
                                msg_fn=msg_fn, update_fn=update_fn)
    frontier0 = jnp.ones((n,), jnp.int32)
    run1 = jax.jit(lambda key: engine.run(
        g, prog, {"pick": jnp.full((n,), -1, jnp.int32)}, frontier0,
        max_iters=1, mode="pull", key=key)["pick"][hub])
    counts = np.zeros(n, np.int64)
    for s in range(400):
        counts[int(run1(jax.random.PRNGKey(s)))] += 1
    assert counts[hub] == 0 and counts[1:].min() > 0
    expected = 400 / (n - 1)
    assert counts[1:].max() < 3 * expected  # loose uniformity bound


def test_sample_requires_key_and_structured_rejects_add():
    g = uniform_random_graph(30, 2, seed=1)
    prog = engine.VertexProgram(edge_op="copy", combine="sample",
                                msg_fn=lambda s, f: f, update_fn=None)
    with pytest.raises(ValueError):
        engine.run(g, prog, {}, jnp.ones((30,), jnp.int32), max_iters=1)
    with pytest.raises(ValueError):
        engine.VertexProgram(edge_op="add", combine="argmax_weighted",
                             msg_fn=None, update_fn=None)


def test_sample_neighbors_distribution_and_sinks():
    n = 7
    g = CSR.from_coo(np.zeros(n - 1, np.int64), np.arange(1, n), None, n, n)
    qs = jnp.zeros((3000,), jnp.int32)
    picks = np.asarray(engine.sample_neighbors(g, qs, jax.random.PRNGKey(0)))
    cnt = np.bincount(picks, minlength=n)
    assert cnt[0] == 0
    assert cnt[1:].min() > 0.7 * 3000 / (n - 1)
    assert cnt[1:].max() < 1.3 * 3000 / (n - 1)
    # sinks (vertices 1..n-1 have no out-edges) self-sample
    sinks = np.asarray(engine.sample_neighbors(
        g, jnp.arange(1, n, dtype=jnp.int32), jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(sinks, np.arange(1, n))


def test_random_walks_next_step_marginal_uniform():
    """Distribution-level equivalence: one-step marginals from a hub match
    the uniform neighbor pick of the pre-refactor sampler."""
    n = 6
    g = CSR.from_coo(np.zeros(n - 1, np.int64), np.arange(1, n), None, n, n)
    walks = np.asarray(random_walks(g, jnp.zeros((4000,), jnp.int32), 1,
                                    jax.random.PRNGKey(3)))
    cnt = np.bincount(walks[:, 1], minlength=n)
    assert cnt[0] == 0
    assert cnt[1:].min() > 0.7 * 4000 / (n - 1)
    assert cnt[1:].max() < 1.3 * 4000 / (n - 1)


def test_auto_delta_tracks_weight_scale():
    g = uniform_random_graph(300, 4, seed=2)
    d1 = auto_delta(g)
    g10 = CSR(g.indptr, g.indices, g.values * 10.0, g.n_rows, g.n_cols)
    d10 = auto_delta(g10)
    assert 5.0 < d10 / d1 < 20.0  # quantile rule scales with the weights
    assert auto_delta(CSR(g.indptr, g.indices, None, g.n_rows, g.n_cols)) == 1.0


# ---------------------------------------------------------------------------
# engine as SpMV (one dense step of the (add, mul) program)
# ---------------------------------------------------------------------------

def test_engine_dense_step_is_spmv():
    # messages flow src->dst, so a dense step over A^T's edge list == A @ x
    g = rmat(6, 6, seed=13)
    t = g.transpose()
    x = jnp.asarray(RNG.random(g.n_cols, np.float32))
    prog = engine.VertexProgram(edge_op="mul", combine="add",
                                msg_fn=lambda s, f: s, update_fn=None)
    acc = engine._dense_step(t.row_ids(), t.indices, t.values, x,
                             t.n_cols, prog)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(spmv(g, x)),
                               rtol=1e-4, atol=1e-5)
