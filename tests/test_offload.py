"""Offload-engine edge cases: queue balance with empty/overfull queues,
owner routing at degenerate capacities, remote combines with all-inactive
input, the structured segment combines, and the routed-byte model.

Multi-shard behavior is covered by tests/_distributed_main.py; everything
here runs on a single-device mesh (the collectives degenerate but the slot
bookkeeping, masking and compaction logic are all exercised).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import dgas, engine, offload, traffic

MESH = jax.make_mesh((1,), ("x",))
SPEC = P("x")


def _mapped(fn, n_in, n_out=1):
    return shard_map(fn, mesh=MESH, in_specs=(SPEC,) * n_in,
                     out_specs=(SPEC,) * n_out if n_out > 1 else SPEC)


# ---------------------------------------------------------------------------
# _route degenerate capacities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity", [0, 1])
def test_route_tiny_capacity_drops_overflow(capacity):
    vals = jnp.asarray(np.array([[10, 20, 30]], np.int32))
    dest = jnp.zeros((1, 3), jnp.int32)  # all to shard 0

    def fn(v, d):
        recv, recvv, _, valid = offload._route(v[0], d[0], "x", capacity)
        return (recv[None], recvv[None], valid[None])

    recv, recvv, valid = _mapped(fn, 2, 3)(vals, dest)
    recv, recvv, valid = (np.asarray(x)[0] for x in (recv, recvv, valid))
    assert recv.shape == (capacity,) and recvv.shape == (capacity,)
    # fixed per-peer capacity: first `capacity` items land, the rest drop
    assert int(valid.sum()) == capacity
    if capacity == 1:
        assert recvv[0] and recv[0] == 10  # deterministic: stable slot order
    else:
        assert not valid.any()


def test_route_negative_dest_dropped():
    vals = jnp.asarray(np.array([[7, 8]], np.int32))
    dest = jnp.asarray(np.array([[-1, 0]], np.int32))

    def fn(v, d):
        recv, recvv, _, valid = offload._route(v[0], d[0], "x", 4)
        return (recv[None], recvv[None], valid[None])

    recv, recvv, valid = _mapped(fn, 2, 3)(vals, dest)
    assert list(np.asarray(valid)[0]) == [False, True]
    got = np.asarray(recv)[0][np.asarray(recvv)[0]]
    assert list(got) == [8]


# ---------------------------------------------------------------------------
# queue_balance: empty and (over)full queues, payload companion
# ---------------------------------------------------------------------------

def test_queue_balance_empty_queue():
    cap = 8
    items = jnp.full((1, cap), -1, jnp.int32)

    def fn(it):
        q = offload.queue_balance(
            offload.QueueState(it[0], jnp.int32(0)), "x")
        return q.items[None], q.count[None, None]

    out_items, out_count = _mapped(fn, 1, 2)(items)
    assert int(np.asarray(out_count).reshape(())) == 0
    assert (np.asarray(out_items) == -1).all()


def test_queue_balance_full_queue_keeps_capacity_and_items():
    cap = 6
    vals = np.arange(100, 100 + cap, dtype=np.int32)

    def fn(it):
        q = offload.queue_balance(
            offload.QueueState(it[0], jnp.int32(cap)), "x")
        return q.items[None], q.count[None, None]

    out_items, out_count = _mapped(fn, 1, 2)(jnp.asarray(vals[None]))
    out_items = np.asarray(out_items)[0]
    # the balanced queue keeps the input buffer size (fixed point under
    # iterated balancing) and loses nothing when the global count fits
    assert out_items.shape == (cap,)
    assert int(np.asarray(out_count).reshape(())) == cap
    assert sorted(out_items.tolist()) == vals.tolist()


def test_queue_balance_routes_payload_with_items():
    cap = 5
    items = np.full((1, cap), -1, np.int32)
    items[0, :3] = [11, 12, 13]
    payload = np.full((1, cap), -7, np.int32)
    payload[0, :3] = [110, 120, 130]

    def fn(it, pl):
        q, p = offload.queue_balance(
            offload.QueueState(it[0], jnp.int32(3)), "x", pl[0])
        return q.items[None], p[None]

    out_items, out_pl = _mapped(fn, 2, 2)(jnp.asarray(items), jnp.asarray(payload))
    out_items, out_pl = np.asarray(out_items)[0], np.asarray(out_pl)[0]
    got = {int(i): int(p) for i, p in zip(out_items, out_pl) if i >= 0}
    assert got == {11: 110, 12: 120, 13: 130}
    # empty slots are scrubbed, not leaking stale payload
    assert (out_pl[out_items < 0] == 0).all()


# ---------------------------------------------------------------------------
# remote combines with all-inactive input
# ---------------------------------------------------------------------------

def test_remote_scatter_combine_all_inactive_is_noop():
    att = dgas.block_rule(8, 1)
    local = jnp.asarray(np.arange(8, dtype=np.float32))
    gidx = jnp.full((1, 4), -1, jnp.int32)
    vals = jnp.full((1, 4), 123.0, jnp.float32)

    def fn(l, g, v):
        return offload.remote_scatter_combine(
            l[0], g[0], v[0], att, "x", combine="min", identity=np.inf,
            capacity=4)[None]

    out = _mapped(fn, 3)(local[None], gidx, vals)
    np.testing.assert_array_equal(np.asarray(out)[0], np.arange(8, dtype=np.float32))


def test_remote_weighted_mode_all_inactive_votes():
    att = dgas.block_rule(4, 1)
    gidx = jnp.full((1, 6), -1, jnp.int32)
    labs = jnp.full((1, 6), 3, jnp.int32)
    w = jnp.ones((1, 6), jnp.float32)

    def fn(g, l, v):
        bw, bl = offload.remote_scatter_weighted_mode(
            4, g[0], l[0], v[0], att, "x", capacity=6)
        return bw[None], bl[None]

    bw, bl = _mapped(fn, 3, 2)(gidx, labs, w)
    assert np.isneginf(np.asarray(bw)[0]).all()
    assert (np.asarray(bl)[0] == -1).all()


# ---------------------------------------------------------------------------
# structured segment combines (pure, no mesh)
# ---------------------------------------------------------------------------

def test_segment_argmax_matches_bruteforce():
    rng = np.random.default_rng(3)
    m, n = 300, 12
    idx = rng.integers(-1, n, m)
    score = rng.random(m).astype(np.float32)
    payload = rng.integers(0, 50, m)
    bw, bp = offload.segment_argmax(jnp.asarray(idx), jnp.asarray(score),
                                    jnp.asarray(payload), n)
    for v in range(n):
        sel = idx == v
        if not sel.any():
            assert np.isneginf(float(bw[v])) and int(bp[v]) == -1
        else:
            best = score[sel].max()
            winners = payload[sel][score[sel] == best]
            assert abs(float(bw[v]) - best) < 1e-6
            assert int(bp[v]) == winners.min()  # ties -> smaller payload


def test_segment_weighted_mode_matches_bruteforce():
    rng = np.random.default_rng(4)
    m, n, L = 400, 15, 7
    idx = rng.integers(-1, n, m)
    lab = rng.integers(-1, L, m)
    w = rng.random(m).astype(np.float32)
    bw, bl = offload.segment_weighted_mode(jnp.asarray(idx), jnp.asarray(lab),
                                           jnp.asarray(w), n)
    for v in range(n):
        sums = {}
        for i in np.nonzero((idx == v) & (lab >= 0))[0]:
            sums[int(lab[i])] = sums.get(int(lab[i]), 0.0) + float(w[i])
        if not sums:
            assert np.isneginf(float(bw[v])) and int(bl[v]) == -1
        else:
            best = max(sums.values())
            want = min(l for l, s in sums.items() if abs(s - best) < 1e-4)
            assert abs(float(bw[v]) - best) < 1e-3
            assert int(bl[v]) == want


def test_segment_weighted_mode_empty_stream():
    bw, bl = offload.segment_weighted_mode(
        jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
        jnp.zeros((0,), jnp.float32), 3)
    assert np.isneginf(np.asarray(bw)).all() and (np.asarray(bl) == -1).all()


# ---------------------------------------------------------------------------
# routed-byte model + capacity rule
# ---------------------------------------------------------------------------

def test_frontier_edge_capacity_shrinks_with_frontier_bound():
    m = 1 << 16
    caps = [engine.frontier_edge_capacity(m, f)
            for f in (1 / 2, 1 / 8, 1 / 32, 1 / 128)]
    assert caps == sorted(caps, reverse=True)
    assert caps[-1] < caps[0] <= m
    assert engine.frontier_edge_capacity(m, 1e-9) >= 1  # floor


def test_routed_bytes_shrink_with_capacity():
    S, m = 8, 1 << 14
    full = traffic.push_level_route_bytes(S, m)
    by_frac = [traffic.push_level_route_bytes(
        S, engine.frontier_edge_capacity(m, f)) for f in (1 / 8, 1 / 32, 1 / 128)]
    assert all(b < full for b in by_frac)
    assert by_frac == sorted(by_frac, reverse=True)
    c = traffic.RouteByteCounter(S)
    c.push_level(m)
    c.push_level(engine.frontier_edge_capacity(m, 1 / 32))
    assert c.levels == 2
    assert c.total_bytes == full + by_frac[1]
