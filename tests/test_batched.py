"""Batched multi-source engine: lane exactness vs per-source runs (the
acceptance bar is *bit identity*, not tolerance), bit-packing edge cases,
the segment_or reduction, the Pallas min/max tile combine, and the
byte-model accounting for batched payloads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, offload, rmat, traffic, uniform_random_graph
from repro.core.algorithms import (auto_delta, bfs, msbfs, ppr, ppr_batched,
                                   ppr_topk, sssp, sssp_batched)
from repro.kernels import ops

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# lane packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [1, 31, 32, 33, 64, 70])
def test_pack_unpack_roundtrip(B):
    bits = (RNG.random((B, 57)) < 0.3).astype(np.int32)
    words = engine.pack_lanes(jnp.asarray(bits))
    assert words.shape == (57, engine.lane_words(B))
    assert words.dtype == jnp.uint32
    back = np.asarray(engine.unpack_lanes(words, B))
    np.testing.assert_array_equal(back, bits)


def test_segment_or_matches_numpy():
    n, m, W = 40, 300, 3
    idx = RNG.integers(-2, n + 2, m).astype(np.int32)  # includes OOB
    words = RNG.integers(0, 2 ** 32, (m, W), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(offload.segment_or(jnp.asarray(idx), jnp.asarray(words), n))
    expect = np.zeros((n, W), np.uint32)
    for i in range(m):
        if 0 <= idx[i] < n:
            expect[idx[i]] |= words[i]
    np.testing.assert_array_equal(got, expect)


def test_segment_or_presorted_matches_unsorted():
    n, m = 16, 120
    idx = np.sort(RNG.integers(0, n, m)).astype(np.int32)
    words = RNG.integers(0, 2 ** 20, (m, 2), dtype=np.uint64).astype(np.uint32)
    a = np.asarray(offload.segment_or(jnp.asarray(idx), jnp.asarray(words), n,
                                      presorted=True))
    b = np.asarray(offload.segment_or(jnp.asarray(idx), jnp.asarray(words), n))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# msbfs == per-source bfs (bit identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["push", "pull", "auto"])
def test_msbfs_matches_per_source_all_modes(mode):
    g = uniform_random_graph(200, 4, seed=3)
    srcs = np.array([0, 7, 50, 199, 0])  # duplicate lane on purpose
    lv = np.asarray(msbfs(g, srcs, mode=mode))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(lv[b], np.asarray(bfs(g, int(s),
                                                            mode=mode)))


def test_msbfs_word_boundary_lanes():
    g = rmat(7, 8, seed=2)
    srcs = np.arange(40) % g.n_rows  # spans the 32-lane word boundary
    lv = np.asarray(msbfs(g, srcs))
    for b in (0, 31, 32, 39):
        np.testing.assert_array_equal(lv[b], np.asarray(bfs(g, int(srcs[b]))))


def test_msbfs_single_lane():
    g = rmat(7, 8, seed=2)
    lv = np.asarray(msbfs(g, np.array([5])))
    np.testing.assert_array_equal(lv[0], np.asarray(bfs(g, 5)))


def test_msbfs_under_jit_and_stats():
    g = rmat(7, 8, seed=1)
    srcs = np.array([0, 3, 9])
    lv, stats = jax.jit(lambda: msbfs(g, srcs, return_stats=True))()
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(msbfs(g, srcs)))
    assert int(stats["iters"]) == int(stats["pushes"]) + int(stats["pulls"])


# ---------------------------------------------------------------------------
# sssp_batched == per-source sssp (bit identity)
# ---------------------------------------------------------------------------

def test_sssp_batched_matches_per_source():
    g = rmat(8, 8, seed=4)
    d = auto_delta(g)
    srcs = np.array([0, 3, 17, 99, 255])
    db = np.asarray(sssp_batched(g, srcs, delta=d))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(db[b], np.asarray(sssp(g, int(s),
                                                             delta=d)))


def test_sssp_batched_unweighted_equals_bfs_levels():
    g = uniform_random_graph(150, 4, seed=5, weighted=False)
    srcs = np.array([0, 10])
    db = np.asarray(sssp_batched(g, srcs, delta=1.5))
    lv = np.asarray(msbfs(g, srcs))
    finite = np.isfinite(db)
    np.testing.assert_array_equal(finite, lv >= 0)
    np.testing.assert_array_equal(db[finite].astype(np.int64),
                                  lv[lv >= 0].astype(np.int64))


# ---------------------------------------------------------------------------
# ppr_batched == per-source ppr (bit identity), and top-k
# ---------------------------------------------------------------------------

def test_ppr_batched_matches_per_source():
    g = rmat(7, 8, seed=6)
    srcs = np.array([0, 5, 100])
    pb = np.asarray(ppr_batched(g, srcs))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(pb[b], np.asarray(ppr(g, int(s))))


def test_ppr_mass_and_personalization():
    g = rmat(7, 8, seed=6)
    x = np.asarray(ppr(g, 3))
    assert abs(float(x.sum()) - 1.0) < 1e-3   # a distribution
    # restart mass concentrates at/near the source
    assert x[3] == x.max()


def test_ppr_topk_shapes_and_order():
    g = rmat(7, 8, seed=6)
    srcs = np.array([1, 2])
    vals, idx = ppr_topk(g, srcs, 5)
    assert vals.shape == (2, 5) and idx.shape == (2, 5)
    v = np.asarray(vals)
    assert (np.diff(v, axis=1) <= 1e-9).all()  # descending
    full = np.asarray(ppr_batched(g, srcs))
    for b in range(2):
        np.testing.assert_allclose(v[b], np.sort(full[b])[::-1][:5], rtol=1e-6)


# ---------------------------------------------------------------------------
# program validation
# ---------------------------------------------------------------------------

def test_or_combine_rejected_outside_batched():
    g = uniform_random_graph(32, 3, seed=0)
    prog = engine.VertexProgram(edge_op="copy", combine="or",
                                msg_fn=lambda s, f: f,
                                update_fn=lambda s, a, f, i: (s, f))
    with pytest.raises(ValueError, match="run_batched"):
        engine.run(g, prog, {}, jnp.zeros((32,), jnp.int32), max_iters=2)


def test_or_combine_requires_copy_edge_op():
    with pytest.raises(ValueError, match="copy"):
        engine.VertexProgram(edge_op="mul", combine="or",
                             msg_fn=lambda s, f: f,
                             update_fn=lambda s, a, f, i: (s, f))


def test_run_batched_rejects_structured():
    g = uniform_random_graph(32, 3, seed=0)
    prog = engine.VertexProgram(edge_op="copy", combine="sample",
                                msg_fn=lambda s, f: f,
                                update_fn=lambda s, a, f, i: (s, f))
    with pytest.raises(NotImplementedError):
        engine.run_batched(g, prog, {}, jnp.zeros((2, 32), jnp.int32),
                           max_iters=1)


# ---------------------------------------------------------------------------
# Pallas min/max tile combine (the extended SpMSpV kernel)
# ---------------------------------------------------------------------------

def _minplus_reference(g, x):
    """y[v] = min over in-edges (u, v) of x[u] + w(u, v)."""
    indptr = np.asarray(g.indptr)
    rows = np.repeat(np.arange(g.n_rows), np.diff(indptr))
    cols = np.asarray(g.indices)
    w = (np.asarray(g.values) if g.values is not None
         else np.ones_like(cols, np.float32))
    y = np.full(g.n_cols, np.inf, np.float32)
    np.minimum.at(y, cols, x[rows] + w)
    return y


@pytest.mark.parametrize("combine", ["min", "max"])
def test_spmspv_kernel_select_combine(combine):
    g = rmat(7, 8, seed=9)
    bb = engine.build_pull_operand(g, block_rows=32, block_cols=32,
                                  tile_nnz=32)
    n = g.n_rows
    ident = np.inf if combine == "min" else -np.inf
    x = np.full(n, ident, np.float32)
    act = RNG.choice(n, 9, replace=False)
    x[act] = RNG.random(9).astype(np.float32)
    frontier = jnp.asarray(np.isfinite(x).astype(np.int32))
    y = np.asarray(ops.spmspv_dma(bb, jnp.asarray(x),
                                  engine.tile_active(bb, frontier),
                                  combine=combine))
    if combine == "min":
        expect = _minplus_reference(g, x)
    else:
        indptr = np.asarray(g.indptr)
        rows = np.repeat(np.arange(n), np.diff(indptr))
        cols = np.asarray(g.indices)
        w = np.asarray(g.values)
        expect = np.full(n, -np.inf, np.float32)
        np.maximum.at(expect, cols, x[rows] + w)
    np.testing.assert_array_equal(y, expect)


def test_spmspv_min_requires_mask():
    g = rmat(6, 4, seed=9)
    bb = engine.build_pull_operand(g, block_rows=32, block_cols=32,
                                  tile_nnz=32)
    import dataclasses as dc
    bare = dc.replace(bb, tile_cnt=None)
    with pytest.raises(ValueError, match="mask"):
        ops.spmspv_dma(bare, jnp.full((g.n_rows,), jnp.inf),
                       jnp.ones((bb.n_tiles,), jnp.int32), combine="min")


def test_sssp_kernel_path_matches_plain():
    g = rmat(7, 8, seed=10)
    d = auto_delta(g)
    bb = engine.build_pull_operand(g, block_rows=32, block_cols=32,
                                  tile_nnz=32)
    ref = np.asarray(sssp(g, 0, delta=d))
    srcs = np.array([0, 12, 60])
    got = np.asarray(sssp_batched(g, srcs, delta=d, kernel_bb=bb))
    np.testing.assert_allclose(got[0], ref, rtol=0, atol=0)
    for b, s in enumerate(srcs):
        np.testing.assert_allclose(got[b], np.asarray(sssp(g, int(s), delta=d)),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# byte model
# ---------------------------------------------------------------------------

def test_batched_payload_bytes_amortizes():
    # 256 packed lanes ride in 8 words: vs 256 single-source items the packed
    # item is ~64x smaller than B * ROUTE_PAYLOAD_BYTES
    b256 = traffic.batched_payload_bytes(256, packed=True)
    assert b256 == 4 + 1 + 4 * 8
    singles = 256 * traffic.ROUTE_PAYLOAD_BYTES
    assert singles / b256 > 60
    assert traffic.batched_payload_bytes(1, packed=False) == 9
    with pytest.raises(ValueError):
        traffic.batched_payload_bytes(0)


def test_route_byte_counter_payload_override():
    ctr = traffic.RouteByteCounter(8)
    base = ctr.push_level(100)
    batched = ctr.push_level(100, payload_bytes=traffic.batched_payload_bytes(
        64, packed=True))
    assert base == 8 * 100 * traffic.ROUTE_PAYLOAD_BYTES
    assert batched == 8 * 100 * (4 + 1 + 4 * 2)
    assert ctr.levels == 2


# ---------------------------------------------------------------------------
# randomized seed sweep (deterministic; the hypothesis-driven property
# variants live in test_property.py, which is skipped without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_seed_sweep_batched_equals_per_source(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 80))
    g = uniform_random_graph(n, int(rng.integers(1, 5)), seed=seed)
    srcs = rng.integers(0, n, int(rng.integers(1, 6)))
    lv = np.asarray(msbfs(g, srcs))
    d = auto_delta(g)
    db = np.asarray(sssp_batched(g, srcs, delta=d))
    pb = np.asarray(ppr_batched(g, srcs, iters=8))
    for b, s in enumerate(srcs):
        np.testing.assert_array_equal(lv[b], np.asarray(bfs(g, int(s))))
        np.testing.assert_array_equal(db[b], np.asarray(sssp(g, int(s),
                                                             delta=d)))
        np.testing.assert_array_equal(pb[b], np.asarray(ppr(g, int(s),
                                                            iters=8)))
