"""Runs the 8-device shard_map validation in a subprocess (device count must
be fixed before jax initializes, so it cannot run in-process with pytest)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_distributed_engines_and_algorithms():
    script = os.path.join(os.path.dirname(__file__), "_distributed_main.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, env=env, timeout=1200)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed validation failed"
