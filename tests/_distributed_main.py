"""Multi-device (8 fake CPU devices) validation of the DGAS offload engines
and distributed algorithms. Run via tests/test_distributed.py subprocess."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import dgas, offload, rmat
from repro.core.algorithms import (spmv, pagerank, bfs, random_walks)
from repro.core.algorithms.spmv import spmv_distributed
from repro.core.algorithms.pagerank import pagerank_distributed
from repro.core.algorithms.bfs import bfs_distributed
from repro.core.algorithms.random_walks import random_walks_distributed
from repro.core.algorithms.distgraph import (shard_graph, shard_vertex_array,
                                             unshard_vertex_array)
from repro.launch.mesh import make_cores_mesh

S = 8
mesh = make_cores_mesh(S)
spec = P("cores")
rng = np.random.default_rng(0)
failures = []


def check(name, ok):
    print(("PASS" if ok else "FAIL"), name, flush=True)
    if not ok:
        failures.append(name)


# --- dgas_gather / remote_scatter_add vs local semantics --------------------
n = 128
for kind, att in [("interleave", dgas.interleave_rule(n, S)),
                  ("block", dgas.block_rule(n, S))]:
    table = rng.standard_normal(n).astype(np.float32)
    sharded = shard_vertex_array(table, att)
    gidx = rng.integers(0, n, (S, 16)).astype(np.int32)

    fn = shard_map(partial(lambda sh, gi, att=att: offload.dgas_gather(
        sh[0], gi[0], att, "cores", capacity=16)[None], ),
        mesh=mesh, in_specs=(spec, spec), out_specs=spec)
    out = np.asarray(fn(sharded, jnp.asarray(gidx)))
    check(f"dgas_gather/{kind}", np.allclose(out, table[gidx], atol=1e-6))

    dest0 = np.zeros(n, np.float32)
    idx = rng.integers(0, n, (S, 16)).astype(np.int32)
    vals = rng.standard_normal((S, 16)).astype(np.float32)
    fn = shard_map(partial(lambda sh, gi, vv, att=att: offload.remote_scatter_add(
        sh[0], gi[0], vv[0], att, "cores", capacity=16 * S)[None], ),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec)
    out = np.asarray(fn(shard_vertex_array(dest0, att), jnp.asarray(idx),
                        jnp.asarray(vals)))
    expect = np.zeros(n, np.float32)
    np.add.at(expect, idx.reshape(-1), vals.reshape(-1))
    got = np.asarray(unshard_vertex_array(jnp.asarray(out), att))
    check(f"remote_scatter_add/{kind}", np.allclose(got, expect, atol=1e-4))

# --- all_gather_gather baseline equals dgas path ----------------------------
att = dgas.block_rule(n, S)
table = rng.standard_normal(n).astype(np.float32)
sharded = shard_vertex_array(table, att)
gidx = rng.integers(0, n, (S, 16)).astype(np.int32)
fn = shard_map(lambda sh, gi: offload.all_gather_gather(
    sh[0], gi[0], att, "cores")[None],
    mesh=mesh, in_specs=(spec, spec), out_specs=spec)
out = np.asarray(fn(sharded, jnp.asarray(gidx)))
check("all_gather_gather/block", np.allclose(out, table[gidx], atol=1e-6))

# --- queue engine balance ----------------------------------------------------
counts = np.array([13, 0, 7, 1, 0, 0, 25, 2], np.int32)
cap = 64
items = np.full((S, cap), -1, np.int32)
for s in range(S):
    items[s, :counts[s]] = rng.integers(0, 1000, counts[s])
fn = shard_map(lambda it, ct: (lambda q: (q.items[None], q.count[None, None]))(
    offload.queue_balance(offload.QueueState(it[0], ct[0, 0]), "cores")),
    mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec))
out_items, out_counts = fn(jnp.asarray(items), jnp.asarray(counts)[:, None])
out_counts = np.asarray(out_counts).reshape(-1)
total = counts.sum()
check("queue_balance/even", out_counts.max() - out_counts.min() <= 1
      and out_counts.sum() == total)
orig = sorted(items[items >= 0].tolist())
got = sorted(np.asarray(out_items)[np.asarray(out_items) >= 0].tolist())
check("queue_balance/preserves", orig == got)

# --- prefix scan -------------------------------------------------------------
x = rng.integers(0, 10, S).astype(np.int32)
fn = shard_map(lambda v: offload.prefix_scan(v[0, 0], "cores")[None, None],
               mesh=mesh, in_specs=(spec,), out_specs=spec)
out = np.asarray(fn(jnp.asarray(x)[:, None])).reshape(-1)
check("prefix_scan", np.array_equal(out, np.concatenate([[0], np.cumsum(x)[:-1]])))

# --- distributed algorithms vs local ----------------------------------------
g = rmat(8, 8, seed=1)
x = rng.random(g.n_cols).astype(np.float32)

gsh, row_att = shard_graph(g, S)
x_att = dgas.block_rule(g.n_cols, S)
x_sh = shard_vertex_array(x, x_att)
y_local = np.asarray(spmv(g, jnp.asarray(x)))
for mode in ("dgas", "allgather"):
    y = spmv_distributed(gsh, x_sh, x_att, row_att, mesh, axis="cores", mode=mode)
    got = np.asarray(unshard_vertex_array(y, row_att))
    check(f"spmv_distributed/{mode}", np.allclose(got, y_local, atol=1e-3))

pr_local = np.asarray(pagerank(g, iters=15))
gsh2, att2 = shard_graph(g, S, row_att=dgas.block_rule(g.n_rows, S))
pr = pagerank_distributed(gsh2, att2, mesh, axis="cores", iters=15)
got = np.asarray(unshard_vertex_array(pr, att2))
check("pagerank_distributed", np.allclose(got, pr_local, atol=1e-5))

lv_local = np.asarray(bfs(g, 0))
lv = bfs_distributed(gsh2, att2, 0, mesh, axis="cores")
got = np.asarray(unshard_vertex_array(lv, att2))
check("bfs_distributed", np.array_equal(got, lv_local))

# --- engine: direction-optimizing BFS + SSSP + CC on the same machinery -----
from repro.core import engine as eng
from repro.core.algorithms.sssp import sssp, sssp_distributed
from repro.core.algorithms.cc import (connected_components,
                                      connected_components_distributed,
                                      symmetrize)

g_rev = eng.reverse_graph(g, att2)
lv2 = bfs_distributed(gsh2, att2, 0, mesh, axis="cores", g_rev=g_rev,
                      mode="auto")
got = np.asarray(unshard_vertex_array(lv2, att2))
check("bfs_distributed/auto_direction", np.array_equal(got, lv_local))

d_local = np.asarray(sssp(g, 0, delta=0.5))
d = sssp_distributed(gsh2, att2, 0, mesh, axis="cores", delta=0.5)
got = np.asarray(unshard_vertex_array(d, att2))
check("sssp_distributed", np.allclose(got, d_local, atol=1e-5, equal_nan=True))

gsym = symmetrize(g)
gshs, atts = shard_graph(gsym, S, row_att=dgas.block_rule(gsym.n_rows, S))
lab_local = np.asarray(connected_components(gsym, symmetrize_input=False))
lab = connected_components_distributed(gshs, atts, mesh, axis="cores")
got = np.asarray(unshard_vertex_array(lab, atts))
check("cc_distributed", np.array_equal(got, lab_local))

# --- frontier-proportional compacted push routing ----------------------------
from repro.core.algorithms.bfs import bfs_program

owner0 = int(att2.owner(jnp.asarray(0)))
local0 = int(att2.local(jnp.asarray(0)))
st0 = {"level": jnp.full((S, att2.per_shard), -1, jnp.int32).at[owner0, local0].set(0)}
f0 = jnp.zeros((S, att2.per_shard), jnp.int32).at[owner0, local0].set(1)
for cap, name in [(16, "tiny_cap_fallback"),
                  (eng.frontier_edge_capacity(gsh2.edges_per_shard, 1 / 32),
                   "derived_cap"),
                  (0, "disabled")]:
    st = eng.run_distributed(gsh2, att2, mesh, bfs_program(), st0, f0,
                             axis="cores", max_iters=64, mode="push",
                             push_edge_capacity=cap)
    got = np.asarray(unshard_vertex_array(st["level"], att2))
    check(f"bfs_compact_push/{name}", np.array_equal(got, lv_local))

d2 = sssp_distributed(gsh2, att2, 0, mesh, axis="cores", delta=0.5)
got = np.asarray(unshard_vertex_array(d2, att2))
check("sssp_distributed/compact_default", np.allclose(got, d_local, atol=1e-5,
                                                      equal_nan=True))

# --- structured combine: distributed weighted label propagation --------------
from repro.core.algorithms.louvain import (label_propagation,
                                           label_propagation_distributed)

lpa_local = np.asarray(label_propagation(g, iters=5))
lpa_att = dgas.block_rule(g.n_rows, S)
lpa = label_propagation_distributed(g, mesh, axis="cores", iters=5)
got = np.asarray(unshard_vertex_array(lpa, lpa_att))
check("label_propagation_distributed", np.array_equal(got, lpa_local))

# --- engine runtime stats: compacted-push fallback counter -------------------
st_tiny, d_stats = eng.run_distributed(gsh2, att2, mesh, bfs_program(), st0, f0,
                                       axis="cores", max_iters=64, mode="push",
                                       push_edge_capacity=16, return_stats=True)
d_stats = {k: int(np.asarray(v)[0]) for k, v in d_stats.items()}
got = np.asarray(unshard_vertex_array(st_tiny["level"], att2))
check("run_distributed_stats/fallbacks",
      np.array_equal(got, lv_local) and d_stats["fallbacks"] > 0
      and d_stats["pushes"] == d_stats["iters"] and d_stats["pulls"] == 0)

# --- multi-level Louvain: modularity, contraction, full pipeline -------------
from repro.core import traffic
from repro.core.graph import contract
from repro.core.algorithms.louvain import (modularity, modularity_distributed,
                                           contract_distributed, multilevel,
                                           multilevel_distributed,
                                           partition_equal)

ml_att = dgas.block_rule(g.n_rows, S)
g_ml, _ = shard_graph(g, S, row_att=ml_att)
lab_rand = rng.integers(0, 40, g.n_rows).astype(np.int32)
q_loc = float(modularity(g, jnp.asarray(lab_rand)))
q_dist = float(np.asarray(modularity_distributed(
    g_ml, ml_att, mesh, shard_vertex_array(lab_rand, ml_att), axis="cores"))[0])
check("modularity_distributed", abs(q_loc - q_dist) < 1e-4)

ctr = traffic.RouteByteCounter(S, payload_bytes=traffic.CONTRACT_PAYLOAD_BYTES)
coarse_d, _, _, ren_d, routed = contract_distributed(
    g_ml, ml_att, jnp.asarray(lab_rand), counter=ctr)
coarse_l, ren_l = contract(g, lab_rand)
check("contract_distributed/renumber",
      np.array_equal(np.asarray(ren_d), np.asarray(ren_l)))
check("contract_distributed/weights",
      np.allclose(np.asarray(coarse_d.to_dense()),
                  np.asarray(coarse_l.to_dense()), atol=1e-3))
check("contract_distributed/route_bytes",
      routed > 0 and ctr.total_bytes == routed * traffic.CONTRACT_PAYLOAD_BYTES)


ml_local, ml_scores = multilevel(g)
ctr2 = traffic.RouteByteCounter(S, payload_bytes=traffic.CONTRACT_PAYLOAD_BYTES)
ml_dist, ml_scores_d = multilevel_distributed(g, mesh, axis="cores",
                                              counter=ctr2)
check("multilevel_distributed/partition", partition_equal(ml_local, ml_dist))
check("multilevel_distributed/scores",
      len(ml_scores) == len(ml_scores_d) and len(ml_scores_d) >= 1
      and all(abs(a - b) < 1e-3 for a, b in zip(ml_scores, ml_scores_d))
      and all(b > a for a, b in zip(ml_scores_d, ml_scores_d[1:])))
check("multilevel_distributed/contract_traffic",
      ctr2.levels == len(ml_scores_d) and ctr2.total_bytes > 0)

# queue-engine walks: walker count deliberately NOT divisible by S (the
# queue balancer owns the load spreading now, not a reshape)
walks = np.asarray(random_walks_distributed(g, jnp.arange(S * 4 + 3), 6,
                                            jax.random.PRNGKey(0), mesh,
                                            axis="cores"))
indptr, indices = np.asarray(g.indptr), np.asarray(g.indices)
ok = walks.shape == (S * 4 + 3, 7)
for w in walks:
    for a, b in zip(w[:-1], w[1:]):
        nbrs = indices[indptr[a]:indptr[a + 1]]
        if not ((b in nbrs) or (b == a and nbrs.size == 0)):
            ok = False
check("random_walks_distributed/queue_engine", ok)

# --- gradient compression ----------------------------------------------------
from repro.optim import compression
gr = {"a": rng.standard_normal((64,)).astype(np.float32) * 0.01}
gr_s = jnp.asarray(np.stack([gr["a"]] * S))  # same grad on each shard
fn = shard_map(lambda g_: compression.psum_bf16({"a": g_[0]}, "cores")["a"][None],
               mesh=mesh, in_specs=(spec,), out_specs=spec)
out = np.asarray(fn(gr_s))[0]
check("psum_bf16", np.allclose(out, gr["a"] * S, rtol=1e-2, atol=1e-3))

ef0 = jnp.zeros((S, 64), jnp.float32)
fn = shard_map(lambda g_, e_: (lambda o, ne: (o["a"][None], ne["a"][None]))(
    *compression.psum_int8_ef({"a": g_[0]}, {"a": e_[0]}, "cores")),
    mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec))
out, ef = fn(gr_s, ef0)
check("psum_int8_ef", np.allclose(np.asarray(out)[0], gr["a"] * S,
                                  rtol=0.05, atol=1e-3))

# --- hierarchical collectives on a 2-axis mesh -------------------------------
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
fn = shard_map(lambda v: offload.hierarchical_psum(v[0, 0], ["model", "data"])
               [None, None],
               mesh=mesh2, in_specs=(P("data", "model"),),
               out_specs=P("data", "model"))
vals = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
out = np.asarray(fn(vals))
check("hierarchical_psum", np.allclose(out, vals.sum()))

# --- GNN DGAS shard_map path == local path ----------------------------------
import dataclasses as _dc
from repro.models import gnn as _G
from repro.data.synthetic import gnn_batch as _gb
from repro.core.graph import uniform_random_graph as _urg
from repro.distributed.sharding import MeshRules as _MR

_mesh2 = jax.make_mesh((2, 4), ("data", "model"))
_rules = _MR(mesh=_mesh2, batch=("data",), seq_sp=None, tp="model",
             fsdp=("data",), expert="model", flat=("data", "model"))
_g = _urg(64, 4, seed=9)   # 64 nodes % 8 == 0; edges padded below
for _arch, _kw in [("gin", dict(n_layers=2, d_hidden=16)),
                   ("gatedgcn", dict(n_layers=2, d_hidden=16)),
                   ("dimenet", dict(n_layers=2, d_hidden=16, triplet_chunk=64)),
                   ("equiformer_v2", dict(n_layers=1, d_hidden=8, l_max=2,
                                          m_max=2, n_heads=2, edge_chunk=32))]:
    _cfg = _G.GNNConfig(name=_arch, arch=_arch, d_feat=8, n_classes=3,
                        dgas_threshold=1,       # force the DGAS path
                        dgas_cap_factor=10**6,  # exact capacity (no drops)
                        **_kw)
    _cfg_local = _dc.replace(_cfg, dgas_threshold=10**12)
    _b = _gb(_arch, _g, 8, 3, l_max=2, seed=3)
    # pad edge arrays to a mesh multiple (input_specs does this in prod)
    _E = _b["src"].shape[0]
    _pad = -(-_E // 8) * 8 - _E
    for _k in ("src", "dst"):
        _b[_k] = np.concatenate([_b[_k], np.full(_pad, -1, np.int32)])
    if "wigner" in _b:
        _b["wigner"] = np.concatenate(
            [_b["wigner"], np.tile(np.eye(9, dtype=np.float32), (_pad, 1, 1))])
    if "triplet_kj" in _b:
        _T = _b["triplet_kj"].shape[0]
        _tp = -(-_T // 8) * 8 - _T
        _b["triplet_kj"] = np.concatenate([_b["triplet_kj"], np.full(_tp, -1, np.int32)])
        _b["triplet_ji"] = np.concatenate([_b["triplet_ji"], np.zeros(_tp, np.int32)])
        _b["angle"] = np.concatenate([_b["angle"], np.zeros(_tp, np.float32)])
    _bj = {k: jnp.asarray(v) for k, v in _b.items()}
    _p = _G.init_params(_cfg, jax.random.PRNGKey(0))
    with jax.sharding.use_mesh(_mesh2) if hasattr(jax.sharding, "use_mesh") else _mesh2:
        _l_dgas = float(jax.jit(lambda pp, bb: _G.loss_fn(_cfg, pp, bb, _rules)[0])(_p, _bj))
    _l_local = float(_G.loss_fn(_cfg_local, _p, _bj)[0])
    ok = abs(_l_dgas - _l_local) < 1e-3 * max(1.0, abs(_l_local))
    check(f"gnn_dgas_vs_local/{_arch}", ok)

# --- FM DGAS lookup == local lookup ------------------------------------------
from repro.models import recsys as _R
_cfgf = _R.FMConfig(name="fm-test", n_fields=4, embed_dim=4, rows_per_field=16,
                    use_dgas=True, dgas_cap_factor=10**6)
_pf = _R.init_params(_cfgf, jax.random.PRNGKey(0))
_ids = jnp.asarray(rng.integers(0, 64, (16, 4)).astype(np.int32))
_rules_f = _MR(mesh=_mesh2, batch=("data",), seq_sp=None, tp="model",
               fsdp=("data",), expert="model", flat=("data", "model"))
_s_dgas = np.asarray(jax.jit(lambda p, i: _R.fm_scores(_cfgf, p, i, _rules_f))(_pf, _ids))
_s_local = np.asarray(_R.fm_scores(_cfgf, _pf, _ids))
check("fm_dgas_vs_local", np.allclose(_s_dgas, _s_local, rtol=1e-4, atol=1e-4))
# gradient path (remote-atomic scatter-add transpose)
_lbl = jnp.asarray(rng.integers(0, 2, 16).astype(np.float32))
_g_d = jax.jit(jax.grad(lambda p: _R.loss_fn(_cfgf, p, {"ids": _ids, "labels": _lbl},
                                             _rules_f)[0]))(_pf)
_g_l = jax.grad(lambda p: _R.loss_fn(_cfgf, p, {"ids": _ids, "labels": _lbl})[0])(_pf)
check("fm_dgas_grad", all(np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)
                          for a, b in zip(jax.tree.leaves(_g_d), jax.tree.leaves(_g_l))))

# --- batched multi-source traversal: partition identity ----------------------
from repro.core import engine as _eng, traffic as _traffic
from repro.core.algorithms import (msbfs_distributed, sssp_batched_distributed,
                                   sssp_distributed)
from repro.core.algorithms.sssp import sssp_batched as _sssp_batched
from repro.core.algorithms.bfs import msbfs as _msbfs
from repro.core.algorithms.distgraph import unshard_vertex_array as _unshard

_gq = rmat(7, 8, seed=3)
_att_q = dgas.block_rule(_gq.n_rows, S)
_gsh_q, _ = shard_graph(_gq, S, row_att=_att_q)
_srcs = np.array([0, 5, 33, 64, 100, 127], np.int32)

# msbfs lanes == per-source bfs_distributed, bit for bit (packed-word routing)
_lv_b = np.asarray(msbfs_distributed(_gsh_q, _att_q, _srcs, mesh))
_ok = all(np.array_equal(_lv_b[:, b, :],
                         np.asarray(bfs_distributed(_gsh_q, _att_q, int(s), mesh)))
          for b, s in enumerate(_srcs))
check("msbfs_distributed/partition_identity", _ok)

# distributed batched lanes == single-device batched lanes (unsharded)
_lv_l = np.asarray(_msbfs(_gq, _srcs))
_ok = all(np.array_equal(
    np.asarray(_unshard(jnp.asarray(_lv_b[:, b, :]), _att_q)), _lv_l[b])
    for b in range(len(_srcs)))
check("msbfs_distributed/matches_single_device", _ok)

# batched delta-stepping: remote atomic-min carries all lanes per exchange
_d_b = np.asarray(sssp_batched_distributed(_gsh_q, _att_q, _srcs, mesh,
                                           delta=1.0))
_ok = all(np.array_equal(_d_b[:, b, :],
                         np.asarray(sssp_distributed(_gsh_q, _att_q, int(s),
                                                     mesh, delta=1.0)))
          for b, s in enumerate(_srcs))
check("sssp_batched_distributed/partition_identity", _ok)

# batched remote_scatter_or == local segment_or semantics
_natt = 64
_att_or = dgas.interleave_rule(_natt, S)
_gidx = rng.integers(0, _natt, (S, 16)).astype(np.int32)
_words = rng.integers(0, 2**32, (S, 16, 2), dtype=np.uint64).astype(np.uint32)
_fn_or = shard_map(
    lambda gi, wo: offload.remote_scatter_or(
        _att_or.per_shard, gi[0], wo[0], _att_or, "cores", capacity=16 * S)[None],
    mesh=mesh, in_specs=(spec, spec), out_specs=spec)
_got = np.asarray(_fn_or(jnp.asarray(_gidx), jnp.asarray(_words)))
_expect = np.zeros((_natt, 2), np.uint32)
for _s in range(S):
    for _i in range(16):
        _expect[_gidx[_s, _i]] |= _words[_s, _i]
_got_global = np.zeros_like(_expect)
for _v in range(_natt):
    _got_global[_v] = _got[_att_or.owner(jnp.asarray(_v)),
                           _att_or.local(jnp.asarray(_v))]
check("remote_scatter_or/interleave", np.array_equal(_got_global, _expect))

# batched fallback counter still fires on toy graphs (stats plumbing)
_, _st_b = msbfs_distributed(_gsh_q, _att_q, _srcs, mesh, return_stats=True)
check("msbfs_distributed/stats_shape",
      all(int(np.asarray(_st_b[k])[0]) >= 0
          for k in ("iters", "pushes", "pulls", "fallbacks"))
      and int(np.asarray(_st_b["pulls"])[0]) == 0)

# --- the query service on the sharded engine (PR 5, DESIGN §14) --------------
# GraphService(mesh=...) must serve reach/dist through run_batched_distributed
# and agree with the local placement query-for-query.
from repro.core import GraphService, Reachability, Distance, PPRTopK

_svc_d = GraphService(_gq, batch_budget=8, mesh=mesh)
_svc_l = GraphService(_gq, batch_budget=8)
_qrng = np.random.default_rng(5)
_nq = _gq.n_rows
_stream = [Reachability(int(s), int(t)) for s, t in
           zip(_qrng.integers(0, _nq, 10), _qrng.integers(0, _nq, 10))]
_stream += [Distance(int(s), int(t)) for s, t in
            zip(_qrng.integers(0, _nq, 6), _qrng.integers(0, _nq, 6))]
_ok_r = all(_svc_d.query(q, deadline=120.0) == _svc_l.query(q)
            for q in _stream if isinstance(q, Reachability))
check("service_distributed/reach_matches_local", _ok_r)
_ok_d = all(abs(_svc_d.query(q, deadline=120.0) - _svc_l.query(q)) < 1e-4
            or _svc_d.query(q) == _svc_l.query(q)   # inf == inf
            for q in _stream if isinstance(q, Distance))
check("service_distributed/dist_matches_local", _ok_d)
# PPR stays on the local placement under a mesh — same answers either way
_ids_d, _sc_d = _svc_d.query(PPRTopK(3, k=4))
_ids_l, _sc_l = _svc_l.query(PPRTopK(3, k=4))
check("service_distributed/ppr_local_fallback",
      np.array_equal(_ids_d, _ids_l) and np.allclose(_sc_d, _sc_l))
check("service_distributed/deadline_miss_rate_zero",
      _svc_d.stats.deadline_miss_rate == 0.0
      and _svc_d.stats.deadline_queries >= 16)
check("service_distributed/route_bytes_measured",
      _svc_d.stats.route_bytes > 0 and _svc_d.stats.push_levels > 0
      and _svc_d.stats.n_model_shards == S)

# --- async placement: bounded-staleness pacing at S=8 (PR 7) -----------------
# Same partitions must fall out of the paced schedule: K collective-free
# micro-steps per global check, remote updates deferred in the dense outbox
# and delivered by one buffered_flush — the monotone combines make the stale
# reads invisible in the fixpoint.

for _k in (1, 2, 8):
    _lv_a = bfs_distributed(gsh2, att2, 0, mesh, axis="cores",
                            placement="async", sync_interval=_k)
    check(f"bfs_async/k{_k}",
          np.array_equal(np.asarray(unshard_vertex_array(_lv_a, att2)),
                         lv_local))
    _d_a = sssp_distributed(gsh2, att2, 0, mesh, axis="cores", delta=0.5,
                            max_iters=4 * g.n_rows, placement="async",
                            sync_interval=_k)
    check(f"sssp_async/k{_k}",
          np.allclose(np.asarray(unshard_vertex_array(_d_a, att2)), d_local,
                      atol=0, equal_nan=True))

_lab_a = connected_components_distributed(gshs, atts, mesh, axis="cores",
                                          placement="async", sync_interval=8)
check("cc_async/k8",
      np.array_equal(np.asarray(unshard_vertex_array(_lab_a, atts)),
                     lab_local))

_lv_ab, _st_a = msbfs_distributed(_gsh_q, _att_q, _srcs, mesh,
                                  placement="async", sync_interval=8,
                                  return_stats=True)
check("msbfs_async/partition_identity",
      np.array_equal(np.asarray(_lv_ab), _lv_b))

# the paced schedule's collective budget, on measured traces: sync pays
# level_collectives() per level (delta-stepping adds 2 bucket pmins), async
# pays 2 per flush.  SSSP must clear the 4x acceptance bar; BFS hops cross
# shards only at a flush, so its win is the per-check collective count
# (5 -> 2) — gate > 1x there.
_, _st_s = msbfs_distributed(_gsh_q, _att_q, _srcs, mesh, return_stats=True)
_sync_red = int(np.asarray(_st_s["iters"])[0]) \
    * _traffic.level_collectives(placement="sync")
_async_red = int(np.asarray(_st_a["pushes"])[0]) \
    * _traffic.level_collectives(placement="async")
check("async/bfs_fewer_reductions", _sync_red > _async_red > 0)
_, _st_ss = sssp_batched_distributed(_gsh_q, _att_q, _srcs, mesh, delta=1.0,
                                     return_stats=True)
_, _st_sa = sssp_batched_distributed(_gsh_q, _att_q, _srcs, mesh, delta=1.0,
                                     return_stats=True, placement="async",
                                     sync_interval=8)
_sync_red = int(np.asarray(_st_ss["iters"])[0]) \
    * _traffic.level_collectives(placement="sync", program_collectives=2)
_async_red = int(np.asarray(_st_sa["pushes"])[0]) \
    * _traffic.level_collectives(placement="async")
check("async/sssp_reduction_ratio_4x", _sync_red >= 4 * _async_red > 0)
check("async/stats_shape",
      int(np.asarray(_st_a["pulls"])[0]) == 0
      and int(np.asarray(_st_a["fallbacks"])[0]) == 0
      and int(np.asarray(_st_a["iters"])[0])
      >= int(np.asarray(_st_a["pushes"])[0]))

# the service serves identical answers under placement='async'
_svc_a = GraphService(_gq, batch_budget=8, mesh=mesh, placement="async",
                      sync_interval=8)
_ok_a = all(_svc_a.query(q) == _svc_l.query(q)
            for q in _stream if isinstance(q, Reachability))
_ok_ad = all(_svc_a.query(q) == _svc_l.query(q)  # exact: min-combine floats
             for q in _stream if isinstance(q, Distance))
check("service_async/matches_local", _ok_a and _ok_ad)
check("service_async/route_bytes_measured", _svc_a.stats.route_bytes > 0)

# --- streaming updates: incremental reshard + distributed repair (PR 8) ------
from repro.core import GraphHandle
from repro.core.algorithms import bfs_repair_distributed, cc_repair_distributed
from repro.core.algorithms.distgraph import update_shards

_h0 = GraphHandle.wrap(_gq, n_partitions=S)
_urng = np.random.default_rng(17)
_k = 24
_ins = (_urng.integers(0, _nq, _k), _urng.integers(0, _nq, _k),
        _urng.uniform(1e-4, 1e-3, _k).astype(np.float32))
_h1, _rep = _h0.apply(_ins)
check("streaming/monotone_safe_batch", _rep.monotone_safe)

# incremental touched-shard reshard == full reshard, bit for bit
_touched = np.unique(np.asarray(_att_q.owner(
    jnp.asarray(_rep.changed_sources, jnp.int32))))
_gsh_up = update_shards(_gsh_q, _h1.csr, _att_q, _touched)
_gsh_full, _ = shard_graph(_h1.csr, S, row_att=_att_q)
if _gsh_up is None:   # padding overflow: the documented full-reshard fallback
    _gsh_up = _gsh_full
check("streaming/update_shards_matches_full",
      np.array_equal(np.asarray(_gsh_up.src), np.asarray(_gsh_full.src))
      and np.array_equal(np.asarray(_gsh_up.dst), np.asarray(_gsh_full.dst))
      and np.array_equal(np.asarray(_gsh_up.val), np.asarray(_gsh_full.val)))

# distributed BFS repair: warm-start from the pre-update fixpoint, seeded by
# the changed endpoints — partition-identical to local scratch on the
# updated graph
_prev_lv = bfs_distributed(_gsh_q, _att_q, 0, mesh, axis="cores")
_lv_rep = bfs_repair_distributed(_gsh_up, _att_q, _prev_lv,
                                 _rep.changed_sources, mesh, axis="cores")
check("streaming/bfs_repair_distributed",
      np.array_equal(np.asarray(unshard_vertex_array(_lv_rep, _att_q)),
                     np.asarray(bfs(_h1.csr, 0))))

# distributed CC repair on the symmetrized updated edge set
_gsym0 = symmetrize(_gq)
_att_s = dgas.block_rule(_gsym0.n_rows, S)
_gshs0, _ = shard_graph(_gsym0, S, row_att=_att_s)
_prev_lab = connected_components_distributed(_gshs0, _att_s, mesh,
                                             axis="cores")
_gshs1, _ = shard_graph(symmetrize(_h1.csr), S, row_att=_att_s)
_lab_rep = cc_repair_distributed(_gshs1, _att_s, _prev_lab,
                                 _rep.changed_vertices, mesh, axis="cores")
check("streaming/cc_repair_distributed",
      np.array_equal(np.asarray(unshard_vertex_array(_lab_rep, _att_s)),
                     np.asarray(connected_components(_h1.csr))))

# the mesh service ingests the same batch and stays partition-identical to a
# fresh local service on the updated graph
_svc_d.apply_updates(inserts=_ins)
_svc_fresh = GraphService(_h1.csr, batch_budget=8)
check("streaming/service_epoch_bumped", _svc_d.epoch == 1)
_ok_r = all(_svc_d.query(q) == _svc_fresh.query(q)
            for q in _stream if isinstance(q, Reachability))
_ok_d = all(_svc_d.query(q) == _svc_fresh.query(q)   # min-combine floats
            for q in _stream if isinstance(q, Distance))
check("streaming/service_apply_updates_matches_local", _ok_r and _ok_d)

print("FAILURES(final):", failures, flush=True)
sys.exit(1 if failures else 0)
