"""Graph query service: result correctness per query kind, micro-batch
grouping and ordering (round-robin across kinds), deadline-aware admission,
lane dedup/occupancy accounting, LRU cache behavior across graph epochs, and
the route-byte ledger."""
import numpy as np
import pytest

from repro.core import (Distance, GraphService, NeighborSample, PPRTopK,
                        Reachability, rmat, uniform_random_graph)
from repro.core.algorithms import bfs, ppr, sssp

G = rmat(7, 8, seed=11)


class FakeClock:
    """Deterministic time source for deadline-admission tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make_service(**kw):
    kw.setdefault("batch_budget", 4)
    kw.setdefault("cache_capacity", 32)
    return GraphService(G, **kw)


# ---------------------------------------------------------------------------
# per-kind correctness against the direct algorithms
# ---------------------------------------------------------------------------

def test_reachability_matches_bfs():
    svc = make_service()
    lv = np.asarray(bfs(G, 3))
    assert svc.query(Reachability(3, 40)) == bool(lv[40] >= 0)
    unreachable = int(np.argmin(lv)) if (lv < 0).any() else None
    if unreachable is not None:
        assert svc.query(Reachability(3, unreachable)) is False


def test_distance_matches_sssp():
    svc = make_service()
    d = np.asarray(sssp(G, 5, delta=svc.delta))
    assert svc.query(Distance(5, 60)) == float(d[60])


def test_ppr_topk_matches_ppr():
    svc = make_service()
    ids, scores = svc.query(PPRTopK(2, k=5))
    full = np.asarray(ppr(G, 2, iters=svc.ppr_iters))
    np.testing.assert_allclose(np.sort(scores)[::-1],
                               np.sort(full)[::-1][:5], rtol=1e-6)
    assert ids.shape == (5,) and scores.shape == (5,)


def test_neighbor_sample_draws_real_neighbors():
    svc = make_service()
    indptr = np.asarray(G.indptr)
    v = int(np.argmax(np.diff(indptr)))  # a vertex with many neighbors
    nbrs = np.asarray(G.indices)[indptr[v]: indptr[v + 1]]
    out = svc.query(NeighborSample(v, fanout=4))
    assert out.shape == (4,)
    assert set(out.tolist()) <= set(nbrs.tolist())


def test_neighbor_sample_fanout_over_budget_rejected():
    svc = make_service(batch_budget=2)
    with pytest.raises(ValueError, match="fanout"):
        svc.submit(NeighborSample(0, fanout=3))


def test_unknown_query_type_rejected():
    svc = make_service()
    with pytest.raises(TypeError):
        svc.submit(("reach", 0, 1))


def test_out_of_range_vertex_rejected():
    svc = make_service()
    with pytest.raises(ValueError, match="outside"):
        svc.submit(Reachability(0, G.n_rows))
    with pytest.raises(ValueError, match="outside"):
        svc.submit(NeighborSample(-1))
    with pytest.raises(ValueError, match="PPRTopK.k"):
        svc.submit(PPRTopK(0, k=svc.ppr_k_max + 1))
    with pytest.raises(ValueError, match="PPRTopK.k"):
        svc.submit(PPRTopK(0, k=0))
    with pytest.raises(ValueError, match="fanout"):
        svc.submit(NeighborSample(0, fanout=0))


def test_update_graph_flushes_pending_against_old_graph():
    # admitted queries execute on the graph they were validated against
    small = uniform_random_graph(16, 3, seed=2)
    svc = make_service()
    old_delta = svc.delta
    t = svc.submit(Distance(100, 40))   # valid on G, out of range on `small`
    svc.update_graph(small)             # must flush t against G first
    ref = float(np.asarray(sssp(G, 100, delta=old_delta))[40])
    assert svc.result(t) == ref


# ---------------------------------------------------------------------------
# micro-batching: grouping, ordering, occupancy
# ---------------------------------------------------------------------------

def test_mixed_stream_results_in_submission_order():
    svc = make_service(batch_budget=3)
    queries = [Reachability(0, 5), Distance(1, 9), Reachability(2, 7),
               PPRTopK(3, k=2), Distance(4, 11), Reachability(6, 1),
               NeighborSample(0, fanout=2)]
    tickets = [svc.submit(q) for q in queries]
    done = svc.flush()
    assert done == sorted(tickets)
    # every ticket resolves, and each against its own query's reference
    for t, q in zip(tickets, queries):
        r = svc.result(t)
        if isinstance(q, Reachability):
            assert r == bool(np.asarray(bfs(G, q.source))[q.target] >= 0)
        elif isinstance(q, Distance):
            assert r == float(np.asarray(sssp(G, q.source,
                                              delta=svc.delta))[q.target])


def test_batches_group_by_kind_up_to_budget():
    svc = make_service(batch_budget=4)
    for s in range(6):
        svc.submit(Reachability(s, (s + 1) % G.n_rows))
    svc.flush()
    # 6 distinct sources under budget 4 -> 2 batches (4 + 2 lanes)
    assert svc.stats.batches == 2
    assert svc.stats.lanes_used == 6
    assert svc.stats.queries == 6
    assert 0 < svc.stats.occupancy <= 1


def test_duplicate_sources_share_a_lane():
    svc = make_service(batch_budget=4)
    for t in range(5):
        svc.submit(Reachability(7, t))
    svc.flush()
    assert svc.stats.batches == 1        # five queries, one lane
    assert svc.stats.lanes_used == 1
    assert svc.stats.queries == 5


def test_ppr_mixed_k_share_one_runner():
    svc = make_service(batch_budget=4)
    t1 = svc.submit(PPRTopK(0, k=2))
    t2 = svc.submit(PPRTopK(1, k=6))
    svc.flush()
    ids1, sc1 = svc.result(t1)
    ids2, sc2 = svc.result(t2)
    assert ids1.shape == (2,) and ids2.shape == (6,)
    svc.query(PPRTopK(2, k=3))  # a third k must not add a runner
    assert len([k for k in svc._runners if k[0] == "ppr"]) == 1


def test_unclaimed_results_are_bounded():
    svc = make_service(batch_budget=2, results_capacity=3)
    tickets = [svc.submit(Reachability(s, 0)) for s in range(5)]
    svc.flush()
    assert len(svc._results) == 3             # oldest two evicted
    assert svc.result(tickets[-1]) is not None
    with pytest.raises(KeyError):
        svc.result(tickets[0])


# ---------------------------------------------------------------------------
# round-robin kind selection (head-of-line fix) + deadline-aware admission
# ---------------------------------------------------------------------------

def test_round_robin_prevents_head_of_line_blocking():
    """A burst of one kind must not starve the others: each rotation serves
    every pending kind one batch before the burst continues."""
    svc = make_service(batch_budget=2)
    order = []
    orig = svc._execute
    svc._execute = lambda kind, batch, lanes: (order.append(kind),
                                               orig(kind, batch, lanes))[1]
    for s in range(6):                       # 3 budget-2 batches of reach
        svc.submit(Reachability(s, 0))
    svc.submit(Distance(0, 1))
    svc.submit(PPRTopK(1, k=2))
    done = svc.flush()
    assert len(done) == 8
    # dist and ppr are served inside the first rotation, not after the burst
    assert order[:3] == ["reach", "dist", "ppr"]
    assert order[3:] == ["reach", "reach"]


def test_no_autoflush_without_deadlines():
    """Deadline-free admission keeps the explicit-flush contract (the
    pre-PR-5 behavior): submissions alone never trigger execution."""
    svc = make_service(batch_budget=2)
    for s in range(5):
        svc.submit(Reachability(s, 0))
    assert svc.stats.batches == 0 and not svc._results


def test_poll_noop_while_slack_remains():
    clk = FakeClock()
    svc = make_service(clock=clk)
    t = svc.submit(Reachability(0, 1), deadline=50.0)
    assert svc.poll() == []
    assert svc.stats.batches == 0
    clk.t = 50.0                    # slack (est cost 0) exhausted exactly now
    assert svc.poll() == [t]
    assert svc.result(t) == bool(np.asarray(bfs(G, 0))[1] >= 0)
    assert svc.stats.deadline_queries == 1
    assert svc.stats.deadline_misses == 0   # served at, not after, the SLO


def test_deadline_armed_flushes_on_full_batch():
    clk = FakeClock()
    svc = make_service(batch_budget=2, clock=clk)
    svc.submit(Reachability(0, 1), deadline=100.0)
    t2 = svc.submit(Reachability(1, 2), deadline=100.0)  # fills the budget
    assert svc.stats.batches == 1          # flushed on admission
    assert t2 in svc._results


def test_negative_slack_flushes_on_admission():
    """A learned batch-cost estimate tightens the slack: a deadline shorter
    than the estimated execution cannot wait at all."""
    clk = FakeClock()
    svc = make_service(clock=clk)
    svc._cost_ewma["reach"] = 2.0
    t = svc.submit(Reachability(0, 1), deadline=1.5)
    assert t in svc._results               # served the moment it was admitted


def test_deadline_miss_and_latency_accounting():
    clk = FakeClock()
    svc = make_service(clock=clk)
    svc.submit(Distance(0, 1), deadline=5.0)
    clk.t = 20.0                            # client polled far too late
    svc.poll()
    st = svc.stats
    assert st.deadline_queries == 1 and st.deadline_misses == 1
    assert st.deadline_miss_rate == 1.0
    # percentiles come from the log-bucketed sketch (PR 9): the reported
    # value is the observation's bucket upper edge, within one 12% bucket
    assert 20e3 <= st.latency_p50_ms <= 20e3 * st.latency_hist.growth
    assert 20e3 <= st.latency_p95_ms <= 20e3 * st.latency_hist.growth
    d = st.as_dict()
    assert {"latency_p50_ms", "latency_p95_ms",
            "deadline_miss_rate"} <= set(d)


def test_cost_ewma_learns_from_measured_batches():
    svc = make_service()                    # real clock
    svc.query(Reachability(0, 1))
    first = svc._cost_ewma["reach"]
    assert first > 0
    svc.query(Reachability(1, 2))
    assert svc._cost_ewma["reach"] > 0      # EWMA keeps tracking


def test_cost_seed_warms_the_ewma():
    """An explicit cost_seed becomes the EWMA's starting estimate (PR 7):
    deadline slack is computed from it before any batch has run, and a
    measured batch blends into — not replaces — the prior."""
    svc = make_service(cost_seed={"reach": 2.0, "dist": 0.25})
    assert svc._est_cost("reach") == 2.0 and svc._est_cost("dist") == 0.25
    assert svc._est_cost("ppr") == 0.0      # unseeded kinds stay unknown
    # a seeded cost shorter than the deadline's slack defers the flush; one
    # longer forces it on admission (the test_negative_slack rule, but from
    # the seed rather than a measured batch)
    clk = FakeClock()
    svc2 = make_service(clock=clk, cost_seed={"reach": 2.0})
    t = svc2.submit(Reachability(0, 1), deadline=1.5)
    assert t in svc2._results               # served the moment it was admitted
    svc3 = make_service(clock=clk, cost_seed={"reach": 2.0})
    svc3.submit(Reachability(0, 1), deadline=10.0)
    assert svc3.stats.batches == 0          # slack remains: batch-fill wait
    # EWMA update blends the measurement with the seed rather than replacing
    # it: under the fake clock a batch measures 0 s, so exactly (1-a)*seed
    svc3.flush()
    a = GraphService.COST_EWMA_ALPHA
    assert svc3._cost_ewma["reach"] == pytest.approx((1 - a) * 2.0)


def test_cost_seed_auto_reads_newest_bench_doc(tmp_path, monkeypatch):
    """cost_seed='auto' resolves through load_cost_priors: the newest
    BENCH_pr<N>.json wins, the local section prices a batch as budget/qps,
    and a missing/unusable doc degrades to the unseeded behavior."""
    import json
    from repro.core.service import load_cost_priors
    (tmp_path / "BENCH_pr6.json").write_text(json.dumps(
        {"service": {"budgets": {"4": {"qps": 1.0}}}}))
    (tmp_path / "BENCH_pr7.json").write_text(json.dumps(
        {"service": {"budgets": {"4": {"qps": 100.0}}},
         "service_distributed": {"budgets": {
             "4": {"latency_p50_ms": 500.0}}}}))
    pri = load_cost_priors(budget=4, bench_dir=str(tmp_path))
    assert pri["reach"] == pytest.approx(4 / 100.0)   # pr7, not pr6
    pri_d = load_cost_priors(distributed=True, budget=4,
                             bench_dir=str(tmp_path))
    assert pri_d["dist"] == pytest.approx(0.5)
    assert load_cost_priors(budget=999, bench_dir=str(tmp_path)) == {}
    assert load_cost_priors(bench_dir=str(tmp_path / "nowhere")) == {}
    monkeypatch.chdir(tmp_path)
    svc = make_service(cost_seed="auto")
    assert svc._est_cost("reach") == pytest.approx(4 / 100.0)


def test_deadline_validation():
    svc = make_service()
    with pytest.raises(ValueError, match="deadline"):
        svc.submit(Reachability(0, 1), deadline=-1.0)


def test_deadline_full_check_mirrors_sample_packing():
    """Fanout slots must replay _collect's greedy packing, not a plain sum:
    one fanout-3 query in a budget-4 batch leaves room, so no auto-flush;
    a second fanout-3 cannot join that batch, so the head batch is as full
    as it can get and the flush fires."""
    clk = FakeClock()
    svc = make_service(batch_budget=4, clock=clk)
    svc.submit(NeighborSample(0, fanout=3), deadline=100.0)
    assert svc.stats.batches == 0
    svc.submit(NeighborSample(1, fanout=3), deadline=100.0)
    assert svc.stats.batches >= 1


def test_deadline_full_check_ignores_cache_hits():
    """Queries that will be served from the cache occupy no lane, so they
    must not count toward the batch-full admission trigger."""
    clk = FakeClock()
    svc = make_service(batch_budget=2, clock=clk)
    svc.query(Reachability(0, 1))                    # now cached
    batches = svc.stats.batches
    svc.submit(Reachability(0, 1), deadline=100.0)   # pure cache hit
    svc.submit(Reachability(1, 2), deadline=100.0)   # one real lane of two
    assert svc.stats.batches == batches              # not full: no auto-flush


# ---------------------------------------------------------------------------
# cache + epochs
# ---------------------------------------------------------------------------

def test_cache_hit_skips_execution_and_counts():
    svc = make_service()
    q = Reachability(1, 8)
    first = svc.query(q)
    batches_before = svc.stats.batches
    again = svc.query(q)
    assert again == first
    assert svc.stats.cache_hits == 1
    assert svc.stats.batches == batches_before  # no new engine pass
    assert svc.stats.hit_rate > 0


def test_cache_invalidated_across_epochs():
    g2 = uniform_random_graph(G.n_rows, 3, seed=1)
    svc = make_service()
    q = Distance(0, 9)
    r1 = svc.query(q)
    epoch = svc.update_graph(g2)
    assert epoch == 1
    r2 = svc.query(q)
    ref2 = float(np.asarray(sssp(g2, 0, delta=svc.delta))[9])
    assert r2 == ref2
    assert svc.stats.cache_hits == 0  # epoch bump means a true recompute
    # the old graph's answer is not served, even if it differed
    if r1 != r2:
        assert svc.query(q) == r2  # and the *new* answer now caches
        assert svc.stats.cache_hits == 1


def test_cached_sample_is_stable_until_epoch_moves():
    svc = make_service()
    q = NeighborSample(2, fanout=3, seed=5)
    s1 = svc.query(q)
    s2 = svc.query(q)                  # LRU hit
    np.testing.assert_array_equal(s1, s2)
    svc._cache.clear()                 # simulate eviction
    s3 = svc.query(q)                  # recomputed draw is keyed identically
    np.testing.assert_array_equal(s1, s3)


def test_lru_evicts_oldest():
    svc = make_service(batch_budget=1, cache_capacity=2)
    svc.query(Reachability(0, 1))
    svc.query(Reachability(1, 2))
    svc.query(Reachability(2, 3))      # evicts (0, 1)
    hits_before = svc.stats.cache_hits
    svc.query(Reachability(1, 2))      # still cached
    assert svc.stats.cache_hits == hits_before + 1
    svc.query(Reachability(0, 1))      # was evicted -> recompute
    assert svc.stats.cache_hits == hits_before + 1


def test_zero_capacity_disables_cache():
    svc = make_service(cache_capacity=0)
    q = Reachability(0, 3)
    svc.query(q)
    svc.query(q)
    assert svc.stats.cache_hits == 0


# ---------------------------------------------------------------------------
# stats ledger
# ---------------------------------------------------------------------------

def test_stats_ledger_accumulates_and_resets():
    svc = make_service()
    svc.query(Reachability(0, 1))
    svc.query(Distance(0, 1))
    st = svc.stats
    assert st.queries == 2 and st.batches == 2
    assert st.route_bytes > 0 and st.route_bytes_per_query > 0
    assert st.busy_s > 0 and st.qps > 0
    d = st.as_dict()
    assert set(d) >= {"qps", "occupancy", "hit_rate", "route_bytes_per_query"}
    svc.reset_stats()
    assert svc.stats.queries == 0 and svc.stats.route_bytes == 0


# ---------------------------------------------------------------------------
# streaming updates: partition-scoped invalidation (DESIGN.md §16)
# ---------------------------------------------------------------------------

def _two_blob_graph(n=256, seed=21):
    """Two disconnected 128-vertex blobs: queries inside blob B (vertices
    128..255, partitions 4..7 under the default 8-partition block rule)
    can never touch blob A's partitions."""
    from repro.core import CSR
    half = n // 2
    a = uniform_random_graph(half, 3, seed=seed)
    b = uniform_random_graph(half, 3, seed=seed + 1)

    def coo(g, off):
        indptr = np.asarray(g.indptr)
        rows = np.repeat(np.arange(half), np.diff(indptr)) + off
        return rows, np.asarray(g.indices) + off, np.asarray(g.values)

    ra, ca, va = coo(a, 0)
    rb, cb, vb = coo(b, half)
    return CSR.from_coo(np.concatenate([ra, rb]), np.concatenate([ca, cb]),
                        np.concatenate([va, vb]), n, n)


def test_apply_updates_keeps_untouched_partition_entries():
    g = _two_blob_graph()
    svc = GraphService(g, batch_budget=4, cache_capacity=64)
    qa = [Reachability(1, 40), Distance(2, 50)]            # blob A
    qb = [Reachability(130, 170), Distance(140, 200),      # blob B
          Reachability(150, 255), Distance(160, 129)]
    for q in qa + qb:
        svc.query(q)
    n_cached = len(svc._cache)
    assert n_cached == len(qa) + len(qb)
    # insert an edge confined to blob A's first partition (vertices 0..31)
    rep = svc.apply_updates(inserts=(np.array([3]), np.array([4]),
                                     np.array([1e-4], np.float32)))
    assert svc.epoch == 1
    assert sorted(rep.touched_partitions.tolist()) == [0]
    # blob B entries survive (>= 50% of the cache), blob A entries are gone
    assert len(svc._cache) >= n_cached // 2
    hits_before = svc.stats.cache_hits
    batches_before = svc.stats.batches
    for q in qb:
        svc.query(q)
    assert svc.stats.cache_hits == hits_before + len(qb)
    assert svc.stats.batches == batches_before     # served from cache
    # blob A entries recompute against the updated graph
    svc.query(qa[0])
    assert svc.stats.batches == batches_before + 1
    lv = np.asarray(bfs(svc.csr, 1))
    assert svc.query(qa[0]) == bool(lv[40] >= 0)


def test_apply_updates_correctness_and_ledger():
    svc = make_service()
    d_before = svc.query(Distance(5, 60))
    rb_before = svc.stats.route_bytes
    # a tiny-weight shortcut 5 -> 60 must change the served distance
    rep = svc.apply_updates(inserts=(np.array([5]), np.array([60]),
                                     np.array([1e-4], np.float32)))
    assert rep.monotone_safe and svc.epoch == 1
    assert svc.stats.updates == 1 and svc.stats.update_edges >= 1
    assert svc.stats.route_bytes > rb_before       # ingest reshard is priced
    d_after = svc.query(Distance(5, 60))
    ref = float(np.asarray(sssp(svc.csr, 5, delta=svc.delta))[60])
    assert d_after == ref and d_after <= d_before


def test_update_graph_is_deprecated_shim():
    svc = make_service()
    svc.query(Reachability(0, 5))
    g2 = uniform_random_graph(G.n_rows, 3, seed=9)
    with pytest.warns(DeprecationWarning):
        epoch = svc.update_graph(g2)
    assert epoch == 1 and svc.epoch == 1
    assert len(svc._cache) == 0        # whole-graph swap stamps everything
