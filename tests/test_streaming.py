"""Streaming-graph tests (DESIGN.md §16): GraphHandle delta ingestion,
epoch/stamp bookkeeping, incremental monotone repair, and the golden
update-stream replay.

The reference model for splice semantics is an edge *dict* (last write
wins) rebuilt through ``CSR.from_coo`` — the overlay splice must be
bit-identical to that clean rebuild at every step (that identity is what
makes ``compact()`` a no-op on the arrays and repair seeds trustworthy).
"""
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSR, GraphHandle, rmat, uniform_random_graph
from repro.core.algorithms import (auto_delta, bfs, bfs_repair, cc_repair,
                                   connected_components, repair_or_recompute,
                                   sssp, sssp_repair)

GOLDEN = Path(__file__).parent / "golden" / "streaming.npz"


# ---------------------------------------------------------------------------
# reference model: edge dict -> from_coo rebuild
# ---------------------------------------------------------------------------

def edges_of(csr):
    indptr = np.asarray(csr.indptr)
    rows = np.repeat(np.arange(csr.n_rows), np.diff(indptr))
    cols = np.asarray(csr.indices)
    vals = (np.asarray(csr.values) if csr.values is not None
            else np.ones_like(cols, np.float32))
    return {(int(r), int(c)): float(v) for r, c, v in zip(rows, cols, vals)}


def rebuild(edges, n):
    if edges:
        rows, cols = map(np.asarray, zip(*sorted(edges)))
        vals = np.asarray([edges[k] for k in sorted(edges)], np.float32)
    else:
        rows = cols = np.zeros(0, np.int64)
        vals = np.zeros(0, np.float32)
    return CSR.from_coo(rows, cols, vals, n, n)


def model_apply(edges, inserts=None, deletes=None):
    """GraphHandle.apply semantics on the dict: deletes first, duplicate
    inserts last-wins, upserts replace."""
    if deletes is not None:
        for r, c in zip(*[np.asarray(a, np.int64) for a in deletes]):
            edges.pop((int(r), int(c)), None)
    if inserts is not None:
        ins = [np.asarray(a) for a in inserts]
        vals = (ins[2].astype(np.float32) if len(ins) == 3
                else np.ones(len(ins[0]), np.float32))
        for r, c, v in zip(ins[0], ins[1], vals):
            edges[(int(r), int(c))] = float(v)
    return edges


def assert_csr_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.indptr), np.asarray(b.indptr))
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    if a.values is None or b.values is None:
        assert a.values is None and b.values is None
    else:
        np.testing.assert_array_equal(np.asarray(a.values),
                                      np.asarray(b.values))


# ---------------------------------------------------------------------------
# splice semantics
# ---------------------------------------------------------------------------

def test_apply_matches_reference_model_random_stream():
    g = uniform_random_graph(60, 3, seed=1)
    handle = GraphHandle.wrap(g, n_partitions=4)
    edges = edges_of(handle.csr)
    rng = np.random.default_rng(3)
    for _ in range(12):
        k, d = int(rng.integers(1, 15)), int(rng.integers(0, 6))
        ins = (rng.integers(0, 60, k), rng.integers(0, 60, k),
               rng.random(k).astype(np.float32))
        dele = (rng.integers(0, 60, d), rng.integers(0, 60, d))
        handle, _ = handle.apply(ins, dele)
        edges = model_apply(edges, ins, dele)
        assert_csr_equal(handle.csr, rebuild(edges, 60))


def test_apply_duplicates_self_loops_and_reinsert():
    g = uniform_random_graph(10, 2, seed=0)
    handle = GraphHandle.wrap(g, n_partitions=2)
    edges = edges_of(handle.csr)
    # duplicate inserts in one batch: LAST occurrence wins; self-loop is an
    # ordinary edge
    ins = (np.array([3, 3, 5]), np.array([7, 7, 5]),
           np.array([0.25, 0.75, 0.5], np.float32))
    handle, rep = handle.apply(ins)
    edges = model_apply(edges, ins)
    assert_csr_equal(handle.csr, rebuild(edges, 10))
    assert edges[(3, 7)] == 0.75 and (5, 5) in edges
    # deleting a missing edge is a no-op; delete-then-reinsert in separate
    # batches round-trips
    handle2, rep2 = handle.apply(deletes=(np.array([9, 3]), np.array([9, 7])))
    edges = model_apply(edges, deletes=(np.array([9, 3]), np.array([9, 7])))
    assert_csr_equal(handle2.csr, rebuild(edges, 10))
    assert rep2.n_deleted == 1          # only (3,7) existed
    handle3, _ = handle2.apply((np.array([3]), np.array([7]),
                                np.array([0.75], np.float32)))
    edges = model_apply(edges, (np.array([3]), np.array([7]),
                                np.array([0.75], np.float32)))
    assert_csr_equal(handle3.csr, rebuild(edges, 10))


def test_apply_bounds_validation():
    handle = GraphHandle.wrap(uniform_random_graph(8, 2, seed=0))
    with pytest.raises(ValueError):
        handle.apply((np.array([8]), np.array([0])))
    with pytest.raises(ValueError):
        handle.apply(deletes=(np.array([0]), np.array([-1])))


def test_compact_roundtrip_bit_identical():
    handle = GraphHandle.wrap(uniform_random_graph(40, 3, seed=2),
                              n_partitions=4)
    rng = np.random.default_rng(5)
    for _ in range(4):
        k = int(rng.integers(1, 10))
        handle, _ = handle.apply((rng.integers(0, 40, k),
                                  rng.integers(0, 40, k),
                                  rng.random(k).astype(np.float32)))
    compacted = handle.compact()
    assert_csr_equal(handle.csr, compacted.csr)     # splice kept it canonical
    assert compacted.delta.size == 0
    assert compacted.epoch == handle.epoch


def test_threshold_triggers_compaction():
    handle = GraphHandle.wrap(uniform_random_graph(30, 2, seed=4),
                              n_partitions=2, compact_threshold=0.05)
    rng = np.random.default_rng(9)
    saw_compaction = False
    for _ in range(6):
        k = 8
        handle, rep = handle.apply((rng.integers(0, 30, k),
                                    rng.integers(0, 30, k),
                                    rng.random(k).astype(np.float32)))
        if rep.compacted:
            saw_compaction = True
            assert handle.delta.size == 0
    assert saw_compaction


# ---------------------------------------------------------------------------
# epoch & stamp bookkeeping
# ---------------------------------------------------------------------------

def test_epoch_monotone_and_stamps_partition_scoped():
    n = 64
    handle = GraphHandle.wrap(uniform_random_graph(n, 2, seed=6),
                              n_partitions=8)
    assert handle.epoch == 0 and (handle.stamps == 0).all()
    # an update confined to partition 0 (vertices 0..7) stamps only it
    h1, rep = handle.apply((np.array([1, 2]), np.array([3, 4]),
                            np.array([0.1, 0.2], np.float32)))
    assert h1.epoch == 1
    assert sorted(rep.touched_partitions.tolist()) == [0]
    assert h1.stamps[0] == 1 and (np.delete(h1.stamps, 0) == 0).all()
    # replace() stamps the world
    h2 = h1.replace(uniform_random_graph(n, 2, seed=7))
    assert h2.epoch == 2 and (h2.stamps == 2).all()
    # epochs never reuse: every mutation returns a strictly larger epoch
    h3, _ = h2.apply((np.array([60]), np.array([61]),
                      np.array([0.3], np.float32)))
    assert h3.epoch == 3
    # old handles are untouched (immutability)
    assert handle.epoch == 0 and h1.epoch == 1


def test_report_monotone_safety_classification():
    g = uniform_random_graph(20, 3, seed=8)
    handle = GraphHandle.wrap(g)
    # pure insert of tiny weights: safe
    _, rep = handle.apply((np.array([0]), np.array([19]),
                           np.array([1e-4], np.float32)))
    assert rep.monotone_safe
    # any delete: unsafe
    edges = edges_of(handle.csr)
    r, c = next(iter(edges))
    _, rep = handle.apply(deletes=(np.array([r]), np.array([c])))
    assert not rep.monotone_safe and rep.n_deleted == 1
    # weight-raising upsert: unsafe
    _, rep = handle.apply((np.array([r]), np.array([c]),
                           np.array([99.0], np.float32)))
    assert not rep.monotone_safe and rep.n_upserted == 1


# ---------------------------------------------------------------------------
# incremental repair == scratch (deterministic)
# ---------------------------------------------------------------------------

def test_repair_bit_identical_insert_batch():
    g = rmat(7, 6, seed=13)
    handle = GraphHandle.wrap(g)
    prev_bfs = bfs(handle.csr, 0)
    prev_cc = connected_components(handle.csr)
    prev_sssp = sssp(handle.csr, 0, delta=auto_delta(handle.csr))
    rng = np.random.default_rng(11)
    k = 25
    handle, rep = handle.apply((rng.integers(0, g.n_rows, k),
                                rng.integers(0, g.n_rows, k),
                                rng.uniform(1e-4, 1e-3, k).astype(np.float32)))
    assert rep.monotone_safe
    csr = handle.csr
    np.testing.assert_array_equal(
        np.asarray(bfs_repair(csr, prev_bfs, rep.changed_sources)),
        np.asarray(bfs(csr, 0)))
    np.testing.assert_array_equal(
        np.asarray(cc_repair(csr, prev_cc, rep.changed_vertices)),
        np.asarray(connected_components(csr)))
    np.testing.assert_array_equal(
        np.asarray(sssp_repair(csr, prev_sssp, rep.changed_sources)),
        np.asarray(sssp(csr, 0, delta=auto_delta(csr))))


def test_deletion_falls_back_and_logs(caplog):
    g = uniform_random_graph(50, 3, seed=14)
    handle = GraphHandle.wrap(g)
    prev = bfs(handle.csr, 0)
    edges = sorted(edges_of(handle.csr))
    r, c = edges[0]
    handle, rep = handle.apply(deletes=(np.array([r]), np.array([c])))
    assert not rep.monotone_safe
    from repro.obs import get_registry
    ctr = get_registry().counter("streaming.full_recompute_fallback")
    ctr0 = ctr.value
    with caplog.at_level("INFO", logger="repro.streaming"):
        got = repair_or_recompute("bfs", handle, prev, rep, source=0)
    assert any("full recompute fallback" in m for m in caplog.messages)
    assert ctr.value == ctr0 + 1        # the logged event is also counted
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(bfs(handle.csr, 0)))


# ---------------------------------------------------------------------------
# golden update-stream replay
# ---------------------------------------------------------------------------

def test_golden_streaming_replay():
    from repro.obs import get_registry
    fallback_ctr = get_registry().counter("streaming.full_recompute_fallback")
    ctr0 = fallback_ctr.value
    data = np.load(GOLDEN)
    scale, ef, seed, n_epochs, source = data["meta"].tolist()
    handle = GraphHandle.wrap(rmat(scale, ef, seed=seed), n_partitions=8)
    prev = {"bfs": data["epoch0/bfs"], "cc": data["epoch0/cc"],
            "sssp": data["epoch0/sssp"]}
    np.testing.assert_array_equal(np.asarray(bfs(handle.csr, source)),
                                  prev["bfs"])
    unsafe_epochs = 0
    for e in range(1, n_epochs + 1):
        handle, rep = handle.apply(
            (data[f"epoch{e}/ins_r"], data[f"epoch{e}/ins_c"],
             data[f"epoch{e}/ins_v"]),
            (data[f"epoch{e}/del_r"], data[f"epoch{e}/del_c"]))
        assert rep.monotone_safe == bool(data[f"epoch{e}/monotone_safe"][0])
        unsafe_epochs += not rep.monotone_safe
        for kind in ("bfs", "cc", "sssp"):
            got = np.asarray(repair_or_recompute(
                kind, handle, prev[kind], rep, source=source))
            np.testing.assert_array_equal(got, data[f"epoch{e}/{kind}"],
                                          err_msg=f"epoch {e} {kind}")
            prev[kind] = got
    # PR 9 guardrail: every full-recompute fallback is a counted event, and
    # only those — each unsafe epoch falls back once per kind, safe epochs
    # never touch the counter (this golden stream is all monotone-safe; the
    # firing case pins in test_deletion_falls_back_and_logs)
    assert fallback_ctr.value - ctr0 == 3 * unsafe_epochs


# ---------------------------------------------------------------------------
# hypothesis property: random insert stream => incremental == scratch
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:      # hypothesis optional: the deterministic tests above
    _HYP = False         # still run; only the property search skips

SETTINGS = dict(max_examples=10, deadline=None)


def _maybe_given(fn):
    if not _HYP:         # degrade to one fixed example, don't lose coverage
        return lambda: fn()
    return settings(**SETTINGS)(
        given(seed=st.integers(0, 10_000), n_epochs=st.integers(1, 3))(fn))


@_maybe_given
def test_incremental_equals_scratch_property(seed=1234, n_epochs=2):
    rng = np.random.default_rng(seed)
    g = uniform_random_graph(96, 3, seed=seed % 29)
    handle = GraphHandle.wrap(g, n_partitions=4)
    prev = {"bfs": bfs(handle.csr, 0),
            "cc": connected_components(handle.csr),
            "sssp": sssp(handle.csr, 0, delta=auto_delta(handle.csr))}
    for _ in range(n_epochs):
        k = int(rng.integers(1, 20))
        handle, rep = handle.apply(
            (rng.integers(0, 96, k), rng.integers(0, 96, k),
             rng.uniform(1e-5, 1e-3, k).astype(np.float32)))
        assert rep.monotone_safe
        csr = handle.csr
        scratch = {"bfs": bfs(csr, 0), "cc": connected_components(csr),
                   "sssp": sssp(csr, 0, delta=auto_delta(csr))}
        for kind in ("bfs", "cc", "sssp"):
            got = repair_or_recompute(kind, handle, prev[kind], rep, source=0)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(scratch[kind]),
                                          err_msg=kind)
            prev[kind] = got
