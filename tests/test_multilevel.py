"""Multi-level Louvain: graph contraction vs a pure-NumPy reference, the
engine-level hierarchy pipeline, modularity invariance/monotonicity, and the
gain-gated local-move sweep."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, offload, rmat, uniform_random_graph
from repro.core.graph import CSR, contract
from repro.core.algorithms import (label_propagation, modularity, multilevel)
from repro.core.algorithms.louvain import louvain_local_moves
from repro.core import traffic

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# contraction vs numpy reference
# ---------------------------------------------------------------------------

def _np_contract(csr, labels):
    """Reference: dense (n_c, n_c) weight accumulation + unique renumbering."""
    uniq, dense = np.unique(labels, return_inverse=True)
    nc = uniq.size
    rows, cols = np.asarray(csr.row_ids()), np.asarray(csr.indices)
    vals = (np.asarray(csr.values) if csr.values is not None
            else np.ones_like(cols, np.float32))
    out = np.zeros((nc, nc))
    np.add.at(out, (dense[rows], dense[cols]), vals)
    return out, dense


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_contract_matches_numpy_reference(seed):
    g = uniform_random_graph(120, 5, seed=seed)
    labels = RNG.integers(0, 17, g.n_rows) * 7 + 3  # sparse, unordered ids
    coarse, renumber = contract(g, labels)
    ref_dense, ref_renumber = _np_contract(g, labels)
    np.testing.assert_array_equal(np.asarray(renumber), ref_renumber)
    assert coarse.n_rows == ref_dense.shape[0]
    np.testing.assert_allclose(np.asarray(coarse.to_dense()), ref_dense,
                               rtol=1e-5, atol=1e-5)


def test_contract_unweighted_counts_edges():
    g = uniform_random_graph(60, 4, seed=3, weighted=False)
    labels = RNG.integers(0, 5, g.n_rows)
    coarse, _ = contract(g, labels)
    ref_dense, _ = _np_contract(g, labels)
    np.testing.assert_allclose(np.asarray(coarse.to_dense()), ref_dense,
                               atol=1e-6)


def test_contract_self_loops_accumulate_intra_weight():
    # two 3-cliques: contracting each clique must put all intra weight on the
    # diagonal and the single cross edge off-diagonal
    rows, cols = [], []
    for c in range(2):
        for i in range(3):
            for j in range(3):
                if i != j:
                    rows.append(c * 3 + i)
                    cols.append(c * 3 + j)
    rows.append(0)
    cols.append(3)
    g = CSR.from_coo(rows, cols, np.ones(len(rows), np.float32), 6, 6)
    coarse, renumber = contract(g, np.array([0, 0, 0, 1, 1, 1]))
    d = np.asarray(coarse.to_dense())
    np.testing.assert_allclose(d, np.array([[6.0, 1.0], [0.0, 6.0]]))
    np.testing.assert_array_equal(np.asarray(renumber), [0, 0, 0, 1, 1, 1])


def test_contract_modularity_invariant():
    g = rmat(8, 6, seed=5)
    labels = RNG.integers(0, 30, g.n_rows)
    coarse, renumber = contract(g, labels)
    q_fine = float(modularity(g, jnp.asarray(np.asarray(renumber))))
    q_coarse = float(modularity(coarse, jnp.arange(coarse.n_rows)))
    assert abs(q_fine - q_coarse) < 1e-5


def test_compact_labels_dense_and_monotone():
    lab = jnp.asarray(np.array([30, 5, 30, 7, 5, 99], np.int32))
    dense, n_c = offload.compact_labels(lab)
    np.testing.assert_array_equal(np.asarray(dense), [2, 0, 2, 1, 0, 3])
    assert int(n_c) == 4


# ---------------------------------------------------------------------------
# engine hierarchy pipeline
# ---------------------------------------------------------------------------

def test_hierarchy_projects_through_levels():
    maps = (jnp.asarray([0, 0, 1, 1, 2]), jnp.asarray([0, 1, 1]))
    hier = engine.Hierarchy(maps)
    top = jnp.asarray([10, 20])
    np.testing.assert_array_equal(np.asarray(hier.project(top)),
                                  [10, 10, 20, 20, 20])
    assert hier.n_levels == 2


def test_run_multilevel_rejects_non_improving_levels():
    g = uniform_random_graph(80, 4, seed=2)
    calls = []

    def level_fn(gl, level):
        calls.append(level)
        return jnp.arange(gl.n_rows, dtype=jnp.int32)  # identity: no merge

    labels, hier, scores = engine.run_multilevel(
        g, level_fn, contract, modularity, max_levels=5)
    # identity assignment cannot improve Q -> zero accepted levels, one call
    assert scores == [] and hier.n_levels == 0 and calls == [0]
    np.testing.assert_array_equal(np.asarray(labels), np.arange(80))


# ---------------------------------------------------------------------------
# multi-level Louvain quality
# ---------------------------------------------------------------------------

def test_local_moves_monotone_and_beat_singletons():
    g = uniform_random_graph(300, 6, seed=4)
    labels, q = louvain_local_moves(g)
    assert q > float(modularity(g, jnp.arange(g.n_rows)))
    assert abs(float(modularity(g, labels)) - q) < 1e-5


def test_multilevel_scores_strictly_increase():
    g = uniform_random_graph(1 << 9, 8, seed=0)
    labels, scores = multilevel(g)
    assert len(scores) >= 1
    assert all(b > a for a, b in zip(scores, scores[1:]))
    assert abs(float(modularity(g, labels)) - scores[-1]) < 1e-5


def test_multilevel_beats_single_lpa_sweep_rmat10():
    """Acceptance criterion: strictly higher modularity than one LPA sweep
    on an RMAT-10 graph."""
    g = rmat(10, 8, seed=0)
    q_sweep = float(modularity(g, label_propagation(g, iters=1)))
    labels, scores = multilevel(g)
    assert scores, "multilevel accepted no level"
    assert scores[-1] > q_sweep
    # and by a wide margin, not a tie-break artifact
    assert scores[-1] > 5 * abs(q_sweep)


def test_multilevel_two_cliques_exact():
    rows, cols = [], []
    for c in range(2):
        for i in range(8):
            for j in range(8):
                if i != j:
                    rows.append(c * 8 + i)
                    cols.append(c * 8 + j)
    rows += [0, 8]
    cols += [8, 0]
    g = CSR.from_coo(rows, cols, np.ones(len(rows), np.float32), 16, 16)
    labels, scores = multilevel(g)
    lab = np.asarray(labels)
    assert len(set(lab[:8])) == 1 and len(set(lab[8:])) == 1
    assert lab[0] != lab[8]
    assert scores[-1] > 0.4


# ---------------------------------------------------------------------------
# contraction byte ledger
# ---------------------------------------------------------------------------

def test_route_byte_counter_contract_level():
    c = traffic.RouteByteCounter(8, payload_bytes=traffic.CONTRACT_PAYLOAD_BYTES)
    b = c.contract_level(100)
    assert b == 100 * traffic.CONTRACT_PAYLOAD_BYTES
    assert c.total_bytes == b and c.levels == 1
    c.push_level(10)  # mixed ledgers still accumulate
    assert c.levels == 2


# ---------------------------------------------------------------------------
# bench JSON artifact (satellite: machine-readable bench + baseline compare)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_bench_engine_writes_json_artifact(tmp_path):
    out = tmp_path / "BENCH_test.json"
    root = os.path.join(os.path.dirname(__file__), "..")
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "benchmarks", "bench_engine.py"),
         "--scale", "6", "--smoke", "--json", str(out)],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
    sys.stdout.write(proc.stdout[-2000:])
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0
    doc = json.loads(out.read_text())
    assert doc["meta"]["scale"] == 6
    assert "bfs/auto" in doc["timings_ms"]
    assert np.isfinite(doc["modularity"]["multilevel"])
    assert doc["modularity"]["multilevel"] > doc["modularity"]["single_sweep"]
    assert 0.0 <= doc["fallback"]["rate"] <= 1.0
    assert doc["bytes"]["reduction"] >= 1.0
