"""ExecutionCore golden-equivalence grid (DESIGN.md §14).

The PR-5 refactor collapsed the engine's five runners into one stepping loop
parameterized by (lane representation x placement).  This suite replays the
pre-refactor outputs — captured by ``scripts/make_golden_core.py`` against
the PR-4 engine and committed as ``tests/golden/core_grid.npz`` — and
asserts **bit identity** across the whole (program family x lane
representation x mode) grid on the local placement, plus the direction-trace
stats.  The distributed placement's equivalence gates in
``tests/_distributed_main.py`` (partition identity under 8 forced devices,
goldens there would bake in the device count).

Also guards the structural invariant itself: ``engine.py`` holds exactly one
stepping loop (the same check `scripts/check_single_core.py` runs in CI).
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, rmat, uniform_random_graph
from repro.core.algorithms import (bfs, connected_components,
                                   label_propagation, msbfs, ppr, ppr_batched,
                                   sssp, sssp_batched)

GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden",
                            "core_grid.npz"))
G = rmat(7, 8, seed=11)
U = uniform_random_graph(150, 4, seed=5)
DELTA = float(GOLD["meta_delta_g"])
SOURCES = np.array([0, 3, 17, 64, 0], dtype=np.int32)  # dup lane on purpose
MODES = ("push", "pull", "auto")


def _gold(key):
    assert key in GOLD.files, f"golden entry {key} missing — regenerate only "\
        "with scripts/make_golden_core.py against a pre-refactor tree"
    return GOLD[key]


# ---------------------------------------------------------------------------
# scalar lanes, local placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_bfs_scalar_golden(mode):
    np.testing.assert_array_equal(np.asarray(bfs(G, 0, mode=mode)),
                                  _gold(f"bfs/scalar/{mode}"))


@pytest.mark.parametrize("mode", MODES)
def test_sssp_scalar_golden(mode):
    np.testing.assert_array_equal(
        np.asarray(sssp(G, 0, delta=DELTA, mode=mode)),
        _gold(f"sssp/scalar/{mode}"))


@pytest.mark.parametrize("mode", MODES)
def test_cc_scalar_golden(mode):
    np.testing.assert_array_equal(
        np.asarray(connected_components(U, mode=mode)),
        _gold(f"cc/scalar/{mode}"))


def test_ppr_scalar_golden():
    np.testing.assert_array_equal(np.asarray(ppr(G, 3, iters=12)),
                                  _gold("ppr/scalar/pull"))


def test_lpa_structured_golden():
    np.testing.assert_array_equal(np.asarray(label_propagation(G, iters=4)),
                                  _gold("lpa/scalar/auto"))


def test_sample_structured_golden():
    key = jax.random.PRNGKey(7)
    q = jnp.arange(64, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(engine.sample_neighbors(G, q, key)),
        _gold("sample/scalar/push"))
    np.testing.assert_array_equal(
        np.asarray(engine.sample_neighbors(G, q, key, weighted=True)),
        _gold("sample/scalar/weighted"))


# ---------------------------------------------------------------------------
# packed / valued lanes, local placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_msbfs_packed_golden(mode):
    np.testing.assert_array_equal(np.asarray(msbfs(G, SOURCES, mode=mode)),
                                  _gold(f"bfs/packed/{mode}"))


@pytest.mark.parametrize("mode", MODES)
def test_sssp_valued_golden(mode):
    np.testing.assert_array_equal(
        np.asarray(sssp_batched(G, SOURCES, delta=DELTA, mode=mode)),
        _gold(f"sssp/valued/{mode}"))


def test_ppr_valued_golden():
    np.testing.assert_array_equal(
        np.asarray(ppr_batched(G, SOURCES, iters=12)),
        _gold("ppr/valued/pull"))


# ---------------------------------------------------------------------------
# direction-decision traces (the refactor must not re-route any level)
# ---------------------------------------------------------------------------

def test_sssp_stats_trace_golden():
    _, st = sssp(G, 0, delta=DELTA, return_stats=True)
    got = [int(st[k]) for k in ("iters", "pushes", "pulls")]
    np.testing.assert_array_equal(got, _gold("sssp/stats/auto"))


def test_msbfs_stats_trace_golden():
    _, st = msbfs(G, SOURCES, return_stats=True)
    got = [int(st[k]) for k in ("iters", "pushes", "pulls")]
    np.testing.assert_array_equal(got, _gold("msbfs/stats/auto"))


# ---------------------------------------------------------------------------
# structural invariant: exactly one stepping loop
# ---------------------------------------------------------------------------

def test_engine_has_single_stepping_loop():
    """The in-suite twin of scripts/check_single_core.py: every frontier
    runner must lower to the one `_core_loop` while_loop."""
    src = open(os.path.join(os.path.dirname(__file__), os.pardir, "src",
                            "repro", "core", "engine.py")).read()
    assert len(re.findall(r"lax\.while_loop\(", src)) == 1
    assert len(re.findall(r"lax\.scan\(", src)) <= 1  # run_queue's body
    for runner in ("def run(", "def run_batched(", "def run_distributed(",
                   "def run_batched_distributed(", "def run_queue("):
        assert runner in src


def test_mapped_cache_is_shared_with_algorithms():
    """One `_MAPPED_CACHE` keying scheme across placements (DESIGN §14)."""
    from repro.core.algorithms import louvain
    assert louvain._MAPPED_CACHE is engine._MAPPED_CACHE
