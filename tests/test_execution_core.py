"""ExecutionCore golden-equivalence grid (DESIGN.md §14).

The PR-5 refactor collapsed the engine's five runners into one stepping loop
parameterized by (lane representation x placement).  This suite replays the
pre-refactor outputs — captured by ``scripts/make_golden_core.py`` against
the PR-4 engine and committed as ``tests/golden/core_grid.npz`` — and
asserts **bit identity** across the whole (program family x lane
representation x mode) grid on the local placement, plus the direction-trace
stats.  The distributed placement's equivalence gates in
``tests/_distributed_main.py`` (partition identity under 8 forced devices,
goldens there would bake in the device count).

Also guards the structural invariant itself: ``engine.py`` holds exactly one
stepping loop (the same check `scripts/check_single_core.py` runs in CI).
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, rmat, uniform_random_graph
from repro.core.algorithms import (bfs, connected_components,
                                   label_propagation, msbfs, ppr, ppr_batched,
                                   sssp, sssp_batched)

GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden",
                            "core_grid.npz"))
G = rmat(7, 8, seed=11)
U = uniform_random_graph(150, 4, seed=5)
DELTA = float(GOLD["meta_delta_g"])
SOURCES = np.array([0, 3, 17, 64, 0], dtype=np.int32)  # dup lane on purpose
MODES = ("push", "pull", "auto")


def _gold(key):
    assert key in GOLD.files, f"golden entry {key} missing — regenerate only "\
        "with scripts/make_golden_core.py against a pre-refactor tree"
    return GOLD[key]


# ---------------------------------------------------------------------------
# scalar lanes, local placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_bfs_scalar_golden(mode):
    np.testing.assert_array_equal(np.asarray(bfs(G, 0, mode=mode)),
                                  _gold(f"bfs/scalar/{mode}"))


@pytest.mark.parametrize("mode", MODES)
def test_sssp_scalar_golden(mode):
    np.testing.assert_array_equal(
        np.asarray(sssp(G, 0, delta=DELTA, mode=mode)),
        _gold(f"sssp/scalar/{mode}"))


@pytest.mark.parametrize("mode", MODES)
def test_cc_scalar_golden(mode):
    np.testing.assert_array_equal(
        np.asarray(connected_components(U, mode=mode)),
        _gold(f"cc/scalar/{mode}"))


def test_ppr_scalar_golden():
    np.testing.assert_array_equal(np.asarray(ppr(G, 3, iters=12)),
                                  _gold("ppr/scalar/pull"))


def test_lpa_structured_golden():
    np.testing.assert_array_equal(np.asarray(label_propagation(G, iters=4)),
                                  _gold("lpa/scalar/auto"))


def test_sample_structured_golden():
    key = jax.random.PRNGKey(7)
    q = jnp.arange(64, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(engine.sample_neighbors(G, q, key)),
        _gold("sample/scalar/push"))
    np.testing.assert_array_equal(
        np.asarray(engine.sample_neighbors(G, q, key, weighted=True)),
        _gold("sample/scalar/weighted"))


# ---------------------------------------------------------------------------
# packed / valued lanes, local placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_msbfs_packed_golden(mode):
    np.testing.assert_array_equal(np.asarray(msbfs(G, SOURCES, mode=mode)),
                                  _gold(f"bfs/packed/{mode}"))


@pytest.mark.parametrize("mode", MODES)
def test_sssp_valued_golden(mode):
    np.testing.assert_array_equal(
        np.asarray(sssp_batched(G, SOURCES, delta=DELTA, mode=mode)),
        _gold(f"sssp/valued/{mode}"))


def test_ppr_valued_golden():
    np.testing.assert_array_equal(
        np.asarray(ppr_batched(G, SOURCES, iters=12)),
        _gold("ppr/valued/pull"))


# ---------------------------------------------------------------------------
# async placement (PR 7): bounded-staleness pacing replays the same goldens
# ---------------------------------------------------------------------------
# A 1-device mesh makes the distributed runners (and so the async placement)
# executable inside the ordinary suite — S=1 still traces the full pacing
# machinery (micro-step scan, buffered flush, termination psum), and the
# monotone programs must land on the *identical* fixpoint the pre-refactor
# engine produced, at every staleness bound.  The S=8 partition checks live
# in tests/_distributed_main.py.

from repro.core import dgas
from repro.core.algorithms import (bfs_distributed, msbfs_distributed,
                                   sssp_distributed, sssp_batched_distributed,
                                   connected_components_distributed,
                                   symmetrize)
from repro.core.algorithms.distgraph import shard_graph
from repro.launch.mesh import make_cores_mesh

INTERVALS = (1, 2, 8)
_MESH1 = make_cores_mesh(1)
_GSH1, _ATT1 = shard_graph(G, 1, row_att=dgas.block_rule(G.n_rows, 1))
_US = symmetrize(U)
_GSH1_U, _ATT1_U = shard_graph(_US, 1, row_att=dgas.block_rule(_US.n_rows, 1))


def _unshard1(x, n):
    return np.asarray(x).reshape(-1)[:n]


@pytest.mark.parametrize("k", INTERVALS)
def test_bfs_async_golden(k):
    lv = bfs_distributed(_GSH1, _ATT1, 0, _MESH1, placement="async",
                         sync_interval=k)
    np.testing.assert_array_equal(_unshard1(lv, G.n_rows),
                                  _gold("bfs/scalar/push"))


@pytest.mark.parametrize("k", INTERVALS)
def test_msbfs_async_golden(k):
    lv = msbfs_distributed(_GSH1, _ATT1, SOURCES, _MESH1, placement="async",
                           sync_interval=k)
    lv = np.asarray(lv).transpose(1, 0, 2).reshape(len(SOURCES), -1)
    np.testing.assert_array_equal(lv[:, : G.n_rows], _gold("bfs/packed/push"))


@pytest.mark.parametrize("k", INTERVALS)
def test_sssp_async_golden(k):
    d = sssp_distributed(_GSH1, _ATT1, 0, _MESH1, delta=DELTA,
                         max_iters=4 * G.n_rows, placement="async",
                         sync_interval=k)
    np.testing.assert_array_equal(_unshard1(d, G.n_rows),
                                  _gold("sssp/scalar/push"))


@pytest.mark.parametrize("k", INTERVALS)
def test_sssp_batched_async_golden(k):
    d = sssp_batched_distributed(_GSH1, _ATT1, SOURCES, _MESH1, delta=DELTA,
                                 max_iters=4 * G.n_rows, placement="async",
                                 sync_interval=k)
    d = np.asarray(d).transpose(1, 0, 2).reshape(len(SOURCES), -1)
    np.testing.assert_array_equal(d[:, : G.n_rows], _gold("sssp/valued/push"))


@pytest.mark.parametrize("k", INTERVALS)
def test_cc_async_golden(k):
    lab = connected_components_distributed(_GSH1_U, _ATT1_U, _MESH1,
                                           placement="async", sync_interval=k)
    np.testing.assert_array_equal(_unshard1(lab, U.n_rows),
                                  _gold("cc/scalar/push"))


def test_async_rejects_structured_and_pull():
    with pytest.raises(ValueError):
        bfs_distributed(_GSH1, _ATT1, 0, _MESH1, mode="pull",
                        placement="async")
    with pytest.raises(ValueError):
        sssp_distributed(_GSH1, _ATT1, 0, _MESH1, placement="async",
                         sync_interval=0)


# ---------------------------------------------------------------------------
# direction-decision traces (the refactor must not re-route any level)
# ---------------------------------------------------------------------------

def test_sssp_stats_trace_golden():
    _, st = sssp(G, 0, delta=DELTA, return_stats=True)
    got = [int(st[k]) for k in ("iters", "pushes", "pulls")]
    np.testing.assert_array_equal(got, _gold("sssp/stats/auto"))


def test_msbfs_stats_trace_golden():
    _, st = msbfs(G, SOURCES, return_stats=True)
    got = [int(st[k]) for k in ("iters", "pushes", "pulls")]
    np.testing.assert_array_equal(got, _gold("msbfs/stats/auto"))


# ---------------------------------------------------------------------------
# structural invariant: exactly one stepping loop
# ---------------------------------------------------------------------------

def test_engine_has_single_stepping_loop():
    """The in-suite twin of scripts/check_single_core.py: every frontier
    runner must lower to the one `_core_loop` while_loop."""
    src = open(os.path.join(os.path.dirname(__file__), os.pardir, "src",
                            "repro", "core", "engine.py")).read()
    assert len(re.findall(r"lax\.while_loop\(", src)) == 1
    assert len(re.findall(r"lax\.scan\(", src)) <= 1  # run_queue's body
    for runner in ("def run(", "def run_batched(", "def run_distributed(",
                   "def run_batched_distributed(", "def run_queue("):
        assert runner in src


def test_mapped_cache_is_shared_with_algorithms():
    """One `_MAPPED_CACHE` keying scheme across placements (DESIGN §14)."""
    from repro.core.algorithms import louvain
    assert louvain._MAPPED_CACHE is engine._MAPPED_CACHE
