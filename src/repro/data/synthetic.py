"""Synthetic data pipelines: LM token streams, GNN graph batches (with the
geometric extras DimeNet/EquiformerV2 need), recsys click batches, and a
host-side prefetch iterator.

Everything is seeded-deterministic numpy on the host; device transfer happens
at the jit boundary (the prefetcher overlaps generation with the train step —
the host-side analogue of the paper's background offload engines).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

from ..core.graph import CSR, rmat, uniform_random_graph
from ..core.algorithms.sampling import neighbor_sample_np

__all__ = ["lm_batches", "gnn_batch", "recsys_batches", "prefetch",
           "build_triplets", "build_wigner", "graph_for_shape"]


def lm_batches(batch: int, seq: int, vocab: int, *, seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        # zipf-ish marginal so embedding gathers see realistic skew
        z = rng.zipf(1.3, size=(batch, seq))
        toks = (z % vocab).astype(np.int32)
        yield {"tokens": toks}


def recsys_batches(batch: int, n_fields: int, rows_per_field: int, *,
                   seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        local = (rng.zipf(1.2, size=(batch, n_fields)) % rows_per_field)
        ids = (local + np.arange(n_fields)[None, :] * rows_per_field).astype(np.int32)
        # planted linear model for learnable labels
        w = np.sin(ids * 0.001).sum(-1)
        labels = (w + rng.standard_normal(batch) * 0.1 > 0).astype(np.float32)
        yield {"ids": ids, "labels": labels}


# ---------------------------------------------------------------------------
# GNN batches
# ---------------------------------------------------------------------------

def build_triplets(src: np.ndarray, dst: np.ndarray, pos: np.ndarray,
                   max_triplets: int, *, seed: int = 0):
    """(k->j) feeding (j->i) triplet lists + bond angle at j."""
    rng = np.random.default_rng(seed)
    E = src.shape[0]
    by_dst: dict[int, list[int]] = {}
    for e in range(E):
        by_dst.setdefault(int(dst[e]), []).append(e)
    t_kj, t_ji = [], []
    for e2 in range(E):
        j = int(src[e2])
        for e1 in by_dst.get(j, ()):
            if int(src[e1]) == int(dst[e2]):
                continue
            t_kj.append(e1)
            t_ji.append(e2)
            if len(t_kj) >= max_triplets:
                break
        if len(t_kj) >= max_triplets:
            break
    n = len(t_kj)
    t_kj = np.array(t_kj + [-1] * (max_triplets - n), np.int32)
    t_ji = np.array(t_ji + [0] * (max_triplets - n), np.int32)
    # angle at j between (k - j) and (i - j)
    safe_kj = np.maximum(t_kj, 0)
    k_ = src[safe_kj]
    j_ = dst[safe_kj]
    i_ = dst[np.maximum(t_ji, 0)]
    v1 = pos[k_] - pos[j_]
    v2 = pos[i_] - pos[j_]
    cos = (v1 * v2).sum(-1) / (np.linalg.norm(v1, axis=-1) *
                               np.linalg.norm(v2, axis=-1) + 1e-9)
    angle = np.arccos(np.clip(cos, -1, 1)).astype(np.float32)
    return t_kj, t_ji, angle


def _rotation_to_y(vec: np.ndarray) -> np.ndarray:
    """Batch of 3x3 rotations sending each vec to the +y axis (eSCN frame)."""
    v = vec / (np.linalg.norm(vec, axis=-1, keepdims=True) + 1e-9)
    y = np.array([0.0, 1.0, 0.0])
    c = v @ y                                   # cos
    ax = np.cross(v, np.broadcast_to(y, v.shape))
    s = np.linalg.norm(ax, axis=-1, keepdims=True)
    ax = ax / (s + 1e-9)
    K = np.zeros(v.shape[:-1] + (3, 3), np.float32)
    K[..., 0, 1], K[..., 0, 2] = -ax[..., 2], ax[..., 1]
    K[..., 1, 0], K[..., 1, 2] = ax[..., 2], -ax[..., 0]
    K[..., 2, 0], K[..., 2, 1] = -ax[..., 1], ax[..., 0]
    I = np.eye(3, dtype=np.float32)
    sin = s[..., None]
    cos = c[..., None, None]
    return (I + sin * K + (1 - cos) * (K @ K)).astype(np.float32)


def build_wigner(src: np.ndarray, dst: np.ndarray, pos: np.ndarray,
                 l_max: int) -> np.ndarray:
    """Per-edge block-diagonal rotation in the irrep basis.

    l=0 -> 1; l=1 -> the geometric rotation; l>=2 -> identity blocks
    (synthetic-pipeline simplification, DESIGN.md §9 — production would table
    e3nn Wigner-D; the on-device model is agnostic to how D was built).
    """
    E = src.shape[0]
    ncoef = (l_max + 1) ** 2
    W = np.tile(np.eye(ncoef, dtype=np.float32), (E, 1, 1))
    vec = pos[np.maximum(dst, 0)] - pos[np.maximum(src, 0)]
    vec[np.linalg.norm(vec, axis=-1) < 1e-6] = np.array([0, 1, 0], np.float32)
    R = _rotation_to_y(vec)
    if l_max >= 1:
        W[:, 1:4, 1:4] = R
    return W


def graph_for_shape(shape_name: str, *, seed: int = 0,
                    scale_override: Optional[int] = None) -> CSR:
    """Representative synthetic graph per assigned GNN shape (scaled for CPU
    smoke; full-size shapes exist only as dry-run ShapeDtypeStructs)."""
    if shape_name in ("full_graph_sm",):
        return uniform_random_graph(2708, 4, seed=seed)
    if shape_name == "molecule":
        return uniform_random_graph(30, 2, seed=seed)
    scale = scale_override or 10
    return rmat(scale, 8, seed=seed)


def gnn_batch(arch: str, csr: CSR, d_feat: int, n_classes: int, *,
              l_max: int = 6, max_triplets: Optional[int] = None,
              graph_id: Optional[np.ndarray] = None, seed: int = 0,
              label_mask: Optional[np.ndarray] = None) -> dict:
    rng = np.random.default_rng(seed)
    n = csr.n_rows
    src = np.asarray(csr.row_ids(), np.int32)
    dst = np.asarray(csr.indices, np.int32)
    b = {
        "x": rng.standard_normal((n, d_feat)).astype(np.float32),
        "src": src, "dst": dst,
        "labels": rng.integers(0, n_classes, n).astype(np.int32),
    }
    if arch in ("dimenet", "equiformer_v2"):
        b["pos"] = rng.standard_normal((n, 3)).astype(np.float32) * 3.0
    if arch == "dimenet":
        mt = max_triplets or min(4 * src.shape[0], 20000)
        t_kj, t_ji, angle = build_triplets(src, dst, b["pos"], mt, seed=seed)
        b.update(triplet_kj=t_kj, triplet_ji=t_ji, angle=angle)
    if arch == "equiformer_v2":
        b["wigner"] = build_wigner(src, dst, b["pos"], l_max)
    if graph_id is not None:
        b["graph_id"] = graph_id
    if label_mask is not None:
        b["label_mask"] = label_mask
    return b


def sampled_gnn_batch(csr: CSR, features: np.ndarray, labels: np.ndarray,
                      batch_nodes: int, fanouts: Sequence[int], *,
                      seed: int = 0) -> dict:
    """minibatch_lg: layered sample flattened to an edge list over local ids.

    Node order: [seeds | hop1 | hop2 ...]; every sampled neighbor contributes
    one edge child->parent.  Loss is masked to the seed nodes.
    """
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, csr.n_rows, batch_nodes)
    layers = neighbor_sample_np(np.asarray(csr.indptr), np.asarray(csr.indices),
                                seeds, fanouts, rng)
    flat_ids = [l.reshape(-1) for l in layers]
    offsets = np.cumsum([0] + [f.shape[0] for f in flat_ids])
    src_l, dst_l = [], []
    for h in range(1, len(layers)):
        parent_local = np.arange(flat_ids[h - 1].shape[0]) + offsets[h - 1]
        child_local = np.arange(flat_ids[h].shape[0]) + offsets[h]
        fan = layers[h].shape[-1]
        src_l.append(child_local)
        dst_l.append(np.repeat(parent_local, fan))
    all_ids = np.concatenate(flat_ids)
    n_local = all_ids.shape[0]
    mask = np.zeros(n_local, bool)
    mask[: batch_nodes] = True
    return {
        "x": features[all_ids].astype(np.float32),
        "src": np.concatenate(src_l).astype(np.int32),
        "dst": np.concatenate(dst_l).astype(np.int32),
        "labels": labels[all_ids].astype(np.int32),
        "label_mask": mask,
    }


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Host-side background prefetch (offload-engine analogue for input data)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
