from . import synthetic
