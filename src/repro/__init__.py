"""repro: PIUMA (Programmable Integrated Unified Memory Architecture) on JAX/TPU."""
__version__ = "0.1.0"
