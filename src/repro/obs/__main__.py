"""``python -m repro.obs summarize <trace.json>`` — per-phase breakdown.

Stdlib-only (the package's export/summarize path imports no jax), so the
CLI runs in the same jax-free environment as the lint lane.
"""
from __future__ import annotations

import argparse
import json
import sys

from .export import format_summary, summarize, validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect exported Chrome trace_event JSON")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sm = sub.add_parser("summarize",
                        help="per-phase time/bytes breakdown of a trace")
    sm.add_argument("trace", help="path to an exported trace JSON")
    sm.add_argument("--json", action="store_true",
                    help="emit the rollup as JSON instead of a table")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    errors = validate_chrome_trace(doc)
    summary = summarize(doc)
    if args.json:
        print(json.dumps({"summary": summary, "structural_errors": errors},
                         indent=1))
    else:
        print(format_summary(summary))
        if errors:
            print(f"\nSTRUCTURAL ERRORS ({len(errors)}):")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"\nstructurally valid "
                  f"({len(doc.get('traceEvents', []))} events)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
