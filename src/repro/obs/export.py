"""Chrome/Perfetto ``trace_event`` export, structural validation, summary.

The export format is the Trace Event JSON object form
(``{"traceEvents": [...]}``) with complete ("X") events only: every event
carries ``pid``/``tid``/``ts``/``dur``/``name`` (µs timestamps), so the file
loads in ``chrome://tracing`` and Perfetto's legacy importer without
metadata events.  Spans map one-to-one; per-level engine traces have no
wall-clock of their own (they were recorded on device), so each traced run
is laid out on its own synthetic tid with the run's engine-span window
subdivided evenly across levels — the *ordering and relative widths* are
synthetic, the per-level args (frontier size, direction, fallback/flush
flags) are the measured payload.

:func:`validate_chrome_trace` is the structural gate the bench and tests
use: field presence plus the per-tid no-partial-overlap rule (spans on one
tid must nest or be disjoint — the property that makes a flame graph
renderable).  Stdlib-only on purpose: the summarize CLI must run in the
jax-free lint environment.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from .spans import Span
from .trace import LevelTrace

__all__ = ["build_chrome_trace", "write_chrome_trace",
           "validate_chrome_trace", "summarize", "format_summary"]

#: Synthetic tids for per-run level-trace lanes start here; service spans
#: use small explicit tids (service.py: 1 = client, 2 = service).
LEVEL_TID_BASE = 1000


def build_chrome_trace(spans: Iterable[Span],
                       level_runs: Iterable[Dict[str, Any]] = (),
                       metrics: Optional[Dict[str, Any]] = None,
                       pid: int = 0) -> Dict[str, Any]:
    """Assemble the trace document.

    level_runs: each ``{"name": str, "t0": s, "t1": s,
    "levels": [LevelTrace]}`` — the engine-span window a traced run
    executed in, plus its decoded per-level records.
    metrics: optional registry snapshot, stashed under ``otherData`` (not an
    event stream — counters have no duration).
    """
    events: List[Dict[str, Any]] = []
    for sp in spans:
        events.append({
            "ph": "X", "name": sp.name, "cat": "service",
            "pid": sp.pid if sp.pid else pid, "tid": sp.tid,
            "ts": round(1e6 * sp.ts, 3), "dur": round(1e6 * sp.dur, 3),
            "args": dict(sp.args),
        })
    for i, run in enumerate(level_runs):
        levels: List[LevelTrace] = list(run.get("levels", ()))
        if not levels:
            continue
        t0, t1 = float(run["t0"]), float(run["t1"])
        slot = max(0.0, t1 - t0) / len(levels)
        tid = LEVEL_TID_BASE + i
        for j, lv in enumerate(levels):
            events.append({
                "ph": "X",
                "name": f"{run.get('name', 'engine')}:L{lv.level}"
                        f":{lv.direction}",
                "cat": "level", "pid": pid, "tid": tid,
                "ts": round(1e6 * (t0 + j * slot), 3),
                "dur": round(1e6 * slot, 3),
                "args": lv.as_dict(),
            })
    events.sort(key=lambda e: (e["pid"], e["tid"], e["ts"], -e["dur"]))
    doc: Dict[str, Any] = {"traceEvents": events, "displayTimeUnit": "ms"}
    if metrics is not None:
        doc["otherData"] = {"metrics": metrics}
    return doc


def write_chrome_trace(path: str, spans: Iterable[Span],
                       level_runs: Iterable[Dict[str, Any]] = (),
                       metrics: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    doc = build_chrome_trace(spans, level_runs, metrics)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Structural errors ([] = valid): every event is a complete event with
    pid/tid/ts/dur/name, and per (pid, tid) spans nest without partial
    overlap.  Timestamps compare with a 0.5 µs slack — the exporter rounds
    to 1 ns precision, and a child emitted in the same clock read as its
    parent's close may tie exactly."""
    errors: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    lanes: Dict[Any, List[Dict[str, Any]]] = {}
    for i, e in enumerate(events):
        for field in ("pid", "tid", "ts", "dur", "name"):
            if field not in e:
                errors.append(f"event {i} ({e.get('name', '?')}) missing "
                              f"{field!r}")
                break
        else:
            if e.get("ph", "X") == "X":
                lanes.setdefault((e["pid"], e["tid"]), []).append(e)
    eps = 0.5
    for key, lane in lanes.items():
        lane.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[Dict[str, Any]] = []   # open enclosing spans
        for e in lane:
            end = e["ts"] + e["dur"]
            while stack and e["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack:
                p = stack[-1]
                if end > p["ts"] + p["dur"] + eps:
                    errors.append(
                        f"tid {key}: {e['name']!r} [{e['ts']:.1f}, {end:.1f}] "
                        f"partially overlaps {p['name']!r} "
                        f"[{p['ts']:.1f}, {p['ts'] + p['dur']:.1f}]")
                    continue
            stack.append(e)
    return errors


def summarize(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Per-phase rollup: for each span name, count / total time / share of
    wall / routed bytes (summed from ``args.route_bytes`` where present).
    Level-lane events (cat == 'level') aggregate per direction instead of
    per name — 40 ``L<k>:push`` rows collapse to one 'level:push' line."""
    events = [e for e in doc.get("traceEvents", ()) if e.get("ph", "X") == "X"]
    if not events:
        return {"wall_ms": 0.0, "phases": {}}
    t_min = min(e["ts"] for e in events)
    t_max = max(e["ts"] + e["dur"] for e in events)
    wall_us = max(t_max - t_min, 1e-9)
    phases: Dict[str, Dict[str, float]] = {}
    for e in events:
        if e.get("cat") == "level":
            name = "level:" + str(e["name"]).rsplit(":", 1)[-1]
        else:
            name = str(e["name"])
        row = phases.setdefault(
            name, {"count": 0, "total_ms": 0.0, "route_bytes": 0})
        row["count"] += 1
        row["total_ms"] += e["dur"] / 1e3
        rb = e.get("args", {}).get("route_bytes")
        if rb is not None:
            row["route_bytes"] += int(rb)
    for row in phases.values():
        row["wall_frac"] = (1e3 * row["total_ms"]) / wall_us
    return {"wall_ms": wall_us / 1e3, "phases": phases}


def format_summary(summary: Dict[str, Any]) -> str:
    """Render the :func:`summarize` rollup as the CLI's fixed-width table."""
    lines = [f"wall time: {summary['wall_ms']:.3f} ms",
             f"{'phase':28s} {'count':>6s} {'total ms':>10s} "
             f"{'% wall':>7s} {'route bytes':>12s}"]
    rows = sorted(summary["phases"].items(),
                  key=lambda kv: -kv[1]["total_ms"])
    for name, row in rows:
        lines.append(f"{name[:28]:28s} {row['count']:6d} "
                     f"{row['total_ms']:10.3f} {100 * row['wall_frac']:6.1f}% "
                     f"{row['route_bytes']:12d}")
    return "\n".join(lines)
