"""Host-side spans: the wall-clock half of the observability layer.

A :class:`Span` is one named, closed time interval on a logical thread
(``tid``) with free-form ``args`` — exactly a Chrome ``trace_event``
complete ("X") event before serialization.  The :class:`SpanRecorder`
keeps them in a bounded deque and enforces the structural contract the
exporter promises downstream (every span closed, per-tid spans either
nest or are disjoint — Perfetto renders overlap as garbage):

* ``span(...)`` (context manager) pushes onto a per-tid stack, so spans
  opened inside another span on the same tid always nest;
* ``record(...)`` admits an interval measured elsewhere (e.g. "time spent
  waiting in the admission queue", whose start predates the recording
  call); its start is clipped to the previous recorded end on that tid so
  retroactive intervals cannot overlap a sibling.

Everything here is host-side stdlib — recording a span never touches a
device array, so the `host-sync` lint rule has nothing to see.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

__all__ = ["Span", "SpanRecorder"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed interval: seconds-based ts/dur, converted to µs on export."""

    name: str
    ts: float            # start, seconds on the recorder's clock
    dur: float           # duration, seconds (>= 0)
    tid: int = 0
    pid: int = 0
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


class SpanRecorder:
    """Bounded span sink with per-tid nesting enforcement.

    clock: injectable monotonic seconds source, so a service driven by a
      fake clock (the deadline tests) records coherent spans.
    capacity: spans retained (oldest dropped) — observability must not be
      the unbounded buffer the latency deque used to be.
    """

    def __init__(self, clock=time.perf_counter, capacity: int = 65536):
        self._clock = clock
        self._spans: "collections.deque[Span]" = \
            collections.deque(maxlen=int(capacity))
        self._stack: Dict[int, List[float]] = {}   # tid -> open-span starts
        self._last_end: Dict[int, float] = {}      # tid -> last closed end

    def now(self) -> float:
        return self._clock()

    @contextmanager
    def span(self, name: str, *, tid: int = 0, **args):
        """Open a span around a code block; ``args`` may be augmented during
        the block via the yielded dict (e.g. a byte count known at exit)."""
        t0 = self._clock()
        self._stack.setdefault(tid, []).append(t0)
        live: Dict[str, Any] = dict(args)
        try:
            yield live
        finally:
            t1 = self._clock()
            self._stack[tid].pop()
            self._emit(Span(name, t0, max(0.0, t1 - t0), tid=tid, args=live))

    def record(self, name: str, t0: float, t1: Optional[float] = None, *,
               tid: int = 0, **args) -> Span:
        """Record an interval measured by the caller.  ``t0`` may lie in the
        past (a queue-wait span emitted at dequeue time); it is clipped
        forward to this tid's previous end so siblings never overlap."""
        if t1 is None:
            t1 = self._clock()
        t0 = min(max(t0, self._last_end.get(tid, t0)), t1)
        sp = Span(name, t0, max(0.0, t1 - t0), tid=tid, args=dict(args))
        self._emit(sp)
        return sp

    def _emit(self, sp: Span) -> None:
        self._spans.append(sp)
        open_starts = self._stack.get(sp.tid)
        if not open_starts:
            # top-level on this tid: later record() calls clip against it
            self._last_end[sp.tid] = max(
                self._last_end.get(sp.tid, 0.0), sp.ts + sp.dur)

    def spans(self) -> List[Span]:
        return list(self._spans)

    def clear(self) -> None:
        self._spans.clear()
        self._last_end.clear()

    def __len__(self) -> int:
        return len(self._spans)
