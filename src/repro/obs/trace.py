"""Per-level engine traces: the device half of the observability layer.

The engine's ``_core_loop`` can carry a fixed-length ``(trace_len, 4)``
int32 array and write one row per level **on device** (``trace=True`` on the
public runners) — columns ``[frontier, was_push, fallback, flush]``, where
``frontier`` is the globally-agreed active count *entering* the level,
``was_push`` the direction decision (1 = sparse push / 0 = dense pull; under
``placement='async'`` the engine counts buffered flushes there), ``fallback``
the compacted-push capacity overflow flag, and ``flush`` mirrors ``was_push``
only under the async placement (an outbox flush happened this check).
Levels beyond ``trace_len`` are dropped on device (``.at[].set(mode='drop')``),
never clamp-overwritten.

Nothing in this module runs inside a trace: :func:`decode_level_trace` is
the host-side readback that turns the returned stats dict into
:class:`LevelTrace` records *after* the run — the split that keeps the
`host-sync` lint rule satisfied by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import numpy as np

__all__ = ["LevelTrace", "decode_level_trace", "TRACE_COLS"]

#: Column order of the on-device trace rows (engine._core_loop contract).
TRACE_COLS = ("frontier", "was_push", "fallback", "flush")


@dataclasses.dataclass(frozen=True)
class LevelTrace:
    """One decoded engine level (or one global check under async pacing)."""

    level: int           # 0-based body-iteration index
    frontier: int        # global active count entering the level
    direction: str       # 'push' | 'pull' ('flush' under async placement)
    fallback: bool       # compacted-push capacity overflow this level
    flush: bool          # outbox flush fired (async placement only)

    def as_dict(self) -> Dict[str, Any]:
        return {"level": self.level, "frontier": self.frontier,
                "direction": self.direction, "fallback": self.fallback,
                "flush": self.flush}


# trace-safe: decode is the post-run host readback of the stats the jitted
# runner already returned — repro-lint: disable=host-sync
def decode_level_trace(stats: Dict[str, Any]) -> List[LevelTrace]:
    """Decode ``stats['trace']`` (a traced run's stats dict) into records.

    Accepts both layouts the runners return: local ``(L, 4)`` and
    distributed ``(S, L, 4)`` — the trace rows are built from globally
    psum'd quantities, so every shard's copy is identical and shard 0 is
    authoritative.  Rows past the recorded level count (``pushes + pulls``
    body iterations) are unwritten and skipped; rows the device dropped
    (level >= trace_len) are simply absent.
    """
    if "trace" not in stats:
        raise KeyError("stats has no 'trace' — run the engine with "
                       "trace=True (and return_stats=True)")
    arr = np.asarray(stats["trace"])
    if arr.ndim == 3:             # distributed: stacked (S, L, 4), identical
        arr = arr[0]
    levels = int(np.asarray(stats["pushes"]).reshape(-1)[0]
                 + np.asarray(stats["pulls"]).reshape(-1)[0])
    out: List[LevelTrace] = []
    for lvl in range(min(levels, arr.shape[0])):
        frontier, was_push, fb, flush = (int(v) for v in arr[lvl])
        direction = ("flush" if flush else ("push" if was_push else "pull"))
        out.append(LevelTrace(level=lvl, frontier=frontier,
                              direction=direction, fallback=bool(fb),
                              flush=bool(flush)))
    return out
