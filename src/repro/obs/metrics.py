"""Metrics registry: counters, gauges, and a log-bucketed histogram.

The PIUMA co-design loop ran on counters — per-level traffic, offload-engine
utilization, collective counts — and this repo had been re-growing ad-hoc
versions of them (a latency deque in ``ServiceStats``, log lines for the
streaming fallback, nothing at all for cache invalidations).  This module is
the one place those events land: stdlib + numpy only, safe to import from
anywhere (including jax-free contexts like the lint lane), O(1) per
observation, O(buckets) memory.

Histogram buckets are geometric: bucket ``i`` covers
``[lo * growth**i, lo * growth**(i + 1))``, so a percentile estimate read
back from the histogram is within one bucket width — a factor of ``growth``
— of the exact order statistic.  That bounded relative error is the contract
``ServiceStats`` leans on when it serves ``latency_p50_ms`` from here instead
of an unbounded sample list (and what ``tests/test_property.py`` pins).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "REGISTRY", "get_registry"]


class Counter:
    """Monotonically increasing event count."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) must be >= 0")
        self._value += int(n)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Log-bucketed histogram over positive values.

    lo: lower edge of bucket 0 — observations below it clamp into bucket 0.
    growth: geometric bucket width; percentile estimates are exact up to one
      factor of ``growth`` (the estimate is the bucket's upper edge, so it
      never *under*-reports a latency percentile).
    n_buckets: observations past the top edge clamp into the last bucket.

    The defaults cover [1 µs, ~1.8 ks) in ~12%-wide buckets — service
    latencies from a cache hit to a pathological cold compile — in 192 ints.
    """

    __slots__ = ("name", "lo", "growth", "_log_growth", "_buckets",
                 "count", "sum")

    def __init__(self, name: str, *, lo: float = 1e-6, growth: float = 1.12,
                 n_buckets: int = 192):
        if not (lo > 0 and growth > 1 and n_buckets > 0):
            raise ValueError(f"histogram {name}: need lo>0, growth>1, "
                             f"n_buckets>0, got {lo}, {growth}, {n_buckets}")
        self.name = name
        self.lo = float(lo)
        self.growth = float(growth)
        self._log_growth = math.log(growth)
        self._buckets = [0] * int(n_buckets)
        self.count = 0
        self.sum = 0.0

    def observe(self, x: float) -> None:
        x = float(x)
        if math.isnan(x):
            return
        self.count += 1
        self.sum += x
        if x <= self.lo:
            i = 0
        else:
            i = min(len(self._buckets) - 1,
                    int(math.log(x / self.lo) / self._log_growth))
        self._buckets[i] += 1

    def bucket_upper(self, i: int) -> float:
        return self.lo * self.growth ** (i + 1)

    def percentile(self, pct: float) -> float:
        """Estimate the pct-th percentile as the upper edge of the bucket
        holding that order statistic (0.0 when empty).  Uses the same
        nearest-rank convention as ``np.percentile(..., method='lower')``
        up to the one-bucket quantization."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(self.count * pct / 100.0))
        seen = 0
        for i, c in enumerate(self._buckets):
            seen += c
            if seen >= rank:
                return self.bucket_upper(i)
        return self.bucket_upper(len(self._buckets) - 1)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Name -> metric, create-on-first-use.  One process-wide default
    (:data:`REGISTRY`) collects library events (streaming fallbacks, cache
    invalidations, compactions); services and benches may also carry their
    own instance for isolated readouts."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            m = self._counters.get(name)
            if m is None:
                m = self._counters[name] = Counter(name)
            return m

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            m = self._gauges.get(name)
            if m is None:
                m = self._gauges[name] = Gauge(name)
            return m

    def histogram(self, name: str, **kwargs) -> Histogram:
        with self._lock:
            m = self._histograms.get(name)
            if m is None:
                m = self._histograms[name] = Histogram(name, **kwargs)
            return m

    def snapshot(self) -> Dict[str, object]:
        """Flat {name: value} for counters/gauges, {name: dict} for
        histograms — the shape the bench persists and `summarize` renders."""
        with self._lock:
            out: Dict[str, object] = {}
            for n, c in self._counters.items():
                out[n] = c.value
            for n, g in self._gauges.items():
                out[n] = g.value
            for n, h in self._histograms.items():
                out[n] = h.snapshot()
            return out

    def reset(self) -> None:
        """Drop every metric (tests isolate themselves with this)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: The process-wide default registry: library code (engine/service/streaming)
#: counts its fallback and degradation events here unconditionally — a
#: counter bump is nanoseconds, so unlike spans there is no off switch.
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
