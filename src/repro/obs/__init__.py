"""Unified telemetry for the engine + service stack (DESIGN.md §17).

Three measurement planes, one export:

* **device**: per-level engine traces — ``trace=True`` on the engine runners
  records ``(frontier, direction, fallback, flush)`` per level into a
  fixed-length device array inside the single stepping loop (no host syncs;
  see :mod:`repro.obs.trace` for the decode contract);
* **host**: spans — :class:`~repro.obs.spans.SpanRecorder` wraps each
  query's life (enqueue → flush-wait → engine → readback) in closed,
  nest-checked intervals;
* **counters**: :mod:`repro.obs.metrics` — the process-wide registry every
  fallback/degradation event lands in (the ROADMAP guardrail).

:class:`Observability` bundles the three for one consumer (a
``GraphService``, a bench section, the example's ``--trace`` flag) and
exports them as one Chrome ``trace_event`` JSON.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
                      get_registry)
from .spans import Span, SpanRecorder
from .trace import LevelTrace, decode_level_trace
from .export import (build_chrome_trace, write_chrome_trace,
                     validate_chrome_trace, summarize, format_summary)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "get_registry", "Span", "SpanRecorder", "LevelTrace",
    "decode_level_trace", "build_chrome_trace", "write_chrome_trace",
    "validate_chrome_trace", "summarize", "format_summary",
    "Observability", "export_chrome_trace",
]


class Observability:
    """One consumer's telemetry bundle: spans + level traces + metrics.

    Attach one to a ``GraphService(obs=...)`` to turn on span recording and
    per-level engine tracing for that service; the metrics registry defaults
    to the process-wide one (counters are always on), but an isolated
    :class:`MetricsRegistry` may be passed for hermetic readouts.
    """

    #: Logical thread ids of the service span schema (DESIGN.md §17).
    TID_CLIENT = 1       # enqueue spans (submit-side)
    TID_SERVICE = 2      # batch / flush-wait / engine / readback spans

    def __init__(self, clock=time.perf_counter,
                 metrics: Optional[MetricsRegistry] = None,
                 span_capacity: int = 65536):
        self.spans = SpanRecorder(clock=clock, capacity=span_capacity)
        self.metrics = metrics if metrics is not None else get_registry()
        self.level_runs: List[Dict[str, Any]] = []

    def add_level_run(self, name: str, t0: float, t1: float,
                      stats: Dict[str, Any]) -> List[LevelTrace]:
        """Register one traced engine run: decode its per-level records and
        anchor them to the wall-clock window ``[t0, t1]`` the engine span
        measured (the exporter lays the levels out inside it)."""
        levels = decode_level_trace(stats)
        self.level_runs.append({"name": name, "t0": float(t0),
                                "t1": float(t1), "levels": levels})
        return levels

    def build_trace(self) -> Dict[str, Any]:
        return build_chrome_trace(self.spans.spans(), self.level_runs,
                                  self.metrics.snapshot())

    def export_chrome_trace(self, path: str) -> Dict[str, Any]:
        """Write the Chrome ``trace_event`` JSON; returns the document."""
        doc = self.build_trace()
        import json
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc

    def summary(self) -> Dict[str, Any]:
        return summarize(self.build_trace())

    def clear(self) -> None:
        self.spans.clear()
        self.level_runs.clear()


def export_chrome_trace(path: str, obs: Observability) -> Dict[str, Any]:
    """Module-level convenience: ``obs.export_chrome_trace(path)``."""
    return obs.export_chrome_trace(path)
