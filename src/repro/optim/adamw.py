"""AdamW with cosine schedule, global-norm clipping, optional bf16 moments.

bf16 moments are the memory lever that fits llama4-maverick-400b training on
256 v5e chips (DESIGN.md §7) — a distributed-optimization trick with precedent
(Gopher, PaLM used bf16/compressed optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "TrainState", "init_state", "apply_update",
           "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Any = jnp.float32   # bf16 for the 400B MoE config


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    m: Any
    v: Any
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.m, self.v, self.step), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(params) -> TrainState:
    zeros_like = lambda dt: lambda p: jnp.zeros(p.shape, dt)
    return TrainState(
        params=params,
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        step=jnp.zeros((), jnp.int32),
    )


def init_state_with_dtype(params, moment_dtype) -> TrainState:
    return TrainState(
        params=params,
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        step=jnp.zeros((), jnp.int32),
    )


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_update(cfg: AdamWConfig, state: TrainState, grads) -> TrainState:
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    gn = global_norm(grads)
    scale = (jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
             if cfg.clip_norm else 1.0)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    return TrainState(
        params=jax.tree.unflatten(treedef, [o[0] for o in out]),
        m=jax.tree.unflatten(treedef, [o[1] for o in out]),
        v=jax.tree.unflatten(treedef, [o[2] for o in out]),
        step=step,
    )
