"""Error-feedback gradient compression for the data-parallel all-reduce.

Two levels (both opt-in via launch/train.py flags):

* bf16 all-reduce — halves DP collective bytes, no state;
* int8 + error feedback — 4x fewer bytes; the quantization residual is carried
  to the next step (1-bit-Adam-style EF guarantees convergence for smooth
  losses).

These run under shard_map so the collective really sees the compressed
payload (with plain pjit the all-reduce dtype is whatever autodiff produced —
the roofline collective term in EXPERIMENTS.md quantifies the difference).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .. import compat

__all__ = ["psum_bf16", "psum_int8_ef", "init_ef_state"]


def psum_bf16(grads, axis_name):
    return jax.tree.map(
        lambda g: lax.psum(g.astype(jnp.bfloat16), axis_name).astype(g.dtype), grads)


def init_ef_state(grads_shape) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)


def _quant_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def psum_int8_ef(grads, ef, axis_name) -> Tuple[Any, Any]:
    """Returns (averaged grads, new error-feedback state)."""
    n = compat.axis_size(axis_name) if isinstance(axis_name, str) else None

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quant_int8(x)
        deq_local = q.astype(jnp.float32) * scale
        new_e = x - deq_local
        # int8 payloads summed in int32 (no overflow below 2^23 shards);
        # per-shard scales reduced alongside (max) for a shared dequant.
        qsum = lax.psum(q.astype(jnp.int32), axis_name)
        smax = lax.pmax(scale, axis_name)
        return (qsum.astype(jnp.float32) * smax).astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(td, [o[0] for o in out]),
            jax.tree.unflatten(td, [o[1] for o in out]))
