from .adamw import AdamWConfig, TrainState, init_state, apply_update, cosine_schedule
from . import compression
