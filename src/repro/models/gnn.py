"""GNN architectures: GIN, GatedGCN, DimeNet, EquiformerV2 (eSCN).

All message passing is routed through the PIUMA primitives: neighbor gathers
are `offload.dma_gather` (fine-grained DGAS reads when sharded) and
aggregations are segment reductions (`remote_scatter_add` semantics).  This is
the paper's own workload class — see DESIGN.md §4.

A single batch schema serves every GNN shape (full graph, sampled minibatch
flattened to an edge list, batched molecules):

    batch = {
      "x":        (N, F) node features,
      "src","dst":(E,) int32 edge lists (-1 padding),
      "labels":   (N,) int32 node labels | (Bg,) graph labels | (Bg,) f32 targets,
      "label_mask": optional (N,) bool (e.g. seed nodes of a sampled batch),
      "graph_id": optional (N,) int32 for batched-small-graph readout,
      "pos":      optional (N, 3) positions (geometric models),
      "wigner":   optional (E, (L+1)^2, (L+1)^2) edge rotations (equiformer),
      "triplet_kj","triplet_ji": optional (T,) int32 edge ids (dimenet),
      "angle":    optional (T,) f32 angles (dimenet),
    }
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import offload
from ..core import dgas as dgas_mod
from ..distributed.sharding import MeshRules, make_rules

__all__ = ["GNNConfig", "init_params", "forward", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                    # gin | gatedgcn | dimenet | equiformer_v2
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int = 2
    task: str = "node"           # node | graph | regression
    # gin
    eps_learnable: bool = True
    # dimenet
    n_radial: int = 6
    n_spherical: int = 7
    n_bilinear: int = 8
    cutoff: float = 5.0
    # equiformer_v2
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    # memory blocking for huge graphs (None = unchunked): edges / triplets are
    # streamed through scans so per-edge irrep intermediates never exceed
    # chunk x ncoef x C (the VMEM/SPAD discipline applied at the HBM level)
    edge_chunk: Optional[int] = None
    triplet_chunk: Optional[int] = None
    # PIUMA fine-grained remote access: above this node-table size (elements),
    # gathers/scatters run as shard_map DGAS exchanges instead of letting
    # GSPMD all-gather the table (the paper's central optimization).
    dgas_threshold: int = 4_000_000
    dgas_cap_factor: int = 4
    dtype: Any = jnp.float32

    @property
    def n_coef(self) -> int:
        return (self.l_max + 1) ** 2


def _dense(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) / np.sqrt(fan_in)


def _mlp_params(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": _dense(ks[i], (dims[i], dims[i + 1])),
             "b": jnp.zeros((dims[i + 1],))} for i in range(len(dims) - 1)]


def _stack_layers(layers):
    """List of identical pytrees -> one pytree with a leading layer dim
    (enables lax.scan over layers: one traced copy, small HLO)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def _mlp(params, x, act=jax.nn.relu):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = act(x)
    return x


def _segment_softmax(scores, seg, num_segments):
    smax = jnp.full((num_segments,), -1e30, scores.dtype).at[seg].max(scores)
    ex = jnp.exp(scores - smax[seg])
    den = jax.ops.segment_sum(ex, seg, num_segments=num_segments)
    return ex / jnp.maximum(den[seg], 1e-20)


# ---------------------------------------------------------------------------
# PIUMA fine-grained node access (shard_map DGAS) — the paper's technique
# ---------------------------------------------------------------------------

def _use_dgas(cfg, rules, x):
    return (rules.mesh is not None
            and int(np.prod(x.shape)) >= cfg.dgas_threshold
            and x.shape[0] % rules._axis_size(rules.flat) == 0)


def _dgas_capacity(cfg, local_n, S):
    return int(min(local_n, cfg.dgas_cap_factor * (-(-local_n // S))))


def gather_nodes(cfg, x, idx, rules: MeshRules):
    """x[idx] with padding (-1 -> 0 rows).

    Small / meshless: one fused local gather.  Large + meshed: a shard_map
    DGAS exchange — index requests route to the owner shard and only the
    requested rows return (never a replica of x), exactly the PIUMA DMA
    gather.  Requires x.shape[0] and idx.shape[0] divisible by the flat mesh
    (input_specs pads to 512).
    """
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    if not _use_dgas(cfg, rules, x):
        return offload.dma_gather(x, idx)
    axes = rules.flat
    S = rules._axis_size(axes)
    n = x.shape[0]
    att = dgas_mod.block_rule(n, S)
    local_n = idx.shape[0] // S
    cap = _dgas_capacity(cfg, local_n, S)
    fspec = P(axes)

    def shard_fn(xs, ids):
        return offload.dgas_gather(xs, ids, att, axes, capacity=cap, fill=0.0)

    return shard_map(
        shard_fn, mesh=rules.mesh,
        in_specs=(P(axes, *([None] * (x.ndim - 1))), fspec),
        out_specs=P(axes, *([None] * (x.ndim - 1))),
    )(x, idx)


def scatter_add_nodes(cfg, dest, idx, vals, rules: MeshRules):
    """Scatter-add vals into dest (an array to accumulate into, or an int n
    for a fresh zero buffer); idx<0 dropped.

    Large + meshed: PIUMA remote atomic adds — (index, value) pairs route to
    the owner shard which applies one fused segment update.
    """
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    if isinstance(dest, int):
        dest = jnp.zeros((dest,) + vals.shape[1:], vals.dtype)
    if not _use_dgas(cfg, rules, dest):
        return offload.dma_scatter_add(dest, idx, vals)
    axes = rules.flat
    S = rules._axis_size(axes)
    att = dgas_mod.block_rule(dest.shape[0], S)
    local_n = idx.shape[0] // S
    cap = _dgas_capacity(cfg, local_n, S)

    def shard_fn(ds, ids, vs):
        return offload.remote_scatter_add(ds, ids, vs, att, axes, capacity=cap)

    nd = dest.ndim
    return shard_map(
        shard_fn, mesh=rules.mesh,
        in_specs=(P(axes, *([None] * (nd - 1))), P(axes),
                  P(axes, *([None] * (vals.ndim - 1)))),
        out_specs=P(axes, *([None] * (nd - 1))),
    )(dest, idx, vals)


def _scatter_mean(vals, seg, num_segments):
    s = jax.ops.segment_sum(vals, seg, num_segments=num_segments)
    c = jax.ops.segment_sum(jnp.ones_like(seg, jnp.float32), seg,
                            num_segments=num_segments)
    return s / jnp.maximum(c, 1.0)[:, None]


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------

def _gin_init(cfg, key):
    ks = jax.random.split(key, cfg.n_layers + 2)
    def one(i, d_in):
        return {"mlp": _mlp_params(ks[i], [d_in, cfg.d_hidden, cfg.d_hidden]),
                "eps": jnp.zeros(()), "ln": jnp.ones((cfg.d_hidden,))}
    return {"layer0": one(0, cfg.d_feat),
            "layers": _stack_layers([one(i, cfg.d_hidden)
                                     for i in range(1, cfg.n_layers)]),
            "readout": _mlp_params(ks[-1], [cfg.d_hidden, cfg.n_classes])}


def _gin_forward(cfg, params, batch, rules):
    x = batch["x"].astype(jnp.float32)
    src, dst = batch["src"], batch["dst"]
    n = x.shape[0]
    valid = (src >= 0)[:, None]

    @jax.checkpoint
    def layer(lyr, x):
        msg = gather_nodes(cfg, x, src, rules) * valid
        agg = scatter_add_nodes(cfg, n, jnp.where(src >= 0, dst, -1), msg, rules)
        x = _mlp(lyr["mlp"], (1.0 + lyr["eps"]) * x + agg)
        x = _rmsnorm(x, lyr["ln"])
        return rules.constrain(x, "nodes", None)

    x = layer(params["layer0"], x)
    x, _ = jax.lax.scan(lambda xx, lyr: (layer(lyr, xx), None),
                        x, params["layers"])
    return x


# ---------------------------------------------------------------------------
# GatedGCN
# ---------------------------------------------------------------------------

def _gatedgcn_init(cfg, key):
    ks = jax.random.split(key, cfg.n_layers * 5 + 3)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "A": _dense(ks[5 * i], (d, d)), "B": _dense(ks[5 * i + 1], (d, d)),
            "D": _dense(ks[5 * i + 2], (d, d)), "E": _dense(ks[5 * i + 3], (d, d)),
            "C": _dense(ks[5 * i + 4], (d, d)),
            "ln_h": jnp.ones((d,)), "ln_e": jnp.ones((d,)),
        })
    return {"embed": _dense(ks[-3], (cfg.d_feat, d)),
            "edge_embed": jnp.zeros((d,)),
            "layers": _stack_layers(layers),
            "readout": _mlp_params(ks[-1], [d, cfg.n_classes])}


def _gatedgcn_forward(cfg, params, batch, rules):
    src, dst = batch["src"], batch["dst"]
    n = batch["x"].shape[0]
    h = batch["x"].astype(jnp.float32) @ params["embed"]
    e = jnp.broadcast_to(params["edge_embed"], (src.shape[0], cfg.d_hidden))
    valid = (src >= 0)[:, None]
    safe_dst = jnp.where(src >= 0, dst, n)

    @jax.checkpoint
    def layer(lyr, h, e):
        hs = gather_nodes(cfg, h, src, rules)
        hd = gather_nodes(cfg, h, dst, rules)
        e_new = e + jax.nn.relu(_rmsnorm(e @ lyr["C"] + hd @ lyr["D"] + hs @ lyr["E"],
                                         lyr["ln_e"]))
        eta = jax.nn.sigmoid(e_new)
        msg = (eta * (hs @ lyr["B"])) * valid
        mdst = jnp.where(src >= 0, dst, -1)
        num = scatter_add_nodes(cfg, n, mdst, msg, rules)
        den = scatter_add_nodes(cfg, n, mdst, eta * valid, rules)
        agg = num / (den + 1e-6)
        h = h + jax.nn.relu(_rmsnorm(h @ lyr["A"] + agg, lyr["ln_h"]))
        h = rules.constrain(h, "nodes", None)
        e = rules.constrain(e_new, "edges", None)
        return h, e

    (h, e), _ = jax.lax.scan(
        lambda carry, lyr: (layer(lyr, *carry), None), (h, e), params["layers"])
    return h


# ---------------------------------------------------------------------------
# DimeNet (directional message passing over triplets)
# ---------------------------------------------------------------------------

def _dimenet_init(cfg, key):
    ks = jax.random.split(key, cfg.n_layers * 6 + 4)
    d = cfg.d_hidden
    sbf = cfg.n_radial * cfg.n_spherical
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append({
            "w_rbf": _dense(ks[6 * i], (cfg.n_radial, d)),
            "w_sbf": _dense(ks[6 * i + 1], (sbf, cfg.n_bilinear)),
            "w_kj": _dense(ks[6 * i + 2], (d, cfg.n_bilinear)),
            "w_bil": _dense(ks[6 * i + 3], (cfg.n_bilinear, d)),
            "mlp": _mlp_params(ks[6 * i + 4], [d, d, d]),
            "out": _mlp_params(ks[6 * i + 5], [d, d]),
        })
    return {"embed": _mlp_params(ks[-4], [2 * cfg.d_feat + cfg.n_radial, cfg.d_hidden]),
            "blocks": _stack_layers(blocks),
            "readout": _mlp_params(ks[-1], [cfg.d_hidden,
                                            cfg.n_classes if cfg.task != "regression" else 1])}


def _rbf(dist, n_radial, cutoff):
    """Sine radial basis (DimeNet eq. 6): sqrt(2/c) sin(n pi d / c) / d."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(dist, 1e-3)[:, None]
    return np.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d / cutoff) / d


def _sbf(dist, angle, n_radial, n_spherical, cutoff):
    """Fourier product basis over (distance, angle) — structural stand-in for
    Bessel x spherical-harmonic products (DESIGN.md §9)."""
    rad = _rbf(dist, n_radial, cutoff)                          # (T, nr)
    ls = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(ls[None, :] * angle[:, None])                 # (T, ns)
    return (rad[:, :, None] * ang[:, None, :]).reshape(dist.shape[0], -1)


def _dimenet_forward(cfg, params, batch, rules):
    src, dst, pos = batch["src"], batch["dst"], batch["pos"]
    x = batch["x"].astype(jnp.float32)
    n = x.shape[0]
    E = src.shape[0]
    valid_e = src >= 0
    d_vec = (gather_nodes(cfg, pos, dst, rules)
             - gather_nodes(cfg, pos, src, rules))
    dist = jnp.sqrt(jnp.sum(d_vec ** 2, -1) + 1e-9)
    rbf = _rbf(dist, cfg.n_radial, cfg.cutoff) * valid_e[:, None]

    m = _mlp(params["embed"], jnp.concatenate(
        [gather_nodes(cfg, x, src, rules), gather_nodes(cfg, x, dst, rules),
         rbf], axis=-1))
    m = m * valid_e[:, None]

    t_kj, t_ji = batch["triplet_kj"], batch["triplet_ji"]
    angle = batch["angle"]
    T = t_kj.shape[0]
    chunk = cfg.triplet_chunk or T
    pad = -(-T // chunk) * chunk - T
    t_kj = jnp.pad(t_kj, (0, pad), constant_values=-1)
    t_ji = jnp.pad(t_ji, (0, pad))
    angle = jnp.pad(angle, (0, pad))
    nc = t_kj.shape[0] // chunk

    node_out = jnp.zeros((n, cfg.d_hidden))

    @jax.checkpoint
    def block(blk, m, node_out):
        # triplet gather: message of edge kj modulated by angular basis -> edge
        # ji; streamed in chunks so the (T, d) intermediates stay bounded
        def tri_body(agg, args, m=m, blk=blk):
            kj, ji, ang = args
            vt = kj >= 0
            sbf = _sbf(gather_nodes(cfg, dist, kj, rules), ang,
                       cfg.n_radial, cfg.n_spherical, cfg.cutoff) * vt[:, None]
            m_kj = gather_nodes(cfg, m, kj, rules)                 # (c, d)
            tri = ((m_kj @ blk["w_kj"]) * (sbf @ blk["w_sbf"]))    # (c, bil)
            tri = (tri @ blk["w_bil"]) * vt[:, None]               # (c, d)
            agg = scatter_add_nodes(cfg, agg, jnp.where(vt, ji, -1), tri, rules)
            agg = rules.constrain(agg, "edges", None)
            return agg, None

        agg0 = jnp.zeros((E, cfg.d_hidden))
        agg, _ = jax.lax.scan(tri_body, agg0,
                              (t_kj.reshape(nc, chunk), t_ji.reshape(nc, chunk),
                               angle.reshape(nc, chunk)))
        m = m + _mlp(blk["mlp"], m * (rbf @ blk["w_rbf"]) + agg)
        m = m * valid_e[:, None]
        m = rules.constrain(m, "edges", None)
        # per-block output: edges -> dst nodes (remote atomic add)
        node_out = node_out + scatter_add_nodes(
            cfg, n, jnp.where(valid_e, dst, -1), _mlp(blk["out"], m), rules)
        return m, node_out

    (m, node_out), _ = jax.lax.scan(
        lambda carry, blk: (block(blk, *carry), None),
        (m, node_out), params["blocks"])
    return node_out


# ---------------------------------------------------------------------------
# EquiformerV2 (eSCN SO(2) convolutions, graph attention)
# ---------------------------------------------------------------------------

def _so2_index_sets(l_max, m_max):
    """Flat irrep index (l^2+l+m) groups per |m| <= m_max."""
    sets = []
    for m in range(m_max + 1):
        pos = [l * l + l + m for l in range(m, l_max + 1)]
        neg = [l * l + l - m for l in range(m, l_max + 1)]
        sets.append((np.array(pos), np.array(neg)))
    return sets


def _equiformer_init(cfg, key):
    ks = jax.random.split(key, cfg.n_layers * 8 + 4)
    C = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        so2 = []
        for m in range(cfg.m_max + 1):
            nl = cfg.l_max + 1 - m
            blk = {"wr": _dense(ks[8 * i], (nl * C, nl * C), nl * C)}
            if m > 0:
                blk["wi"] = _dense(ks[8 * i + 1], (nl * C, nl * C), nl * C)
            so2.append(blk)
        layers.append({
            "so2": so2,
            "alpha": _mlp_params(ks[8 * i + 2], [2 * C, C, cfg.n_heads]),
            "gate": _mlp_params(ks[8 * i + 3], [C, (cfg.l_max + 1) * C]),
            "ln": jnp.ones((C,)),
            "ffn_gate": _mlp_params(ks[8 * i + 4], [C, (cfg.l_max + 1) * C]),
            "ffn": _dense(ks[8 * i + 5], (C, C)),
            "proj": _dense(ks[8 * i + 6], (C, C)),
        })
    return {"embed": _dense(ks[-3], (cfg.d_feat, C)),
            "layers": _stack_layers(layers),
            "readout": _mlp_params(ks[-1], [C,
                                            cfg.n_classes if cfg.task != "regression" else 1])}


def _so2_conv(x_rot, so2_params, idx_sets, C):
    """x_rot (E, ncoef, C): SO(2) conv mixing l within each |m| block."""
    out = jnp.zeros_like(x_rot)
    for m, (pos, neg) in enumerate(idx_sets):
        nl = pos.shape[0]
        xp = x_rot[:, pos, :].reshape(-1, nl * C)
        wr, wi = so2_params[m]["wr"], so2_params[m].get("wi")
        if m == 0:
            out = out.at[:, pos, :].set((xp @ wr).reshape(-1, nl, C))
        else:
            xn = x_rot[:, neg, :].reshape(-1, nl * C)
            yp = xp @ wr - xn @ wi
            yn = xp @ wi + xn @ wr
            out = out.at[:, pos, :].set(yp.reshape(-1, nl, C))
            out = out.at[:, neg, :].set(yn.reshape(-1, nl, C))
    return out


def _equiformer_forward(cfg, params, batch, rules):
    src, dst = batch["src"], batch["dst"]
    wig = batch["wigner"].astype(jnp.float32)      # (E, ncoef, ncoef), orthogonal
    n = batch["x"].shape[0]
    E = src.shape[0]
    C = cfg.d_hidden
    ncoef = cfg.n_coef
    valid = (src >= 0)
    idx_sets = _so2_index_sets(cfg.l_max, cfg.m_max)

    # embed invariant features into l=0; higher l start at 0
    X = jnp.zeros((n, ncoef, C))
    X = X.at[:, 0, :].set(batch["x"].astype(jnp.float32) @ params["embed"])

    l_ids = np.concatenate([[l] * (2 * l + 1) for l in range(cfg.l_max + 1)])
    l_ids = jnp.asarray(l_ids)

    # edge streaming (huge graphs): pad E to a chunk multiple
    chunk = cfg.edge_chunk or E
    pad = -(-E // chunk) * chunk - E
    src_p = jnp.pad(src, (0, pad), constant_values=-1)
    dst_p = jnp.pad(dst, (0, pad), constant_values=-1)
    wig_p = jnp.pad(wig, ((0, pad), (0, 0), (0, 0)))
    nc = src_p.shape[0] // chunk
    src_c = src_p.reshape(nc, chunk)
    dst_c = dst_p.reshape(nc, chunk)
    wig_c = wig_p.reshape(nc, chunk, ncoef, ncoef)

    def layer_fn(X, lyr):
        # pass A: attention logits from invariant (l=0) features — the l=0 row
        # of the block-diagonal Wigner is identity, so no rotation needed
        def alpha_body(_, args, X=X, lyr=lyr):
            s, d = args
            xi0 = gather_nodes(cfg, X[:, 0, :], d, rules)
            xj0 = gather_nodes(cfg, X[:, 0, :], s, rules)
            return 0, _mlp(lyr["alpha"], jnp.concatenate([xi0, xj0], -1))

        _, alpha = jax.lax.scan(alpha_body, 0, (src_c, dst_c))
        alpha = alpha.reshape(nc * chunk, cfg.n_heads)[:E]
        alpha = _edge_head_softmax(alpha, valid, dst, n, cfg.n_heads)
        alpha_c = jnp.pad(alpha.mean(-1), (0, pad)).reshape(nc, chunk)

        # pass B: eSCN messages, streamed; aggregation = remote atomic add
        @jax.checkpoint
        def msg_body(agg, args, X=X, lyr=lyr):
            s, d, w, a = args
            vmask = (s >= 0)
            Xi = gather_nodes(cfg, X, d, rules)
            Xj = gather_nodes(cfg, X, s, rules)
            Zi = jnp.einsum("eab,ebc->eac", w, Xi)       # rotate to edge frame
            Zj = jnp.einsum("eab,ebc->eac", w, Xj)
            msg = _so2_conv(Zi + Zj, lyr["so2"], idx_sets, C)
            gate = _mlp(lyr["gate"], msg[:, 0, :]).reshape(-1, cfg.l_max + 1, C)
            msg = msg * jax.nn.sigmoid(gate)[:, l_ids, :]
            msg = msg * a[:, None, None]
            back = jnp.einsum("eba,ebc->eac", w, msg)    # rotate back (D^T)
            back = back * vmask[:, None, None]
            agg = scatter_add_nodes(cfg, agg, jnp.where(vmask, d, -1), back,
                                    rules)
            agg = rules.constrain(agg, "nodes", None, None)
            return agg, None

        agg0 = jnp.zeros((n, ncoef, C))
        agg, _ = jax.lax.scan(msg_body, agg0, (src_c, dst_c, wig_c, alpha_c))
        X = X + agg @ lyr["proj"]
        # equivariant FFN: per-l gated by scalar MLP
        g = _mlp(lyr["ffn_gate"], _rmsnorm(X[:, 0, :], lyr["ln"]))
        g = jax.nn.sigmoid(g.reshape(n, cfg.l_max + 1, C))[:, l_ids, :]
        X = X + (X @ lyr["ffn"]) * g
        X = rules.constrain(X, "nodes", None, None)
        return X

    X, _ = jax.lax.scan(lambda xx, lyr: (layer_fn(xx, lyr), None),
                        X, params["layers"])
    return X[:, 0, :]


def _edge_head_softmax(alpha, valid, dst, n, n_heads):
    safe = jnp.where(valid, dst, n)
    smax = jnp.full((n + 1, n_heads), -1e30).at[safe].max(
        jnp.where(valid[:, None], alpha, -1e30))
    ex = jnp.exp(alpha - smax[safe]) * valid[:, None]
    den = jax.ops.segment_sum(ex, safe, num_segments=n + 1)
    return ex / jnp.maximum(den[safe], 1e-20)


# ---------------------------------------------------------------------------
# shared entry points
# ---------------------------------------------------------------------------

def _rmsnorm(x, w, eps=1e-6):
    rms = jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    return x * rms * w


_FWD = {"gin": _gin_forward, "gatedgcn": _gatedgcn_forward,
        "dimenet": _dimenet_forward, "equiformer_v2": _equiformer_forward}
_INIT = {"gin": _gin_init, "gatedgcn": _gatedgcn_init,
         "dimenet": _dimenet_init, "equiformer_v2": _equiformer_init}


def init_params(cfg: GNNConfig, key) -> dict:
    return _INIT[cfg.arch](cfg, key)


def forward(cfg: GNNConfig, params, batch, rules: Optional[MeshRules] = None):
    """Returns per-node hidden -> logits/outputs after readout."""
    rules = rules or make_rules(None)
    h = _FWD[cfg.arch](cfg, params, batch, rules)
    gid = batch.get("graph_id")
    if gid is not None and cfg.task in ("graph", "regression"):
        nm = batch.get("node_mask")
        if nm is not None:   # padded nodes contribute nothing to the readout
            h = h * nm[:, None].astype(h.dtype)
        n_graphs = int(batch["labels"].shape[0])
        h = jax.ops.segment_sum(h, gid, num_segments=n_graphs)
    return _mlp(params["readout"], h)


def loss_fn(cfg: GNNConfig, params, batch, rules: Optional[MeshRules] = None):
    out = forward(cfg, params, batch, rules)
    labels = batch["labels"]
    if cfg.task == "regression":
        pred = out[..., 0]
        loss = jnp.mean((pred - labels.astype(jnp.float32)) ** 2)
        return loss, {"loss": loss}
    logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), 1)[:, 0]
    mask = batch.get("label_mask")
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    else:
        loss = nll.mean()
    acc = (out.argmax(-1) == labels)
    if mask is not None:
        acc = jnp.where(mask, acc, False).sum() / jnp.maximum(mask.sum(), 1)
    else:
        acc = acc.mean()
    return loss, {"loss": loss, "acc": acc}
