from . import transformer, gnn, recsys
