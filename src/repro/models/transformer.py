"""LM transformer family: GQA / MLA / qk-norm / sliding-window / RoPE / MoE.

One parameterized stack covers the five assigned LM architectures
(mistral-large-123b, qwen3-14b, minicpm3-4b, llama4-maverick, mixtral-8x7b).

Engineering notes:
* layers are scanned over stacked weights (small HLO, fast compile, remat per
  layer) in groups of `moe_period` so dense/MoE interleaving costs nothing;
* attention is an online-softmax (flash) pure-jnp implementation — the
  Pallas kernel (kernels/flash_attention.py) is the TPU-target backend and is
  numerically validated against the same reference;
* MoE uses sort-based capacity dispatch (tokens sorted by expert, fixed
  per-expert capacity, overflow dropped) — the TPU-native analogue of the
  paper's queue-engine work distribution;
* all activation shardings go through distributed.sharding.MeshRules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import MeshRules, make_rules

__all__ = ["LMConfig", "MoEConfig", "MLAConfig", "init_params", "forward",
           "loss_fn", "init_cache", "decode_step", "param_logical_axes",
           "count_params"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    period: int = 1               # every `period`-th layer is MoE (last in group)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    rope_head_dim: int
    nope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_theta: float = 1e6
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 1024
    k_chunk: int = 64
    tie_embeddings: bool = False
    # fuse wq/wk/wv into one matmul and w1/w3 into one (Megatron-style): the
    # residual stream is read from HBM once instead of 3x / 2x per block
    fused_qkv: bool = False

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the TP axis divides it (padded logits sliced
        off before the loss); standard embedding-table padding."""
        return self.vocab if self.vocab % 16 == 0 else -(-self.vocab // 256) * 256

    @property
    def moe_period(self) -> int:
        return self.moe.period if self.moe else 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.moe_period == 0
        return self.n_layers // self.moe_period


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(shape):
    return jnp.ones(shape, jnp.float32)


def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2]
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


def _attn_params(cfg: LMConfig, key, G):
    ks = jax.random.split(key, 8)
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is None:
        if cfg.fused_qkv:
            p = {
                "wqkv": _dense_init(ks[0], (G, d, (H + 2 * Kv) * hd), cfg.dtype, d),
                "wo": _dense_init(ks[3], (G, H * hd, d), cfg.dtype, H * hd),
            }
        else:
            p = {
                "wq": _dense_init(ks[0], (G, d, H * hd), cfg.dtype, d),
                "wk": _dense_init(ks[1], (G, d, Kv * hd), cfg.dtype, d),
                "wv": _dense_init(ks[2], (G, d, Kv * hd), cfg.dtype, d),
                "wo": _dense_init(ks[3], (G, H * hd, d), cfg.dtype, H * hd),
            }
    else:
        m = cfg.mla
        qd = m.nope_head_dim + m.rope_head_dim
        p = {
            "wq_a": _dense_init(ks[0], (G, d, m.q_lora_rank), cfg.dtype, d),
            "q_a_norm": jnp.ones((G, m.q_lora_rank), jnp.float32),
            "wq_b": _dense_init(ks[1], (G, m.q_lora_rank, H * qd), cfg.dtype, m.q_lora_rank),
            "wkv_a": _dense_init(ks[2], (G, d, m.kv_lora_rank + m.rope_head_dim), cfg.dtype, d),
            "kv_a_norm": jnp.ones((G, m.kv_lora_rank), jnp.float32),
            "wk_b": _dense_init(ks[3], (G, m.kv_lora_rank, H * m.nope_head_dim),
                                cfg.dtype, m.kv_lora_rank),
            "wv_b": _dense_init(ks[4], (G, m.kv_lora_rank, H * m.v_head_dim),
                                cfg.dtype, m.kv_lora_rank),
            "wo": _dense_init(ks[5], (G, H * m.v_head_dim, d), cfg.dtype, H * m.v_head_dim),
        }
    if cfg.qk_norm:
        qk_dim = cfg.head_dim if cfg.mla is None else (
            cfg.mla.nope_head_dim + cfg.mla.rope_head_dim)
        p["q_norm"] = jnp.ones((G, qk_dim), jnp.float32)
        p["k_norm"] = jnp.ones((G, qk_dim), jnp.float32)
    return p


def _mlp_params(cfg: LMConfig, key, G, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    if cfg.fused_qkv:
        return {
            "w13": _dense_init(k1, (G, d, 2 * d_ff), cfg.dtype, d),
            "w2": _dense_init(k3, (G, d_ff, d), cfg.dtype, d_ff),
        }
    return {
        "w1": _dense_init(k1, (G, d, d_ff), cfg.dtype, d),
        "w3": _dense_init(k2, (G, d, d_ff), cfg.dtype, d),
        "w2": _dense_init(k3, (G, d_ff, d), cfg.dtype, d_ff),
    }


def _moe_params(cfg: LMConfig, key, G):
    m = cfg.moe
    k0, k1, k2, k3 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "router": _dense_init(k0, (G, d, m.n_experts), jnp.float32, d),
        "w1": _dense_init(k1, (G, m.n_experts, d, m.d_ff), cfg.dtype, d),
        "w3": _dense_init(k2, (G, m.n_experts, d, m.d_ff), cfg.dtype, d),
        "w2": _dense_init(k3, (G, m.n_experts, m.d_ff, d), cfg.dtype, m.d_ff),
    }


def init_params(cfg: LMConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    G, P = cfg.n_groups, cfg.moe_period
    layers = {}
    for j in range(P):
        sub = {
            "ln1": jnp.ones((G, cfg.d_model), jnp.float32),
            "ln2": jnp.ones((G, cfg.d_model), jnp.float32),
            "attn": _attn_params(cfg, jax.random.fold_in(keys[0], j), G),
        }
        # last sublayer of each group is MoE (if configured)
        if cfg.moe is not None and j == P - 1:
            sub["moe"] = _moe_params(cfg, jax.random.fold_in(keys[1], j), G)
        else:
            sub["mlp"] = _mlp_params(cfg, jax.random.fold_in(keys[2], j), G, cfg.d_ff)
        layers[f"sub{j}"] = sub
    p = {
        "embed": _dense_init(keys[3], (cfg.vocab_padded, cfg.d_model), cfg.dtype, cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(keys[4], (cfg.d_model, cfg.vocab_padded), cfg.dtype, cfg.d_model)
    return p


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_logical_axes(cfg: LMConfig, tp_size: int = 16) -> dict:
    """Logical axes per parameter (drives FSDP/TP in_shardings).

    MoE weights use expert parallelism when n_experts divides the TP axis,
    else tensor parallelism within each (replicated-across-TP) expert —
    mixtral's 8 experts on a 16-way axis take the TP path.
    """
    def attn_axes():
        if cfg.mla is None:
            if cfg.fused_qkv:
                a = {"wqkv": (None, "embed", "heads"),
                     "wo": (None, "heads", "embed")}
            else:
                a = {"wq": (None, "embed", "heads"), "wk": (None, "embed", "kv_heads"),
                     "wv": (None, "embed", "kv_heads"), "wo": (None, "heads", "embed")}
        else:
            a = {"wq_a": (None, "embed", None), "q_a_norm": (None, None),
                 "wq_b": (None, None, "heads"), "wkv_a": (None, "embed", None),
                 "kv_a_norm": (None, None), "wk_b": (None, None, "heads"),
                 "wv_b": (None, None, "heads"), "wo": (None, "heads", "embed")}
        if cfg.qk_norm:
            a["q_norm"] = (None, None)
            a["k_norm"] = (None, None)
        return a

    layers = {}
    for j in range(cfg.moe_period):
        sub = {"ln1": (None, None), "ln2": (None, None), "attn": attn_axes()}
        if cfg.moe is not None and j == cfg.moe_period - 1:
            if cfg.moe.n_experts % tp_size == 0:   # expert parallel
                sub["moe"] = {"router": (None, None, None),
                              "w1": (None, "expert", "embed", None),
                              "w3": (None, "expert", "embed", None),
                              "w2": (None, "expert", None, "embed")}
            else:                                  # TP within expert
                sub["moe"] = {"router": (None, None, None),
                              "w1": (None, None, "embed", "ff"),
                              "w3": (None, None, "embed", "ff"),
                              "w2": (None, None, "ff", "embed")}
        elif cfg.fused_qkv:
            sub["mlp"] = {"w13": (None, "embed", "ff"), "w2": (None, "ff", "embed")}
        else:
            sub["mlp"] = {"w1": (None, "embed", "ff"), "w3": (None, "embed", "ff"),
                          "w2": (None, "ff", "embed")}
        layers[f"sub{j}"] = sub
    out = {"embed": ("vocab", "embed"), "final_norm": (None,), "layers": layers}
    if not cfg.tie_embeddings:
        out["lm_head"] = ("embed", "vocab")
    return out


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms * w).astype(x.dtype)


def rope(x, positions, theta):
    """x (..., S, H, hd) rotated pairwise; positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attn(q, k, v, causal, window, seq_off, k_chunk, scale):
    """Pure-jnp flash attention with a flash-style custom VJP.

    q (B,S,H,hd); k,v (B,Skv,Kv,hd) -> (B,S,H,hv).

    Forward: online softmax over KV blocks with O(B*S*H) carries (m, l) —
    differentiating the naive scan would checkpoint the O(B*S*H*hv) `acc`
    carry per block (~5 GB/device x n_blocks at 14B-train scale).  The custom
    backward recomputes each block's probabilities from (q, k, v, lse)
    instead, so residuals are just q, k, v, out, lse.

    Queries are NOT blocked: under sequence parallelism q stays seq-sharded
    on the TP axis (all-gather-KV context parallelism); a q-chunk scan would
    place the sharded axis on a scan dim, which SPMD cannot partition.
    """
    out, lse = _flash_fwd_impl(q, k, v, causal, window, seq_off, k_chunk, scale)
    return out


def _blocks(x, k_chunk):
    B, Skv = x.shape[0], x.shape[1]
    t = min(k_chunk, Skv)
    while Skv % t:
        t -= 1
    nk = Skv // t
    return jnp.moveaxis(x.reshape(B, nk, t, *x.shape[2:]), 1, 0), nk, t


def _blk_logits(qr, kb, ki, k_chunk, scale, causal, window, seq_off):
    s = jnp.einsum("bqkgd,bckd->bqkgc", qr.astype(jnp.float32),
                   kb.astype(jnp.float32)) * scale
    Sq = qr.shape[1]
    qpos = (jnp.arange(Sq) + seq_off)[None, :, None, None, None]
    kpos = (ki * k_chunk + jnp.arange(kb.shape[1]))[None, None, None, None, :]
    mask = jnp.ones_like(s, jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return jnp.where(mask, s, -1e30)


def _flash_fwd_impl(q, k, v, causal, window, seq_off, k_chunk, scale):
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    hv = v.shape[-1]
    qr = q.reshape(B, Sq, Kv, G, hd)
    kr, nk, ck = _blocks(k, k_chunk)
    vr, _, _ = _blocks(v, k_chunk)

    def body(carry, inputs):
        m, l, acc = carry
        ki, kb, vb = inputs
        s = _blk_logits(qr, kb, ki, ck, scale, causal, window, seq_off)
        mc = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - mc[..., None])
        alpha = jnp.exp(m - mc)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32))
        return (mc, l, acc), None

    m0 = jnp.full((B, Sq, Kv, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Sq, Kv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Kv, G, hv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(nk), kr, vr))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    return out.reshape(B, Sq, H, hv), lse


def _flash_fwd(q, k, v, causal, window, seq_off, k_chunk, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, seq_off, k_chunk, scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, seq_off, k_chunk, scale, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    hv = v.shape[-1]
    qr = q.reshape(B, Sq, Kv, G, hd)
    do = dout.reshape(B, Sq, Kv, G, hv).astype(jnp.float32)
    og = out.reshape(B, Sq, Kv, G, hv).astype(jnp.float32)
    delta = jnp.sum(do * og, axis=-1)                       # (B,S,Kv,G)
    kr, nk, ck = _blocks(k, k_chunk)
    vr, _, _ = _blocks(v, k_chunk)

    def body(dq, inputs):
        ki, kb, vb = inputs
        s = _blk_logits(qr, kb, ki, ck, scale, causal, window, seq_off)
        p = jnp.exp(s - lse[..., None])                     # (B,S,Kv,G,c)
        dv = jnp.einsum("bqkgc,bqkgd->bckd", p, do)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", do, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqkgc,bckd->bqkgd", ds, kb.astype(jnp.float32))
        dk = jnp.einsum("bqkgc,bqkgd->bckd", ds, qr.astype(jnp.float32))
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (jnp.arange(nk), kr, vr))
    dk = jnp.moveaxis(dk, 0, 1).reshape(k.shape)
    dv = jnp.moveaxis(dv, 0, 1).reshape(v.shape)
    return (dq.reshape(q.shape).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_flash_attn.defvjp(_flash_fwd, _flash_bwd)


def _online_softmax_attn(q, k, v, *, causal, window, seq_off, q_chunk, k_chunk,
                         scale):
    return _flash_attn(q, k, v, causal, window, seq_off, k_chunk, scale)


def _mla_decode_attention(cfg: LMConfig, p, x, rules: MeshRules, *,
                          positions, cache, cache_len):
    """Absorbed-MLA decode (DeepSeek-V2 style): the KV cache stores only the
    latent c_kv (+ shared RoPE key) — kv_lora_rank + rope_head_dim floats per
    token instead of 2*H*head_dim."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    cq = rmsnorm(x @ p["wq_a"], p["q_a_norm"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv_a = x @ p["wkv_a"]
    c_new = rmsnorm(kv_a[..., : m.kv_lora_rank], p["kv_a_norm"])
    kr_new = rope(kv_a[..., m.kv_lora_rank:].reshape(B, S, 1, m.rope_head_dim),
                  positions, cfg.rope_theta)[:, :, 0]
    Smax_c = cache["ckv"].shape[1]
    hot = (jnp.arange(Smax_c) == cache_len)[None, :, None]
    ckv = jnp.where(hot, c_new.astype(cache["ckv"].dtype), cache["ckv"])
    krope = jnp.where(hot, kr_new.astype(cache["krope"].dtype), cache["krope"])
    new_cache = {"ckv": ckv, "krope": krope}

    # absorb W_kb into q: score = (W_kb^T q_nope) . c  +  q_rope . k_rope
    wkb = p["wk_b"].reshape(m.kv_lora_rank, H, m.nope_head_dim)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       wkb.astype(jnp.float32))                    # (B,1,H,r)
    s = (jnp.einsum("bshr,bcr->bhc", q_abs, ckv.astype(jnp.float32))
         + jnp.einsum("bshn,bcn->bhc", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32)))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    Smax = ckv.shape[1]
    mask = jnp.arange(Smax)[None, :] <= positions[:, -1:]
    s = jnp.where(mask[:, None, :], s * scale, -1e30)
    pr = jax.nn.softmax(s, axis=-1)                                # (B,H,Smax)
    ctx = jnp.einsum("bhc,bcr->bhr", pr, ckv.astype(jnp.float32))  # (B,H,r)
    wvb = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wvb.astype(jnp.float32)) # (B,H,hv)
    out = out.reshape(B, S, H * m.v_head_dim).astype(x.dtype)
    return (out @ p["wo"]).astype(x.dtype), new_cache


def _attention(cfg: LMConfig, p, x, rules: MeshRules, *, positions,
               cache=None, cache_len=None, window=None, return_kv=False):
    """Returns (out (B,S,d), new_cache | collected kv | None)."""
    if cache is not None and cfg.mla is not None:
        return _mla_decode_attention(cfg, p, x, rules, positions=positions,
                                     cache=cache, cache_len=cache_len)
    B, S, d = x.shape
    H = cfg.n_heads

    if cfg.mla is None:
        Kv, hd = cfg.n_kv_heads, cfg.head_dim
        if cfg.fused_qkv:
            qkv = x @ p["wqkv"]
            q = qkv[..., : H * hd].reshape(B, S, H, hd)
            k = qkv[..., H * hd: (H + Kv) * hd].reshape(B, S, Kv, hd)
            v = qkv[..., (H + Kv) * hd:].reshape(B, S, Kv, hd)
        else:
            q = (x @ p["wq"]).reshape(B, S, H, hd)
            k = (x @ p["wk"]).reshape(B, S, Kv, hd)
            v = (x @ p["wv"]).reshape(B, S, Kv, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"])
            k = rmsnorm(k, p["k_norm"])
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        hv = hd
    else:
        m = cfg.mla
        Kv = H
        qd = m.nope_head_dim + m.rope_head_dim
        cq = rmsnorm(x @ p["wq_a"], p["q_a_norm"])
        q = (cq @ p["wq_b"]).reshape(B, S, H, qd)
        kv_a = x @ p["wkv_a"]
        c_kv = rmsnorm(kv_a[..., : m.kv_lora_rank], p["kv_a_norm"])
        k_rope = kv_a[..., m.kv_lora_rank:].reshape(B, S, 1, m.rope_head_dim)
        k_rope = rope(k_rope, positions, cfg.rope_theta)
        q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
        q_rope = rope(q_rope, positions, cfg.rope_theta)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"])
        k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, m.nope_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.rope_head_dim))], axis=-1)
        if cfg.qk_norm:
            k = rmsnorm(k, p["k_norm"])
        v = (c_kv @ p["wv_b"]).reshape(B, S, H, m.v_head_dim)
        hd, hv = qd, m.v_head_dim

    if cache is None:
        # context-parallel layout: q seq-sharded, K/V gathered (GQA-small)
        q = rules.constrain(q, "batch", "seq_sp", None, None)
        k = rules.constrain(k, "batch", None, None, None)
        v = rules.constrain(v, "batch", None, None, None)

    if cache is None:
        out = _online_softmax_attn(
            q, k, v, causal=True, window=window, seq_off=0,
            q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk, scale=hd ** -0.5)
        if return_kv:
            if cfg.mla is not None:
                kv = {"ckv": c_kv, "krope": k_rope[:, :, 0]}
            else:
                kv = {"k": k, "v": v}
            out = out.reshape(B, S, -1)
            return (out @ p["wo"]).astype(x.dtype), kv
    else:
        # decode: S == 1; append to cache (ring buffer when len == window size)
        ck, cv = cache["k"], cache["v"]
        Smax = ck.shape[1]
        write = cache_len % Smax
        # one-hot blend (not dynamic-update-slice): a runtime-variable index
        # into the seq-SHARDED cache would force SPMD to replicate the cache;
        # the blend is elementwise and stays sharded.
        hot = (jnp.arange(Smax) == write)[None, :, None, None]
        ck = jnp.where(hot, k.astype(ck.dtype), ck)
        cv = jnp.where(hot, v.astype(cv.dtype), cv)
        cache = {"k": ck, "v": cv}
        # positions of cache slots (ring-aware)
        slot = jnp.arange(Smax)
        abs_pos = jnp.where(Smax >= cache_len + 1,
                            slot,
                            jnp.where(slot <= write, slot + cache_len - write,
                                      slot + cache_len - write - Smax))
        qpos = positions[:, -1:]                                   # (B,1)
        logit_mask = (abs_pos[None, :] <= qpos)                    # (B,Smax)
        if window is not None:
            logit_mask &= abs_pos[None, :] > qpos - window
        Gq = H // (k.shape[2] if cfg.mla is None else H)
        Kvh = ck.shape[2]
        G = H // Kvh
        qg = q.reshape(B, 1, Kvh, G, hd)
        s = jnp.einsum("bqkgd,bckd->bkgc", qg.astype(jnp.float32),
                       ck.astype(jnp.float32)) * hd ** -0.5       # (B,Kv,G,Smax)
        s = jnp.where(logit_mask[:, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgc,bckd->bkgd", pr, cv.astype(jnp.float32))
        out = out.reshape(B, 1, H, hv).astype(x.dtype)

    out = out.reshape(B, S, -1)
    return (out @ p["wo"]).astype(x.dtype), cache


def _mlp(p, x, rules: MeshRules):
    if "w13" in p:
        ff = p["w2"].shape[-2]
        h13 = x @ p["w13"]
        h = jax.nn.silu(h13[..., :ff]) * h13[..., ff:]
    else:
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = rules.constrain(h, "batch", None, "ff")
    return (h @ p["w2"]).astype(x.dtype)


def _moe_ffn(cfg: LMConfig, p, x, rules: MeshRules):
    """Sort-based capacity MoE with PER-DATA-SHARD dispatch.

    Tokens are grouped by DP shard; each group sorts ITS tokens by expert and
    fills a fixed local capacity (the queue-engine pattern: local queues +
    all-to-all to the expert owners).  All dispatch tensors carry the group
    dim, so nothing global-sized is ever materialized or sorted — the
    cross-shard movement is exactly the (dp-group, expert) exchange GSPMD
    lowers to an all-to-all over the EP axis.

    x (B,S,d) -> (out, aux_loss)
    """
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    dp = rules.dp_size()
    if B % dp != 0:
        dp = 1
    G = dp
    Ng = N // G
    k, E = m.top_k, m.n_experts
    C = int(np.ceil(Ng * k / E * m.capacity_factor / 128)) * 128   # MXU-aligned

    xg = x.reshape(G, Ng, d)
    xg = rules.constrain(xg, "batch", None, None)
    logits = (xg.astype(jnp.float32) @ p["router"])                # (G, Ng, E)
    if k == 1:
        probs = jax.nn.softmax(logits, axis=-1)
        gate = jnp.max(probs, -1, keepdims=True)
        eidx = jnp.argmax(logits, -1)[..., None]
    else:
        vals, eidx = jax.lax.top_k(logits, k)                      # (G, Ng, k)
        gate = jax.nn.softmax(vals, axis=-1)

    fe = rules.constrain(eidx.reshape(G, Ng * k).astype(jnp.int32), "batch", None)
    ft = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Ng, dtype=jnp.int32), k)[None], (G, Ng * k))
    fg = rules.constrain(gate.reshape(G, Ng * k), "batch", None)
    order = jnp.argsort(fe, axis=-1, stable=True)                  # local sort
    se = rules.constrain(jnp.take_along_axis(fe, order, -1), "batch", None)
    st = rules.constrain(jnp.take_along_axis(ft, order, -1), "batch", None)
    sg = rules.constrain(jnp.take_along_axis(fg, order, -1), "batch", None)
    starts = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(E, dtype=row.dtype)))(se)                  # (G, E)
    pos = (jnp.arange(Ng * k, dtype=jnp.int32)[None]
           - jnp.take_along_axis(starts, se, -1).astype(jnp.int32))
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)                    # overflow sink

    def _disp(xg_l, st_l, slot_l, keep_l):
        # local per-DP-group permutation (g dim == 1 inside the shard)
        xsel = jnp.take_along_axis(xg_l, st_l[..., None], axis=1)
        buf = jnp.zeros((xg_l.shape[0], E * C + 1, d), cfg.dtype)
        gi = jnp.arange(xg_l.shape[0], dtype=jnp.int32)[:, None]
        return buf.at[gi, slot_l].set(jnp.where(keep_l[..., None], xsel, 0))

    if rules.mesh is not None and G == rules.dp_size():
        from ..compat import shard_map
        from jax.sharding import PartitionSpec as PS
        bspec = rules.spec("batch")[0]
        xbuf = shard_map(
            _disp, mesh=rules.mesh,
            in_specs=(PS(bspec, None, None), PS(bspec, None),
                      PS(bspec, None), PS(bspec, None)),
            out_specs=PS(bspec, None, None))(xg, st, slot, keep)
    else:
        xbuf = _disp(xg, st, slot, keep)
    xe = xbuf[:, :-1].reshape(G, E, C, d)
    xe = rules.constrain(xe, "batch", "expert", None, None)
    w1, w3, w2 = p["w1"], p["w3"], p["w2"]
    if E % 16 != 0:
        # TP-within-expert mode: explicitly all-gather the FSDP-sharded d dim
        # of the weights (else SPMD reshards the much larger activations)
        w1 = rules.constrain(w1, None, None, "ff")
        w3 = rules.constrain(w3, None, None, "ff")
        w2 = rules.constrain(w2, None, "ff", None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w1)) * jnp.einsum(
        "gecd,edf->gecf", xe, w3)
    ye = jnp.einsum("gecf,efd->gecd", h, w2)
    ye = rules.constrain(ye, "batch", "expert", None, None)
    def _undisp(ye_l, st_l, slot_l, gk_l):
        yf = jnp.concatenate([ye_l.reshape(ye_l.shape[0], E * C, d),
                              jnp.zeros((ye_l.shape[0], 1, d), ye_l.dtype)], 1)
        contrib = jnp.take_along_axis(yf, slot_l[..., None], axis=1)
        contrib = contrib * gk_l[..., None].astype(ye_l.dtype)
        gi = jnp.arange(ye_l.shape[0], dtype=jnp.int32)[:, None]
        return jnp.zeros((ye_l.shape[0], Ng, d), ye_l.dtype).at[gi, st_l].add(
            contrib)

    gk = (sg * keep)
    if rules.mesh is not None and G == rules.dp_size():
        from ..compat import shard_map
        from jax.sharding import PartitionSpec as PS
        bspec = rules.spec("batch")[0]
        out = shard_map(
            _undisp, mesh=rules.mesh,
            in_specs=(PS(bspec, None, None, None), PS(bspec, None),
                      PS(bspec, None), PS(bspec, None)),
            out_specs=PS(bspec, None, None))(ye, st, slot, gk)
    else:
        out = _undisp(ye, st, slot, gk)

    # aux losses (GShard load balance + router z-loss)
    me = jax.nn.softmax(logits, axis=-1).mean((0, 1))              # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[fe.reshape(-1)].add(1.0) / (N * k)
    aux = m.aux_coef * E * jnp.sum(me * ce) + m.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# forward / loss / decode
# ---------------------------------------------------------------------------

def _layer_group(cfg: LMConfig, gparams, x, rules: MeshRules, positions):
    """One scan step: `moe_period` sublayers; returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    for j in range(cfg.moe_period):
        p = gparams[f"sub{j}"]
        h, _ = _attention(cfg, p["attn"], rmsnorm(x, p["ln1"]), rules,
                          positions=positions, window=cfg.window)
        x = x + h
        x = rules.constrain(x, "batch", "seq_sp", None)
        hin = rmsnorm(x, p["ln2"])
        if "moe" in p:
            h, a = _moe_ffn(cfg, p["moe"], hin, rules)
            aux = aux + a
        else:
            h = _mlp(p["mlp"], hin, rules)
        x = x + h
        x = rules.constrain(x, "batch", "seq_sp", None)
    return x, aux


def forward(cfg: LMConfig, params, tokens, rules: Optional[MeshRules] = None):
    """tokens (B,S) int32 -> (logits (B,S,vocab), aux)."""
    rules = rules or make_rules(None)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = rules.constrain(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, gparams):
        x, aux = carry
        fn = functools.partial(_layer_group, cfg, rules=rules, positions=positions)
        if cfg.remat:
            step = jax.checkpoint(lambda gp, xx: fn(gp, xx),
                                  policy=jax.checkpoint_policies.nothing_saveable)
        else:
            step = lambda gp, xx: fn(gp, xx)
        x2, a = step(gparams, x)
        return (x2, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    logits = rules.constrain(logits, "batch", "seq_sp", "vocab")
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., : cfg.vocab]
    return logits, aux


def prefill(cfg: LMConfig, params, tokens, rules: Optional[MeshRules] = None):
    """Inference prefill: forward pass + KV-cache materialization.

    Returns (last-position logits (B, vocab), cache ready for decode_step).
    """
    rules = rules or make_rules(None)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = rules.constrain(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, gparams):
        kv_out = {}
        for j in range(cfg.moe_period):
            p = gparams[f"sub{j}"]
            h, kv = _attention(cfg, p["attn"], rmsnorm(x, p["ln1"]), rules,
                               positions=positions, window=cfg.window,
                               return_kv=True)
            for kk, vv in kv.items():
                kv_out.setdefault(kk, []).append(vv.astype(cfg.dtype))
            x = x + h
            hin = rmsnorm(x, p["ln2"])
            if "moe" in p:
                h, _ = _moe_ffn(cfg, p["moe"], hin, rules)
            else:
                h = _mlp(p["mlp"], hin, rules)
            x = x + h
            x = rules.constrain(x, "batch", "seq_sp", None)
        return x, {kk: jnp.stack(vv) for kk, vv in kv_out.items()}

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x[:, -1], params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., : cfg.vocab]
    cache["len"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def loss_fn(cfg: LMConfig, params, batch, rules: Optional[MeshRules] = None):
    """batch = {tokens (B,S), labels? (B,S)}; next-token x-entropy + aux."""
    tokens = batch["tokens"]
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
    logits, aux = forward(cfg, params, tokens, rules)
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, logz - gold, 0.0)
    ntok = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / ntok
    zloss = 1e-4 * jnp.where(valid, logz ** 2, 0.0).sum() / ntok
    return loss + zloss + aux, {"loss": loss, "aux": aux, "ntok": ntok}


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Stacked KV cache (G, period, B, max_len, ...) per sublayer.

    GQA: full k/v heads; MLA: latent c_kv + shared RoPE key only.
    For sliding-window models pass max_len=window to get a ring buffer.
    """
    dtype = dtype or cfg.dtype
    G, P = cfg.n_groups, cfg.moe_period
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((G, P, batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((G, P, batch, max_len, m.rope_head_dim), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    Kv, hd, hv = cfg.n_kv_heads, cfg.head_dim, cfg.head_dim
    return {
        "k": jnp.zeros((G, P, batch, max_len, Kv, hd), dtype),
        "v": jnp.zeros((G, P, batch, max_len, Kv, hv), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: LMConfig, params, cache, tokens,
                rules: Optional[MeshRules] = None):
    """One decode step. tokens (B,1) -> (logits (B,vocab), new cache)."""
    rules = rules or make_rules(None)
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    clen = cache["len"]
    positions = jnp.broadcast_to(clen[None, None], (B, 1)).astype(jnp.int32)

    cache_keys = [k for k in cache.keys() if k != "len"]

    # the cache rides in the scan CARRY with per-layer in-place updates
    # (xs->ys stacking would double-buffer the multi-GB cache)
    def body(carry, gparams):
        x, caches, li = carry
        for j in range(cfg.moe_period):
            p = gparams[f"sub{j}"]
            sub_cache = {k: jax.lax.dynamic_index_in_dim(caches[k], li, 0,
                                                         keepdims=False)[j]
                         for k in cache_keys}
            h, sub_cache = _attention(cfg, p["attn"], rmsnorm(x, p["ln1"]), rules,
                                      positions=positions, cache=sub_cache,
                                      cache_len=clen, window=cfg.window)
            caches = {k: jax.lax.dynamic_update_index_in_dim(
                caches[k],
                jax.lax.dynamic_index_in_dim(
                    caches[k], li, 0, keepdims=False).at[j].set(sub_cache[k]),
                li, 0) for k in cache_keys}
            x = x + h
            hin = rmsnorm(x, p["ln2"])
            if "moe" in p:
                h, _ = _moe_ffn(cfg, p["moe"], hin, rules)
            else:
                h = _mlp(p["mlp"], hin, rules)
            x = x + h
        return (x, caches, li + 1), None

    (x, ncache, _), _ = jax.lax.scan(
        body, (x, {k: cache[k] for k in cache_keys}, jnp.int32(0)),
        params["layers"])
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype))[:, 0]
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., : cfg.vocab]
    ncache["len"] = clen + 1
    return logits.astype(jnp.float32), ncache
