"""Factorization Machine (Rendle, ICDM'10) over the PIUMA embedding engine.

score(x) = w0 + sum_i w_i x_i + 1/2 [ (sum_i v_i x_i)^2 - sum_i (v_i x_i)^2 ]

The hot path is the sparse table lookup: linear weight and latent vector are
FUSED into one (V, 1+k) table so a single fine-grained gather (one PIUMA DMA
descriptor) serves both — exactly the paper's "fetch only the useful 8 bytes"
discipline.  Multi-hot fields go through the embedding-bag engine
(kernels/embedding_bag.py).  Backward of the gather is a scatter-add — a
remote atomic at the owning table shard when distributed.

Batch schemas:
  train/serve:   {"ids": (B, F) int32 global row ids, "labels": (B,) f32}
  retrieval:     {"ids": (1, F) user fields, "cand": (Ncand, k) item vectors,
                  "cand_bias": (Ncand,)}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import offload
from ..distributed.sharding import MeshRules, make_rules

__all__ = ["FMConfig", "init_params", "fm_scores", "loss_fn", "retrieval_scores"]


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str
    n_fields: int = 39
    embed_dim: int = 10
    rows_per_field: int = 1_000_000
    # PIUMA fine-grained table access: when True (and a mesh is active), the
    # lookup runs as a shard_map DGAS exchange against the row-sharded table
    # instead of GSPMD's gather (which replicates request/result tensors).
    # Backward of the routed gather is the routed scatter-add = remote atomic.
    use_dgas: bool = False
    dgas_cap_factor: int = 4
    dtype: Any = jnp.float32

    @property
    def n_rows(self) -> int:
        return self.n_fields * self.rows_per_field

    @property
    def n_rows_padded(self) -> int:
        """Table padded to a mesh multiple (row-sharded DGAS block rule)."""
        return -(-self.n_rows // 512) * 512


def init_params(cfg: FMConfig, key) -> dict:
    k1, = jax.random.split(key, 1)
    # fused [linear | latent] table
    table = jax.random.normal(k1, (cfg.n_rows_padded, 1 + cfg.embed_dim), jnp.float32)
    table = (table * 0.01).astype(cfg.dtype)
    return {"table": table, "w0": jnp.zeros((), jnp.float32)}


def _fm_from_rows(w0, rows):
    lin = rows[..., 0].sum(-1)
    v = rows[..., 1:].astype(jnp.float32)                       # (..., F, k)
    s = v.sum(axis=-2)                                          # sum-square trick
    inter = 0.5 * (jnp.sum(s * s, -1) - jnp.sum(v * v, axis=(-2, -1)))
    return w0 + lin.astype(jnp.float32) + inter


def _fm_scores_dgas(cfg: FMConfig, params, ids, rules: MeshRules):
    """shard_map DGAS lookup: index requests route to the owning table shard,
    only the requested (1+k)-float rows return — never a table replica."""
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    from ..core.dgas import block_rule
    axes = rules.flat
    S = rules._axis_size(axes)
    B, F = ids.shape
    V = params["table"].shape[0]
    if B % S != 0 or V % S != 0:
        rows = offload.dma_gather(params["table"], ids)
        return _fm_from_rows(params["w0"], rows)
    att = block_rule(V, S)
    local_req = (B // S) * F
    cap = int(min(local_req, cfg.dgas_cap_factor * (-(-local_req // S))))

    def shard_fn(table, ids_l, w0):
        flat = offload.dgas_gather(table, ids_l.reshape(-1), att, axes,
                                   capacity=cap)
        return _fm_from_rows(w0, flat.reshape(ids_l.shape + (table.shape[-1],)))

    return shard_map(
        shard_fn, mesh=rules.mesh,
        in_specs=(P(axes, None), P(axes, None), P()),
        out_specs=P(axes),
    )(params["table"], ids, params["w0"])


def fm_scores(cfg: FMConfig, params, ids: jnp.ndarray,
              rules: Optional[MeshRules] = None) -> jnp.ndarray:
    """ids (B, F) -> (B,) scores. One fused gather per (sample, field)."""
    rules = rules or make_rules(None)
    if cfg.use_dgas and rules.mesh is not None:
        return _fm_scores_dgas(cfg, params, ids, rules)
    rows = offload.dma_gather(params["table"], ids)            # (B, F, 1+k)
    rows = rules.constrain(rows, "batch", None, None)
    return _fm_from_rows(params["w0"], rows)


def loss_fn(cfg: FMConfig, params, batch, rules: Optional[MeshRules] = None):
    scores = fm_scores(cfg, params, batch["ids"], rules)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(scores, 0) - scores * y
                    + jnp.log1p(jnp.exp(-jnp.abs(scores))))     # stable BCE
    auc_proxy = jnp.mean((scores > 0) == (y > 0.5))
    return loss, {"loss": loss, "acc": auc_proxy}


def retrieval_scores(cfg: FMConfig, params, ids: jnp.ndarray,
                     cand: jnp.ndarray, cand_bias: jnp.ndarray,
                     rules: Optional[MeshRules] = None) -> jnp.ndarray:
    """Score ONE query against Ncand candidates as a single batched dot.

    FM decomposes: score(u, c) = const(u) + bias_c + <sum_f v_f(u), v_c>
    so retrieval is a (Ncand, k) @ (k,) matvec — never a loop.
    """
    rules = rules or make_rules(None)
    rows = offload.dma_gather(params["table"], ids)             # (1, F, 1+k)
    v = rows[..., 1:].astype(jnp.float32)[0]                    # (F, k)
    u_vec = v.sum(0)                                            # (k,)
    u_const = (params["w0"] + rows[..., 0].sum()
               + 0.5 * (jnp.sum(u_vec ** 2) - jnp.sum(v * v)))
    cand = rules.constrain(cand, "rows", None)
    return u_const + cand_bias.astype(jnp.float32) + cand.astype(jnp.float32) @ u_vec
