"""Kernel/engine autotuning: sweep, persist, resolve (DESIGN.md §18).

The package closes ROADMAP open item 5's loop:

* :mod:`repro.tune.space` — the canonical defaults + sweep grids for every
  tunable constant (BBCSR tile geometry, switch_frac/push_slack, SSSP
  delta scale, service lane budget);
* :mod:`repro.tune.sweep` — the sweep harness (compiled best-of-N on a
  real device; deterministic interpret-mode + jnp-oracle cost models on
  CPU so CI stays green and reproducible) and the bench measurement lane
  (:func:`kernel_rows`);
* :mod:`repro.tune.resolve` — TUNED.json lookup at construction time with
  the precedence **explicit kwarg > tuned entry (backend, nearest scale) >
  default**, firing the ``tune.autotune_fallback`` obs counter on a miss;
* ``python -m repro.tune --scale N`` — regenerate the committed TUNED.json.

Import surface note: ``resolve``/``space`` are jax-free (usable from the
lint lane and stdlib tooling); the sweep machinery imports jax lazily.
"""
from __future__ import annotations

from . import space
from .resolve import (SCALE_WINDOW, TUNED_PATH, clear_cache, current_backend,
                      load_tuned, lookup, resolve, scale_of)

__all__ = ["space", "resolve", "lookup", "load_tuned", "clear_cache",
           "scale_of", "current_backend", "TUNED_PATH", "SCALE_WINDOW",
           "autotune", "kernel_rows", "stream_peak_bytes_per_s"]


def autotune(scale, **kw):
    """Lazy forwarder to :func:`repro.tune.sweep.autotune` (jax)."""
    from .sweep import autotune as _autotune
    return _autotune(scale, **kw)


def kernel_rows(scale, **kw):
    """Lazy forwarder to :func:`repro.tune.sweep.kernel_rows` (jax)."""
    from .sweep import kernel_rows as _kernel_rows
    return _kernel_rows(scale, **kw)


def stream_peak_bytes_per_s(**kw):
    """Lazy forwarder to the STREAM-triad roofline anchor (jax)."""
    from .sweep import stream_peak_bytes_per_s as _peak
    return _peak(**kw)
