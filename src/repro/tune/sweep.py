"""The autotuner: sweep the tunable grid, pick winners, emit TUNED entries.

Two measurement regimes behind one sweep (the ISSUE-10 loop):

* **real device** (``jax.default_backend() == 'tpu'``): every candidate is
  timed with a compiled best-of-N harness on the actual kernels — the
  hardware-true numbers the PIM-benchmarking literature asks for;
* **CPU / CI fallback**: kernels run in interpret mode only to *validate*
  (bit-identity vs the default config on a probe graph — wall clock of a
  Python-interpreted kernel is not kernel performance), and candidates are
  scored with deterministic cost models: modeled HBM stream bytes for the
  BBCSR tile geometry (padding waste + per-tile vector refetch, using the
  probe graph's real per-level frontier occupancy for the DMA-skip path),
  the §7 routed-bytes/fallback replay for ``switch_frac``/``push_slack``,
  measured *iteration counts* for the SSSP delta scale, and the packed-word
  amortization model for the service lane budget.  Deterministic scores →
  byte-identical TUNED.json across runs, so the tuning file can be committed
  and CI-diffed.

The incumbent default always competes and survives ties (space.HYSTERESIS):
a tuned entry only moves off the hand-picked value when the model/measure
says it is > 10% better, and a kernel candidate is only *admissible* when
its outputs are bit-identical to the default config's on the probe graph —
min/max tile combines reorder freely (exact semirings), but an 'add' shape
that reparenthesizes the f32 accumulation is rejected, keeping the golden
grid bit-stable under tuning by construction.
"""
# This whole module is a host-side measurement driver: every jit here is
# built, called, and block_until_ready'd from the host timing loop, and the
# numpy pulls read back *finished* probe results — nothing in this file ever
# runs under someone else's trace.
# repro-lint: disable-file=host-sync
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import space
from .resolve import current_backend, resolve

__all__ = ["autotune", "kernel_rows", "stream_peak_bytes_per_s",
           "bbcsr_stream_bytes", "probe_graph", "bfs_level_sets"]


# ---------------------------------------------------------------------------
# Probe machinery (host-side, deterministic)
# ---------------------------------------------------------------------------

def probe_graph(scale: int):
    """The sweep's input class: weighted RMAT at Graph500 skew, seed-pinned
    (the same generator family every bench section runs on)."""
    from ..core import rmat
    return rmat(scale, 8, seed=0)


def bfs_level_sets(csr) -> List[np.ndarray]:
    """Per-level frontier vertex sets of a source-0 BFS, in numpy — the
    deterministic activity profile the traffic/DMA-skip models replay."""
    indptr = np.asarray(csr.indptr)
    indices = np.asarray(csr.indices)
    seen = np.zeros(csr.n_rows, bool)
    seen[0] = True
    frontier = np.array([0], np.int64)
    out = []
    while frontier.size:
        out.append(frontier)
        nbr = np.concatenate([indices[indptr[v]:indptr[v + 1]]
                              for v in frontier]) if frontier.size else \
            np.zeros(0, np.int64)
        nbr = np.unique(nbr)
        frontier = nbr[~seen[nbr]]
        seen[frontier] = True
    return out


def _eccentricities(csr, sources: Sequence[int]) -> List[int]:
    indptr = np.asarray(csr.indptr)
    indices = np.asarray(csr.indices)
    eccs = []
    for s in sources:
        seen = np.zeros(csr.n_rows, bool)
        seen[s] = True
        frontier = np.array([s], np.int64)
        levels = 0
        while frontier.size:
            levels += 1
            nbr = np.unique(np.concatenate(
                [indices[indptr[v]:indptr[v + 1]] for v in frontier]))
            frontier = nbr[~seen[nbr]]
            seen[frontier] = True
        eccs.append(levels)
    return eccs


def _best_of(fn, reps: int = 5) -> float:
    """Compiled best-of-N wall time in seconds (jit + block_until_ready)."""
    import jax
    jax.block_until_ready(fn())          # compile outside the timed region
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _pick(table: List[Tuple[dict, float]]) -> dict:
    """Hysteresis winner: table[0] is the incumbent default; a challenger
    must beat it by > space.HYSTERESIS of its cost to replace it."""
    default_cfg, default_cost = table[0]
    best_cfg, best_cost = default_cfg, default_cost
    for cfg, cost in table[1:]:
        if cost < best_cost:
            best_cfg, best_cost = cfg, cost
    if best_cost < default_cost * (1.0 - space.HYSTERESIS):
        return best_cfg
    return default_cfg


# ---------------------------------------------------------------------------
# BBCSR kernel geometry: stream-byte model + bit-identity admissibility
# ---------------------------------------------------------------------------

def bbcsr_stream_bytes(bb) -> int:
    """Modeled HBM bytes one full SpMV sweep streams: every tile's
    (rows, cols, vals) triple, one x-block fetch per tile, and the y blocks
    written back.  Padding is real traffic — fuller tiles win."""
    n_tiles = int(bb.tile_rb.shape[0])
    per_tile = bb.tile_nnz * (4 + 4 + 4) + bb.block_cols * 4
    n_rb = -(-bb.n_rows // bb.block_rows)
    return n_tiles * per_tile + n_rb * bb.block_rows * 4


def _spmspv_stream_bytes(bb, level_sets: List[np.ndarray]) -> int:
    """DMA-skip traffic over a BFS run: per level, only tiles whose column
    block holds an active source stream (collapse_inactive_blocks), using
    the probe's real frontier sets rather than a density closed form."""
    tile_cb = np.asarray(bb.tile_cb)
    per_tile = bb.tile_nnz * (4 + 4 + 4) + bb.block_cols * 4
    n_rb = -(-bb.n_rows // bb.block_rows)
    y_bytes = n_rb * bb.block_rows * 4
    total = 0
    for frontier in level_sets:
        active_cb = np.unique(frontier // bb.block_cols)
        active_tiles = int(np.isin(tile_cb, active_cb).sum())
        total += active_tiles * per_tile + y_bytes
    return total


def _kernel_operands(csr, cfg: dict):
    from ..core import CSR, to_bbcsr
    t = csr.transpose()
    bb_w = to_bbcsr(t, **cfg)
    bb_u = to_bbcsr(CSR(t.indptr, t.indices, None, t.n_rows, t.n_cols), **cfg)
    return bb_w, bb_u


def _bit_identical(csr, cand: dict, default: dict, combine: str) -> bool:
    """Kernel outputs under the candidate tile shape must equal the default
    shape's bit-for-bit on the probe (interpret mode — same arithmetic a
    TPU run traces).  Always true for the exact min/max semirings; prunes
    'add' shapes that reorder the f32 accumulation."""
    import jax.numpy as jnp
    from ..core import engine
    from ..kernels import ops
    if cand == default:
        return True
    bb_c, bbu_c = _kernel_operands(csr, cand)
    bb_d, bbu_d = _kernel_operands(csr, default)
    n = csr.n_rows
    rng = np.random.default_rng(0)
    if combine == "add":
        x = jnp.asarray(rng.random(n, np.float32))
        return bool(np.array_equal(np.asarray(ops.spmv_dma(bb_c, x)),
                                   np.asarray(ops.spmv_dma(bb_d, x))))
    # 'min': a mid-BFS frontier exercises both active and skipped tiles
    frontier = jnp.zeros((n,), jnp.int32).at[::7].set(1)
    x = jnp.where(frontier > 0, jnp.asarray(rng.random(n, np.float32)),
                  jnp.inf)
    got_c = ops.spmspv_dma(bb_c, x, engine.tile_active(bb_c, frontier),
                           combine="min")
    got_d = ops.spmspv_dma(bb_d, x, engine.tile_active(bb_d, frontier),
                           combine="min")
    return bool(np.array_equal(np.asarray(got_c), np.asarray(got_d)))


def _time_kernel(csr, cfg: dict, combine: str, reps: int) -> float:
    """Hardware path: compiled best-of-N of the real Pallas kernel (µs)."""
    import jax
    import jax.numpy as jnp
    from ..core import engine
    from ..kernels import ops
    bb, bb_u = _kernel_operands(csr, cfg)
    n = csr.n_rows
    rng = np.random.default_rng(0)
    if combine == "add":
        x = jnp.asarray(rng.random(n, np.float32))
        fn = jax.jit(lambda: ops.spmv_dma(bb, x, interpret=False))
    else:
        frontier = jnp.zeros((n,), jnp.int32).at[::7].set(1)
        x = jnp.where(frontier > 0, jnp.asarray(rng.random(n, np.float32)),
                      jnp.inf)
        ta = engine.tile_active(bb, frontier)
        fn = jax.jit(lambda: ops.spmspv_dma(bb, x, ta, combine="min",
                                            interpret=False))
    return _best_of(fn, reps) * 1e6


def _sweep_bbcsr(section: str, csr, probe, level_sets, on_device: bool,
                 reps: int):
    combine = "add" if section.endswith("add") else "min"
    table = []
    for cfg in space.kernel_grid(section):
        if not _bit_identical(probe, cfg, space.kernel_grid(section)[0],
                              combine):
            continue
        if on_device:
            cost = _time_kernel(csr, cfg, combine, reps)
        else:
            bb, _ = _kernel_operands(csr, cfg)
            cost = float(bbcsr_stream_bytes(bb) if combine == "add"
                         else _spmspv_stream_bytes(bb, level_sets))
        table.append((cfg, cost))
    return table


# ---------------------------------------------------------------------------
# Engine / SSSP / service models
# ---------------------------------------------------------------------------

def _route_cost(level_sets, deg: np.ndarray, n: int, m: int,
                switch_frac: float, slack: float) -> float:
    """§7 replay: per BFS level, compacted-capacity routing while the
    frontier fits ``switch_frac * n`` (full-partition fallback on
    active-edge overflow), dense pull otherwise."""
    from ..core import engine
    cap = engine.frontier_edge_capacity(m, switch_frac, slack=slack)
    cost = 0.0
    for frontier in level_sets:
        edges = float(deg[frontier].sum())
        if frontier.size <= n * switch_frac:
            cost += cap if edges <= cap else m
        else:
            cost += m
    return cost


def _sweep_engine(csr, level_sets) -> Dict[str, float]:
    deg = np.diff(np.asarray(csr.indptr))
    n, m = csr.n_rows, csr.nnz
    slack0 = space.DEFAULTS["engine.push_slack"]
    table = [({"switch_frac": f},
              _route_cost(level_sets, deg, n, m, f, slack0))
             for f in space.GRIDS["engine"]["switch_frac"]]
    # grid order != default-first: rotate the incumbent to the front
    table.sort(key=lambda t: t[0]["switch_frac"]
               != space.DEFAULTS["engine.switch_frac"])
    f_win = _pick(table)["switch_frac"]
    stable = [({"push_slack": s},
               _route_cost(level_sets, deg, n, m, f_win, s))
              for s in space.GRIDS["engine"]["push_slack"]]
    stable.sort(key=lambda t: t[0]["push_slack"]
                != space.DEFAULTS["engine.push_slack"])
    return {"switch_frac": f_win, "push_slack": _pick(stable)["push_slack"]}


def _sweep_delta(csr) -> Tuple[float, Dict[str, float]]:
    """Measured iteration counts (deterministic on every backend) per
    delta-scale candidate; fewer engine levels = fewer global barriers."""
    from ..core.algorithms.sssp import auto_delta, sssp
    base = auto_delta(csr, scaled=False)
    table = []
    for s in space.GRIDS["sssp"]["delta_scale"]:
        _, stats = sssp(csr, 0, delta=base * s, return_stats=True)
        table.append(({"delta_scale": s}, float(int(stats["iters"]))))
    table.sort(key=lambda t: t[0]["delta_scale"]
               != space.DEFAULTS["sssp.delta_scale"])
    scores = {str(cfg["delta_scale"]): cost for cfg, cost in table}
    return _pick(table)["delta_scale"], scores


def _sweep_budget(csr) -> int:
    """Packed-lane amortization: per-query cost ∝ levels(B)·ceil(B/32)/B
    (the reachability lanes are 32-wide uint32 words), with levels(B) the
    max eccentricity over B spread sources, estimated from 8 probes."""
    n = csr.n_rows
    sources = np.linspace(0, n - 1, 8).astype(np.int64)
    ecc = max(_eccentricities(csr, sources))
    table = []
    for b in space.GRIDS["service"]["batch_budget"]:
        words = -(-b // 32)
        table.append(({"batch_budget": b}, ecc * words * csr.nnz / b))
    table.sort(key=lambda t: t[0]["batch_budget"]
               != space.DEFAULTS["service.batch_budget"])
    return _pick(table)["batch_budget"]


# ---------------------------------------------------------------------------
# The sweep driver + the bench measurement lane
# ---------------------------------------------------------------------------

def autotune(scale: int, *, backend: Optional[str] = None,
             reps: int = 5) -> dict:
    """One TUNED.json entry for (backend, scale): sweep every grid, apply
    the hysteresis/admissibility rules, record per-candidate scores."""
    backend = backend if backend is not None else current_backend()
    on_device = backend == "tpu"
    csr = probe_graph(scale)
    probe = probe_graph(min(scale, 8))   # interpret-mode validation input
    level_sets = bfs_level_sets(csr)
    params: Dict[str, float] = {}
    scores: Dict[str, Dict] = {}

    for section in ("kernels.bbcsr_add", "kernels.bbcsr_min"):
        table = _sweep_bbcsr(section, csr, probe, level_sets, on_device, reps)
        win = _pick(table)
        params.update({f"{section}.{k}": v for k, v in win.items()})
        scores[section] = {
            "unit": "us" if on_device else "model_bytes",
            "candidates": [[cfg, cost] for cfg, cost in table]}

    eng = _sweep_engine(csr, level_sets)
    params["engine.switch_frac"] = eng["switch_frac"]
    params["engine.push_slack"] = eng["push_slack"]

    delta_scale, delta_scores = _sweep_delta(csr)
    params["sssp.delta_scale"] = delta_scale
    scores["sssp"] = {"unit": "engine_iters", "candidates": delta_scores}

    params["service.batch_budget"] = _sweep_budget(csr)

    # unswept tunables ship their defaults so a matched entry is always
    # complete — the fallback counter means "no entry", never "hole"
    for name, val in space.DEFAULTS.items():
        params.setdefault(name, val)
    return {"backend": backend, "scale": int(scale), "params": params,
            "scores": scores}


def stream_peak_bytes_per_s(nbytes: int = 1 << 26, reps: int = 5) -> float:
    """Roofline anchor: measured STREAM-triad bandwidth (y = a·x + z) on the
    running backend — 3 streamed arrays per element."""
    import jax
    import jax.numpy as jnp
    n = nbytes // 4 // 3
    x = jnp.arange(n, dtype=jnp.float32)
    z = jnp.ones((n,), jnp.float32)
    fn = jax.jit(lambda: 2.0 * x + z)
    t = _best_of(fn, reps)
    return 3 * n * 4 / t


def _oracle_spmspv_min(csr_t, x):
    """jnp oracle for the (min,+) lane: compiled XLA segment-min over the
    edge stream of A^T (rows = destinations)."""
    import jax
    import jax.numpy as jnp
    indptr = jnp.asarray(csr_t.indptr)
    rows = jnp.repeat(jnp.arange(csr_t.n_rows), jnp.diff(indptr),
                      total_repeat_length=csr_t.nnz)
    w = (jnp.asarray(csr_t.values) if csr_t.values is not None
         else jnp.ones((csr_t.nnz,), jnp.float32))
    return jax.ops.segment_min(jnp.take(x, jnp.asarray(csr_t.indices)) + w,
                               rows, num_segments=csr_t.n_rows)


def kernel_rows(scale: int, *, backend: Optional[str] = None,
                path: Optional[str] = None, reps: int = 5) -> List[dict]:
    """The bench lane's kernel grid: default vs tuned config per BBCSR
    kernel, timed hardware-true on TPU or via the compiled jnp oracle on
    CPU (interpret-mode wall clock is not kernel performance), plus the
    folded-in oracle microbenches that used to live in bench_kernels.py.
    benchmarks/roofline.py turns these rows into achieved-vs-peak
    fractions."""
    import jax
    import jax.numpy as jnp
    from ..kernels import ref
    backend = backend if backend is not None else current_backend()
    on_device = backend == "tpu"
    csr = probe_graph(scale)
    level_sets = bfs_level_sets(csr)
    n = csr.n_rows
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(n, np.float32))
    rows = []
    for section, combine in (("kernels.bbcsr_add", "add"),
                             ("kernels.bbcsr_min", "min")):
        names = sorted(space.GRIDS[section])
        default = {k: space.DEFAULTS[f"{section}.{k}"] for k in names}
        tuned = {k: resolve(f"{section}.{k}", scale=scale, backend=backend,
                            path=path) for k in names}
        for label, cfg in (("default", default), ("tuned", tuned)):
            bb, _ = _kernel_operands(csr, cfg)
            bytes_model = (bbcsr_stream_bytes(bb) if combine == "add"
                           else _spmspv_stream_bytes(bb, level_sets))
            if on_device:
                us = _time_kernel(csr, cfg, combine, reps)
            elif combine == "add":
                us = _best_of(jax.jit(
                    lambda bb=bb: ref.spmv_bbcsr_ref(bb, x)), reps) * 1e6
            else:
                t = csr.transpose()
                us = _best_of(jax.jit(
                    lambda t=t: _oracle_spmspv_min(t, x)), reps) * 1e6
            rows.append({
                "name": f"kernels/{section.split('.')[1]}/{label}",
                "config": cfg, "us": round(us, 1),
                "bytes_model": int(bytes_model),
                "measured": "device" if on_device else "jnp_oracle",
                "bytes_per_s": bytes_model / (us * 1e-6)})

    # folded jnp-oracle microbenches (formerly benchmarks/bench_kernels.py):
    # modeled fine-grained traffic / measured oracle time, baseline-gated now
    q = jnp.asarray(np.random.default_rng(1).standard_normal(
        (4, 8, 1024, 128)).astype(np.float32))
    k = q[:, :4]
    us = _best_of(jax.jit(lambda: ref.flash_attention_ref(q, k, k)),
                  reps) * 1e6
    fa_bytes = (q.size + 2 * k.size + q.size) * 4
    rows.append({"name": "kernels/flash_attn_oracle_b4h8s1024",
                 "us": round(us, 1), "bytes_model": int(fa_bytes),
                 "measured": "jnp_oracle", "bytes_per_s": fa_bytes / (us * 1e-6)})
    table = jnp.asarray(np.random.default_rng(2).standard_normal(
        (100_000, 16)).astype(np.float32))
    idx = jnp.asarray(np.random.default_rng(3).integers(
        0, 100_000, 8192).astype(np.int32))
    bag = jnp.asarray(np.sort(np.random.default_rng(4).integers(
        0, 512, 8192)).astype(np.int32))
    us = _best_of(jax.jit(lambda: ref.embedding_bag_ref(table, idx, bag, 512)),
                  reps) * 1e6
    eb_bytes = 8192 * 64 + 512 * 64       # gathered rows + bag outputs
    rows.append({"name": "kernels/embedding_bag_oracle_8k_lookups",
                 "us": round(us, 1), "bytes_model": int(eb_bytes),
                 "measured": "jnp_oracle", "bytes_per_s": eb_bytes / (us * 1e-6)})
    return rows
