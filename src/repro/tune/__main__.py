"""CLI: regenerate the committed TUNED.json.

    PYTHONPATH=src python -m repro.tune --scale 7 --scale 12

Sweeps each requested scale on the running backend and merges the entries
into the output document: existing entries for *other* (backend, scale)
pairs are preserved, so a TPU run appends hardware-true entries next to
the committed CPU-model ones instead of clobbering them.
"""
from __future__ import annotations

import argparse
import json
import sys

from .sweep import autotune
from .resolve import TUNED_PATH, current_backend


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--scale", type=int, action="append", required=True,
                    help="graph scale(s) to tune at (repeatable)")
    ap.add_argument("--out", default=TUNED_PATH, metavar="PATH",
                    help="TUNED.json to merge into (default: repo root)")
    ap.add_argument("--reps", type=int, default=5,
                    help="best-of-N repetitions for device timing")
    args = ap.parse_args(argv)

    try:
        with open(args.out) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {"version": 1, "tool": "python -m repro.tune", "entries": []}

    backend = current_backend()
    for scale in args.scale:
        entry = autotune(scale, backend=backend, reps=args.reps)
        doc["entries"] = [e for e in doc.get("entries", [])
                          if (e.get("backend"), e.get("scale"))
                          != (backend, scale)] + [entry]
        print(f"tuned ({backend}, scale {scale}): "
              + ", ".join(f"{k}={v}" for k, v in sorted(entry["params"].items())))
    doc["entries"].sort(key=lambda e: (e.get("backend", ""),
                                       e.get("scale", 0)))
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
