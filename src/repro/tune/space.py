"""The tunable-parameter space: canonical defaults + sweep grids.

This module is the single home of every hand-picked performance constant
the engine/kernels/service historically inlined (ROADMAP open item 5).
``DEFAULTS`` is the ground truth the resolver falls back to when no tuned
entry matches (and what the `tuned-constants` lint rule forces the call
sites to route through); ``GRIDS`` is what `repro.tune.sweep` sweeps.

Stdlib-only on purpose: the resolver must stay importable from the lint
lane and from jax-free tooling.
"""
from __future__ import annotations

# Canonical hand-picked defaults, keyed "<section>.<param>".  These are the
# exact values the code shipped with before the autotuner existed — the
# resolver's fallback and the baseline the "tuned never slower than default"
# bench gate compares against.
DEFAULTS = {
    # BBCSR tile geometry per kernel semiring family.  'add' is the MXU
    # one-hot val*msg path (spmv_dma / spmspv_dma combine='add'); 'min' is
    # the masked-select (min,+)/(max,+) distance path (bb.tile_cnt users).
    "kernels.bbcsr_add.block_rows": 256,
    "kernels.bbcsr_add.block_cols": 512,
    "kernels.bbcsr_add.tile_nnz": 512,
    "kernels.bbcsr_min.block_rows": 256,
    "kernels.bbcsr_min.block_cols": 512,
    "kernels.bbcsr_min.tile_nnz": 512,
    # sorted segment-sum tile width (kernels/ops.segment_sum_sorted).
    "kernels.segment_sum.block_n": 512,
    # flash-attention tile shape (kernels/ops.flash_attention).
    "kernels.flash_attention.block_q": 128,
    "kernels.flash_attention.block_k": 128,
    # distributed direction switch: push while |frontier| <= switch_frac*n
    # (Beamer), and the frontier-proportional routing capacity derives from
    # it (engine.frontier_edge_capacity: m * switch_frac * push_slack).
    "engine.switch_frac": 1 / 32,
    "engine.push_slack": 4.0,
    # delta-stepping bucket width multiplier on the auto_delta histogram
    # quantile (algorithms/sssp.auto_delta).
    "sssp.delta_scale": 1.0,
    # service micro-batch lane budget (GraphService batch_budget).
    "service.batch_budget": 32,
}

# Sweep grids.  The incumbent default is always a candidate, and the
# autotuner keeps it unless a challenger wins by > HYSTERESIS — tuned
# configs should not churn on modeling noise, and a tie must never move
# behavior away from the values the golden/bench baselines pinned.
GRIDS = {
    "kernels.bbcsr_add": {
        "block_rows": (128, 256),
        "block_cols": (256, 512),
        "tile_nnz": (256, 512),
    },
    "kernels.bbcsr_min": {
        "block_rows": (128, 256),
        "block_cols": (256, 512),
        "tile_nnz": (256, 512),
    },
    "engine": {"switch_frac": (1 / 64, 1 / 32, 1 / 16),
               "push_slack": (2.0, 4.0, 8.0)},
    "sssp": {"delta_scale": (0.5, 1.0, 2.0)},
    "service": {"batch_budget": (16, 32, 64)},
}

#: A challenger must beat the incumbent default's modeled/measured cost by
#: this fraction before it replaces it (anti-churn, see GRIDS note).
HYSTERESIS = 0.10

#: Per-core VMEM budget a kernel candidate's working set must fit (bytes).
#: ~16 MiB/core on current TPUs; half is left for double buffering.
VMEM_BUDGET = 8 * 1024 * 1024


def bbcsr_vmem_bytes(block_rows: int, block_cols: int, tile_nnz: int) -> int:
    """Modeled VMEM working set of one SpMV/SpMSpV grid step: the x block,
    the accumulating y block, the (rows, cols, vals) tile streams, and the
    one-hot scatter/gather operands the MXU path materializes."""
    tile = tile_nnz * (4 + 4 + 4)                      # rows, cols, vals
    vecs = (block_cols + block_rows) * 4               # x block + y block
    onehot = tile_nnz * (block_cols + block_rows) * 4  # gather + scatter
    return tile + vecs + onehot


def kernel_grid(section: str):
    """All candidate dicts for a kernel section, VMEM-filtered, default
    first (the incumbent the hysteresis rule protects)."""
    grid = GRIDS[section]
    names = sorted(grid)
    default = {n: DEFAULTS[f"{section}.{n}"] for n in names}
    out = [default]
    stack = [{}]
    for name in names:
        stack = [dict(c, **{name: v}) for c in stack for v in grid[name]]
    for cand in stack:
        if cand == default:
            continue
        if section.startswith("kernels.bbcsr") and \
                bbcsr_vmem_bytes(**cand) > VMEM_BUDGET:
            continue
        out.append(cand)
    return out
