"""Tuned-config resolution: TUNED.json lookup with explicit-kwarg precedence.

``resolve(name, explicit=...)`` is the one funnel every tunable constant in
`core/engine.py`, `core/service.py`, and `kernels/ops.py` goes through
(machine-enforced by the `tuned-constants` lint rule).  Precedence:

1. an **explicit kwarg** at the call site (``explicit`` is not None) always
   wins — callers opt out of tuning per call;
2. the committed **TUNED.json** entry for the running backend whose scale
   is nearest the queried graph scale (within ``SCALE_WINDOW`` doublings —
   a scale-7 tuning says nothing about a scale-30 graph);
3. the hand-picked default from :data:`repro.tune.space.DEFAULTS`, in which
   case the ``tune.autotune_fallback`` obs counter fires (the standing
   guardrail: silent degradation to untuned behavior must be countable).

Entries are written by ``python -m repro.tune`` (see autotune.py); the file
schema is documented in DESIGN.md §18.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, Optional

from .space import DEFAULTS

__all__ = ["TUNED_PATH", "SCALE_WINDOW", "load_tuned", "lookup", "resolve",
           "scale_of", "current_backend", "clear_cache"]

#: Committed tuned-config document at the repo root (next to BENCH_*.json).
TUNED_PATH = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "TUNED.json"))

#: Max |graph scale - entry scale| (in log2 vertices) a tuned entry covers.
SCALE_WINDOW = 3

# (path -> (mtime, parsed doc or None)) — TUNED.json is read once per file
# version; resolve() runs at every runner dispatch and must stay O(dict).
_DOC_CACHE: Dict[str, Any] = {}


def clear_cache() -> None:
    """Drop the parsed-document cache (tests that swap TUNED files)."""
    _DOC_CACHE.clear()


def scale_of(n: int) -> int:
    """Graph scale = round(log2 n): the granularity entries are keyed at."""
    return int(round(math.log2(max(int(n), 2))))


def current_backend() -> str:
    """The running jax backend ('cpu', 'tpu', ...); 'cpu' without jax so
    the resolver stays importable from jax-free tooling."""
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "cpu"


def load_tuned(path: Optional[str] = None):
    """Parsed TUNED.json (or None when absent/unreadable — never raises:
    a missing tuning file degrades to defaults, counted, not a crash)."""
    path = path or TUNED_PATH
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return None
    hit = _DOC_CACHE.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = None
    _DOC_CACHE[path] = (mtime, doc)
    return doc


def lookup(backend: Optional[str] = None, scale: Optional[int] = None, *,
           path: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """The tuned entry for (backend, scale): same backend, nearest scale
    within SCALE_WINDOW (ties break toward the smaller scale).  None when
    nothing matches."""
    doc = load_tuned(path)
    if not doc:
        return None
    backend = backend if backend is not None else current_backend()
    best = None
    for entry in doc.get("entries", ()):
        if entry.get("backend") != backend:
            continue
        if scale is None:
            dist = 0
        else:
            dist = abs(int(entry.get("scale", 0)) - int(scale))
            if dist > SCALE_WINDOW:
                continue
        key = (dist, int(entry.get("scale", 0)))
        if best is None or key < best[0]:
            best = (key, entry)
    return best[1] if best else None


def _fallback_counter():
    # lazy: repro.obs is stdlib but keep import cost off the module path
    from ..obs.metrics import get_registry
    return get_registry().counter("tune.autotune_fallback")


def resolve(name: str, *, explicit: Any = None, n: Optional[int] = None,
            scale: Optional[int] = None, backend: Optional[str] = None,
            path: Optional[str] = None) -> Any:
    """Resolve tunable ``name`` ("<section>.<param>", see space.DEFAULTS).

    explicit: the call site's kwarg — returned untouched when not None.
    n / scale: graph size (scale wins when both given) keying the lookup.
    backend / path: overrides for tests; default running backend + repo file.
    """
    if explicit is not None:
        return explicit
    if name not in DEFAULTS:
        raise KeyError(f"unknown tunable {name!r} (add it to "
                       "repro.tune.space.DEFAULTS)")
    if scale is None and n is not None:
        scale = scale_of(n)
    entry = lookup(backend, scale, path=path)
    if entry is not None:
        params = entry.get("params", {})
        if name in params:
            return params[name]
    _fallback_counter().inc()
    return DEFAULTS[name]
