import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count at first init).

r"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * compiled.memory_analysis()  — proves the program fits per-chip HBM,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * a collective inventory parsed from the post-SPMD HLO (bytes per
    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
    — the roofline's collective term,
  * derived roofline terms (seconds) against TPU v5e constants.

Usage:
  python -m repro.launch.dryrun --arch gin-tu --shape molecule [--multi-pod]
  python -m repro.launch.dryrun --sweep --out results/dryrun.json [--multi-pod]

Results are written incrementally (one JSON per completed cell merged into
--out), so a long sweep can be watched and resumed (--resume skips done cells).
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat
from ..configs import (get_config, ARCH_NAMES, input_specs, shape_names,
                       make_step, state_shapes, state_logical_axes,
                       param_logical_axes)
from ..configs.common import param_shardings, apply_variant
from ..distributed.sharding import make_rules
from ..optim import adamw
from .mesh import make_production_mesh, HW

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum per-device payload bytes of every collective in the (post-SPMD)
    HLO.  For each op we take max(result bytes, operand bytes) as the payload
    estimate — all-gather counts the gathered result, reduce-scatter the
    scattered operand, all-reduce its (equal) payload."""
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        eq = line.find("=")
        if eq < 0:
            continue
        m = _COLL_RE.search(line, eq)
        if not m:
            continue
        kind = m.group(1).lower()
        # HLO grammar: %name = <result shape(s)> op-name(<operand shapes>...)
        res_b = _shape_bytes(line[eq + 1: m.start()])
        paren = line[m.end():]          # regex consumed the opening '('
        depth, end = 1, len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        op_b = _shape_bytes(paren[:end])
        payload = max(res_b, op_b)
        s = stats.setdefault(kind, {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += payload
    total = sum(s["bytes"] for s in stats.values())
    return {"ops": stats, "total_bytes": total}


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float) -> dict:
    """Per-device roofline terms in seconds (TPU v5e constants)."""
    t_c = flops / HW["peak_bf16_flops"]
    t_m = hbm_bytes / HW["hbm_bw"]
    t_n = coll_bytes / HW["ici_bw"]
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dom,
            "bound_s": max(t_c, t_m, t_n),
            "roofline_fraction": (max(t_c, t_m) / max(t_c, t_m, t_n, 1e-30)
                                  if max(t_c, t_m, t_n) > 0 else 0.0)}


def model_flops(ac, bundle) -> Optional[float]:
    """MODEL_FLOPS = 6*N*D (dense LM) / 6*N_active*D (MoE) — global, fwd+bwd."""
    if ac.family != "lm":
        return None
    cfg = bundle.model
    from ..models.transformer import count_params, init_params as ip
    shapes = jax.eval_shape(lambda: ip(cfg, jax.random.PRNGKey(0)))
    n_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if cfg.moe is not None:
        m = cfg.moe
        moe_layers = cfg.n_layers // m.period
        expert_params_per_layer = 3 * cfg.d_model * m.d_ff
        n_active = (n_total
                    - moe_layers * m.n_experts * expert_params_per_layer
                    + moe_layers * max(m.top_k, 1) * expert_params_per_layer)
    else:
        n_active = n_total
    toks = int(np.prod(bundle.batch["tokens"].shape))
    mult = 6 if bundle.kind == "train" else 2
    return float(mult) * n_active * toks


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             seq_parallel: bool = True, donate: bool = True,
             variant: str = None) -> dict:
    # seq_parallel default ON: the per-layer saved residuals are sequence-
    # sharded (Megatron SP), without which an 88-layer 123B model cannot fit
    # its remat carries in 16 GB/chip (DESIGN.md §7).
    ac = get_config(arch)
    if shape in ac.skips:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": ac.skips[shape]}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, seq_parallel=seq_parallel)
    bundle = apply_variant(input_specs(ac, shape), variant)
    step = make_step(ac, bundle, rules)
    n_chips = int(np.prod(mesh.devices.shape))

    batch_structs = dict(bundle.batch)
    batch_sh = {k: rules.input_sharding(v.shape, *bundle.batch_axes[k])
                for k, v in batch_structs.items()}

    params_shape, state_shape = state_shapes(ac, bundle.model)
    pax = param_logical_axes(ac, bundle.model, params_shape)
    p_sh = param_shardings(rules, params_shape, pax)

    if bundle.kind == "train":
        st_sh = adamw.TrainState(params=p_sh, m=p_sh, v=p_sh,
                                 step=rules.input_sharding(()))
        fn = jax.jit(step, in_shardings=(st_sh, batch_sh),
                     donate_argnums=(0,) if donate else ())
        lowered = fn.lower(state_shape, batch_structs)
    elif bundle.kind == "prefill":
        fn = jax.jit(step, in_shardings=(p_sh, batch_sh))
        lowered = fn.lower(params_shape, batch_structs)
    elif bundle.kind == "decode":
        cache_sh = {k: rules.input_sharding(v.shape, *bundle.cache_axes[k])
                    for k, v in bundle.cache.items()}
        fn = jax.jit(step, in_shardings=(p_sh, cache_sh, batch_sh),
                     donate_argnums=(1,) if donate else ())
        lowered = fn.lower(params_shape, bundle.cache, batch_structs)
    else:  # serve / retrieval
        fn = jax.jit(step, in_shardings=(p_sh, batch_sh))
        lowered = fn.lower(params_shape, batch_structs)

    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compat.cost_analysis_dict(compiled)
    flops = float(cost.get("flops", -1.0))
    hbm_bytes = float(cost.get("bytes accessed", -1.0))
    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_d[attr] = int(getattr(mem, attr, -1))
    per_device_total = (mem_d["temp_size_in_bytes"]
                        + mem_d["argument_size_in_bytes"])

    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    roof = roofline_terms(max(flops, 0.0), max(hbm_bytes, 0.0),
                          coll["total_bytes"])
    mf = model_flops(ac, bundle)

    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "variant": variant,
        "status": "ok", "kind": bundle.kind, "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": flops, "hbm_bytes": hbm_bytes,
            "collective_bytes": coll["total_bytes"],
            "memory": mem_d, "total_hbm_used": per_device_total,
            "fits_16gb": bool(per_device_total < HW["hbm_per_chip"]),
        },
        "collectives": coll["ops"],
        "roofline": roof,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (flops * n_chips)
                               if (mf and flops > 0) else None),
        "hlo_bytes": len(hlo),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--seq-parallel", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--out", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="named model transform (configs.common.VARIANTS)")
    args = ap.parse_args()

    results = []
    if args.out and args.resume and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}

    def emit(rec):
        results.append(rec)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
        r = rec.get("roofline", {})
        print(f"[{rec['arch']} x {rec['shape']} pods={1+int(rec['multi_pod'])}] "
              f"{rec['status']} "
              + (f"compile={rec.get('compile_s')}s dom={r.get('dominant')} "
                 f"fit={rec['per_device']['fits_16gb']} "
                 f"cT={r.get('compute_s', 0):.2e} mT={r.get('memory_s', 0):.2e} "
                 f"nT={r.get('collective_s', 0):.2e}"
                 if rec["status"] == "ok" else rec.get("reason", rec.get("error", ""))),
              flush=True)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.sweep:
        cells = []
        for a in ARCH_NAMES:
            ac = get_config(a)
            for s in shape_names(ac):
                for mp in meshes:
                    cells.append((a, s, mp))
        # smallest families first so results accumulate early
        order = {"gin-tu": 0, "gatedgcn": 1, "fm": 2, "dimenet": 3,
                 "equiformer-v2": 4, "mixtral-8x7b": 5, "qwen3-14b": 6,
                 "minicpm3-4b": 7, "llama4-maverick-400b-a17b": 8,
                 "mistral-large-123b": 9}
        cells.sort(key=lambda c: (order.get(c[0], 99), c[1], c[2]))
        for a, s, mp in cells:
            if (a, s, mp) in done:
                continue
            try:
                emit(run_cell(a, s, multi_pod=mp,
                              seq_parallel=args.seq_parallel))
            except Exception as e:  # noqa: BLE001 — record and continue sweep
                emit({"arch": a, "shape": s, "multi_pod": mp,
                      "status": "error", "error": f"{type(e).__name__}: {e}",
                      "trace": traceback.format_exc()[-2000:]})
    else:
        assert args.arch and args.shape, "--arch and --shape (or --sweep)"
        for mp in meshes:
            emit(run_cell(args.arch, args.shape, multi_pod=mp,
                          seq_parallel=args.seq_parallel,
                          variant=args.variant))


if __name__ == "__main__":
    main()
