"""End-to-end training driver.

Composes the full substrate: config registry -> synthetic data pipeline ->
sharded step (pjit) -> AdamW -> fault-tolerant loop (periodic async
checkpoints, restart-on-failure, straggler log) -> metrics.

On this CPU container it trains reduced configs end-to-end (examples/ uses
it for the ~100M-param run); on a real pod the same driver runs the full
configs — only --arch/--smoke and the mesh change (PIUMA's "the application
code does not need to change").

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt [--compress bf16]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.common import (input_specs, make_step, state_shapes,
                              param_logical_axes, param_shardings)
from ..checkpoint.ckpt import CheckpointManager
from ..data import synthetic
from ..distributed.fault_tolerance import FTConfig, run_training
from ..distributed.sharding import make_rules
from ..models import transformer as TF
from ..models import gnn as GNN
from ..models import recsys as RS
from ..optim import adamw
from ..core.graph import uniform_random_graph


def build_batch_iter(ac, model_cfg, args):
    if ac.family == "lm":
        it = synthetic.lm_batches(args.batch, args.seq, model_cfg.vocab,
                                  seed=args.seed)
        return ({"tokens": jnp.asarray(b["tokens"])} for b in synthetic.prefetch(it))
    if ac.family == "recsys":
        it = synthetic.recsys_batches(args.batch, model_cfg.n_fields,
                                      model_cfg.rows_per_field, seed=args.seed)
        return ({k: jnp.asarray(v) for k, v in b.items()}
                for b in synthetic.prefetch(it))
    # gnn: resample a graph batch every step
    def gen():
        g = uniform_random_graph(args.gnn_nodes, 4, seed=args.seed)
        i = 0
        while True:
            b = synthetic.gnn_batch(model_cfg.arch, g, model_cfg.d_feat,
                                    model_cfg.n_classes,
                                    l_max=model_cfg.l_max, seed=args.seed + i)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            i += 1
    return synthetic.prefetch(gen())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--gnn-nodes", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    ac = get_config(args.arch)
    model_cfg = ac.smoke if args.smoke else ac.model
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    rules = make_rules(mesh)

    # build a train bundle matching the runtime batch
    import dataclasses as dc
    from ..configs.common import SpecBundle
    bundle = SpecBundle("train", model_cfg, {}, {})
    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                            total_steps=args.steps,
                            moment_dtype=ac.moment_dtype)
    step = make_step(ac, bundle, rules, opt)

    key = jax.random.PRNGKey(args.seed)
    from ..configs.common import init_params as ip
    params = ip(ac, model_cfg, key)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={args.arch} params={n_params/1e6:.1f}M devices={n_dev}")
    state = adamw.init_state_with_dtype(params, ac.moment_dtype)

    step_jit = jax.jit(step, donate_argnums=(0,))
    batches = build_batch_iter(ac, model_cfg, args)
    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every, keep=3)

    logs = []

    def on_metrics(i, m):
        if i % args.log_every == 0 or i == args.steps:
            rec = {"step": i, **{k: float(np.asarray(v)) for k, v in m.items()}}
            logs.append(rec)
            print(json.dumps(rec), flush=True)

    t0 = time.time()
    state, report = run_training(step_jit, state, batches, ckpt, args.steps,
                                 FTConfig(ckpt_every=args.ckpt_every),
                                 on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"done: {report['steps_run']} steps in {dt:.1f}s "
          f"({dt / max(report['steps_run'], 1):.3f}s/step), "
          f"restarts={report['restarts']}, "
          f"stragglers={len(report['straggler_events'])}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"logs": logs, "report": {k: v for k, v in report.items()
                                                if k != "straggler_events"}}, f)


if __name__ == "__main__":
    main()
