"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the pod axis
is the HyperX top level (optical links in PIUMA; ICI-over-DCN on TPU pods).

Defined as functions so importing this module never touches jax device state
(device count is locked at first jax init — dryrun.py sets
xla_force_host_platform_device_count BEFORE importing anything).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_cores_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cores_mesh(n: int | None = None, name: str = "cores"):
    """1-D mesh over all available devices (graph-algorithm tests/benchmarks)."""
    n = n or len(jax.devices())
    return jax.make_mesh((n,), (name,))


# TPU v5e hardware constants for the roofline (per chip / per link)
HW = {
    "peak_bf16_flops": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_per_chip": 16 * 2**30,  # bytes
}
