"""Batched serving driver: prefill + decode loop with a shared KV cache.

Serves an LM config against synthetic request batches (greedy decode),
or scores recsys batches.  The decode loop is one jitted `decode_step` per
token — cache donated, so serving is allocation-free after warmup.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..distributed.sharding import make_rules
from ..models import transformer as TF
from ..models import recsys as RS
from ..data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ac = get_config(args.arch)
    cfg = ac.smoke if args.smoke else ac.model
    rules = make_rules(None)

    if ac.family == "recsys":
        params = RS.init_params(cfg, jax.random.PRNGKey(args.seed))
        it = synthetic.recsys_batches(args.batch, cfg.n_fields,
                                      cfg.rows_per_field, seed=args.seed)
        score = jax.jit(lambda p, ids: RS.fm_scores(cfg, p, ids, rules))
        b = next(it)
        t0 = time.time()
        s = score(params, jnp.asarray(b["ids"]))
        s.block_until_ready()
        print(f"scored {args.batch} requests in {time.time()-t0:.3f}s; "
              f"mean score {float(s.mean()):.4f}")
        return

    params = TF.init_params(cfg, jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen
    if cfg.window is not None:
        max_len = min(max_len, max(cfg.window, 1))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)

    prefill = jax.jit(lambda p, t: TF.prefill(cfg, p, t, rules))
    decode = jax.jit(lambda p, c, t: TF.decode_step(cfg, p, c, t, rules),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, jnp.asarray(prompts))
    # right-size the cache for decoding
    pad = max_len - args.prompt_len
    if pad > 0:
        cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)) +
                             ((0, 0),) * (v.ndim - 4)) if hasattr(v, "ndim") and v.ndim > 1
                     else v)
                 for k, v in cache.items()}
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = np.stack(out, 1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.3f}s; "
          f"decoded {args.gen} tokens in {t_decode:.3f}s "
          f"({args.batch*args.gen/max(t_decode,1e-9):.1f} tok/s)")
    print("sample generation ids:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
