"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP + pod axis).

Models annotate activations with *logical* axes; `MeshRules` maps them onto
the physical mesh.  When no mesh is active (CPU smoke tests), constraints are
no-ops, so model code is mesh-agnostic.

The mapping mirrors PIUMA's ATT: a programmable table translating application
space (logical axes) to physical location (mesh axes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import with_sharding_constraint

Axes = Union[None, str, Sequence[str]]

__all__ = ["MeshRules", "LOGICAL", "make_rules"]

# logical axis -> role
LOGICAL = {
    "batch": "data parallel (pod x data)",
    "seq": "sequence parallel (model) — opt-in",
    "heads": "tensor parallel",
    "kv_heads": "tensor parallel (may be smaller than mesh axis)",
    "ff": "tensor parallel",
    "vocab": "tensor parallel",
    "expert": "expert parallel",
    "embed": "FSDP (weights only)",
    "nodes": "graph vertex partition (data x model flattened)",
    "edges": "graph edge partition (data x model flattened)",
    "rows": "embedding-table row partition",
}


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Optional[Mesh]
    batch: Axes
    seq_sp: Axes          # sequence-parallel target (None = replicated seq)
    tp: Axes
    fsdp: Axes
    expert: Axes
    flat: Axes            # fully-flattened axis set (graph / recsys)

    def spec(self, *logical: Optional[str]) -> P:
        table = {
            "batch": self.batch, "seq": None, "seq_sp": self.seq_sp,
            "heads": self.tp, "kv_heads": self.tp, "ff": self.tp,
            "vocab": self.tp, "expert": self.expert, "embed": self.fsdp,
            "seq_kv": self.tp,   # decode KV caches shard the sequence dim (SP)
            "nodes": self.flat, "edges": self.flat, "rows": self.flat,
            None: None,
        }
        return P(*(table[a] for a in logical))

    def dp_size(self) -> int:
        """Number of data-parallel shards (1 when meshless)."""
        if self.mesh is None:
            return 1
        return self._axis_size(self.batch)

    def _axis_size(self, axes: Axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[a]
        return n

    def constrain(self, x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
        """Sharding constraint; dims the mesh does not divide are left
        UNCONSTRAINED (e.g. 40 heads on a 16-way TP axis, batch=1 decode)."""
        if self.mesh is None:
            return x
        spec = self.spec(*logical)
        entries = []
        used: set = set()
        for dim, axes in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
            names = ((axes,) if isinstance(axes, str) else tuple(axes or ()))
            if axes is not None and (dim % self._axis_size(axes) != 0
                                     or used & set(names)):
                # non-dividing dim, or a mesh axis already consumed by an
                # earlier dim (e.g. seq-parallel + vocab-parallel logits)
                entries.append(P.UNCONSTRAINED)
            else:
                entries.append(axes)
                used |= set(names)
        return with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries)))

    def sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def input_sharding(self, shape, *logical: Optional[str]):
        """NamedSharding for jit in_shardings: non-dividing dims -> replicated."""
        if self.mesh is None:
            return None
        spec = self.spec(*logical)
        entries = []
        for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            entries.append(axes if axes is None or dim % self._axis_size(axes) == 0
                           else None)
        return NamedSharding(self.mesh, P(*entries))


def make_rules(mesh: Optional[Mesh] = None, *, seq_parallel: bool = False) -> MeshRules:
    """Build rules from a mesh with axes ('data','model') or ('pod','data','model')."""
    if mesh is None:
        return MeshRules(None, None, None, None, None, None, None)
    names = mesh.axis_names
    has_pod = "pod" in names
    batch = ("pod", "data") if has_pod else ("data",)
    return MeshRules(
        mesh=mesh,
        batch=batch,
        seq_sp="model" if seq_parallel else None,
        tp="model",
        fsdp=batch,
        expert="model",
        flat=(("pod", "data", "model") if has_pod else ("data", "model")),
    )
