"""Fault tolerance: restartable training driver, straggler watch, elastic
re-meshing.

The driver wraps any jitted step function with:
  * periodic async checkpoints (checkpoint.CheckpointManager),
  * automatic restore-and-continue across (injected or real) failures,
  * straggler detection — steps slower than `straggler_factor` x rolling
    median are logged with the offending step index (at fleet scale this event
    feeds the scheduler that drains the slow host; here it is observable
    behaviour under test),
  * SIGTERM -> synchronous final checkpoint (preemption safety).

Elasticity: `reshard_state` moves a TrainState onto a different mesh; with
checkpoint.restore(shardings=...) a job killed on 512 chips resumes on 256
(tests/test_fault_tolerance.py exercises a shrink and a grow).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from ..checkpoint.ckpt import CheckpointManager

__all__ = ["FTConfig", "SimulatedFailure", "run_training", "reshard_state"]


class SimulatedFailure(RuntimeError):
    """Raised by a fail_injector to emulate a node loss."""


@dataclasses.dataclass
class FTConfig:
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 32
    install_sigterm: bool = False


def run_training(step_fn: Callable, state: Any, batches: Iterator,
                 ckpt: CheckpointManager, max_steps: int,
                 ft: FTConfig = FTConfig(), *,
                 fail_injector: Optional[Callable[[int], None]] = None,
                 on_metrics: Optional[Callable[[int, dict], None]] = None,
                 shardings: Any = None) -> tuple[Any, dict]:
    """Run to max_steps with restart-on-failure. Returns (state, report)."""
    report = {"restarts": 0, "straggler_events": [], "steps_run": 0}
    durations: list[float] = []

    restored, step0 = ckpt.restore_latest(jax.eval_shape(lambda: state), shardings)
    if restored is not None:
        state = restored
        start = int(step0)
    else:
        start = 0
        ckpt.maybe_save(0, state, force=True)

    if ft.install_sigterm:
        def _on_term(signum, frame):
            ckpt.wait()
            ckpt.maybe_save(int(np.asarray(state.step)), state, force=True)
            raise SystemExit(143)
        signal.signal(signal.SIGTERM, _on_term)

    step = start
    restarts = 0
    while step < max_steps:
        try:
            batch = next(batches)
            if fail_injector is not None:
                fail_injector(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            durations.append(dt)
            if len(durations) > ft.straggler_window:
                durations.pop(0)
            med = float(np.median(durations))
            if len(durations) >= 8 and dt > ft.straggler_factor * med:
                report["straggler_events"].append(
                    {"step": step, "dt": dt, "median": med})
            step += 1
            report["steps_run"] += 1
            ckpt.maybe_save(step, state)
            if on_metrics is not None:
                on_metrics(step, jax.tree.map(np.asarray, metrics))
        except SimulatedFailure:
            restarts += 1
            report["restarts"] = restarts
            if restarts > ft.max_restarts:
                raise
            ckpt.wait()
            restored, step0 = ckpt.restore_latest(
                jax.eval_shape(lambda: state), shardings)
            if restored is None:
                raise
            state = restored
            step = int(step0)
    ckpt.wait()
    ckpt.maybe_save(step, state, force=True)
    ckpt.wait()
    return state, report


def reshard_state(state: Any, shardings: Any) -> Any:
    """Move a pytree onto new shardings (elastic mesh change)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        state, shardings)
