from . import sharding, fault_tolerance
