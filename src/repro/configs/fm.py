"""fm [recsys] 39 sparse fields, embed_dim=10, FM 2-way interactions via the
O(nk) sum-square trick. [ICDM'10 (Rendle); paper]

Tables: 39 fields x 1M rows, linear+latent fused into one (V, 1+k) table so a
single fine-grained gather serves both (PIUMA DMA discipline).
"""
from ..models.recsys import FMConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = FMConfig(name="fm", n_fields=39, embed_dim=10, rows_per_field=1_000_000)
    smoke = FMConfig(name="fm-smoke", n_fields=6, embed_dim=4, rows_per_field=1000)
    return ArchConfig(name="fm", family="recsys", model=model, smoke=smoke)
