"""mixtral-8x7b [moe] 32L d4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
8 experts top-2, sliding-window attention (4096). [arXiv:2401.04088; hf]
"""
import jax.numpy as jnp
from ..models.transformer import LMConfig, MoEConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = LMConfig(
        name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000, window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=14336, period=1),
        rope_theta=1e6, dtype=jnp.bfloat16)
    smoke = LMConfig(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128, window=8, dtype=jnp.float32,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, period=1),
        q_chunk=16, k_chunk=16)
    return ArchConfig(
        name="mixtral-8x7b", family="lm", model=model, smoke=smoke,
        notes="SWA makes long_500k decodable with a window-sized ring cache "
              "(the only LM in the pool that runs that cell)")
