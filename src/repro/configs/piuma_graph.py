"""The paper's own workload configs: RMAT scales + algorithm selections.

These drive benchmarks/table1_spmv.py and table2_apps.py (CPU-scaled: the
paper uses RMAT-30; CPU validation uses RMAT-14..18 with the same structure).
"""
import dataclasses

@dataclasses.dataclass(frozen=True)
class GraphWorkloadConfig:
    rmat_scale: int = 14
    edge_factor: int = 16
    pagerank_iters: int = 20
    bfs_max_levels: int = 32
    walkers: int = 4096
    walk_steps: int = 16
    lpa_iters: int = 8
    spmv_block_rows: int = 256
    spmv_block_cols: int = 512
    spmv_tile_nnz: int = 512

def config() -> GraphWorkloadConfig:
    return GraphWorkloadConfig()
