"""dimenet [gnn] 6 blocks d128 n_bilinear=8 n_spherical=7 n_radial=6.

[arXiv:2003.03123; unverified]  Triplet lists precomputed by the data
pipeline; triplet count capped at 4*E for the huge shapes (sampled triplets).
"""
from ..models.gnn import GNNConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = GNNConfig(name="dimenet", arch="dimenet", n_layers=6, d_hidden=128,
                      d_feat=100, n_radial=6, n_spherical=7, n_bilinear=8)
    smoke = GNNConfig(name="dimenet-smoke", arch="dimenet", n_layers=2,
                      d_hidden=16, d_feat=8, n_radial=4, n_spherical=3,
                      n_bilinear=4)
    return ArchConfig(name="dimenet", family="gnn", model=model, smoke=smoke)
