"""mistral-large-123b [dense] 88L d12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = LMConfig(
        name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
        n_kv_heads=8, head_dim=128, d_ff=28672, vocab=32768,
        rope_theta=1e6, dtype=jnp.bfloat16)
    smoke = LMConfig(
        name="mistral-large-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=128, rope_theta=1e6,
        dtype=jnp.float32, q_chunk=16, k_chunk=16)
    return ArchConfig(
        name="mistral-large-123b", family="lm", model=model, smoke=smoke,
        skips={"long_500k": "pure full attention (no sub-quadratic path); "
                            "see DESIGN.md §4"},
        notes="largest dense LM in the pool; FSDP+TP memory plan DESIGN.md §7")
