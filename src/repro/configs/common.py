"""ArchConfig + per-family shape tables + input_specs + step builders.

Every assigned (architecture x input-shape) cell resolves here to:
  * a model config (possibly shape-adapted, e.g. edge-chunk sizes),
  * a batch of ShapeDtypeStructs + logical sharding axes,
  * a step function (train / prefill / decode / serve / retrieval),
  * state structure + logical axes (for FSDP/TP in_shardings).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as TF
from ..models import gnn as GNN
from ..models import recsys as RS
from ..optim import adamw
from ..distributed.sharding import MeshRules, make_rules

S = jax.ShapeDtypeStruct

__all__ = ["ArchConfig", "SpecBundle", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES",
           "shape_names", "input_specs", "param_logical_axes", "init_params",
           "make_step", "state_shapes", "state_logical_axes"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # lm | gnn | recsys
    model: Any
    smoke: Any
    moment_dtype: Any = jnp.float32
    skips: Dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""


@dataclasses.dataclass
class SpecBundle:
    kind: str                        # train | prefill | decode | serve | retrieval
    model: Any                       # possibly shape-adapted model config
    batch: Dict[str, Any]            # name -> ShapeDtypeStruct
    batch_axes: Dict[str, tuple]     # name -> logical axes
    cache: Optional[Dict[str, Any]] = None
    cache_axes: Optional[Dict[str, tuple]] = None


LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n=2708, e=10556, d_feat=1433, classes=7),
    "minibatch_lg": dict(kind="train", n=169984, e=168960, d_feat=602,
                         classes=41, masked=True),
    "ogb_products": dict(kind="train", n=2449029, e=61859140, d_feat=100,
                         classes=47),
    "molecule": dict(kind="train", n=3840, e=8192, d_feat=11, graphs=128,
                     task="regression"),
}
RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
}

_FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


def shape_names(ac: ArchConfig):
    return list(_FAMILY_SHAPES[ac.family].keys())


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _lm_specs(ac: ArchConfig, shape_name: str) -> SpecBundle:
    sh = LM_SHAPES[shape_name]
    cfg: TF.LMConfig = ac.model
    B, L = sh["batch"], sh["seq"]
    if sh["kind"] == "train":
        batch = {"tokens": S((B, L), jnp.int32)}
        axes = {"tokens": ("batch", None)}
        return SpecBundle("train", cfg, batch, axes)
    if sh["kind"] == "prefill":
        batch = {"tokens": S((B, L), jnp.int32)}
        return SpecBundle("prefill", cfg, batch, {"tokens": ("batch", None)})
    # decode: cache of seq_len (ring = window for SWA long-context)
    max_len = L
    if shape_name == "long_500k":
        assert cfg.window is not None, "long_500k requires sub-quadratic attention"
        max_len = cfg.window
    cache_tree = jax.eval_shape(lambda: TF.init_cache(cfg, B, max_len))
    G, P = cfg.n_groups, cfg.moe_period
    if cfg.mla is not None:
        cache_axes = {"ckv": (None, None, "batch", "seq_kv", None),
                      "krope": (None, None, "batch", "seq_kv", None),
                      "len": ()}
    else:
        cache_axes = {"k": (None, None, "batch", "seq_kv", None, None),
                      "v": (None, None, "batch", "seq_kv", None, None),
                      "len": ()}
    batch = {"tokens": S((B, 1), jnp.int32)}
    return SpecBundle("decode", cfg, batch, {"tokens": ("batch", None)},
                      cache={k: v for k, v in cache_tree.items()},
                      cache_axes=cache_axes)


def _pad512(x: int) -> int:
    """Pad graph dims to a multiple of 512 so both production meshes divide
    them (padding edges carry src=dst=-1, padding nodes are masked)."""
    return -(-x // 512) * 512


def _gnn_specs(ac: ArchConfig, shape_name: str) -> SpecBundle:
    sh = GNN_SHAPES[shape_name]
    cfg: GNN.GNNConfig = ac.model
    n, e, f = _pad512(sh["n"]), _pad512(sh["e"]), sh["d_feat"]
    task = sh.get("task", "node")
    cfg = dataclasses.replace(
        cfg, d_feat=f, n_classes=sh.get("classes", 2), task=task,
        # memory blocking for the big shapes
        edge_chunk=(262144 if e > 1_000_000 else None),
        triplet_chunk=(1_048_576 if e > 1_000_000 else None),
    )
    batch = {
        "x": S((n, f), jnp.float32),
        "src": S((e,), jnp.int32),
        "dst": S((e,), jnp.int32),
    }
    axes = {"x": ("nodes", None), "src": ("edges",), "dst": ("edges",)}
    if task == "regression":
        batch["labels"] = S((sh["graphs"],), jnp.float32)
        axes["labels"] = (None,)
    else:
        batch["labels"] = S((n,), jnp.int32)
        axes["labels"] = ("nodes",)
        # padded nodes are always masked out of the loss
        batch["label_mask"] = S((n,), jnp.bool_)
        axes["label_mask"] = ("nodes",)
    if sh.get("graphs"):
        batch["graph_id"] = S((n,), jnp.int32)
        axes["graph_id"] = ("nodes",)
        batch["node_mask"] = S((n,), jnp.bool_)
        axes["node_mask"] = ("nodes",)
    if cfg.arch in ("dimenet", "equiformer_v2"):
        batch["pos"] = S((n, 3), jnp.float32)
        axes["pos"] = ("nodes", None)
    if cfg.arch == "dimenet":
        t = min(4 * e, 256_000_000)
        batch["triplet_kj"] = S((t,), jnp.int32)
        batch["triplet_ji"] = S((t,), jnp.int32)
        batch["angle"] = S((t,), jnp.float32)
        axes.update(triplet_kj=("edges",), triplet_ji=("edges",), angle=("edges",))
    if cfg.arch == "equiformer_v2":
        nc = cfg.n_coef
        batch["wigner"] = S((e, nc, nc), jnp.float32)
        axes["wigner"] = ("edges", None, None)
    return SpecBundle("train", cfg, batch, axes)


def _recsys_specs(ac: ArchConfig, shape_name: str) -> SpecBundle:
    sh = RECSYS_SHAPES[shape_name]
    cfg: RS.FMConfig = ac.model
    B = sh["batch"]
    if sh["kind"] == "retrieval":
        ncand = _pad512(sh["n_cand"])
        batch = {"ids": S((1, cfg.n_fields), jnp.int32),
                 "cand": S((ncand, cfg.embed_dim), jnp.float32),
                 "cand_bias": S((ncand,), jnp.float32)}
        axes = {"ids": (None, None), "cand": ("rows", None), "cand_bias": ("rows",)}
        return SpecBundle("retrieval", cfg, batch, axes)
    batch = {"ids": S((B, cfg.n_fields), jnp.int32)}
    axes = {"ids": ("batch", None)}
    if sh["kind"] == "train":
        batch["labels"] = S((B,), jnp.float32)
        axes["labels"] = ("batch",)
        return SpecBundle("train", cfg, batch, axes)
    return SpecBundle("serve", cfg, batch, axes)


def input_specs(ac: ArchConfig, shape_name: str) -> SpecBundle:
    if shape_name in ac.skips:
        raise ValueError(f"{ac.name} skips {shape_name}: {ac.skips[shape_name]}")
    return {"lm": _lm_specs, "gnn": _gnn_specs, "recsys": _recsys_specs}[ac.family](
        ac, shape_name)


# Named model-config transforms for perf hillclimbing (dryrun --variant X):
# each is hypothesis -> change; results land in EXPERIMENTS.md §Perf.
VARIANTS = {
    # PIUMA fine-grained embedding exchange instead of GSPMD gather
    "fm_dgas": lambda m: dataclasses.replace(m, use_dgas=True),
    # halve DGAS all_to_all buffer capacity (graph models)
    "dgas_cap2": lambda m: dataclasses.replace(m, dgas_cap_factor=2),
    # larger / smaller edge streaming chunks (graph models)
    "chunk_512k": lambda m: dataclasses.replace(m, edge_chunk=524288),
    "chunk_128k": lambda m: dataclasses.replace(m, edge_chunk=131072),
    # Megatron-style fused QKV + fused gate matmuls (LM)
    "fused_qkv": lambda m: dataclasses.replace(m, fused_qkv=True),
    # no sequence-parallel residuals (ablation; LM)
}


def apply_variant(bundle: SpecBundle, variant: Optional[str]) -> SpecBundle:
    if not variant:
        return bundle
    bundle.model = VARIANTS[variant](bundle.model)
    return bundle


# ---------------------------------------------------------------------------
# params / state
# ---------------------------------------------------------------------------

def init_params(ac: ArchConfig, model_cfg, key):
    if ac.family == "lm":
        return TF.init_params(model_cfg, key)
    if ac.family == "gnn":
        return GNN.init_params(model_cfg, key)
    return RS.init_params(model_cfg, key)


def param_logical_axes(ac: ArchConfig, model_cfg, params_shape):
    if ac.family == "lm":
        return TF.param_logical_axes(model_cfg)
    if ac.family == "recsys":
        return {"table": ("rows", None), "w0": ()}
    # gnn params are small: replicate
    return jax.tree.map(lambda x: (None,) * len(x.shape), params_shape)


def state_shapes(ac: ArchConfig, model_cfg, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: init_params(ac, model_cfg, key))
    st = jax.eval_shape(
        lambda: adamw.init_state_with_dtype(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shape),
            ac.moment_dtype))
    return params_shape, st


def state_logical_axes(ac: ArchConfig, model_cfg, params_shape):
    pax = param_logical_axes(ac, model_cfg, params_shape)
    return adamw.TrainState(params=pax, m=pax, v=pax, step=())


def zip_with_axes(shape_tree, axes_tree, fn):
    """tree.map substitute that treats the tuples in an axes tree as leaves."""
    if isinstance(shape_tree, dict):
        return {k: zip_with_axes(shape_tree[k], axes_tree[k], fn)
                for k in shape_tree}
    if isinstance(shape_tree, (list, tuple)) and not hasattr(shape_tree, "shape"):
        return [zip_with_axes(s, a, fn) for s, a in zip(shape_tree, axes_tree)]
    return fn(shape_tree, axes_tree)


def param_shardings(rules: MeshRules, params_shape, pax):
    """NamedShardings for a parameter pytree from its logical-axes pytree."""
    return zip_with_axes(
        params_shape, pax,
        lambda s, ax: rules.input_sharding(s.shape, *(ax or ())))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_step(ac: ArchConfig, bundle: SpecBundle, rules: MeshRules,
              opt: Optional[adamw.AdamWConfig] = None) -> Callable:
    """Returns the jittable step for this cell.

    train:      step(state, batch) -> (state, metrics)
    prefill:    step(params, batch) -> (logits, cache)
    decode:     step(params, cache, batch) -> (logits, cache)
    serve:      step(params, batch) -> scores
    retrieval:  step(params, batch) -> scores
    """
    cfg = bundle.model
    opt = opt or adamw.AdamWConfig()

    if bundle.kind == "train":
        def loss(params, batch):
            if ac.family == "lm":
                return TF.loss_fn(cfg, params, batch, rules)
            if ac.family == "gnn":
                return GNN.loss_fn(cfg, params, batch, rules)
            return RS.loss_fn(cfg, params, batch, rules)

        def step(state, batch):
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                state.params, batch)
            new_state = adamw.apply_update(opt, state, grads)
            return new_state, metrics
        return step

    if bundle.kind == "prefill":
        return lambda params, batch: TF.prefill(cfg, params, batch["tokens"], rules)

    if bundle.kind == "decode":
        return lambda params, cache, batch: TF.decode_step(
            cfg, params, cache, batch["tokens"], rules)

    if bundle.kind == "serve":
        return lambda params, batch: RS.fm_scores(cfg, params, batch["ids"], rules)

    if bundle.kind == "retrieval":
        return lambda params, batch: RS.retrieval_scores(
            cfg, params, batch["ids"], batch["cand"], batch["cand_bias"], rules)

    raise ValueError(bundle.kind)
