"""Architecture registry: --arch <id> resolves here."""
from . import (mistral_large_123b, qwen3_14b, minicpm3_4b,
               llama4_maverick_400b, mixtral_8x7b, equiformer_v2, dimenet,
               gatedgcn, gin_tu, fm, piuma_graph)
from .common import (ArchConfig, SpecBundle, input_specs, shape_names,
                     make_step, state_shapes, state_logical_axes,
                     param_logical_axes, init_params)

_REGISTRY = {
    "mistral-large-123b": mistral_large_123b.config,
    "qwen3-14b": qwen3_14b.config,
    "minicpm3-4b": minicpm3_4b.config,
    "llama4-maverick-400b-a17b": llama4_maverick_400b.config,
    "mixtral-8x7b": mixtral_8x7b.config,
    "equiformer-v2": equiformer_v2.config,
    "dimenet": dimenet.config,
    "gatedgcn": gatedgcn.config,
    "gin-tu": gin_tu.config,
    "fm": fm.config,
}

ARCH_NAMES = list(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return _REGISTRY[name]()
