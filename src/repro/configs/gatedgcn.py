"""gatedgcn [gnn] 16L d70, gated edge aggregation. [arXiv:2003.00982; paper]"""
from ..models.gnn import GNNConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = GNNConfig(name="gatedgcn", arch="gatedgcn", n_layers=16,
                      d_hidden=70, d_feat=100)
    smoke = GNNConfig(name="gatedgcn-smoke", arch="gatedgcn", n_layers=3,
                      d_hidden=16, d_feat=8)
    return ArchConfig(name="gatedgcn", family="gnn", model=model, smoke=smoke)
