"""qwen3-14b [dense] 40L d5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk_norm.

[hf:Qwen/Qwen3-8B; hf]
"""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = LMConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
        dtype=jnp.bfloat16)
    smoke = LMConfig(
        name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128, qk_norm=True, dtype=jnp.float32,
        q_chunk=16, k_chunk=16)
    return ArchConfig(
        name="qwen3-14b", family="lm", model=model, smoke=smoke,
        skips={"long_500k": "pure full attention (no sub-quadratic path)"})
