"""minicpm3-4b [dense] 62L d2560 40H d_ff=6400 vocab=73448 — MLA attention.

[hf:openbmb/MiniCPM3-4B; hf]  MLA dims: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64 (MiniCPM3 reference config).
"""
import jax.numpy as jnp
from ..models.transformer import LMConfig, MLAConfig
from .common import ArchConfig

def config() -> ArchConfig:
    mla = MLAConfig(q_lora_rank=768, kv_lora_rank=256, rope_head_dim=32,
                    nope_head_dim=64, v_head_dim=64)
    model = LMConfig(
        name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
        n_kv_heads=40, head_dim=96, d_ff=6400, vocab=73448, mla=mla,
        rope_theta=1e4, dtype=jnp.bfloat16)
    smoke = LMConfig(
        name="minicpm3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=24, d_ff=128, vocab=128, dtype=jnp.float32,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        q_chunk=16, k_chunk=16)
    return ArchConfig(
        name="minicpm3-4b", family="lm", model=model, smoke=smoke,
        skips={"long_500k": "pure full attention (MLA latent cache but "
                            "quadratic prefill/decode attention)"},
        notes="MLA: decode uses absorbed latent-cache attention")
