"""llama4-maverick-400b-a17b [moe] 48L d5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1, dense/MoE interleaved (period 2).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
Early-fusion vision frontend is a STUB per the assignment (text backbone only;
input_specs provide token ids — precomputed patch embeddings would enter the
same residual stream).  bf16 Adam moments per DESIGN.md §7 memory plan.
"""
import jax.numpy as jnp
from ..models.transformer import LMConfig, MoEConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = LMConfig(
        name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
        moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, period=2),
        rope_theta=5e5, dtype=jnp.bfloat16)
    smoke = LMConfig(
        name="llama4-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128, dtype=jnp.float32,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff=64, period=2),
        q_chunk=16, k_chunk=16)
    return ArchConfig(
        name="llama4-maverick-400b-a17b", family="lm", model=model, smoke=smoke,
        moment_dtype=jnp.bfloat16,
        skips={"long_500k": "full attention backbone here (chunked-attention "
                            "variant not modeled); see DESIGN.md §4"})
