"""gin-tu [gnn] 5L d64 sum aggregator, learnable eps. [arXiv:1810.00826; paper]"""
from ..models.gnn import GNNConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = GNNConfig(name="gin-tu", arch="gin", n_layers=5, d_hidden=64,
                      d_feat=100, eps_learnable=True)
    smoke = GNNConfig(name="gin-smoke", arch="gin", n_layers=2, d_hidden=16,
                      d_feat=8)
    return ArchConfig(name="gin-tu", family="gnn", model=model, smoke=smoke)
