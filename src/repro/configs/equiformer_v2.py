"""equiformer-v2 [gnn] 12L d128 l_max=6 m_max=2 8 heads, eSCN SO(2) conv.

[arXiv:2306.12059; unverified]  Wigner rotations are precomputed per edge by
the data pipeline (DESIGN.md §9).
"""
from ..models.gnn import GNNConfig
from .common import ArchConfig

def config() -> ArchConfig:
    model = GNNConfig(name="equiformer-v2", arch="equiformer_v2", n_layers=12,
                      d_hidden=128, d_feat=100, l_max=6, m_max=2, n_heads=8)
    smoke = GNNConfig(name="equiformer-v2-smoke", arch="equiformer_v2",
                      n_layers=2, d_hidden=16, d_feat=8, l_max=2, m_max=2,
                      n_heads=4)
    return ArchConfig(name="equiformer-v2", family="gnn", model=model,
                      smoke=smoke)
