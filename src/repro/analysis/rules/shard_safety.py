"""shard-safety: shard_map bodies use bound axes and blessed routing.

Two hazards inside functions mapped by ``shard_map`` (directly, or through
the engine's ``_shard_apply`` wrapper, or reached by call from such a
function):

* **unbound collective** — ``psum``/``pmax``/``all_gather``/... without an
  axis name (or with an explicit ``None``) silently reduces over *nothing*
  or raises at trace time depending on jax version.  Every collective must
  name a bound mesh axis, positionally or via ``axis_name=``/``axes=``.
* **raw cross-shard routing** — ``all_to_all``/``ppermute``/``pshuffle``
  are the DGAS-bypass primitives; outside ``core/offload.py`` (the one
  module allowed to implement remote access) cross-shard movement must go
  through ``dgas`` / ``offload.remote_*`` so the address-translation layer
  stays the single source of placement truth.
"""
from __future__ import annotations

import ast
from typing import Iterable, Set

from ..callgraph import ModuleGraph, dotted_name
from ..core import Finding, ParsedModule, Rule

# collective tail -> index of the positional axis argument
_COLLECTIVES = {
    "psum": 1, "pmax": 1, "pmin": 1, "pmean": 1, "psum_scatter": 1,
    "all_gather": 1, "all_to_all": 1, "ppermute": 1, "pshuffle": 1,
    "axis_index": 0, "hierarchical_psum": 1, "pbroadcast": 1,
    # the async placement's buffered-flush exchange (offload.buffered_flush)
    # is a collective: the outbox transpose must bind the mapped axis
    "buffered_flush": 1,
}
_AXIS_KWARGS = ("axis_name", "axes", "axis")
_ROUTING = {"all_to_all", "ppermute", "pshuffle"}


def _axis_argument(call: ast.Call, pos: int):
    if len(call.args) > pos:
        return call.args[pos]
    for kw in call.keywords:
        if kw.arg in _AXIS_KWARGS:
            return kw.value
    return None


class ShardSafetyRule(Rule):
    id = "shard-safety"
    doc = ("inside shard_map-mapped functions, collectives must name a "
           "bound mesh axis and cross-shard routing goes through "
           "dgas/offload, not raw all_to_all/ppermute")

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        graph = ModuleGraph(module)
        roots: Set[ast.AST] = set()
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            tail = name.split(".")[-1] if name else ""
            if tail in ("shard_map", "_shard_apply", "smap"):
                roots.update(graph._function_args(node))
        mapped = graph.reachable_from(roots)
        in_offload = module.path.endswith("core/offload.py") or \
            module.path.endswith("/offload.py") or module.path == "offload.py"
        for fn in mapped:
            for node in graph.body_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                tail = name.split(".")[-1]
                if tail not in _COLLECTIVES:
                    continue
                if tail in _ROUTING and not in_offload:
                    yield self.finding(
                        module, node,
                        f"raw `{name}` routing inside a shard_map body — "
                        "cross-shard movement bypasses the DGAS layer",
                        "use dgas / offload.remote_* (offload.py is the "
                        "only module allowed to route directly)")
                    continue
                axis = _axis_argument(node, _COLLECTIVES[tail])
                if axis is None or (isinstance(axis, ast.Constant)
                                    and axis.value is None):
                    yield self.finding(
                        module, node,
                        f"collective `{name}` without a bound mesh axis "
                        "inside a shard_map body",
                        "pass the mapped axis name (e.g. axis_name=axis)")
