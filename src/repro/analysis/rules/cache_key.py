"""cache-key: values feeding engine.cached_mapped keys must be hashable.

``engine.cached_mapped(key, build)`` memoises compiled shard_map callables
by ``key``.  An unhashable key raises at call time; worse, a *mutable but
identity-hashed* key (or a mutable default argument feeding one) is a
recompile bomb — every call builds a fresh key object, the cache never
hits, and each miss re-traces and re-compiles the mapped function.

Flagged:

* a list/dict/set literal (or comprehension, or ``list()``/``dict()``/
  ``set()``/``sorted()`` call) passed as the key argument of
  ``cached_mapped`` / ``_cached_mapped`` or as a ``cache_key=``/``ident=``
  kwarg anywhere — including through one level of simple local assignment
  (``key = [...]; cached_mapped(key, ...)``);
* a mutable default parameter on any function that calls ``cached_mapped``
  (the classic way a "static" key argument turns out to be a fresh object
  per call).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional

from ..callgraph import dotted_name
from ..core import Finding, ParsedModule, Rule

_KEY_FUNCS = ("cached_mapped", "_cached_mapped")
_KEY_KWARGS = ("cache_key", "ident")
_MUTABLE_CTORS = {"list", "dict", "set", "sorted", "bytearray"}


def _unhashable(node: ast.AST, assigns: Dict[str, List[ast.AST]],
                depth: int = 0) -> Optional[ast.AST]:
    """The sub-node proving ``node`` is unhashable/mutable, else None."""
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.SetComp, ast.DictComp, ast.GeneratorExp)):
        return node
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) and \
            node.func.id in _MUTABLE_CTORS:
        return node
    if isinstance(node, ast.Tuple):
        for e in node.elts:
            bad = _unhashable(e, assigns, depth)
            if bad is not None:
                return bad
    if isinstance(node, ast.Starred):
        return _unhashable(node.value, assigns, depth)
    if isinstance(node, ast.Name) and depth < 2:
        for value in assigns.get(node.id, ()):
            bad = _unhashable(value, assigns, depth + 1)
            if bad is not None:
                return bad
    return None


class CacheKeyRule(Rule):
    id = "cache-key"
    doc = ("arguments feeding engine.cached_mapped keys (key arg, "
           "cache_key=/ident= kwargs) must be hashable and static; "
           "mutable defaults on cached_mapped callers are recompile bombs")

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        assigns: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        assigns.setdefault(tgt.id, []).append(node.value)
            elif isinstance(node, ast.AnnAssign) and node.value is not None \
                    and isinstance(node.target, ast.Name):
                assigns.setdefault(node.target.id, []).append(node.value)

        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(module, node, assigns)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_defaults(module, node, assigns)

    def _check_call(self, module: ParsedModule, call: ast.Call,
                    assigns) -> Iterable[Finding]:
        name = dotted_name(call.func)
        tail = name.split(".")[-1] if name else ""
        if tail in _KEY_FUNCS and call.args:
            bad = _unhashable(call.args[0], assigns)
            if bad is not None:
                yield self.finding(
                    module, call.args[0],
                    f"unhashable/mutable value feeds the `{tail}` cache key",
                    "use a tuple of hashable, static parts (sort + "
                    "tuple() any collections first)")
        for kw in call.keywords:
            if kw.arg in _KEY_KWARGS:
                bad = _unhashable(kw.value, assigns)
                if bad is not None:
                    yield self.finding(
                        module, kw.value,
                        f"unhashable/mutable value passed as `{kw.arg}=` "
                        "compile-cache key",
                        "use a tuple of hashable, static parts")

    def _check_defaults(self, module: ParsedModule, fn,
                        assigns) -> Iterable[Finding]:
        calls_cache = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name and name.split(".")[-1] in _KEY_FUNCS:
                    calls_cache = True
                    break
        if not calls_cache:
            return
        args = fn.args
        defaults = list(args.defaults) + \
            [d for d in args.kw_defaults if d is not None]
        for default in defaults:
            bad = _unhashable(default, {})
            if bad is not None:
                yield self.finding(
                    module, default,
                    f"mutable default on `{fn.name}` (a cached_mapped "
                    "caller) — a fresh object per call defeats the "
                    "compile cache",
                    "default to None (or a tuple) and normalise inside")
