"""tuned-constants: tunable knobs in the hot paths route through repro.tune.

DESIGN.md §18's config funnel only works if the tunable parameters —
kernel tile shapes, the push/pull switch fraction, routing capacities,
the service lane budget — actually reach ``repro.tune.resolve``.  A
hard-coded literal in ``core/engine.py``, ``core/service.py`` or
``kernels/ops.py`` silently shadows the committed TUNED.json entry for
the backend: the knob looks tuned (the sweep ran, the entry exists) but
the hot path never reads it, and the `tune.autotune_fallback` counter
can't fire because resolve() is never consulted.

Flagged, in those three modules only:

* a function parameter named like a tunable whose default is a numeric
  literal (should default to None and resolve inside — explicit kwargs
  then still win over TUNED.json);
* a numeric-literal argument for a tunable keyword (or for
  ``frontier_edge_capacity``'s switch_frac positional) in calls to
  ``to_bbcsr`` / ``frontier_edge_capacity`` — the construction sites the
  funnel exists for.

Literals elsewhere (tests, benchmarks, the kernel modules' own internal
defaults behind the ops.py funnel) are fine and not scanned.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..callgraph import dotted_name
from ..core import Finding, ParsedModule, Rule

# modules the funnel covers (suffix-matched against the module path)
_FUNNEL_MODULES = ("core/engine.py", "core/service.py", "kernels/ops.py")

# parameter / keyword names that have TUNED.json entries (space.DEFAULTS)
_TUNABLE = {
    "switch_frac", "push_edge_capacity", "slack",
    "block_rows", "block_cols", "tile_nnz",
    "block_n", "block_q", "block_k",
    "batch_budget",
}

# call targets whose tunable arguments must come through resolve()
_FUNNEL_CALLS = {"to_bbcsr", "frontier_edge_capacity"}


def _numeric_literal(node: ast.AST) -> bool:
    """True for 512, 1/32, -1.0, 4 * 1024 — constant numeric expressions."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float)) and \
            not isinstance(node.value, bool)
    if isinstance(node, ast.UnaryOp):
        return _numeric_literal(node.operand)
    if isinstance(node, ast.BinOp):
        return _numeric_literal(node.left) and _numeric_literal(node.right)
    return False


class TunedConstantsRule(Rule):
    id = "tuned-constants"
    doc = ("tunable knobs (tile shapes, switch_frac, capacities, lane "
           "budget) in engine/service/ops must default to None and go "
           "through repro.tune.resolve, not hard-coded literals")

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        if not module.path.endswith(_FUNNEL_MODULES):
            return
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_signature(module, node)
            elif isinstance(node, ast.Call):
                yield from self._check_call(module, node)

    def _check_signature(self, module: ParsedModule, fn) -> Iterable[Finding]:
        a = fn.args
        pos = a.posonlyargs + a.args
        pairs = list(zip(pos[len(pos) - len(a.defaults):], a.defaults)) + \
            [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
             if d is not None]
        for arg, default in pairs:
            if arg.arg in _TUNABLE and _numeric_literal(default):
                yield self.finding(
                    module, default,
                    f"`{fn.name}` hard-codes tunable `{arg.arg}` default — "
                    "TUNED.json entries for it are silently ignored",
                    "default to None and call repro.tune.resolve(...) "
                    "inside (explicit kwargs still win)")

    def _check_call(self, module: ParsedModule, call: ast.Call) -> Iterable[Finding]:
        name = dotted_name(call.func)
        tail = name.split(".")[-1] if name else ""
        if tail not in _FUNNEL_CALLS:
            return
        if tail == "frontier_edge_capacity" and len(call.args) >= 2 and \
                _numeric_literal(call.args[1]):
            yield self.finding(
                module, call.args[1],
                "literal switch_frac passed to `frontier_edge_capacity` "
                "bypasses the tuned config",
                "pass repro.tune.resolve('engine.switch_frac', ...) or a "
                "caller-supplied value")
        for kw in call.keywords:
            if kw.arg in _TUNABLE and _numeric_literal(kw.value):
                yield self.finding(
                    module, kw.value,
                    f"literal `{kw.arg}=` in `{tail}` call bypasses the "
                    "tuned config",
                    "route through repro.tune.resolve (explicit kwargs "
                    "win over TUNED.json)")
