"""single-core: the engine has exactly ONE stepping loop (DESIGN.md §14).

AST port of the retired grep guard in ``scripts/check_single_core.py``.
Grep counted the *string* ``lax.while_loop(`` — a comment, docstring, or
aliased import could dodge it in either direction.  Here we count actual
``Call`` nodes, so ``# lax.while_loop(`` no longer trips the guard and
``wl = lax.while_loop; wl(...)`` no longer slips past it (the aliasing
assignment itself references the primitive attribute and is counted).

Invariants, checked only against ``core/engine.py``:

* exactly one ``lax.while_loop`` use (the ``_core_loop`` stepping loop);
* at most one ``lax.scan`` use (the dense fallback inside the same loop);
* no ``fori_loop`` anywhere;
* all five public runners exist and the ExecutionCore seam is intact:
  ``_run_local`` / ``_run_distributed`` delegation calls are present and
  something invokes ``_core_loop(core, ...)``.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..callgraph import dotted_name
from ..core import Finding, ParsedModule, Rule

_RUNNERS = ("run", "run_batched", "run_distributed",
            "run_batched_distributed", "run_queue", "_core_loop")


def _is_lax_primitive(node: ast.AST, tail: str) -> bool:
    """True for ``lax.<tail>`` / ``jax.lax.<tail>`` attribute uses and for
    bare ``<tail>`` names bound by a ``from jax.lax import <tail>``-style
    alias (conservatively: any bare Name of that spelling)."""
    if isinstance(node, ast.Attribute) and node.attr == tail:
        base = dotted_name(node.value)
        return base is not None and base.split(".")[-1] == "lax"
    if isinstance(node, ast.Name) and node.id == tail:
        return True
    return False


class SingleCoreRule(Rule):
    id = "single-core"
    doc = ("engine.py keeps exactly one lax.while_loop stepping loop, "
           "<=1 lax.scan, no fori_loop, and runners delegate through "
           "_run_local/_run_distributed into _core_loop(core, ...)")

    def applies(self, module: ParsedModule) -> bool:
        return module.path.endswith("core/engine.py") or \
            module.path == "engine.py"

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        if not self.applies(module):
            return
        while_loops: List[ast.AST] = []
        scans: List[ast.AST] = []
        fori: List[ast.AST] = []
        defs = set()
        calls = set()
        core_loop_on_core = False
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.Attribute, ast.Name)):
                if _is_lax_primitive(node, "while_loop"):
                    while_loops.append(node)
                elif _is_lax_primitive(node, "fori_loop"):
                    fori.append(node)
            if isinstance(node, ast.Attribute) and node.attr == "scan" and \
                    _is_lax_primitive(node, "scan"):
                scans.append(node)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.add(node.name)
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                calls.add(node.func.id)
                if node.func.id == "_core_loop" and node.args and \
                        isinstance(node.args[0], ast.Name) and \
                        node.args[0].id == "core":
                    core_loop_on_core = True

        anchor = module.tree  # line 1 anchor for structural findings
        if len(while_loops) != 1:
            target = while_loops[1] if len(while_loops) > 1 else anchor
            yield self.finding(
                module, target,
                f"engine has {len(while_loops)} lax.while_loop uses, "
                "expected exactly 1 (the _core_loop stepping loop)",
                "fold the extra loop into _core_loop / ExecutionCore")
        if len(scans) > 1:
            yield self.finding(
                module, scans[1],
                f"engine has {len(scans)} lax.scan uses, expected at most 1",
                "express the extra scan through the core stepping loop")
        for node in fori:
            yield self.finding(
                module, node, "fori_loop is banned in engine.py",
                "use the _core_loop while_loop (bounded by max_iters)")
        for name in _RUNNERS:
            if name not in defs:
                yield self.finding(
                    module, anchor, f"required runner `{name}` is missing",
                    "runners are the engine's public contract; restore it")
        for name in ("_run_local", "_run_distributed"):
            if name not in calls:
                yield self.finding(
                    module, anchor,
                    f"no call to `{name}` — runner delegation seam broken",
                    "public runners must delegate through "
                    "_run_local/_run_distributed")
        if "_core_loop" in defs and not core_loop_on_core:
            yield self.finding(
                module, anchor,
                "no `_core_loop(core, ...)` call — ExecutionCore is bypassed",
                "drive the stepping loop through an ExecutionCore instance")
