"""mutable-handle: graph epoch/CSR identity belongs to GraphHandle.

DESIGN.md §16 makes the epoch-versioned :class:`GraphHandle` the one graph
currency: the epoch, the CSR it versions, and the per-partition mutation
stamps are bookkeeping that must only ever change inside ``core/graph.py``
(``apply`` / ``replace`` / ``compact`` return NEW handles).  A stray
``svc.epoch += 1`` or ``self.csr = new_csr`` elsewhere silently desyncs the
epoch from the delta log and the partition stamps — the cache then serves
stale results with no failing invariant to catch it (the exact bug class
the pre-PR-8 service's hand-maintained ``self.epoch`` invited).

Flagged, outside ``core/graph.py``:

* any attribute assignment (plain, augmented, annotated, or tuple-unpacked)
  to ``.epoch``, ``.csr``, or ``.stamps``;
* ``object.__setattr__(x, "epoch"/"csr"/"stamps", ...)`` — the frozen-
  dataclass backdoor.

Reading the fields is fine (that is the API); so is any other attribute
name.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..callgraph import dotted_name
from ..core import Finding, ParsedModule, Rule

_FIELDS = ("epoch", "csr", "stamps")
_HOME = "core/graph.py"


def _attr_targets(node: ast.AST):
    """Yield Attribute nodes assigned to by an Assign/AugAssign/AnnAssign,
    descending through tuple/list unpacking and starred targets."""
    if isinstance(node, ast.Assign):
        stack = list(node.targets)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        stack = [node.target]
    else:
        return
    while stack:
        t = stack.pop()
        if isinstance(t, ast.Attribute):
            yield t
        elif isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        elif isinstance(t, ast.Starred):
            stack.append(t.value)


class MutableHandleRule(Rule):
    id = "mutable-handle"
    doc = ("graph epoch/CSR/stamp bookkeeping is GraphHandle's: no "
           ".epoch/.csr/.stamps assignment (or object.__setattr__) outside "
           "core/graph.py")

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        if module.path.endswith(_HOME):
            return
        for node in ast.walk(module.tree):
            for tgt in _attr_targets(node):
                if tgt.attr in _FIELDS:
                    yield self.finding(
                        module, tgt,
                        f"assignment to `.{tgt.attr}` outside core/graph.py "
                        "— epoch/CSR identity is GraphHandle bookkeeping",
                        "mutate through GraphHandle.apply/replace and store "
                        "the returned handle")
            if isinstance(node, ast.Call) and \
                    dotted_name(node.func) == "object.__setattr__" and \
                    len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Constant) and \
                    node.args[1].value in _FIELDS:
                yield self.finding(
                    module, node,
                    f"object.__setattr__(..., {node.args[1].value!r}, ...) "
                    "outside core/graph.py bypasses the frozen GraphHandle",
                    "build a new handle via GraphHandle.apply/replace "
                    "instead of mutating one in place")
