"""repro-lint rule registry (DESIGN.md §15).

To add a rule: subclass :class:`repro.analysis.core.Rule` in a new module
here, give it a unique ``id`` and a one-line ``doc``, implement
``check(module) -> Iterable[Finding]``, append an instance to ``ALL_RULES``,
and add paired true-positive / true-negative fixtures to
``tests/test_analysis.py``.
"""
from __future__ import annotations

from .cache_key import CacheKeyRule
from .compat_boundary import CompatBoundaryRule
from .host_sync import HostSyncRule
from .mutable_handle import MutableHandleRule
from .shard_safety import ShardSafetyRule
from .single_core import SingleCoreRule
from .tuned_constants import TunedConstantsRule

ALL_RULES = [
    SingleCoreRule(),
    CompatBoundaryRule(),
    HostSyncRule(),
    ShardSafetyRule(),
    CacheKeyRule(),
    MutableHandleRule(),
    TunedConstantsRule(),
]

RULES_BY_ID = {rule.id: rule for rule in ALL_RULES}

__all__ = ["ALL_RULES", "RULES_BY_ID", "SingleCoreRule", "CompatBoundaryRule",
           "HostSyncRule", "ShardSafetyRule", "CacheKeyRule",
           "MutableHandleRule", "TunedConstantsRule"]
