"""host-sync: no device->host synchronisation inside traced functions.

Inside anything that executes under ``jit`` / ``shard_map`` / Pallas
tracing (see :mod:`repro.analysis.callgraph` for how "traced" is
approximated), the following are silent performance cliffs or outright
trace errors:

* ``x.item()`` and ``x.block_until_ready()`` — a blocking transfer per call;
* ``np.asarray(x)`` / ``np.array(x)`` on a non-literal — either a blocking
  transfer (concrete array) or a TracerConversionError (traced value);
* ``float(...)`` / ``int(...)`` / ``bool(...)`` of a *computed* value —
  concretisation; ``int(static_param)`` on a plain argument is left alone,
  only conversions whose argument contains a call are flagged;
* ``jnp.nonzero`` / ``jnp.unique`` / ``jnp.where`` (1-arg) without ``size=``
  — data-dependent output shape, untraceable (trace-hazard sub-check).

Deliberate pre-trace host pulls (the engine's CSR cache in
``_dst_sorted_stream``, host-driven multilevel scoring, ...) carry a
``# repro-lint: disable=host-sync`` pragma with a why-comment; the pragma
IS the allowlist, kept next to the code it excuses.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..callgraph import ModuleGraph, dotted_name
from ..core import Finding, ParsedModule, Rule

_NP_BASES = {"np", "numpy", "onp"}
_JNP_BASES = {"jnp", "np", "numpy", "jax.numpy"}
_SIZED = {"nonzero", "unique", "argwhere", "flatnonzero"}


def _is_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_literal(e) for e in node.elts)
    if isinstance(node, ast.UnaryOp):
        return _is_literal(node.operand)
    return False


def _contains_call(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) for n in ast.walk(node))


class HostSyncRule(Rule):
    id = "host-sync"
    doc = ("no .item()/.block_until_ready()/np.asarray()/float(computed) "
           "inside functions reachable from jit/shard_map/Pallas tracing; "
           "jnp.nonzero-style calls there need size=")

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        graph = ModuleGraph(module)
        for fn in graph.functions:
            if fn not in graph.traced:
                continue
            for node in graph.body_nodes(fn):
                if isinstance(node, ast.Call):
                    yield from self._check_call(module, node)

    def _check_call(self, module: ParsedModule,
                    call: ast.Call) -> Iterable[Finding]:
        func = call.func
        if isinstance(func, ast.Attribute):
            if func.attr == "item" and not call.args and not call.keywords:
                yield self.finding(
                    module, call,
                    ".item() forces a device->host sync in a traced function",
                    "keep the value on device, or hoist the readback out of "
                    "the traced region")
                return
            if func.attr == "block_until_ready":
                yield self.finding(
                    module, call,
                    ".block_until_ready() inside a traced function",
                    "synchronise at the host call site, not inside the trace")
                return
        name = dotted_name(func)
        if name is not None:
            parts = name.split(".")
            tail, base = parts[-1], ".".join(parts[:-1])
            if tail in ("asarray", "array") and \
                    (base in _NP_BASES or base.endswith(".numpy")) and \
                    not base.startswith("jax") and base != "jnp":
                if call.args and not _is_literal(call.args[0]):
                    yield self.finding(
                        module, call,
                        f"{name}() on a non-literal pulls the value to host "
                        "(or fails on a tracer)",
                        "use jnp, or hoist the pull before tracing and "
                        "pragma it with a why-comment")
            elif tail in _SIZED and (base in _JNP_BASES
                                     or base.endswith(".numpy")):
                if not any(kw.arg == "size" for kw in call.keywords):
                    yield self.finding(
                        module, call,
                        f"{name}() without size= has a data-dependent "
                        "output shape — untraceable",
                        "pass size= (and fill_value=) for a static shape")
        if isinstance(func, ast.Name) and func.id in ("float", "int", "bool") \
                and len(call.args) == 1 and not call.keywords \
                and _contains_call(call.args[0]):
            yield self.finding(
                module, call,
                f"{func.id}() of a computed value concretises it "
                "(host sync / trace error)",
                "keep it as a jnp scalar, or pragma a deliberate "
                "host-driver readback")
