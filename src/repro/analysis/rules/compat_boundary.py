"""compat-boundary: drift-prone jax spellings live in compat.py ONLY.

DESIGN.md §10: every jax API whose spelling or semantics moved between the
versions we straddle is wrapped once in ``src/repro/compat.py``; the rest of
the tree imports the wrapper.  This rule flags direct use of the drifted
spellings anywhere else:

* ``tree_flatten_with_path`` / ``flatten_with_path`` on ``jax.tree_util`` /
  ``jax.tree`` — use ``compat.tree_flatten_with_path``;
* ``lax.axis_size`` — use ``compat.axis_size`` (psum(1) fallback);
* any ``.cost_analysis()`` method call — use ``compat.cost_analysis_dict``
  (the return shape drifted: dict vs list-of-dict);
* ``shard_map`` imported or referenced from ``jax`` / ``jax.experimental``
  — use ``compat.shard_map`` (the entry point moved out of experimental and
  the ``check_rep`` kwarg was renamed along the way);
* ``with_sharding_constraint`` on ``lax`` / ``pjit`` — use
  ``compat.with_sharding_constraint``.

A method named like a drifted spelling on a *non-jax* object (for example
``MeshRules._axis_size``, a host-side mesh-shape helper) is not flagged —
this is exactly the false positive the old grep sweep could not avoid.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from ..callgraph import dotted_name
from ..core import Finding, ParsedModule, Rule

_TREE_ATTRS = ("tree_flatten_with_path", "flatten_with_path")


def _base(node: ast.Attribute) -> Optional[str]:
    return dotted_name(node.value)


class CompatBoundaryRule(Rule):
    id = "compat-boundary"
    doc = ("drift-prone jax spellings (tree_flatten_with_path, axis_size, "
           "cost_analysis, shard_map, with_sharding_constraint) must go "
           "through src/repro/compat.py")

    def applies(self, module: ParsedModule) -> bool:
        return not module.path.endswith("compat.py")

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        if not self.applies(module):
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ImportFrom):
                yield from self._check_import(module, node)
            elif isinstance(node, ast.Attribute):
                yield from self._check_attr(module, node)
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "cost_analysis":
                yield self.finding(
                    module, node,
                    "direct .cost_analysis() call — its return shape "
                    "drifted across jax versions",
                    "use compat.cost_analysis_dict(compiled)")

    def _check_import(self, module: ParsedModule,
                      node: ast.ImportFrom) -> Iterable[Finding]:
        mod = node.module or ""
        if node.level:  # relative import — intra-repo, never a jax drift
            return
        names = [a.name for a in node.names]
        if "shard_map" in mod or (mod in ("jax", "jax.experimental")
                                  and "shard_map" in names):
            yield self.finding(
                module, node,
                f"shard_map imported from `{mod}` — the entry point moved "
                "across jax versions",
                "from repro.compat import shard_map")
        if mod in ("jax.tree_util", "jax.tree"):
            for name in names:
                if name in _TREE_ATTRS:
                    yield self.finding(
                        module, node,
                        f"`{name}` imported from `{mod}` — spelling drifted",
                        "from repro.compat import tree_flatten_with_path")
        if mod.endswith("lax") and "axis_size" in names:
            yield self.finding(
                module, node,
                "lax.axis_size imported directly — not present in older jax",
                "from repro.compat import axis_size")
        if (mod.endswith("pjit") or mod.endswith("lax")) and \
                "with_sharding_constraint" in names:
            yield self.finding(
                module, node,
                f"with_sharding_constraint imported from `{mod}` — "
                "home module drifted",
                "from repro.compat import with_sharding_constraint")

    def _check_attr(self, module: ParsedModule,
                    node: ast.Attribute) -> Iterable[Finding]:
        base = _base(node)
        if base is None:
            return
        tail = base.split(".")[-1]
        if node.attr in _TREE_ATTRS and \
                (tail == "tree_util" or base == "jax.tree"
                 or base.endswith(".tree")):
            yield self.finding(
                module, node,
                f"`{base}.{node.attr}` bypasses the compat boundary",
                "use compat.tree_flatten_with_path")
        elif node.attr == "axis_size" and tail == "lax":
            yield self.finding(
                module, node,
                f"`{base}.axis_size` bypasses the compat boundary",
                "use compat.axis_size (psum(1) on older jax)")
        elif node.attr == "shard_map" and \
                (base == "jax" or tail in ("experimental", "shard_map")):
            yield self.finding(
                module, node,
                f"`{base}.shard_map` bypasses the compat boundary",
                "use compat.shard_map")
        elif node.attr == "with_sharding_constraint" and \
                tail in ("lax", "pjit"):
            yield self.finding(
                module, node,
                f"`{base}.with_sharding_constraint` bypasses the compat "
                "boundary",
                "use compat.with_sharding_constraint")
