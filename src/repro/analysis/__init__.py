"""repro-lint: AST-based invariant & trace-hazard analyzer (DESIGN.md §15).

Stdlib-only — safe to run in CI lanes without jax installed::

    python -m repro.analysis src tests

Public surface: :class:`Analyzer` + :data:`ALL_RULES` for programmatic use
(``scripts/check_single_core.py``, tests), :func:`main` for the CLI.
"""
from __future__ import annotations

from .core import (Analyzer, Finding, ParsedModule, Report, Rule,
                   collect_files, load_baseline, parse_module)
from .rules import ALL_RULES, RULES_BY_ID

__all__ = [
    "Analyzer", "Finding", "ParsedModule", "Report", "Rule",
    "collect_files", "load_baseline", "parse_module",
    "ALL_RULES", "RULES_BY_ID", "main",
]


def main(argv=None) -> int:
    from .__main__ import main as _main
    return _main(argv)
