"""CLI for repro-lint.  Exit codes: 0 clean, 1 findings, 2 usage error."""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from .core import Analyzer, collect_files, load_baseline, write_baseline
from .rules import ALL_RULES, RULES_BY_ID

DEFAULT_BASELINE = "lint_baseline.json"


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: AST invariant & trace-hazard analyzer "
                    "(DESIGN.md §15). Stdlib-only, no jax import.")
    p.add_argument("paths", nargs="*", default=["src", "tests"],
                   help="files or directories to analyze "
                        "(default: src tests)")
    p.add_argument("--rule", action="append", dest="rules", metavar="ID",
                   help="run only this rule (repeatable)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help=f"baseline JSON of grandfathered findings "
                        f"(default: {DEFAULT_BASELINE} if present)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore any baseline file")
    p.add_argument("--write-baseline", default=None, metavar="FILE",
                   help="write current (pragma-filtered) findings as the "
                        "new baseline and exit 0")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress the summary line (findings still print)")
    return p


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id:16s} {rule.doc}")
        return 0

    rules = ALL_RULES
    if args.rules:
        unknown = [r for r in args.rules if r not in RULES_BY_ID]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2
        rules = [RULES_BY_ID[r] for r in args.rules]

    files = collect_files(args.paths)
    if not files:
        print(f"no .py files under: {' '.join(args.paths)}", file=sys.stderr)
        return 2

    baseline = {}
    if args.write_baseline is None and not args.no_baseline:
        baseline_path = args.baseline or DEFAULT_BASELINE
        if Path(baseline_path).exists():
            baseline = load_baseline(baseline_path)
        elif args.baseline is not None:
            print(f"baseline not found: {baseline_path}", file=sys.stderr)
            return 2

    t0 = time.monotonic()
    report = Analyzer(rules, baseline).run_files(files)
    dt = time.monotonic() - t0

    if args.write_baseline is not None:
        write_baseline(args.write_baseline, report.findings, report.modules)
        print(f"wrote {len(report.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    for finding in report.findings:
        print(finding.format())
    if not args.quiet:
        print(f"repro-lint: {len(report.findings)} finding(s) in "
              f"{report.n_files} files ({dt:.2f}s; "
              f"{report.pragma_suppressed} pragma-suppressed, "
              f"{report.baseline_suppressed} baselined)")
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
