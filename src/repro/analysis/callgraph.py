"""Traced-reachability over one module's AST, shared by the host-sync and
shard-safety rules.

jax hazards are positional: ``np.asarray`` in a graph loader is fine, the
same call inside a function that executes under ``jit``/``shard_map``/Pallas
tracing is a silent host sync (or a TracerConversionError three layers
away).  This module approximates "executes under tracing" per module, with
four root classes:

1. **trace arguments** — functions (or lambdas) passed to a tracing entry
   point: ``lax.while_loop/scan/cond/fori_loop/switch/map``, ``jit``,
   ``vmap``/``pmap``, ``shard_map``, ``pl.pallas_call``, ``grad`` & co.
2. **jit-decorated** functions.
3. **escaping closures** — local functions that are referenced other than by
   a direct call (passed as an argument, returned, stored) in a module that
   itself uses tracing machinery.  The engine's planner factories
   (``dense``/``sparse``/``shard_fn``/``build``) all escape into tracing
   contexts through call indirection a per-module analysis cannot follow, so
   escape-in-a-tracing-module is the sound approximation.
4. **public API of a tracing library module** — any public module-level
   function of a ``src/repro`` module that uses tracing machinery is
   presumed jit-callable (the engine's documented contract: runners and
   their helpers "stay usable under jit").  Host-only helpers that live in
   such modules by design carry a pragma documenting why they are
   trace-safe.  Test files do NOT get this root: tests are host drivers.

Reachability then propagates through module-local calls (direct ``name(...)``
calls and ``self._method(...)`` calls, matched by name).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["dotted_name", "is_tracing_call", "ModuleGraph"]

# tail names that trace their function arguments, keyed by how ambiguous the
# bare spelling is: BARE names are unambiguous enough to match without a
# module prefix; PREFIXED ones only count under a jax-ish base (plain
# ``map``/``switch``/``checkpoint`` calls must not root anything).
_TRACING_BARE = {
    "jit", "pjit", "vmap", "pmap", "shard_map", "pallas_call", "while_loop",
    "scan", "fori_loop", "grad", "value_and_grad", "remat",
}
_TRACING_PREFIXED = _TRACING_BARE | {
    "cond", "switch", "map", "associative_scan", "checkpoint", "custom_jvp",
    "custom_vjp",
}
_JAXISH_BASES = {"jax", "lax", "pl", "pltpu", "pallas", "nn", "experimental"}


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.experimental.shard_map' for nested Attributes on a Name, else
    None (calls on call results, subscripts, ... are not resolvable)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_tracing_call(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    if name is None:
        return False
    parts = name.split(".")
    tail, base = parts[-1], parts[:-1]
    if not base:
        return tail in _TRACING_BARE
    return tail in _TRACING_PREFIXED and (base[-1] in _JAXISH_BASES
                                          or "jax" in base)


class ModuleGraph:
    """Function nodes, local call edges, and the traced-reachable set."""

    def __init__(self, module, *, is_library: Optional[bool] = None):
        self.module = module
        tree = module.tree
        if is_library is None:
            path = module.path
            name = path.rsplit("/", 1)[-1]
            is_library = ("src/repro/" in path or path.startswith("repro/")) \
                and not name.startswith("test_") and name != "conftest.py"
        self.is_library = is_library

        #: every def/lambda node in the module
        self.functions: List[ast.AST] = []
        #: name -> def nodes carrying that name (scope-collapsed: a
        #: per-module approximation, names rarely collide in practice)
        self.by_name: Dict[str, List[ast.AST]] = {}
        #: AST node -> enclosing function node (or None for module scope)
        self.owner: Dict[ast.AST, Optional[ast.AST]] = {}
        self.module_level: Set[ast.AST] = set()

        self._index(tree)
        self.uses_tracing = self._module_uses_tracing(tree)
        self.edges = self._call_edges()
        self.traced: Set[ast.AST] = self._reach(self._roots(tree))

    # -- construction ------------------------------------------------------

    def _index(self, tree: ast.Module) -> None:
        def visit(node: ast.AST, fn: Optional[ast.AST], depth: int) -> None:
            for child in ast.iter_child_nodes(node):
                self.owner[child] = fn
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    self.functions.append(child)
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        self.by_name.setdefault(child.name, []).append(child)
                        if depth == 0:
                            self.module_level.add(child)
                    visit(child, child, depth + 1)
                else:
                    visit(child, fn, depth)

        self.owner[tree] = None
        visit(tree, None, 0)

    def _module_uses_tracing(self, tree: ast.Module) -> bool:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and is_tracing_call(node):
                return True
            if isinstance(node, ast.ImportFrom) and node.module and \
                    ("shard_map" in node.module or "pallas" in node.module):
                return True
        return False

    def _call_edges(self) -> Dict[ast.AST, Set[ast.AST]]:
        edges: Dict[ast.AST, Set[ast.AST]] = {}
        for node in ast.walk(self.module.tree):
            if not isinstance(node, ast.Call):
                continue
            caller = self.owner.get(node)
            if caller is None:
                continue
            callee_name = None
            if isinstance(node.func, ast.Name):
                callee_name = node.func.id
            elif isinstance(node.func, ast.Attribute) and \
                    isinstance(node.func.value, ast.Name) and \
                    node.func.value.id in ("self", "cls"):
                callee_name = node.func.attr
            if callee_name is None:
                continue
            for target in self.by_name.get(callee_name, ()):
                edges.setdefault(caller, set()).add(target)
        return edges

    def _function_args(self, call: ast.Call) -> List[ast.AST]:
        """Local function defs (and literal lambdas) passed to ``call``."""
        out: List[ast.AST] = []
        args = list(call.args) + [kw.value for kw in call.keywords]
        for a in args:
            if isinstance(a, ast.Lambda):
                out.append(a)
            elif isinstance(a, ast.Name):
                out.extend(self.by_name.get(a.id, ()))
        return out

    def _roots(self, tree: ast.Module) -> Set[ast.AST]:
        roots: Set[ast.AST] = set()
        called_as: Dict[ast.AST, int] = {}
        referenced: Dict[str, int] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                # (1) trace arguments
                if is_tracing_call(node):
                    roots.update(self._function_args(node))
                if isinstance(node.func, ast.Name):
                    called_as[node.func] = 1
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # (2) jit-ish decorators
                for dec in node.decorator_list:
                    try:
                        text = ast.unparse(dec)
                    except Exception:  # pragma: no cover - unparse is total
                        text = ""
                    if "jit" in text.split("(")[0].split(".")[-1] or \
                            ".jit" in text or "jit(" in text:
                        roots.add(node)
        # (3) escaping closures in tracing modules
        if self.uses_tracing:
            for node in ast.walk(tree):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and \
                        node not in called_as and \
                        node.id in self.by_name:
                    referenced[node.id] = referenced.get(node.id, 0) + 1
            for name in referenced:
                roots.update(self.by_name.get(name, ()))
        # (4) public API of tracing library modules
        if self.uses_tracing and self.is_library:
            for fn in self.module_level:
                if not fn.name.startswith("_"):
                    roots.add(fn)
        return roots

    def _reach(self, roots: Set[ast.AST]) -> Set[ast.AST]:
        seen = set(roots)
        stack = list(roots)
        while stack:
            fn = stack.pop()
            for callee in self.edges.get(fn, ()):
                if callee not in seen:
                    seen.add(callee)
                    stack.append(callee)
        return seen

    # -- queries -----------------------------------------------------------

    def reachable_from(self, roots: Set[ast.AST]) -> Set[ast.AST]:
        return self._reach(set(roots))

    def body_nodes(self, fn: ast.AST):
        """AST nodes owned *directly* by ``fn`` — nested function bodies are
        excluded (they are separate nodes with their own traced status)."""
        for node in ast.walk(fn):
            if node is fn:
                continue
            if self.owner.get(node) is fn:
                yield node
