"""repro-lint core: findings, pragmas, baselines, and the analyzer driver.

This package is the repo's static-analysis substrate (DESIGN.md §15): an
AST-level replacement for the grep guards that previously policed the
engine's invariants.  Everything here is stdlib-only — ``python -m
repro.analysis src tests`` must run in CI lanes that never install jax and
finish in seconds.

Three suppression mechanisms, in priority order:

* **inline pragmas** — ``# repro-lint: disable=<rule>[,<rule>...]`` on the
  offending line, on a comment-only line immediately above it, or on a
  ``def`` line (or the comment line / decorator block directly above the
  ``def``) to cover the whole function body.  ``disable=all`` silences every
  rule for that scope.  Use a pragma when the code is *deliberately* shaped
  like a hazard and a one-line why-comment belongs next to it.
* **file pragma** — ``# repro-lint: disable-file=<rule>`` anywhere in the
  file silences the rule for the entire module (for generated or
  deliberately-hostile fixture files).
* **baseline** — a committed JSON file of grandfathered findings matched by
  (path, rule, stripped source line), so a new rule can land with the
  existing debt recorded instead of fixed-or-pragma'd in the same PR.  The
  match is line-number independent: code can move without invalidating the
  baseline, but *editing* a grandfathered line surfaces the finding again.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding", "ParsedModule", "Rule", "Report", "Analyzer",
    "collect_files", "parse_module", "load_baseline", "baseline_entry",
]

PRAGMA_RE = re.compile(
    r"repro-lint:\s*(disable-file|disable)\s*=\s*([A-Za-z0-9_\-]+"
    r"(?:\s*,\s*[A-Za-z0-9_\-]+)*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored at path:line:col."""

    path: str
    line: int
    col: int
    rule: str
    message: str
    hint: str = ""

    def format(self) -> str:
        s = f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"
        if self.hint:
            s += f"  (fix: {self.hint})"
        return s


class ParsedModule:
    """A parsed source file plus the side tables the rules share: raw lines,
    pragma locations, comment-only lines, and function spans for
    function-scope pragma resolution."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path.replace("\\", "/")
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        #: line number -> set of rule ids disabled on that line
        self.line_disables: Dict[int, Set[str]] = {}
        self.file_disables: Set[str] = set()
        self.comment_only_lines: Set[int] = set()
        self._scan_pragmas()
        #: (def_line, first_decorator_line, end_line) per function
        self.func_spans: List[Tuple[int, int, int]] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                deco_line = min([node.lineno]
                                + [d.lineno for d in node.decorator_list])
                self.func_spans.append(
                    (node.lineno, deco_line, node.end_lineno or node.lineno))

    def _scan_pragmas(self) -> None:
        code_lines: Set[int] = set()
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.source).readline))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            tokens = []
        for tok in tokens:
            if tok.type == tokenize.COMMENT:
                m = PRAGMA_RE.search(tok.string)
                if m:
                    rules = {r.strip() for r in m.group(2).split(",")}
                    if m.group(1) == "disable-file":
                        self.file_disables |= rules
                    else:
                        self.line_disables.setdefault(
                            tok.start[0], set()).update(rules)
            elif tok.type not in (tokenize.NL, tokenize.NEWLINE,
                                  tokenize.INDENT, tokenize.DEDENT,
                                  tokenize.ENDMARKER):
                code_lines.add(tok.start[0])
        for line in self.line_disables:
            if line not in code_lines:
                self.comment_only_lines.add(line)

    def _disabled_at(self, line: int, rule: str) -> bool:
        rules = self.line_disables.get(line)
        return bool(rules) and (rule in rules or "all" in rules)

    def is_suppressed(self, finding: Finding) -> bool:
        rule = finding.rule
        if rule in self.file_disables or "all" in self.file_disables:
            return True
        line = finding.line
        if self._disabled_at(line, rule):
            return True
        # comment-only pragma line immediately above the finding
        if line - 1 in self.comment_only_lines and \
                self._disabled_at(line - 1, rule):
            return True
        # function-scope pragma: on the def line, on a decorator line, or on
        # the comment-only line immediately above the def/decorator block
        for def_line, deco_line, end_line in self.func_spans:
            if not deco_line <= line <= end_line:
                continue
            for l in range(deco_line, def_line + 1):
                if self._disabled_at(l, rule):
                    return True
            if deco_line - 1 in self.comment_only_lines and \
                    self._disabled_at(deco_line - 1, rule):
                return True
        return False

    def source_line(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


class Rule:
    """Base class: subclasses set ``id``/``doc`` and implement ``check``."""

    id: str = ""
    doc: str = ""

    def check(self, module: ParsedModule) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, module: ParsedModule, node: ast.AST, message: str,
                hint: str = "") -> Finding:
        return Finding(module.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), self.id, message, hint)


@dataclasses.dataclass
class Report:
    findings: List[Finding]          # unsuppressed — these fail the build
    pragma_suppressed: int = 0
    baseline_suppressed: int = 0
    n_files: int = 0
    #: every finding before suppression, for --write-baseline
    all_findings: List[Finding] = dataclasses.field(default_factory=list)
    modules: Dict[str, ParsedModule] = dataclasses.field(default_factory=dict)


def collect_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted list of .py files (skipping
    hidden directories and __pycache__)."""
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if any(part.startswith(".") or part == "__pycache__"
                       for part in f.parts):
                    continue
                out.append(f)
        elif path.suffix == ".py":
            out.append(path)
    seen: Set[Path] = set()
    uniq = []
    for f in out:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def parse_module(path: str, source: Optional[str] = None):
    """Parse one file.  Returns a ParsedModule, or a Finding (rule
    ``parse-error``) when the source does not parse."""
    if source is None:
        source = Path(path).read_text()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return Finding(str(path).replace("\\", "/"), e.lineno or 1,
                       e.offset or 0, "parse-error", f"syntax error: {e.msg}")
    return ParsedModule(str(path), source, tree)


# ---------------------------------------------------------------------------
# Baseline (grandfathered findings)
# ---------------------------------------------------------------------------

def baseline_entry(finding: Finding, module: Optional[ParsedModule]) -> dict:
    context = module.source_line(finding.line) if module is not None else ""
    return {"path": finding.path, "rule": finding.rule, "context": context}


def load_baseline(path) -> Dict[Tuple[str, str, str], int]:
    """Baseline file -> multiset of (path, rule, context) keys."""
    data = json.loads(Path(path).read_text())
    counts: Dict[Tuple[str, str, str], int] = {}
    for e in data.get("findings", []):
        key = (e["path"], e["rule"], e.get("context", ""))
        counts[key] = counts.get(key, 0) + 1
    return counts


def write_baseline(path, findings: List[Finding],
                   modules: Dict[str, ParsedModule]) -> None:
    entries = [baseline_entry(f, modules.get(f.path)) for f in findings]
    Path(path).write_text(json.dumps(
        {"version": 1, "findings": entries}, indent=2, sort_keys=True) + "\n")


# ---------------------------------------------------------------------------
# Analyzer driver
# ---------------------------------------------------------------------------

class Analyzer:
    def __init__(self, rules: Sequence[Rule],
                 baseline: Optional[Dict[Tuple[str, str, str], int]] = None):
        self.rules = list(rules)
        self.baseline = dict(baseline) if baseline else {}

    def run_files(self, files: Sequence) -> Report:
        report = Report(findings=[], n_files=len(files))
        budget = dict(self.baseline)
        for f in files:
            parsed = parse_module(str(f))
            if isinstance(parsed, Finding):
                report.all_findings.append(parsed)
                report.findings.append(parsed)
                continue
            report.modules[parsed.path] = parsed
            for rule in self.rules:
                for finding in rule.check(parsed):
                    report.all_findings.append(finding)
                    if parsed.is_suppressed(finding):
                        report.pragma_suppressed += 1
                        continue
                    key = (finding.path, finding.rule,
                           parsed.source_line(finding.line))
                    if budget.get(key, 0) > 0:
                        budget[key] -= 1
                        report.baseline_suppressed += 1
                        continue
                    report.findings.append(finding)
        report.findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
        return report

    def run_source(self, source: str, path: str = "<memory>") -> List[Finding]:
        """Analyze one in-memory source string (the test-fixture entry
        point).  Pragmas apply; the baseline does not."""
        parsed = parse_module(path, source)
        if isinstance(parsed, Finding):
            return [parsed]
        out: List[Finding] = []
        for rule in self.rules:
            for finding in rule.check(parsed):
                if not parsed.is_suppressed(finding):
                    out.append(finding)
        out.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
        return out
