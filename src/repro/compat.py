"""Version-tolerant aliases for jax APIs that drifted across releases.

Policy (DESIGN.md §10): repro code never calls a jax symbol that only exists
in some of the versions we support.  Every such symbol gets one alias here,
written as "try the new spelling, fall back to the old one", so a version bump
is a one-file change and the rest of the tree stays on a stable surface.

Covered today (installed jax 0.4.x):

* ``tree_flatten_with_path``  — ``jax.tree.flatten_with_path`` only appears in
  newer jax; ``jax.tree_util.tree_flatten_with_path`` is the stable spelling.
* ``axis_size``               — ``lax.axis_size`` is missing on this version;
  ``lax.psum(1, axis_name)`` is the documented equivalent and constant-folds
  to a static ``int`` under ``shard_map``, so it remains usable for shapes.
* ``cost_analysis_dict``      — ``Compiled.cost_analysis()`` has returned a
  dict, a list of dicts (one per program), or ``None`` depending on version.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence, Union

import jax
from jax import lax

__all__ = ["tree_flatten_with_path", "axis_size", "cost_analysis_dict"]

AxisName = Union[str, Sequence[str]]


def tree_flatten_with_path(tree: Any):
    """(path, leaf) pairs + treedef, on any jax that has either spelling."""
    if hasattr(jax, "tree") and hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def _one_axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    # psum of a literal is constant-folded to the axis size (a python int),
    # so this works even where the result feeds a static shape.
    return lax.psum(1, axis_name)


def axis_size(axis_name: AxisName) -> int:
    """Size of one mesh axis, or the product over a tuple of axes."""
    if isinstance(axis_name, (tuple, list)):
        s = 1
        for a in axis_name:
            s *= _one_axis_size(a)
        return s
    return _one_axis_size(axis_name)


def cost_analysis_dict(compiled: Any) -> Mapping[str, float]:
    """Normalize ``Compiled.cost_analysis()`` to a single flat dict."""
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: dict[str, float] = {}
        for entry in cost:
            if isinstance(entry, Mapping):
                merged.update(entry)
        return merged
    return cost
