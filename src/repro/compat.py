"""Version-tolerant aliases for jax APIs that drifted across releases.

Policy (DESIGN.md §10): repro code never calls a jax symbol that only exists
in some of the versions we support.  Every such symbol gets one alias here,
written as "try the new spelling, fall back to the old one", so a version bump
is a one-file change and the rest of the tree stays on a stable surface.

Covered today (installed jax 0.4.x):

* ``tree_flatten_with_path``  — ``jax.tree.flatten_with_path`` only appears in
  newer jax; ``jax.tree_util.tree_flatten_with_path`` is the stable spelling.
* ``axis_size``               — ``lax.axis_size`` is missing on this version;
  ``lax.psum(1, axis_name)`` is the documented equivalent and constant-folds
  to a static ``int`` under ``shard_map``, so it remains usable for shapes.
* ``cost_analysis_dict``      — ``Compiled.cost_analysis()`` has returned a
  dict, a list of dicts (one per program), or ``None`` depending on version.
* ``shard_map``               — lived in ``jax.experimental.shard_map`` for
  the whole 0.4.x line and graduated to ``jax.shard_map`` (where the
  ``check_rep`` kwarg became ``check_vma``) in newer releases.
* ``with_sharding_constraint`` — moved homes from ``jax.experimental.pjit``
  to ``jax.lax`` (and the pjit spelling now warns).

The ``repro-lint`` compat-boundary rule enforces this policy mechanically:
any use of the raw spellings above outside this file is a finding.
"""
from __future__ import annotations

import inspect
from typing import Any, Mapping, Sequence, Union

import jax
from jax import lax

__all__ = ["tree_flatten_with_path", "axis_size", "cost_analysis_dict",
           "shard_map", "with_sharding_constraint"]

AxisName = Union[str, Sequence[str]]


def tree_flatten_with_path(tree: Any):
    """(path, leaf) pairs + treedef, on any jax that has either spelling."""
    if hasattr(jax, "tree") and hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def _one_axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    # psum of a literal is constant-folded to the axis size (a python int),
    # so this works even where the result feeds a static shape.
    return lax.psum(1, axis_name)


def axis_size(axis_name: AxisName) -> int:
    """Size of one mesh axis, or the product over a tuple of axes."""
    if isinstance(axis_name, (tuple, list)):
        s = 1
        for a in axis_name:
            s *= _one_axis_size(a)
        return s
    return _one_axis_size(axis_name)


# the drifted spellings below are the one sanctioned use — compat.py is the
# single module exempt from the compat-boundary lint rule.
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f: Any, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_rep: bool = False, **kwargs: Any):
    """``shard_map`` with the stable pre-graduation calling convention.

    Accepts ``check_rep`` everywhere and translates it to ``check_vma`` on
    jax versions where the kwarg was renamed; drops it entirely if neither
    spelling exists.
    """
    kwargs.update(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_rep
    elif "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_rep
    return _shard_map_impl(f, **kwargs)


if hasattr(lax, "with_sharding_constraint"):
    _wsc_impl = lax.with_sharding_constraint
else:  # pre-0.4 spelling, kept for completeness of the policy
    from jax.experimental.pjit import with_sharding_constraint as _wsc_impl


def with_sharding_constraint(x: Any, shardings: Any):
    """``with_sharding_constraint`` from whichever home module this jax has."""
    return _wsc_impl(x, shardings)


def cost_analysis_dict(compiled: Any) -> Mapping[str, float]:
    """Normalize ``Compiled.cost_analysis()`` to a single flat dict."""
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: dict[str, float] = {}
        for entry in cost:
            if isinstance(entry, Mapping):
                merged.update(entry)
        return merged
    return cost
