"""Checkpointing: per-leaf .npy + JSON manifest, atomic, async, elastic.

* atomic    — written to ``<dir>/tmp_<step>`` then os.rename'd to ``step_<N>``
              (a crashed save can never shadow a good checkpoint);
* async     — device->host copy happens synchronously (cheap), disk I/O on a
              background thread so the train loop keeps stepping;
* elastic   — restore() takes target shardings: the same checkpoint restores
              onto ANY mesh (128, 256, 512 chips...) — resharding is a
              device_put with the new NamedSharding, PIUMA's "code does not
              change for multinode" applied to state;
* resumable — latest_step() scans the directory, so a restarted job (fault
              tolerance driver) picks up where it died.

At >1k-node scale each host would write only its addressable shards; the
manifest format already records per-leaf shapes/dtypes so that extension is a
file-layout change, not a format change (DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "/"


def _flatten(tree):
    flat, treedef = compat.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, jax.tree.structure(tree)


def save(directory: str, step: int, tree: Any, *, async_: bool = False
         ) -> Optional[threading.Thread]:
    """Write checkpoint for `step`. Returns the writer thread when async."""
    os.makedirs(directory, exist_ok=True)
    items, _ = _flatten(tree)
    # synchronous device->host snapshot (consistent state), async disk write.
    # bf16 (and other ml_dtypes) are stored as uint16 bit patterns — the
    # manifest records the logical dtype for exact restore.
    def to_host(v):
        a = np.asarray(v)
        if a.dtype.kind not in "fiub?":
            return str(a.dtype), a.view(np.uint16 if a.dtype.itemsize == 2
                                        else np.uint8)
        return str(a.dtype), a

    host = [(k,) + to_host(v) for k, v in items]
    manifest = {
        "step": step,
        "leaves": [{"key": k, "shape": list(a.shape), "dtype": dt}
                   for k, dt, a in host],
    }

    def _write():
        tmp = os.path.join(directory, f"tmp_{step}")
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, (k, dt, a) in enumerate(host):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), a)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `target` (pytree of arrays or
    ShapeDtypeStructs). `shardings`: matching pytree of NamedShardings (or
    None) — THIS is where elastic re-meshing happens."""
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    items, treedef = _flatten(target)
    keys = {e["key"]: i for i, e in enumerate(manifest["leaves"])}
    shard_items = (None if shardings is None else
                   [s for _, s in _flatten(shardings)[0]])
    leaves = []
    for j, (k, tgt) in enumerate(items):
        if k not in keys:
            raise KeyError(f"checkpoint missing leaf {k}")
        entry = manifest["leaves"][keys[k]]
        arr = np.load(os.path.join(final, f"leaf_{keys[k]}.npy"))
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {tgt.shape}")
        if str(arr.dtype) != entry["dtype"]:
            arr = arr.view(jnp.dtype(entry["dtype"]))  # stored bit pattern
        arr = arr.astype(jnp.dtype(str(tgt.dtype)))
        sh = shard_items[j] if shard_items is not None else None
        leaves.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    """Every-N-steps async checkpointing with bounded retention."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, *, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return
        self.wait()
        self._pending = save(self.directory, step, tree, async_=True)
        self._gc(pending_step=step)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, pending_step: Optional[int] = None):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        if pending_step is not None and pending_step not in steps:
            steps = sorted(steps + [pending_step])  # count the in-flight save
        doomed = [s for s in steps[: -self.keep] if s != pending_step]
        for s in doomed:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, target, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore(self.directory, step, target, shardings), step
