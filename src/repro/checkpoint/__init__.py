from .ckpt import save, restore, latest_step, CheckpointManager
