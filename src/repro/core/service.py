"""Graph query service: micro-batched multi-source traversal serving.

PIUMA's concurrency story is *many traversals in flight at once* — the
single-query engine reproduces the memory/network story (DESIGN.md §3–§7),
this module reproduces the serving story on top of the batched engine
(`engine.run_batched`): a typed query API, an admission queue that
micro-batches compatible queries into one batched engine pass, an LRU result
cache keyed by (graph epoch, query), and a stats ledger
(queries/sec, batch occupancy, cache hit rate, modeled route bytes/query).

Queries and their results
-------------------------

=====================  =============================  =====================
query                  engine pass                    result
=====================  =============================  =====================
:class:`Reachability`  bit-packed MS-BFS lane         bool
:class:`Distance`      batched delta-stepping lane    float (inf = no path)
:class:`PPRTopK`       vmapped personalized-PR lane   (ids (k,), scores (k,))
:class:`NeighborSample` keyed one-hop sample slots    ids (fanout,)
=====================  =============================  =====================

Micro-batching policy (DESIGN.md §13): the admission queue is FIFO; each
round takes the *kind* of the oldest pending query and collects queries of
that kind — in submission order, leaving other kinds queued — until the
batch budget of lanes is full.  Traversal queries occupying the same source
share a lane (dedup), sample queries occupy ``fanout`` slots.  Batches are
padded to the full budget so each (kind, budget) pair compiles exactly once;
padding lanes replay lane 0 and are discarded.

Cache keying rule: ``(epoch, query)`` — the query dataclasses are frozen and
hashable, and ``update_graph`` bumps the epoch, so a mutated graph can never
serve stale results while an unchanged graph keeps its whole cache.  Sampled
results are cached too (a repeated NeighborSample query returns the *same*
draw until evicted or the epoch moves — the draw is keyed by
(seed, epoch, query), not by batch composition, so identical resubmissions
after eviction also redraw identically).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, traffic
from .graph import CSR
from .algorithms.bfs import msbfs
from .algorithms.pagerank import ppr_topk
from .algorithms.sssp import auto_delta, sssp_batched

__all__ = [
    "Reachability", "Distance", "PPRTopK", "NeighborSample",
    "ServiceStats", "GraphService",
]


# ---------------------------------------------------------------------------
# Typed queries (frozen => hashable => cache keys)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Reachability:
    """Is `target` reachable from `source`?  Served by an MS-BFS lane."""

    source: int
    target: int


@dataclasses.dataclass(frozen=True)
class Distance:
    """Shortest weighted distance source -> target (inf if unreachable).
    Served by a batched delta-stepping lane (the graph-level `auto_delta`)."""

    source: int
    target: int


@dataclasses.dataclass(frozen=True)
class PPRTopK:
    """Top-k personalized-PageRank neighborhood of `source`.  k may vary per
    query up to the service's ``ppr_k_max``; every batch computes
    ``ppr_k_max`` candidates and slices each query's k (one compile per
    (kind, budget))."""

    source: int
    k: int = 8


@dataclasses.dataclass(frozen=True)
class NeighborSample:
    """`fanout` independent one-hop neighbor draws from `vertex` (uniform
    over out-edges; sinks return the vertex itself).  `seed` salts the draw
    so distinct queries on one vertex stay independent."""

    vertex: int
    fanout: int = 1
    seed: int = 0


_KIND = {Reachability: "reach", Distance: "dist", PPRTopK: "ppr",
         NeighborSample: "sample"}


# ---------------------------------------------------------------------------
# Stats ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServiceStats:
    """Counters over a service's lifetime (or since `reset_stats`).

    route_bytes is the §7/§13 *model* of what a distributed deployment would
    move: per batched push level one compacted exchange at the derived
    capacity whose items carry all B lanes (`traffic.batched_payload_bytes`),
    per dense level a full-partition gather of the lane payloads — computed
    from the run's measured push/pull trace, n_model_shards wide.
    """

    budget: int
    n_model_shards: int = 8
    queries: int = 0
    cache_hits: int = 0
    batches: int = 0
    lanes_used: int = 0
    busy_s: float = 0.0
    route_bytes: int = 0
    push_levels: int = 0
    pull_levels: int = 0

    @property
    def qps(self) -> float:
        return self.queries / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of the lane budget a batch actually fills."""
        return self.lanes_used / (self.batches * self.budget) \
            if self.batches else 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.queries if self.queries else 0.0

    @property
    def route_bytes_per_query(self) -> float:
        return self.route_bytes / self.queries if self.queries else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "queries": self.queries, "cache_hits": self.cache_hits,
            "batches": self.batches, "lanes_used": self.lanes_used,
            "busy_s": self.busy_s, "route_bytes": self.route_bytes,
            "push_levels": self.push_levels, "pull_levels": self.pull_levels,
            "qps": self.qps, "occupancy": self.occupancy,
            "hit_rate": self.hit_rate,
            "route_bytes_per_query": self.route_bytes_per_query,
        }

    def __str__(self) -> str:
        return (f"ServiceStats(queries={self.queries}, qps={self.qps:.1f}, "
                f"occupancy={self.occupancy:.2f}, "
                f"hit_rate={self.hit_rate:.2f}, "
                f"route_B/query={self.route_bytes_per_query:.0f}, "
                f"batches={self.batches})")


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------

class GraphService:
    """Serve typed graph queries from one (mutable-by-epoch) graph.

    batch_budget: lanes per micro-batch — the B the batched engine runs at.
    cache_capacity: LRU entries; 0 disables caching.
    results_capacity: completed-but-unclaimed results kept for
      :meth:`result`; the oldest are dropped beyond this (a fire-and-forget
      client must not leak the service's memory).
    ppr_iters / damping / mode / ppr_k_max: engine knobs shared by every
      query (part of the compatibility rule: everything but the
      source/k/fanout is service-level, so same-kind queries always batch —
      every PPR batch computes ``ppr_k_max`` candidates and slices each
      query's k, keeping one compile per (kind, budget)).
    n_model_shards: width of the route-byte model (see ServiceStats).
    """

    def __init__(self, csr: CSR, *, batch_budget: int = 32,
                 cache_capacity: int = 4096, results_capacity: int = 65536,
                 ppr_iters: int = 20, damping: float = 0.85,
                 mode: str = "auto", ppr_k_max: int = 64,
                 n_model_shards: int = 8, seed: int = 0):
        if batch_budget < 1:
            raise ValueError("batch_budget must be >= 1")
        self.budget = int(batch_budget)
        self.cache_capacity = int(cache_capacity)
        self.results_capacity = int(results_capacity)
        self.ppr_k_max = int(ppr_k_max)
        self.ppr_iters = ppr_iters
        self.damping = damping
        self.mode = mode
        self.seed = seed
        self.epoch = 0
        self.stats = ServiceStats(budget=self.budget,
                                  n_model_shards=n_model_shards)
        self._cache: "collections.OrderedDict[Tuple, Any]" = \
            collections.OrderedDict()
        self._queue: "collections.deque[Tuple[int, Any]]" = collections.deque()
        self._results: "collections.OrderedDict[int, Any]" = \
            collections.OrderedDict()
        self._next_ticket = 0
        self._set_graph(csr)

    # -- graph epoch -------------------------------------------------------

    def _set_graph(self, csr: CSR) -> None:
        self.csr = csr
        self.delta = auto_delta(csr)
        self._ppr_k = min(self.ppr_k_max, csr.n_rows)
        self._runners: Dict[Tuple, Any] = {}
        m_per = -(-csr.nnz // self.stats.n_model_shards)
        self._edge_cap = engine.frontier_edge_capacity(m_per, 1 / 32)
        self._m_per_shard = m_per

    def update_graph(self, csr: CSR) -> int:
        """Swap the served graph; bumps the epoch (old cache entries can
        never be served again) and drops the compiled runners.  Pending
        queries were *admitted* (and bounds-validated) against the old graph,
        so they are flushed against it first — a query never executes on a
        different graph than the one it was accepted for."""
        if self._queue:
            self.flush()
        self.epoch += 1
        self._set_graph(csr)
        # keys embed the epoch, so stale entries are unreachable — purge them
        # eagerly rather than letting them age out of the LRU
        self._cache.clear()
        return self.epoch

    def reset_stats(self) -> None:
        self.stats = ServiceStats(budget=self.budget,
                                  n_model_shards=self.stats.n_model_shards)

    # -- cache -------------------------------------------------------------

    def _cache_get(self, q) -> Tuple[bool, Any]:
        key = (self.epoch, q)
        if key in self._cache:
            self._cache.move_to_end(key)
            return True, self._cache[key]
        return False, None

    def _cache_put(self, q, value) -> None:
        if self.cache_capacity <= 0:
            return
        key = (self.epoch, q)
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_capacity:
            self._cache.popitem(last=False)

    # -- admission ---------------------------------------------------------

    def submit(self, q) -> int:
        """Enqueue a query; returns a ticket for :meth:`result`."""
        if type(q) not in _KIND:
            raise TypeError(f"unknown query type {type(q).__name__}")
        if isinstance(q, NeighborSample) and not 0 < q.fanout <= self.budget:
            raise ValueError(f"fanout {q.fanout} outside [1, {self.budget}] "
                             "(one batch slot per draw)")
        n = self.csr.n_rows
        for field in ("source", "target", "vertex"):
            v = getattr(q, field, None)
            if v is not None and not 0 <= v < n:
                raise ValueError(f"{type(q).__name__}.{field}={v} outside "
                                 f"[0, {n})")
        if isinstance(q, PPRTopK) and not 0 < q.k <= self._ppr_k:
            raise ValueError(f"PPRTopK.k={q.k} outside [1, {self._ppr_k}] "
                             "(raise ppr_k_max to serve larger k)")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, q))
        return t

    def result(self, ticket: int):
        if ticket not in self._results:
            if 0 <= ticket < self._next_ticket and \
                    not any(t == ticket for t, _ in self._queue):
                raise KeyError(f"ticket {ticket} was claimed already or "
                               "evicted (results_capacity bounds unclaimed "
                               "results)")
            raise KeyError(f"ticket {ticket} has no result (flush pending "
                           "queries first)")
        return self._results.pop(ticket)

    def query(self, q):
        """Submit + flush + return: the synchronous convenience path."""
        t = self.submit(q)
        self.flush()
        return self.result(t)

    def flush(self) -> List[int]:
        """Drain the admission queue; returns the processed tickets in
        submission order.  Each round micro-batches the oldest pending
        query's kind (FIFO within the kind) up to the lane budget."""
        done: List[int] = []
        t0 = time.perf_counter()
        while self._queue:
            kind = _KIND[type(self._queue[0][1])]
            batch, lanes = self._collect(kind, done)
            done.extend(t for t, _ in batch)
            self._execute(kind, batch, lanes)
            if batch:
                self.stats.batches += 1
        self.stats.busy_s += time.perf_counter() - t0
        return sorted(done)

    def _collect(self, kind: str, done: List[int]):
        """Pull same-kind queries from the queue (submission order) until the
        lane budget fills.  Returns ([(ticket, query)], ordered lane keys) —
        traversal queries dedupe on source, sample queries take fanout
        slots."""
        batch: List[Tuple[int, Any]] = []
        lanes: List[int] = []
        slots = 0
        keep: List[Tuple[int, Any]] = []
        while self._queue:
            t, q = self._queue.popleft()
            if _KIND[type(q)] != kind:
                keep.append((t, q))
                continue
            hit, val = self._cache_get(q)
            if hit:
                self._store_result(t, val)
                done.append(t)
                self.stats.queries += 1
                self.stats.cache_hits += 1
                continue
            if kind == "sample":
                need = q.fanout
                if slots + need > self.budget and slots > 0:
                    keep.append((t, q))
                    break
                slots += min(need, self.budget)
            else:
                src = q.source
                if src not in lanes:
                    if len(lanes) >= self.budget:
                        keep.append((t, q))
                        break
                    lanes.append(src)
            batch.append((t, q))
        self._queue.extendleft(reversed(keep))
        return batch, lanes

    # -- execution ---------------------------------------------------------

    def _pad(self, xs: List[int]) -> np.ndarray:
        out = np.zeros((self.budget,), np.int32)
        out[: len(xs)] = xs
        if xs:
            out[len(xs):] = xs[0]
        return out

    def _runner(self, key, build):
        fn = self._runners.get(key)
        if fn is None:
            fn = self._runners[key] = build()
        return fn

    def _charge(self, n_lanes: int, pushes: int, pulls: int, *,
                packed: bool) -> None:
        """Route-byte model of the batch (see ServiceStats).  Push levels
        move routed items (index + validity header + lanes) at the compacted
        capacity; dense pull levels gather the bare lane payload for the
        full edge partition — no routing header."""
        st = self.stats
        item = traffic.batched_payload_bytes(n_lanes, packed=packed)
        lane_bytes = item - (4 + 1)
        ctr = traffic.RouteByteCounter(st.n_model_shards)
        for _ in range(int(pushes)):
            ctr.push_level(self._edge_cap, payload_bytes=item)
        for _ in range(int(pulls)):
            ctr.pull_level(self._m_per_shard * lane_bytes)
        st.route_bytes += ctr.total_bytes
        st.push_levels += int(pushes)
        st.pull_levels += int(pulls)

    def _execute(self, kind: str, batch, lanes: List[int]) -> None:
        if not batch:
            return
        if kind == "sample":
            self._execute_sample(batch)
            return
        srcs = jnp.asarray(self._pad(lanes))
        lane_of = {s: i for i, s in enumerate(lanes)}
        if kind == "reach":
            run = self._runner(("reach", self.budget), lambda: jax.jit(
                lambda s: msbfs(self.csr, s, mode=self.mode,
                                return_stats=True)))
            levels, stats = run(srcs)
            levels = np.asarray(levels)
            for t, q in batch:
                self._finish(t, q, bool(levels[lane_of[q.source],
                                               q.target] >= 0))
            self._charge(self.budget, stats["pushes"], stats["pulls"],
                         packed=True)
        elif kind == "dist":
            run = self._runner(("dist", self.budget), lambda: jax.jit(
                lambda s: sssp_batched(self.csr, s, delta=self.delta,
                                       mode=self.mode, return_stats=True)))
            dist, stats = run(srcs)
            dist = np.asarray(dist)
            for t, q in batch:
                self._finish(t, q, float(dist[lane_of[q.source], q.target]))
            self._charge(self.budget, stats["pushes"], stats["pulls"],
                         packed=False)
        elif kind == "ppr":
            # every batch computes ppr_k_max candidates and slices per query:
            # compiles stay one per (kind, budget), not per observed k
            k = self._ppr_k
            run = self._runner(("ppr", self.budget), lambda: jax.jit(
                lambda s: ppr_topk(self.csr, s, k, damping=self.damping,
                                   iters=self.ppr_iters)))
            vals, ids = run(srcs)
            vals, ids = np.asarray(vals), np.asarray(ids)
            for t, q in batch:
                ln = lane_of[q.source]
                self._finish(t, q, (ids[ln, : q.k].copy(),
                                    vals[ln, : q.k].copy()))
            self._charge(self.budget, 0, self.ppr_iters, packed=False)
        self.stats.lanes_used += len(lanes)
        self.stats.queries += len(batch)

    def _execute_sample(self, batch) -> None:
        verts = np.zeros((self.budget,), np.int32)
        salts = np.zeros((self.budget,), np.uint32)
        spans: List[Tuple[int, int]] = []
        pos = 0
        for t, q in batch:
            take = q.fanout
            # _collect's slot accounting and submit's fanout bound guarantee
            # the batch fits; fail loudly (not by truncating-and-caching a
            # wrong-shaped result) if that invariant ever regresses
            assert pos + take <= self.budget, (pos, take, self.budget)
            verts[pos: pos + take] = q.vertex
            # the draw is keyed by (epoch, query, slot) — batch-composition
            # independent, so cached and recomputed answers agree
            qh = np.uint32(hash((q.vertex, q.fanout, q.seed)) & 0x7FFFFFFF)
            salts[pos: pos + take] = qh + np.arange(take, dtype=np.uint32)
            spans.append((pos, take))
            pos += take

        def build():
            base = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                      self.epoch)
            def run(v, s):
                keys = jax.vmap(lambda si: jax.random.fold_in(base, si))(s)
                return jax.vmap(
                    lambda kk, vv: engine.sample_neighbors(
                        self.csr, vv[None], kk)[0])(keys, v)
            return jax.jit(run)

        run = self._runner(("sample", self.budget), build)
        nbrs = np.asarray(run(jnp.asarray(verts), jnp.asarray(salts)))
        for (t, q), (s, take) in zip(batch, spans):
            self._finish(t, q, nbrs[s: s + take].copy())
        ctr = traffic.RouteByteCounter(self.stats.n_model_shards)
        ctr.push_level(self.budget,
                       payload_bytes=traffic.ROUTE_PAYLOAD_BYTES)
        self.stats.route_bytes += ctr.total_bytes
        self.stats.push_levels += 1
        self.stats.lanes_used += pos
        self.stats.queries += len(batch)

    def _store_result(self, ticket: int, value) -> None:
        self._results[ticket] = value
        while len(self._results) > self.results_capacity:
            self._results.popitem(last=False)  # oldest unclaimed ticket

    def _finish(self, ticket: int, q, value) -> None:
        self._store_result(ticket, value)
        self._cache_put(q, value)
