"""Graph query service: micro-batched multi-source traversal serving.

PIUMA's concurrency story is *many traversals in flight at once* — the
single-query engine reproduces the memory/network story (DESIGN.md §3–§7),
this module reproduces the serving story on top of the batched engine: a
typed query API, an admission queue that micro-batches compatible queries
into one batched engine pass, an LRU result cache keyed by (graph epoch,
query), and a stats ledger (queries/sec, batch occupancy, cache hit rate,
latency percentiles, deadline-miss rate, route bytes/query).

Placement follows the ExecutionCore grid (DESIGN.md §14): constructed with a
``mesh``, the service serves traversal queries from the **sharded** engine —
`engine.run_batched_distributed` via ``msbfs_distributed`` /
``sssp_batched_distributed`` — so one compacted owner-routed exchange per
level carries every lane of the batch; without a mesh it serves from the
local batched engine exactly as before.  ``placement='async'`` serves the
traversal kinds under the engine's bounded-staleness placement instead —
``sync_interval`` collective-free micro-steps between global checks, same
results (the traversal programs are monotone), ~K× fewer global reductions
per query — and ``cost_seed='auto'`` warms the deadline cost EWMA from the
last committed bench doc.  PPR and neighbor-sample queries
stay on the local placement either way (PPR is a dense-regime program with
no batched-distributed port yet; sampling is one compacted gather).

Queries and their results
-------------------------

=====================  =============================  =====================
query                  engine pass                    result
=====================  =============================  =====================
:class:`Reachability`  bit-packed MS-BFS lane         bool
:class:`Distance`      batched delta-stepping lane    float (inf = no path)
:class:`PPRTopK`       vmapped personalized-PR lane   (ids (k,), scores (k,))
:class:`NeighborSample` keyed one-hop sample slots    ids (fanout,)
=====================  =============================  =====================

Micro-batching policy (DESIGN.md §13/§14): the admission queue preserves
submission order *within* a kind, and each round picks the next kind
**round-robin** over the kinds with pending queries — a burst of one kind
can therefore no longer starve the others (the pre-PR-5 policy served the
oldest query's kind first, so head-of-line bursts monopolized the engine).
Queries of the round's kind are collected in submission order until the
batch budget of lanes is full.  Traversal queries occupying the same source
share a lane (dedup), sample queries occupy ``fanout`` slots.  Batches are
padded to the full budget so each (kind, budget) pair compiles exactly once;
padding lanes replay lane 0 and are discarded.

Deadline-aware admission (DESIGN.md §14): ``submit(q, deadline=s)`` attaches
a latency SLO (seconds from submission).  The micro-batcher then flushes not
only on demand but the moment the oldest admitted deadline's *slack* —
deadline minus now minus the kind's estimated batch cost (an EWMA of
measured executions) — is exhausted, or as soon as a kind's pending lane
demand fills the budget: a deadline query waits for batch-fill only while
waiting is free.  ``poll()`` is the client-driven tick between submissions.
The deadline never changes *what* is computed — only when the batch is cut —
so it stays out of the cache key.

Graph mutation (DESIGN.md §16): the service's graph currency is an
epoch-versioned :class:`~repro.core.graph.GraphHandle` — CSR + epoch +
delta log + per-partition mutation stamps, with all epoch bookkeeping in
``graph.py`` (machine-enforced by the `mutable-handle` repro-lint rule).
``apply_updates(inserts, deletes)`` splices an edge-update batch through
``GraphHandle.apply`` and invalidates the cache **partition-scoped**: each
cached entry records which partitions its computation touched (the
traversal's reached set, mapped to block partitions), and an update evicts
only the entries whose touched set intersects the mutated partitions.
That is sound because an edge change at (u, v) can alter a traversal's
result only if the traversal reached u's (or, symmetrically priced, v's)
partition — an entry that never touched them never saw the edge.  The
legacy ``update_graph(csr)`` whole-swap survives as a deprecated shim over
``GraphHandle.replace`` (every partition stamped, so everything evicts).

Cache keying rule: the frozen query dataclass itself — epochs no longer
live in the key because invalidation is eager: a mutation evicts exactly
the entries it could have changed, and what survives is still correct.
Sampled results are cached too (a repeated NeighborSample query returns
the *same* draw until evicted or its partition is mutated — the draw is
keyed by (seed, epoch, query), not by batch composition).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import os
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, traffic
from .. import tune as _tune
from .dgas import block_rule
from .graph import CSR, GraphHandle, UpdateReport
from ..obs import Histogram, Observability, get_registry
from .algorithms.bfs import msbfs, msbfs_distributed
from .algorithms.distgraph import shard_graph, update_shards
from .algorithms.pagerank import ppr_topk
from .algorithms.sssp import auto_delta, sssp_batched, sssp_batched_distributed

__all__ = [
    "Reachability", "Distance", "PPRTopK", "NeighborSample",
    "ServiceStats", "GraphService", "load_cost_priors",
]

_log = logging.getLogger("repro.streaming")


# trace-safe: host-side bench-doc discovery at service construction —
# repro-lint: disable=host-sync
def load_cost_priors(*, distributed: bool = False, budget: int = 32,
                     bench_dir: Optional[str] = None) -> Dict[str, float]:
    """Per-kind batch-cost priors (seconds) from the newest committed bench
    doc (``BENCH_pr<N>.json``, highest N wins, searched in ``bench_dir`` or
    the working directory).

    The deadline-slack estimate subtracts the kind's EWMA batch cost, but the
    EWMA starts empty — the first observation is the compile-inflated cold
    run, so early deadlines either fire pessimistically or (before any batch)
    not at all.  Seeding from the last bench run gives admission a
    steady-state prior from the first submit; the EWMA still converges to
    this deployment's true cost.  Returns {} when no usable doc exists (the
    pre-seed behavior), so construction never fails on a missing file.
    """
    import glob
    import json
    import re
    pat = os.path.join(bench_dir or os.getcwd(), "BENCH_pr*.json")
    best, best_n = None, -1
    for p in glob.glob(pat):
        m = re.match(r"BENCH_pr(\d+)\.json$", os.path.basename(p))
        if m and int(m.group(1)) > best_n:
            best, best_n = p, int(m.group(1))
    if best is None:
        return {}
    try:
        with open(best) as f:
            doc = json.load(f)
        section = doc["service_distributed" if distributed else "service"]
        row = section["budgets"][str(budget)]
        if distributed:
            cost = float(row["latency_p50_ms"]) / 1e3
        else:
            cost = float(budget) / float(row["qps"])
    except (KeyError, TypeError, ValueError, OSError):
        return {}
    if not (cost > 0.0 and np.isfinite(cost)):
        return {}
    # one coarse per-batch prior for every kind — the EWMA refines per kind
    return {k: cost for k in _KIND_ROTATION}


# ---------------------------------------------------------------------------
# Typed queries (frozen => hashable => cache keys)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Reachability:
    """Is `target` reachable from `source`?  Served by an MS-BFS lane."""

    source: int
    target: int


@dataclasses.dataclass(frozen=True)
class Distance:
    """Shortest weighted distance source -> target (inf if unreachable).
    Served by a batched delta-stepping lane (the graph-level `auto_delta`)."""

    source: int
    target: int


@dataclasses.dataclass(frozen=True)
class PPRTopK:
    """Top-k personalized-PageRank neighborhood of `source`.  k may vary per
    query up to the service's ``ppr_k_max``; every batch computes
    ``ppr_k_max`` candidates and slices each query's k (one compile per
    (kind, budget))."""

    source: int
    k: int = 8


@dataclasses.dataclass(frozen=True)
class NeighborSample:
    """`fanout` independent one-hop neighbor draws from `vertex` (uniform
    over out-edges; sinks return the vertex itself).  `seed` salts the draw
    so distinct queries on one vertex stay independent."""

    vertex: int
    fanout: int = 1
    seed: int = 0


_KIND = {Reachability: "reach", Distance: "dist", PPRTopK: "ppr",
         NeighborSample: "sample"}
# fixed rotation for the round-robin batch-kind selection
_KIND_ROTATION = ("reach", "dist", "ppr", "sample")


# ---------------------------------------------------------------------------
# Stats ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServiceStats:
    """Counters over a service's lifetime (or since `reset_stats`).

    route_bytes is the §7/§13 *model* of what a distributed deployment moves:
    per batched push level one compacted exchange at the derived capacity
    whose items carry all B lanes (`traffic.batched_payload_bytes`) — levels
    the engine reports as capacity-overflow fallbacks are charged at the full
    partition instead — per dense level a full-partition gather of the lane
    payloads.  Under a mesh the trace comes from the *real* distributed run
    (`run_batched_distributed(return_stats=True)`), so the ledger prices what
    actually executed; n_model_shards is then the mesh size.

    Latency is recorded per query (submit -> result stored), and every query
    submitted with a deadline counts toward ``deadline_miss_rate`` — a miss
    is a result that lands after its absolute deadline.
    """

    budget: int
    n_model_shards: int = 8
    queries: int = 0
    cache_hits: int = 0
    batches: int = 0
    lanes_used: int = 0
    busy_s: float = 0.0
    route_bytes: int = 0
    push_levels: int = 0
    pull_levels: int = 0
    deadline_queries: int = 0
    deadline_misses: int = 0
    updates: int = 0            # apply_updates batches ingested
    update_edges: int = 0       # edges changed across those batches
    cache_evicted: int = 0      # entries evicted by partition-scoped purges
    # log-bucketed latency sketch (repro.obs): O(buckets) retention no
    # matter how many queries are served, percentiles within one bucket
    # width (12%) of exact — replaces the raw 65536-deep sample deque
    latency_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram("service.latency_s"))

    @property
    def qps(self) -> float:
        return self.queries / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of the lane budget a batch actually fills."""
        return self.lanes_used / (self.batches * self.budget) \
            if self.batches else 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.queries if self.queries else 0.0

    @property
    def route_bytes_per_query(self) -> float:
        return self.route_bytes / self.queries if self.queries else 0.0

    def _latency_pct(self, pct: float) -> float:
        return self.latency_hist.percentile(pct)

    @property
    def latency_p50_ms(self) -> float:
        return 1e3 * self._latency_pct(50)

    @property
    def latency_p95_ms(self) -> float:
        return 1e3 * self._latency_pct(95)

    @property
    def deadline_miss_rate(self) -> float:
        return self.deadline_misses / self.deadline_queries \
            if self.deadline_queries else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "queries": self.queries, "cache_hits": self.cache_hits,
            "batches": self.batches, "lanes_used": self.lanes_used,
            "busy_s": self.busy_s, "route_bytes": self.route_bytes,
            "push_levels": self.push_levels, "pull_levels": self.pull_levels,
            "qps": self.qps, "occupancy": self.occupancy,
            "hit_rate": self.hit_rate,
            "route_bytes_per_query": self.route_bytes_per_query,
            "latency_p50_ms": self.latency_p50_ms,
            "latency_p95_ms": self.latency_p95_ms,
            "deadline_queries": self.deadline_queries,
            "deadline_misses": self.deadline_misses,
            "deadline_miss_rate": self.deadline_miss_rate,
            "updates": self.updates, "update_edges": self.update_edges,
            "cache_evicted": self.cache_evicted,
        }

    def __str__(self) -> str:
        return (f"ServiceStats(queries={self.queries}, qps={self.qps:.1f}, "
                f"occupancy={self.occupancy:.2f}, "
                f"hit_rate={self.hit_rate:.2f}, "
                f"p50={self.latency_p50_ms:.1f}ms, "
                f"p95={self.latency_p95_ms:.1f}ms, "
                f"miss_rate={self.deadline_miss_rate:.3f}, "
                f"route_B/query={self.route_bytes_per_query:.0f}, "
                f"batches={self.batches})")


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------

class GraphService:
    """Serve typed graph queries from one (mutable-by-epoch) graph.

    batch_budget: lanes per micro-batch — the B the batched engine runs at
      (None = the tuned lane budget for this backend/scale, repro.tune).
    cache_capacity: LRU entries; 0 disables caching.
    results_capacity: completed-but-unclaimed results kept for
      :meth:`result`; the oldest are dropped beyond this (a fire-and-forget
      client must not leak the service's memory).
    ppr_iters / damping / mode / ppr_k_max: engine knobs shared by every
      query (part of the compatibility rule: everything but the
      source/k/fanout is service-level, so same-kind queries always batch —
      every PPR batch computes ``ppr_k_max`` candidates and slices each
      query's k, keeping one compile per (kind, budget)).
    mesh: optional jax Mesh — serve traversal kinds from the sharded engine
      (`run_batched_distributed`); the graph is block-sharded over the mesh's
      first axis and the route-byte ledger prices the *measured* level trace.
    n_model_shards: width of the route-byte model when no mesh is given
      (with a mesh the real shard count is used).
    clock: injectable monotonic time source (seconds) — deadlines, latency
      percentiles and the EWMA batch-cost estimate all read it, so tests can
      drive admission deterministically with a fake clock.
    deadline_safety: slack margin in seconds — a deadline is considered
      "about to expire" once slack <= this margin, so a client that polls at
      least once per ``deadline_safety`` window is never served late while
      the engine is idle (the §14 property the hypothesis suite asserts).
    placement: 'sync' (default) or 'async'.  With a mesh, traversal kinds
      then run under the engine's bounded-staleness placement —
      ``sync_interval`` collective-free local micro-steps between global
      convergence checks — which returns identical results (the traversal
      programs are monotone) with ~``sync_interval``× fewer global
      reductions.  Ignored without a mesh (the local engine has no barrier
      to relax).
    sync_interval: micro-steps per global check under placement='async'
      (default 8); 1 reproduces the sync schedule exactly.
    cost_seed: optional per-kind batch-cost priors in seconds ({kind: s}),
      or 'auto' to read the newest committed bench doc
      (:func:`load_cost_priors`) — deadline admission then starts from a
      steady-state estimate instead of learning from the compile-inflated
      first batch.
    obs: optional :class:`repro.obs.Observability` — attaching one turns on
      host-side span recording (enqueue / flush-wait / engine / readback,
      DESIGN.md §17) and per-level engine tracing (the traversal runners
      compile with ``trace=True`` and each run's decoded level trace lands
      in ``obs.level_runs``).  ``None`` (default) records nothing and the
      runners compile exactly as before; degradation counters (push-capacity
      fallback, cache invalidations, EWMA updates) always land in
      ``obs.metrics`` when attached, else the process-wide registry.
    """

    #: EWMA weight for the per-kind batch-cost estimate the deadline slack
    #: subtracts; ~0.3 tracks warmup -> steady-state within a few batches.
    COST_EWMA_ALPHA = 0.3

    def __init__(self, csr, *, batch_budget: Optional[int] = None,
                 cache_capacity: int = 4096, results_capacity: int = 65536,
                 ppr_iters: int = 20, damping: float = 0.85,
                 mode: str = "auto", ppr_k_max: int = 64,
                 mesh=None, n_model_shards: int = 8, seed: int = 0,
                 clock=time.perf_counter, deadline_safety: float = 0.0,
                 placement: str = "sync",
                 sync_interval: Optional[int] = None,
                 cost_seed=None, obs: Optional[Observability] = None):
        # tuned-config funnel (DESIGN.md §18): explicit batch_budget wins,
        # None takes the tuned lane budget for this backend and graph scale
        _n_rows = (csr.csr if isinstance(csr, GraphHandle) else csr).n_rows
        batch_budget = int(_tune.resolve("service.batch_budget",
                                         explicit=batch_budget, n=_n_rows))
        if batch_budget < 1:
            raise ValueError("batch_budget must be >= 1")
        if placement not in ("sync", "async"):
            raise ValueError(f"placement must be 'sync' or 'async', "
                             f"got {placement!r}")
        self.budget = int(batch_budget)
        self.cache_capacity = int(cache_capacity)
        self.results_capacity = int(results_capacity)
        self.ppr_k_max = int(ppr_k_max)
        self.ppr_iters = ppr_iters
        self.damping = damping
        self.mode = mode
        self.seed = seed
        self.mesh = mesh
        self.placement = placement
        self.sync_interval = int(sync_interval) if sync_interval is not None \
            else (8 if placement == "async" else 1)
        self._clock = clock
        self.obs = obs
        self._metrics = obs.metrics if obs is not None else get_registry()
        self._trace = obs is not None
        self.deadline_safety = float(deadline_safety)
        if mesh is not None:
            n_model_shards = 1
            for a in mesh.axis_names:
                n_model_shards *= int(mesh.shape[a])
        self.stats = ServiceStats(budget=self.budget,
                                  n_model_shards=n_model_shards)
        self._cache: "collections.OrderedDict[Tuple, Any]" = \
            collections.OrderedDict()
        # (ticket, query, absolute deadline or None, submit time)
        self._queue: "collections.deque[Tuple[int, Any, Optional[float], float]]" = \
            collections.deque()
        self._results: "collections.OrderedDict[int, Any]" = \
            collections.OrderedDict()
        self._next_ticket = 0
        self._rr = 0                      # round-robin rotation cursor
        self._n_deadlines = 0             # queued entries carrying a deadline
        self._cost_ewma: Dict[str, float] = {}
        if cost_seed == "auto":
            cost_seed = load_cost_priors(distributed=mesh is not None,
                                         budget=self.budget)
        self._cost_ewma.update({k: float(v)
                                for k, v in (cost_seed or {}).items()})
        handle = csr if isinstance(csr, GraphHandle) else \
            GraphHandle.wrap(csr, n_partitions=n_model_shards)
        self._att = self._gsh = None
        self._set_graph(handle)

    # -- graph epoch (GraphHandle is the currency; see graph.py) -----------

    @property
    def epoch(self) -> int:
        """The served graph's epoch — read-only handle bookkeeping."""
        return self.handle.epoch

    @property
    def csr(self) -> CSR:
        """The served graph's CSR (the handle's current effective graph)."""
        return self.handle.csr

    # trace-safe: host-side graph installation — concrete handle/ATT
    # arithmetic before any runner is (re)compiled —
    # repro-lint: disable=host-sync
    def _set_graph(self, handle: GraphHandle,
                   report: Optional[UpdateReport] = None) -> None:
        self.handle = handle
        csr = handle.csr
        self.delta = auto_delta(csr)
        self._ppr_k = min(self.ppr_k_max, csr.n_rows)
        # compiled runners capture the old CSR as trace constants: drop them
        self._runners: Dict[Tuple, Any] = {}
        if self.mesh is not None:
            S = self.stats.n_model_shards
            gsh = None
            if report is not None and self._gsh is not None \
                    and not report.compacted:
                # incremental reshard: only shards owning a changed SOURCE
                # row moved edges (the stacked layout is source-partitioned)
                srcs = jnp.asarray(report.changed_sources, jnp.int32)
                shards = np.unique(np.asarray(self._att.owner(srcs))) \
                    if report.changed_sources.size else np.zeros(0, np.int64)
                gsh = update_shards(self._gsh, csr, self._att, shards)
                if gsh is None:
                    _log.info("epoch %d: shard padding overflow — full "
                              "reshard", handle.epoch)
            if gsh is None:           # cold start / compaction / overflow
                self._att = block_rule(csr.n_rows, S)
                gsh, _ = shard_graph(csr, S, row_att=self._att)
            self._gsh = gsh
            m_per = self._gsh.edges_per_shard
        else:
            self._att = self._gsh = None
            m_per = -(-csr.nnz // self.stats.n_model_shards)
        self._edge_cap = engine.frontier_edge_capacity(
            m_per, _tune.resolve("engine.switch_frac", n=csr.n_rows),
            n=csr.n_rows)
        self._m_per_shard = m_per

    # trace-safe: host-side ingest driver — the report's concrete partition
    # counts feed the ledger, nothing here is traced —
    # repro-lint: disable=host-sync
    def apply_updates(self, inserts=None, deletes=None) -> UpdateReport:
        """Ingest one edge-update batch (DESIGN.md §16).

        inserts: (rows, cols) or (rows, cols, vals); deletes: (rows, cols)
        — ``GraphHandle.apply`` semantics (deletes first, duplicate inserts
        last-wins, upserts replace weights).  Bumps the epoch, reshards only
        the touched partitions under a mesh, and invalidates the cache
        partition-scoped: entries whose recorded touched-partition set is
        disjoint from the mutation survive.  Pending queries were admitted
        against the old graph, so they flush against it first.  Returns the
        :class:`~repro.core.graph.UpdateReport` (the repair seed for
        ``algorithms.incremental``).
        """
        if self._queue:
            self.flush()
        handle, report = self.handle.apply(inserts, deletes)
        self._set_graph(handle, report=report)
        evicted = self._invalidate_partitions(report.touched_partitions)
        # route-byte model: a deployment reships the touched partitions'
        # edge lists (every partition on compaction), one contract-payload
        # item per surviving edge — the §9 contract_level pricing
        counts = handle.partition_edge_counts()
        self._charge_ingest(int(counts.sum()) if report.compacted
                            else int(counts[report.touched_partitions].sum()))
        st = self.stats
        st.updates += 1
        st.update_edges += report.n_changed
        st.cache_evicted += evicted
        return report

    def update_graph(self, csr: CSR) -> int:
        """Deprecated whole-graph swap — a thin shim over
        ``GraphHandle.replace`` (every partition is stamped, so the
        partition-scoped invalidation evicts everything).  Use
        :meth:`apply_updates` for streaming deltas.  Pending queries were
        *admitted* (and bounds-validated) against the old graph, so they are
        flushed against it first — a query never executes on a different
        graph than the one it was accepted for."""
        warnings.warn(
            "GraphService.update_graph(csr) is deprecated; use "
            "apply_updates(inserts, deletes) for streaming edge deltas, or "
            "rebuild the service from GraphHandle.replace(csr) for a "
            "whole-graph swap", DeprecationWarning, stacklevel=2)
        if self._queue:
            self.flush()
        self._set_graph(self.handle.replace(csr))
        self._invalidate_partitions(range(self.handle.n_partitions))
        self._charge_ingest(self.csr.nnz)
        return self.epoch

    def reset_stats(self) -> None:
        self.stats = ServiceStats(budget=self.budget,
                                  n_model_shards=self.stats.n_model_shards)

    # -- cache -------------------------------------------------------------
    # entries are q -> (value, touched_parts): `touched_parts` is the
    # frozenset of block partitions the computation read (None = all, the
    # conservative default), recorded so apply_updates can evict exactly the
    # entries a mutation could have changed (module docstring soundness
    # argument) instead of purging the world.

    def _cache_get(self, q) -> Tuple[bool, Any]:
        if q in self._cache:
            self._cache.move_to_end(q)
            return True, self._cache[q][0]
        return False, None

    def _cache_put(self, q, value, parts: Optional[frozenset] = None) -> None:
        if self.cache_capacity <= 0:
            return
        self._cache[q] = (value, parts)
        self._cache.move_to_end(q)
        while len(self._cache) > self.cache_capacity:
            self._cache.popitem(last=False)

    # trace-safe: host-side cache sweep over concrete partition ids —
    # repro-lint: disable=host-sync
    def _invalidate_partitions(self, parts) -> int:
        """Evict entries whose touched-partition set intersects `parts`
        (entries with no recorded set count as touching everything).
        Returns the number evicted."""
        ps = {int(p) for p in np.asarray(list(parts)).reshape(-1)}
        evict = [k for k, (_, ent) in self._cache.items()
                 if ent is None or ent & ps]
        for k in evict:
            del self._cache[k]
        if evict:
            self._metrics.counter("service.cache_invalidations").inc(
                len(evict))
        return len(evict)

    def _charge_ingest(self, n_edges: int) -> None:
        """Price a reshard of `n_edges` surviving edges in the route-byte
        ledger — contract-payload items (src, dst, weight), §9 pricing."""
        ctr = traffic.RouteByteCounter(self.stats.n_model_shards)
        ctr.contract_level(int(n_edges))
        self.stats.route_bytes += ctr.total_bytes

    # partition attribution of results: block partitions are contiguous
    # vertex ranges (GraphHandle arithmetic), so a traversal's touched set
    # is the owners of its reached vertices — computed from the result
    # arrays the executors already pulled to host.

    # trace-safe: partition attribution over result arrays the executors
    # already pulled to host — repro-lint: disable=host-sync
    def _parts_of_mask(self, reached: np.ndarray) -> frozenset:
        """Touched partitions of one lane's (n,) reached mask."""
        idx = np.nonzero(reached)[0]
        return frozenset(
            int(p) for p in np.unique(self.handle.partition_of(idx)))

    # trace-safe: same host-side attribution, per-shard variant —
    # repro-lint: disable=host-sync
    def _parts_of_shard_mask(self, shard_mask: np.ndarray) -> frozenset:
        """Touched partitions from a per-shard reached indicator (S,) —
        shards are contiguous global ranges under the block ATT, so each
        reached shard maps to the partition range covering it."""
        per = self._att.per_shard
        n = self.csr.n_rows
        parts = set()
        for s in np.nonzero(shard_mask)[0]:
            lo, hi = int(s) * per, min(n, (int(s) + 1) * per) - 1
            if hi >= lo:
                parts.update(range(int(self.handle.partition_of(lo)),
                                   int(self.handle.partition_of(hi)) + 1))
        return frozenset(parts)

    # -- admission ---------------------------------------------------------

    def submit(self, q, deadline: Optional[float] = None) -> int:
        """Enqueue a query; returns a ticket for :meth:`result`.

        deadline: optional latency SLO in seconds from now.  Deadline-aware
        admission then arms: the service flushes as soon as the oldest
        admitted deadline's slack (deadline - now - the kind's estimated
        batch cost) runs out, or a kind's pending lane demand fills the
        budget — instead of waiting for an explicit :meth:`flush`.
        """
        if type(q) not in _KIND:
            raise TypeError(f"unknown query type {type(q).__name__}")
        if deadline is not None and deadline < 0:
            raise ValueError(f"deadline must be >= 0, got {deadline}")
        if isinstance(q, NeighborSample) and not 0 < q.fanout <= self.budget:
            raise ValueError(f"fanout {q.fanout} outside [1, {self.budget}] "
                             "(one batch slot per draw)")
        n = self.csr.n_rows
        for field in ("source", "target", "vertex"):
            v = getattr(q, field, None)
            if v is not None and not 0 <= v < n:
                raise ValueError(f"{type(q).__name__}.{field}={v} outside "
                                 f"[0, {n})")
        if isinstance(q, PPRTopK) and not 0 < q.k <= self._ppr_k:
            raise ValueError(f"PPRTopK.k={q.k} outside [1, {self._ppr_k}] "
                             "(raise ppr_k_max to serve larger k)")
        t = self._next_ticket
        self._next_ticket += 1
        now = self._clock()
        self._queue.append((t, q, None if deadline is None else now + deadline,
                            now))
        if self.obs is not None:
            # enqueue span ends before any armed flush below fires, so the
            # client lane never swallows a whole batch execution
            self.obs.spans.record("enqueue", now, self._clock(),
                                  tid=Observability.TID_CLIENT,
                                  kind=_KIND[type(q)], ticket=t,
                                  deadline_s=deadline)
        if deadline is not None:
            self._n_deadlines += 1
        if self._deadline_armed() and (self._deadline_due()
                                       or self._some_kind_full()):
            self.flush()
        return t

    def poll(self) -> List[int]:
        """The client-driven admission tick: flush iff some admitted query's
        deadline slack is exhausted (a no-op otherwise).  Call between
        submissions; returns the tickets served, like :meth:`flush`."""
        if self._deadline_armed() and self._deadline_due():
            return self.flush()
        return []

    def _deadline_armed(self) -> bool:
        # O(1): deadline-free streams pay nothing for the admission checks
        # (the counter resets when flush drains the queue)
        return self._n_deadlines > 0

    def _est_cost(self, kind: str) -> float:
        """EWMA estimate of one batch execution of this kind (0 until the
        first measured batch — an unknown cost must not hold a deadline)."""
        return self._cost_ewma.get(kind, 0.0)

    def _deadline_due(self) -> bool:
        """True iff some admitted deadline is about to expire: its slack
        (deadline - now - estimated batch cost) is within the safety margin,
        so serving any later could land past the deadline."""
        now = self._clock()
        return any(dl is not None
                   and now >= dl - self._est_cost(_KIND[type(q)])
                   - self.deadline_safety
                   for _, q, dl, _ in self._queue)

    def _some_kind_full(self) -> bool:
        """True iff some kind's head batch is as packed as it can ever get,
        by replaying `_collect`'s exact accounting: cache hits occupy no
        lane, traversal sources dedupe, and the sample batch cuts at the
        first query whose fanout no longer fits (FIFO within the kind, so a
        later small query could never join that batch anyway)."""
        lanes: Dict[str, Any] = {k: set() for k in _KIND_ROTATION}
        slots = 0
        for _, q, _, _ in self._queue:
            if q in self._cache:
                continue            # will be served from cache, takes no lane
            kind = _KIND[type(q)]
            if kind == "sample":
                if slots + q.fanout > self.budget:
                    return True     # _collect would cut the batch here
                slots += q.fanout
                if slots == self.budget:
                    return True
            else:
                lanes[kind].add(q.source)
                if len(lanes[kind]) >= self.budget:
                    return True
        return False

    def result(self, ticket: int):
        if ticket not in self._results:
            if 0 <= ticket < self._next_ticket and \
                    not any(t == ticket for t, *_ in self._queue):
                raise KeyError(f"ticket {ticket} was claimed already or "
                               "evicted (results_capacity bounds unclaimed "
                               "results)")
            raise KeyError(f"ticket {ticket} has no result (flush pending "
                           "queries first)")
        return self._results.pop(ticket)

    def query(self, q, deadline: Optional[float] = None):
        """Submit + flush + return: the synchronous convenience path."""
        t = self.submit(q, deadline=deadline)
        self.flush()
        return self.result(t)

    def flush(self) -> List[int]:
        """Drain the admission queue; returns the processed tickets in
        submission order.  Each round micro-batches one kind — chosen
        round-robin over the kinds with pending queries, FIFO within the
        kind — up to the lane budget."""
        done: List[int] = []
        t0 = self._clock()
        while self._queue:
            kind = self._next_kind()
            batch, lanes = self._collect(kind, done)
            done.extend(t for t, *_ in batch)
            self._execute(kind, batch, lanes)
            if batch:
                self.stats.batches += 1
        self._n_deadlines = 0           # queue drained: nothing armed
        self.stats.busy_s += self._clock() - t0
        return sorted(done)

    def _next_kind(self) -> str:
        """Round-robin across kinds with pending queries (the PR-5 fix for
        FIFO head-of-line blocking: a burst of one kind no longer starves
        the others — each kind gets a batch per rotation)."""
        pending = {_KIND[type(q)] for _, q, *_ in self._queue}
        K = len(_KIND_ROTATION)
        for i in range(K):
            kind = _KIND_ROTATION[(self._rr + i) % K]
            if kind in pending:
                self._rr = (_KIND_ROTATION.index(kind) + 1) % K
                return kind
        raise AssertionError("flush loop entered with an empty queue")

    def _collect(self, kind: str, done: List[int]):
        """Pull same-kind queries from the queue (submission order) until the
        lane budget fills.  Returns ([(ticket, query, deadline, t_submit)],
        ordered lane keys) — traversal queries dedupe on source, sample
        queries take fanout slots."""
        batch: List[Tuple] = []
        lanes: List[int] = []
        slots = 0
        keep: List[Tuple] = []
        while self._queue:
            entry = self._queue.popleft()
            t, q, dl, ts = entry
            if _KIND[type(q)] != kind:
                keep.append(entry)
                continue
            hit, val = self._cache_get(q)
            if hit:
                self._store_result(t, val)
                done.append(t)
                self.stats.queries += 1
                self.stats.cache_hits += 1
                self._account_latency(dl, ts)
                continue
            if kind == "sample":
                need = q.fanout
                if slots + need > self.budget and slots > 0:
                    keep.append(entry)
                    break
                slots += min(need, self.budget)
            else:
                src = q.source
                if src not in lanes:
                    if len(lanes) >= self.budget:
                        keep.append(entry)
                        break
                    lanes.append(src)
            batch.append(entry)
        self._queue.extendleft(reversed(keep))
        return batch, lanes

    # -- execution ---------------------------------------------------------

    def _pad(self, xs: List[int]) -> np.ndarray:
        out = np.zeros((self.budget,), np.int32)
        out[: len(xs)] = xs
        if xs:
            out[len(xs):] = xs[0]
        return out

    def _runner(self, key, build):
        fn = self._runners.get(key)
        if fn is None:
            fn = self._runners[key] = build()
        return fn

    def _account_latency(self, dl: Optional[float], ts: float) -> None:
        now = self._clock()
        self.stats.latency_hist.observe(now - ts)
        if dl is not None:
            self.stats.deadline_queries += 1
            if now > dl:
                self.stats.deadline_misses += 1

    def _update_cost(self, kind: str, seconds: float) -> None:
        prev = self._cost_ewma.get(kind)
        a = self.COST_EWMA_ALPHA
        self._cost_ewma[kind] = seconds if prev is None \
            else (1 - a) * prev + a * seconds
        self._metrics.counter("service.cost_ewma_updates").inc()

    def _charge(self, n_lanes: int, pushes: int, pulls: int, *,
                packed: bool, fallbacks: int = 0) -> None:
        """Route-byte model of the batch (see ServiceStats).  Push levels
        move routed items (index + validity header + lanes) at the compacted
        capacity — except measured capacity-overflow fallbacks, which routed
        the full partition; dense pull levels gather the bare lane payload
        for the full edge partition — no routing header."""
        st = self.stats
        item = traffic.batched_payload_bytes(n_lanes, packed=packed)
        lane_bytes = item - (4 + 1)
        ctr = traffic.RouteByteCounter(st.n_model_shards)
        fallbacks = min(int(fallbacks), int(pushes))
        for _ in range(int(pushes) - fallbacks):
            ctr.push_level(self._edge_cap, payload_bytes=item)
        for _ in range(fallbacks):
            ctr.push_level(self._m_per_shard, payload_bytes=item)
        for _ in range(int(pulls)):
            ctr.pull_level(self._m_per_shard * lane_bytes)
        st.route_bytes += ctr.total_bytes
        st.push_levels += int(pushes)
        st.pull_levels += int(pulls)

    # trace-safe: host side of result extraction, after the jitted runner
    # returned — repro-lint: disable=host-sync
    def _vertex_slots(self, verts: List[int]) -> Tuple[np.ndarray, np.ndarray]:
        """(owner, local) of each vertex under the serving ATT — the host
        side of reading one vertex out of a stacked (S, ..., per) result."""
        v = jnp.asarray(np.asarray(verts, np.int32))
        return np.asarray(self._att.owner(v)), np.asarray(self._att.local(v))

    def _execute(self, kind: str, batch, lanes: List[int]) -> None:
        if not batch:
            return
        t_exec = self._clock()
        if self.obs is not None:
            # queue wait + collect, measured from the batch's oldest submit;
            # the recorder clips the start forward to the previous round's
            # readback end, so successive rounds tile the service lane
            self.obs.spans.record(
                "flush_wait", min(ts for *_, ts in batch), t_exec,
                tid=Observability.TID_SERVICE, kind=kind,
                batch_size=len(batch))
        if kind == "sample":
            self._execute_sample(batch)
        else:
            self._execute_traversal(kind, batch, lanes)
        self._update_cost(kind, self._clock() - t_exec)
        for _, _, dl, ts in batch:
            self._account_latency(dl, ts)

    # trace-safe: host executor — readbacks AFTER the jitted runner return
    # are the service's product — repro-lint: disable=host-sync
    def _execute_traversal(self, kind: str, batch, lanes: List[int]) -> None:
        # the engine span opens before the host->device source upload: the
        # staging transfer is engine dispatch work, not queue wait
        t_eng0 = self._clock()
        rb0 = self.stats.route_bytes
        srcs = jnp.asarray(self._pad(lanes))
        lane_of = {s: i for i, s in enumerate(lanes)}
        distributed = self.mesh is not None and kind in ("reach", "dist")
        lane_parts: Dict[int, frozenset] = {}
        trace = self._trace

        def parts_of(ln: int, reached) -> frozenset:
            # reached: (n,) lane mask locally, (S, per) stacked distributed —
            # memoised per lane (dedup'd queries share the computation)
            if ln not in lane_parts:
                lane_parts[ln] = (
                    self._parts_of_shard_mask(reached.any(axis=1))
                    if distributed else self._parts_of_mask(reached))
            return lane_parts[ln]

        if kind == "reach":
            if distributed:
                run = self._runner(("reach", self.budget, trace),
                                   lambda: jax.jit(
                    lambda s: msbfs_distributed(
                        self._gsh, self._att, s, self.mesh,
                        max_levels=self.csr.n_rows, return_stats=True,
                        placement=self.placement,
                        sync_interval=self.sync_interval, trace=trace)))
            else:
                run = self._runner(("reach", self.budget, trace),
                                   lambda: jax.jit(
                    lambda s: msbfs(self.csr, s, mode=self.mode,
                                    return_stats=True, trace=trace)))
            levels, stats = run(srcs)
            levels = np.asarray(levels)
            t_eng1 = self._clock()
            if distributed:
                own, loc = self._vertex_slots([q.target for _, q, *_ in batch])
                for (t, q, *_), o, l in zip(batch, own, loc):
                    ln = lane_of[q.source]
                    self._finish(t, q, bool(levels[o, ln, l] >= 0),
                                 parts=parts_of(ln, levels[:, ln, :] >= 0))
            else:
                for t, q, *_ in batch:
                    ln = lane_of[q.source]
                    self._finish(t, q, bool(levels[ln, q.target] >= 0),
                                 parts=parts_of(ln, levels[ln] >= 0))
            self._charge_traversal(stats, packed=True, distributed=distributed)
        elif kind == "dist":
            if distributed:
                run = self._runner(("dist", self.budget, trace),
                                   lambda: jax.jit(
                    lambda s: sssp_batched_distributed(
                        self._gsh, self._att, s, self.mesh, delta=self.delta,
                        max_iters=4 * self.csr.n_rows, return_stats=True,
                        placement=self.placement,
                        sync_interval=self.sync_interval, trace=trace)))
            else:
                run = self._runner(("dist", self.budget, trace),
                                   lambda: jax.jit(
                    lambda s: sssp_batched(self.csr, s, delta=self.delta,
                                           mode=self.mode,
                                           return_stats=True, trace=trace)))
            dist, stats = run(srcs)
            dist = np.asarray(dist)
            t_eng1 = self._clock()
            if distributed:
                own, loc = self._vertex_slots([q.target for _, q, *_ in batch])
                for (t, q, *_), o, l in zip(batch, own, loc):
                    ln = lane_of[q.source]
                    self._finish(t, q, float(dist[o, ln, l]),
                                 parts=parts_of(ln, np.isfinite(
                                     dist[:, ln, :])))
            else:
                for t, q, *_ in batch:
                    ln = lane_of[q.source]
                    self._finish(t, q, float(dist[ln, q.target]),
                                 parts=parts_of(ln, np.isfinite(dist[ln])))
            self._charge_traversal(stats, packed=False,
                                   distributed=distributed)
        elif kind == "ppr":
            # every batch computes ppr_k_max candidates and slices per query:
            # compiles stay one per (kind, budget), not per observed k
            k = self._ppr_k
            run = self._runner(("ppr", self.budget, trace), lambda: jax.jit(
                lambda s: ppr_topk(self.csr, s, k, damping=self.damping,
                                   iters=self.ppr_iters, return_stats=True,
                                   trace=trace)))
            vals, ids, stats = run(srcs)
            vals, ids = np.asarray(vals), np.asarray(ids)
            t_eng1 = self._clock()
            for t, q, *_ in batch:
                ln = lane_of[q.source]
                # PPR iterates dense over the whole graph: parts=None means
                # "touched everything", so any mutation evicts it
                self._finish(t, q, (ids[ln, : q.k].copy(),
                                    vals[ln, : q.k].copy()))
            self._charge_traversal(stats, packed=False, distributed=False)
        self.stats.lanes_used += len(lanes)
        self.stats.queries += len(batch)
        if self.obs is not None:
            self._record_batch_spans(kind, batch, lanes, stats,
                                     t_eng0, t_eng1, rb0)

    def _record_batch_spans(self, kind: str, batch, lanes, stats,
                            t_eng0: float, t_eng1: float, rb0: int) -> None:
        """Close one executed batch's engine + readback spans and decode its
        per-level trace into the attached Observability (DESIGN.md §17).
        The engine span ends at the result readback (`np.asarray` is the
        device sync point); everything after — per-query extraction,
        partition attribution, ledger pricing — is the readback span."""
        obs = self.obs
        slacks = [dl - t_eng0 for _, _, dl, _ in batch if dl is not None]
        obs.spans.record(
            "engine", t_eng0, t_eng1, tid=Observability.TID_SERVICE,
            kind=kind, lanes=len(lanes), budget=self.budget,
            epoch=self.epoch,
            route_bytes=self.stats.route_bytes - rb0,
            deadline_slack_s=min(slacks) if slacks else None)
        obs.spans.record("readback", t_eng1, self._clock(),
                         tid=Observability.TID_SERVICE, kind=kind)
        if "trace" in stats:
            obs.add_level_run(f"{kind}@{self.epoch}", t_eng0, t_eng1, stats)

    # trace-safe: ledger accounting over concrete returned stats —
    # repro-lint: disable=host-sync
    def _charge_traversal(self, stats, *, packed: bool,
                          distributed: bool) -> None:
        """Feed the ledger the run's level trace — stacked (S,) and globally
        identical under the distributed placement, scalar locally.

        Async placement: the engine reports buffered flushes in 'pushes'
        (micro-steps move no network traffic), so the ledger prices each as
        one dense outbox exchange — `traffic.flush_route_bytes` at the
        resident partition width with the batch's lane payload — instead of
        compacted push levels."""
        def first(x):
            a = np.asarray(x)
            return int(a.reshape(-1)[0])
        fallbacks = first(stats["fallbacks"]) if distributed else 0
        if fallbacks:
            self._metrics.counter("service.push_capacity_fallback").inc(
                fallbacks)
        if distributed and self.placement == "async":
            st = self.stats
            flushes = first(stats["pushes"])
            ctr = traffic.RouteByteCounter(st.n_model_shards)
            for _ in range(flushes):
                ctr.flush_level(self._att.per_shard,
                                elem_bytes=4 * self.budget)
            st.route_bytes += ctr.total_bytes
            st.push_levels += flushes
            return
        self._charge(self.budget, first(stats["pushes"]),
                     first(stats["pulls"]), packed=packed,
                     fallbacks=fallbacks)

    # trace-safe: host executor, readback after the jitted sampler returns —
    # repro-lint: disable=host-sync
    def _execute_sample(self, batch) -> None:
        t_eng0 = self._clock()
        rb0 = self.stats.route_bytes
        verts = np.zeros((self.budget,), np.int32)
        salts = np.zeros((self.budget,), np.uint32)
        spans: List[Tuple[int, int]] = []
        pos = 0
        for t, q, *_ in batch:
            take = q.fanout
            # _collect's slot accounting and submit's fanout bound guarantee
            # the batch fits; fail loudly (not by truncating-and-caching a
            # wrong-shaped result) if that invariant ever regresses
            assert pos + take <= self.budget, (pos, take, self.budget)
            verts[pos: pos + take] = q.vertex
            # the draw is keyed by (epoch, query, slot) — batch-composition
            # independent, so cached and recomputed answers agree
            qh = np.uint32(hash((q.vertex, q.fanout, q.seed)) & 0x7FFFFFFF)
            salts[pos: pos + take] = qh + np.arange(take, dtype=np.uint32)
            spans.append((pos, take))
            pos += take

        def build():
            base = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                      self.epoch)
            def run(v, s):
                keys = jax.vmap(lambda si: jax.random.fold_in(base, si))(s)
                return jax.vmap(
                    lambda kk, vv: engine.sample_neighbors(
                        self.csr, vv[None], kk)[0])(keys, v)
            return jax.jit(run)

        run = self._runner(("sample", self.budget), build)
        nbrs = np.asarray(run(jnp.asarray(verts), jnp.asarray(salts)))
        t_eng1 = self._clock()
        for (t, q, *_), (s, take) in zip(batch, spans):
            # a one-hop draw reads only the vertex's own out-edge list,
            # which lives in its source partition
            self._finish(t, q, nbrs[s: s + take].copy(),
                         parts=frozenset(
                             {int(self.handle.partition_of(q.vertex))}))
        ctr = traffic.RouteByteCounter(self.stats.n_model_shards)
        ctr.push_level(self.budget,
                       payload_bytes=traffic.ROUTE_PAYLOAD_BYTES)
        self.stats.route_bytes += ctr.total_bytes
        self.stats.push_levels += 1
        self.stats.lanes_used += pos
        self.stats.queries += len(batch)
        if self.obs is not None:
            # one-hop sampling has no level loop, so no level-trace run —
            # just the engine/readback pair (stats carries no 'trace')
            self._record_batch_spans("sample", batch, list(range(pos)), {},
                                     t_eng0, t_eng1, rb0)

    def _store_result(self, ticket: int, value) -> None:
        self._results[ticket] = value
        while len(self._results) > self.results_capacity:
            self._results.popitem(last=False)  # oldest unclaimed ticket

    def _finish(self, ticket: int, q, value,
                parts: Optional[frozenset] = None) -> None:
        self._store_result(ticket, value)
        self._cache_put(q, value, parts)
