"""Graph substrate: CSR storage, RMAT synthesis, partitioning, kernel formats.

PIUMA "directly operates on sparse data (e.g., CSR)"; this module is the CSR
layer plus the two derived formats the TPU kernels need:

* padded-ELL row blocks (per-row fixed budget) for vectorized per-row work, and
* BBCSR — *block-bucketed* COO, nonzeros sorted by (column block, row), so a
  Pallas kernel can DMA one dense-vector block into VMEM and service every
  nonzero that touches it (the TPU-native re-expression of PIUMA's 8-byte
  gather; see DESIGN.md §2).

Streaming mutation (DESIGN.md §16): :class:`GraphHandle` is the one graph
currency for code that serves a graph *changing under the queries* — an
immutable (CSR, epoch, delta log, per-partition mutation stamps) tuple.
``handle.apply(inserts, deletes)`` splices a batch of edge updates into the
CSR as an overlay delta (no global re-sort), bumps the epoch, stamps the
touched partitions, and appends to the :class:`DeltaLog`; once the log
outgrows ``compact_threshold`` of the edge count, the handle compacts back
into a clean ``CSR.from_coo`` rebuild.  Epoch and stamp bookkeeping lives
HERE and only here — the `mutable-handle` repro-lint rule rejects
``.epoch`` / ``.csr`` assignment anywhere else.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import get_registry

__all__ = ["CSR", "rmat", "uniform_random_graph", "to_padded_ell", "to_bbcsr", "BBCSR",
           "contract", "DeltaLog", "UpdateReport", "GraphHandle"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row matrix / adjacency.

    indptr:  (n_rows+1,) int32
    indices: (nnz,) int32 column ids
    values:  (nnz,) float — edge weights (None -> implicit 1.0 handled by callers)
    """

    indptr: jnp.ndarray
    indices: jnp.ndarray
    values: Optional[jnp.ndarray]
    n_rows: int
    n_cols: int

    def tree_flatten(self):
        return (self.indptr, self.indices, self.values), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], aux[1])

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> jnp.ndarray:
        return self.indptr[1:] - self.indptr[:-1]

    def row_ids(self) -> jnp.ndarray:
        """Expand indptr to a (nnz,) row id per nonzero (sorted)."""
        return jnp.searchsorted(
            self.indptr, jnp.arange(self.indices.shape[0], dtype=self.indptr.dtype), side="right"
        ) - 1

    def to_dense(self) -> jnp.ndarray:
        vals = self.values if self.values is not None else jnp.ones_like(self.indices, jnp.float32)
        out = jnp.zeros((self.n_rows, self.n_cols), vals.dtype)
        return out.at[self.row_ids(), self.indices].add(vals)

    def transpose(self) -> "CSR":
        """Host-side transpose (CSC view as a CSR). The frontier engine's pull
        direction iterates in-edges, so it needs A^T sharing A's vertex ids.

        The result stays NumPy-backed: wrapping with `jnp.asarray` inside a
        jit trace would stage the arrays into tracers, and callers that
        transpose under jit (e.g. a jitted LPA) need the result concrete so
        the engine can still derive its static gather budgets from it.
        """
        indptr = np.asarray(self.indptr)
        rows = np.repeat(np.arange(self.n_rows), np.diff(indptr))
        cols = np.asarray(self.indices)
        vals = None if self.values is None else np.asarray(self.values)
        return CSR.from_coo(cols, rows, vals, self.n_cols, self.n_rows,
                            device=False)

    def contract(self, labels) -> tuple["CSR", jnp.ndarray]:
        """Collapse label groups into supernodes; see :func:`contract`."""
        return contract(self, labels)

    @staticmethod
    def from_coo(rows, cols, vals, n_rows, n_cols, *, sum_duplicates: bool = False,
                 device: bool = True) -> "CSR":
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = None if vals is None else np.asarray(vals)
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        if vals is not None:
            vals = vals[order]
        if sum_duplicates:
            keep = np.ones(rows.shape[0], bool)
            keep[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
            if vals is not None:
                seg = np.cumsum(keep) - 1
                vals = np.bincount(seg, weights=vals, minlength=int(keep.sum()))
            rows, cols = rows[keep], cols[keep]
        indptr = np.zeros(n_rows + 1, np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        wrap = jnp.asarray if device else np.asarray
        return CSR(
            wrap(np.asarray(indptr, np.int32)),
            wrap(np.asarray(cols, np.int32)),
            None if vals is None else wrap(np.asarray(vals, np.float32)),
            int(n_rows),
            int(n_cols),
        )


def contract(csr: CSR, labels) -> tuple[CSR, jnp.ndarray]:
    """Collapse communities into a coarsened graph (multi-level Louvain's
    level step; PAPERS: Gill et al. hinge community-detection throughput on
    cheap contraction between levels).

    ``labels`` is any (n_rows,) int assignment.  The labels are renumbered to
    dense coarse vertex ids with :func:`offload.compact_labels`, every edge
    (u, v, w) becomes (label[u], label[v], w), and parallel coarse edges are
    merged by a segment-sum over the lex-sorted (src_label, dst_label) pairs
    — the same fused run-reduction the engine's structured combines use.
    Intra-community edges accumulate into self-loops (they carry the
    community's internal weight, which keeps modularity invariant under
    contraction: Q(coarse, identity) == Q(fine, labels)).

    Returns (coarse CSR (n_c x n_c, weighted), renumber (n_rows,) int32
    mapping each fine vertex to its coarse vertex id).  Host-boundary op:
    the coarse shapes are data-dependent, so like `CSR.transpose` the result
    is concrete (usable for deriving the next level's static budgets), not a
    jit-traceable value.
    """
    from . import offload

    lab = jnp.asarray(labels).astype(jnp.int32)
    if lab.shape[0] != csr.n_rows:
        raise ValueError(f"labels must be ({csr.n_rows},), got {lab.shape}")
    dense, n_c_dev = offload.compact_labels(lab)
    n_c = int(n_c_dev) if csr.n_rows else 0
    m = csr.nnz
    if m == 0:
        return CSR(jnp.zeros((n_c + 1,), jnp.int32), jnp.zeros((0,), jnp.int32),
                   jnp.zeros((0,), jnp.float32), n_c, n_c), dense
    vals = (csr.values if csr.values is not None
            else jnp.ones((m,), jnp.float32))
    rows = offload.dma_gather(dense, csr.row_ids())
    cols = offload.dma_gather(dense, csr.indices)
    # segment-sum of edge weights over (src_label, dst_label) runs
    order = jnp.lexsort((cols, rows))
    sr, sc = jnp.take(rows, order), jnp.take(cols, order)
    sv = jnp.take(vals, order)
    is_start, run_id = offload.run_starts(sr, sc)
    run_w = jax.ops.segment_sum(sv, run_id, num_segments=m)
    starts = np.asarray(is_start)
    n_runs = int(starts.sum())
    sr_h, sc_h = np.asarray(sr)[starts], np.asarray(sc)[starts]
    w_h = np.asarray(run_w)[:n_runs]
    coarse = CSR.from_coo(sr_h, sc_h, w_h, n_c, n_c)
    return coarse, dense


def rmat(scale: int, edge_factor: int = 16, *, a=0.57, b=0.19, c=0.19, seed: int = 0,
         weighted: bool = True, dedup: bool = True) -> CSR:
    """RMAT generator (Graph500 parameters by default). n = 2**scale vertices.

    Matches the paper's evaluation input class ("RMAT-30 synthetic matrix",
    scaled down for CPU validation).  Pure numpy; deterministic per seed.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    rows = np.zeros(m, np.int64)
    cols = np.zeros(m, np.int64)
    # per-bit quadrant choice
    pa, pb, pc = a, b, c
    for bit in range(scale):
        r = rng.random(m)
        go_right = (r >= pa + pc) & (r < pa + pc + pb) | (r >= pa + pb + pc)
        go_down = (r >= pa) & (r < pa + pc) | (r >= pa + pb + pc)
        rows |= go_down.astype(np.int64) << bit
        cols |= go_right.astype(np.int64) << bit
    vals = rng.random(m).astype(np.float32) if weighted else None
    return CSR.from_coo(rows, cols, vals, n, n, sum_duplicates=dedup)


def uniform_random_graph(n: int, avg_degree: int, *, seed: int = 0, weighted: bool = True) -> CSR:
    rng = np.random.default_rng(seed)
    m = n * avg_degree
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    vals = rng.random(m).astype(np.float32) if weighted else None
    return CSR.from_coo(rows, cols, vals, n, n, sum_duplicates=True)


# ---------------------------------------------------------------------------
# Streaming mutation: DeltaLog + epoch-versioned GraphHandle (DESIGN.md §16)
# ---------------------------------------------------------------------------

def _edge_keys(csr: CSR) -> np.ndarray:
    """(nnz,) int64 ``row * n_cols + col`` keys.  Canonical CSRs (everything
    a GraphHandle holds) have strictly increasing keys: row-major, columns
    sorted within each row, no duplicate (row, col) pairs."""
    indptr = np.asarray(csr.indptr, np.int64)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    return rows * np.int64(csr.n_cols) + np.asarray(csr.indices, np.int64)


def _canonical(csr: CSR) -> CSR:
    """Return `csr` if its keys are strictly increasing, else a
    duplicate-summed `from_coo` rebuild (the handle's splice arithmetic
    relies on sorted-unique keys)."""
    key = _edge_keys(csr)
    if key.size == 0 or bool(np.all(key[1:] > key[:-1])):
        return csr
    rows, cols = key // csr.n_cols, key % csr.n_cols
    vals = None if csr.values is None else np.asarray(csr.values)
    return CSR.from_coo(rows, cols, vals, csr.n_rows, csr.n_cols,
                        sum_duplicates=True)


def _coerce_edges(edges, *, weighted: bool):
    """Normalize an (rows, cols[, vals]) tuple / None to int64/f32 arrays."""
    if edges is None:
        e = np.zeros((0,), np.int64)
        return e, e.copy(), (np.zeros((0,), np.float32) if weighted else None)
    rows, cols = np.asarray(edges[0], np.int64), np.asarray(edges[1], np.int64)
    if rows.shape != cols.shape or rows.ndim != 1:
        raise ValueError(f"edge endpoints must be matching 1-d arrays, got "
                         f"{rows.shape} vs {cols.shape}")
    vals = None
    if weighted:
        vals = (np.asarray(edges[2], np.float32) if len(edges) > 2
                and edges[2] is not None else np.ones(rows.shape, np.float32))
        if vals.shape != rows.shape:
            raise ValueError(f"edge values shape {vals.shape} != {rows.shape}")
    return rows, cols, vals


@dataclasses.dataclass(frozen=True)
class DeltaLog:
    """Pending edge updates since the last compaction, as flat COO arrays.

    The log is *bookkeeping*, not the source of truth: every ``apply``
    already splices the batch into the handle's canonical CSR.  The log
    records what changed since the CSR was last rebuilt clean — its size
    drives the compaction trigger, and its endpoint set is what a
    distributed deployment must reship (only the touched partitions)."""

    ins_rows: np.ndarray
    ins_cols: np.ndarray
    ins_vals: Optional[np.ndarray]
    del_rows: np.ndarray
    del_cols: np.ndarray

    @classmethod
    def empty(cls, *, weighted: bool = True) -> "DeltaLog":
        e = np.zeros((0,), np.int64)
        return cls(e, e.copy(), np.zeros((0,), np.float32) if weighted
                   else None, e.copy(), e.copy())

    @property
    def size(self) -> int:
        """Pending update count (inserts + deletes since last compaction)."""
        return int(self.ins_rows.size + self.del_rows.size)

    def extend(self, ins_r, ins_c, ins_v, del_r, del_c) -> "DeltaLog":
        return DeltaLog(
            np.concatenate([self.ins_rows, ins_r]),
            np.concatenate([self.ins_cols, ins_c]),
            None if self.ins_vals is None
            else np.concatenate([self.ins_vals, ins_v]),
            np.concatenate([self.del_rows, del_r]),
            np.concatenate([self.del_cols, del_c]))


@dataclasses.dataclass(frozen=True)
class UpdateReport:
    """What one ``GraphHandle.apply`` batch did — the repair/invalidation
    contract: ``changed_sources`` seeds incremental monotone recompute
    (algorithms.incremental), ``touched_partitions`` scopes cache eviction
    and distributed resharding, ``monotone_safe`` says whether label-
    correcting repair is valid (insert-only, no weight increases) or the
    caller must fall back to full recompute."""

    epoch: int
    n_inserted: int          # new edges spliced in (upserts excluded)
    n_deleted: int           # edges actually removed
    n_upserted: int          # existing edges whose weight was replaced
    changed_sources: np.ndarray     # unique source endpoints of changed edges
    changed_vertices: np.ndarray    # unique endpoints, both sides
    touched_partitions: np.ndarray  # unique partition ids (both endpoints)
    monotone_safe: bool
    compacted: bool

    @property
    def n_changed(self) -> int:
        return self.n_inserted + self.n_deleted + self.n_upserted


@dataclasses.dataclass(frozen=True)
class GraphHandle:
    """Epoch-versioned graph: the one currency for mutable-graph serving.

    Immutable — every mutation returns a NEW handle (so readers holding the
    old one keep a consistent graph+epoch pair):

    * ``apply(inserts, deletes)``: splice one update batch into the CSR as
      an overlay delta — deletes mask matched edges, inserts upsert existing
      (row, col) pairs in place and splice genuinely new edges at their
      sorted positions (O(m + d), no global re-sort).  Bumps the epoch,
      stamps the partitions owning either endpoint of any changed edge, and
      extends the :class:`DeltaLog`.  Batch semantics: deletes apply before
      inserts; duplicate inserts in one batch keep the LAST occurrence;
      inserting an existing edge replaces its weight; deleting a missing
      edge is a no-op; self-loops are ordinary edges.
    * ``replace(csr)``: whole-graph swap (the deprecated
      ``GraphService.update_graph`` shim) — every partition is stamped.
    * ``compact()``: rebuild the CSR clean via ``CSR.from_coo`` and clear
      the log; ``apply`` auto-compacts once the log exceeds
      ``compact_threshold`` × nnz.

    Partitions are contiguous vertex blocks (``ceil(n / n_partitions)`` per
    block — the same arithmetic as ``dgas.block_rule``), so partition ids
    line up with the distributed service's shard ids.  ``stamps[p]`` is the
    epoch partition ``p`` last mutated: a cached result that only touched
    partitions whose stamp predates it is still valid (DESIGN.md §16).
    """

    csr: CSR
    epoch: int
    delta: DeltaLog
    stamps: np.ndarray          # (n_partitions,) int64 last-mutated epoch
    n_partitions: int
    compact_threshold: float = 0.25

    @classmethod
    def wrap(cls, csr: CSR, *, n_partitions: int = 8,
             compact_threshold: float = 0.25) -> "GraphHandle":
        if n_partitions < 1:
            raise ValueError("n_partitions must be >= 1")
        return cls(_canonical(csr), 0,
                   DeltaLog.empty(weighted=csr.values is not None),
                   np.zeros((n_partitions,), np.int64), int(n_partitions),
                   float(compact_threshold))

    @property
    def per_partition(self) -> int:
        return -(-self.csr.n_rows // self.n_partitions)

    def partition_of(self, vertices) -> np.ndarray:
        """Owning partition of each vertex (block rule)."""
        return np.asarray(vertices, np.int64) // self.per_partition

    def partition_edge_counts(self) -> np.ndarray:
        """(n_partitions,) edges whose SOURCE row each partition owns —
        what a block-sharded deployment stores (and must reship) per
        partition."""
        indptr = np.asarray(self.csr.indptr, np.int64)
        per = self.per_partition
        bounds = np.minimum(np.arange(self.n_partitions + 1) * per,
                            self.csr.n_rows)
        return np.diff(indptr[bounds])

    # -- mutation ----------------------------------------------------------

    def apply(self, inserts=None, deletes=None) -> tuple["GraphHandle",
                                                         UpdateReport]:
        """Apply one update batch; returns (new handle, report).

        inserts: (rows, cols) or (rows, cols, vals) arrays; vals default 1.0
          on weighted graphs and are ignored on unweighted (values=None)
          graphs.
        deletes: (rows, cols) arrays.
        """
        weighted = self.csr.values is not None
        ins_r, ins_c, ins_v = _coerce_edges(inserts, weighted=weighted)
        del_r, del_c, _ = _coerce_edges(deletes, weighted=False)
        n, ncol = self.csr.n_rows, self.csr.n_cols
        for name, (r, c) in (("insert", (ins_r, ins_c)),
                             ("delete", (del_r, del_c))):
            if r.size and not ((0 <= r).all() and (r < n).all()
                               and (0 <= c).all() and (c < ncol).all()):
                raise ValueError(f"{name} endpoints outside [0, {n}) x "
                                 f"[0, {ncol})")

        csr, stats = _splice_updates(self.csr, ins_r, ins_c, ins_v,
                                     del_r, del_c)
        n_ins, n_del, n_ups, weight_grew = stats
        epoch = self.epoch + 1

        ch_src = np.unique(np.concatenate([ins_r, del_r]))
        ch_all = np.unique(np.concatenate([ins_r, ins_c, del_r, del_c]))
        touched = np.unique(self.partition_of(ch_all)) if ch_all.size \
            else np.zeros((0,), np.int64)
        stamps = self.stamps.copy()
        stamps[touched] = epoch

        delta = self.delta.extend(ins_r, ins_c, ins_v, del_r, del_c)
        compacted = delta.size > self.compact_threshold * max(1, csr.nnz)
        if compacted:
            csr = _canonical(CSR.from_coo(
                *_coo_of(csr), csr.n_rows, csr.n_cols))
            delta = DeltaLog.empty(weighted=weighted)
            get_registry().counter("graph.compactions").inc()
        handle = GraphHandle(csr, epoch, delta, stamps, self.n_partitions,
                             self.compact_threshold)
        report = UpdateReport(
            epoch=epoch, n_inserted=n_ins, n_deleted=n_del, n_upserted=n_ups,
            changed_sources=ch_src, changed_vertices=ch_all,
            touched_partitions=touched,
            monotone_safe=(n_del == 0 and not weight_grew),
            compacted=compacted)
        return handle, report

    def replace(self, csr: CSR) -> "GraphHandle":
        """Whole-graph swap: epoch bumps, every partition is stamped."""
        epoch = self.epoch + 1
        csr = _canonical(csr)
        n_p = self.n_partitions
        return GraphHandle(csr, epoch,
                           DeltaLog.empty(weighted=csr.values is not None),
                           np.full((n_p,), epoch, np.int64), n_p,
                           self.compact_threshold)

    def compact(self) -> "GraphHandle":
        """Explicit compaction: clean ``from_coo`` rebuild + empty log.
        Bit-identical arrays (the overlay splice already keeps the CSR
        canonical — the round-trip test pins this)."""
        csr = CSR.from_coo(*_coo_of(self.csr), self.csr.n_rows,
                           self.csr.n_cols)
        return GraphHandle(csr, self.epoch,
                           DeltaLog.empty(weighted=csr.values is not None),
                           self.stamps.copy(), self.n_partitions,
                           self.compact_threshold)


def _coo_of(csr: CSR):
    indptr = np.asarray(csr.indptr)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    vals = None if csr.values is None else np.asarray(csr.values)
    return rows, np.asarray(csr.indices, np.int64), vals


def _splice_updates(csr: CSR, ins_r, ins_c, ins_v, del_r, del_c):
    """Overlay-splice one update batch into a canonical CSR.

    Returns (new CSR, (n_inserted, n_deleted, n_upserted, weight_grew)).
    O(m + d log d): delete by sorted-key membership mask, upsert in place,
    splice new edges at their searchsorted positions — the result is
    bit-identical to a clean ``CSR.from_coo`` over the effective edge set.
    """
    n_cols = np.int64(csr.n_cols)
    key = _edge_keys(csr)
    cols = np.asarray(csr.indices, np.int64)
    vals = None if csr.values is None else np.asarray(csr.values, np.float32)

    n_del = 0
    if del_r.size:
        dkey = np.unique(del_r * n_cols + del_c)
        keep = ~np.isin(key, dkey)
        n_del = int(key.size - keep.sum())
        key, cols = key[keep], cols[keep]
        if vals is not None:
            vals = vals[keep]

    n_ins = n_ups = 0
    weight_grew = False
    if ins_r.size:
        ikey = ins_r * n_cols + ins_c
        order = np.argsort(ikey, kind="stable")
        ikey = ikey[order]
        iv = None if ins_v is None else ins_v[order]
        last = np.ones(ikey.size, bool)          # duplicate keys: last wins
        last[:-1] = ikey[1:] != ikey[:-1]
        ikey = ikey[last]
        if iv is not None:
            iv = iv[last]
        pos = np.searchsorted(key, ikey)
        exists = (pos < key.size)
        exists[exists] = key[pos[exists]] == ikey[exists]
        n_ups = int(exists.sum())
        n_ins = int(ikey.size - n_ups)
        if vals is not None and n_ups:
            old = vals[pos[exists]]
            new = iv[exists]
            weight_grew = bool((new > old).any())
            vals = vals.copy()
            vals[pos[exists]] = new
        newkey = ikey[~exists]
        if newkey.size:
            at = pos[~exists]
            key = np.insert(key, at, newkey)
            cols = np.insert(cols, at, newkey % n_cols)
            if vals is not None:
                vals = np.insert(vals, at, iv[~exists])

    rows = key // n_cols
    indptr = np.zeros(csr.n_rows + 1, np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    out = CSR(jnp.asarray(np.asarray(indptr, np.int32)),
              jnp.asarray(np.asarray(cols, np.int32)),
              None if vals is None else jnp.asarray(vals),
              csr.n_rows, csr.n_cols)
    return out, (n_ins, n_del, n_ups, weight_grew)


# ---------------------------------------------------------------------------
# Kernel-facing formats
# ---------------------------------------------------------------------------

def to_padded_ell(csr: CSR, max_nnz_per_row: Optional[int] = None):
    """Pad each row to a fixed nonzero budget.

    Returns (cols (n_rows, k) int32, vals (n_rows, k) f32, mask (n_rows, k) bool).
    Padding entries have col=0, val=0.
    """
    indptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = np.asarray(csr.values) if csr.values is not None else np.ones_like(cols, np.float32)
    deg = indptr[1:] - indptr[:-1]
    k = int(max_nnz_per_row or deg.max())
    out_c = np.zeros((csr.n_rows, k), np.int32)
    out_v = np.zeros((csr.n_rows, k), np.float32)
    mask = np.zeros((csr.n_rows, k), bool)
    for r in range(csr.n_rows):  # host-side preprocessing; fine offline
        d = min(int(deg[r]), k)
        s = indptr[r]
        out_c[r, :d] = cols[s:s + d]
        out_v[r, :d] = vals[s:s + d]
        mask[r, :d] = True
    return jnp.asarray(out_c), jnp.asarray(out_v), jnp.asarray(mask)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BBCSR:
    """Block-bucketed sparse format for the Pallas SpMV kernel.

    Nonzeros are bucketed by (row block, column block) and sorted by
    (row_block, col_block, row); each bucket is padded to a multiple of
    ``tile_nnz``.  The kernel grid walks tiles in that order: the output row
    block is revisited only *consecutively* (legal Pallas accumulation), and
    for each (rb, cb) pair the dense-vector block is DMA'd into VMEM once
    (PIUMA: "DMA gather into SPAD") and gathered/scattered with one-hot MXU
    matmuls.  Every row block gets at least one (possibly all-padding) tile so
    the output is fully initialized.

    rows_local / cols_local : (n_tiles, tile_nnz) int32, local to the block
    vals                    : (n_tiles, tile_nnz) f32 (0 on padding)
    tile_rb / tile_cb       : (n_tiles,) int32 — owning row/col block
    tile_init               : (n_tiles,) int32 — 1 on first tile of a row block
    tile_cnt                : (n_tiles,) int32 — real (non-padding) nonzeros
                              in the tile; padding is always the tile's tail,
                              so `slot < tile_cnt` is the validity mask the
                              min/max tile combines need (a padded (0, 0, 0.0)
                              entry is indistinguishable from a real
                              zero-weight edge at the block origin).  None on
                              operands built before the field existed;
                              `to_bbcsr` always fills it.
    """

    rows_local: jnp.ndarray
    cols_local: jnp.ndarray
    vals: jnp.ndarray
    tile_rb: jnp.ndarray
    tile_cb: jnp.ndarray
    tile_init: jnp.ndarray
    n_rows: int
    n_cols: int
    block_rows: int
    block_cols: int
    tile_nnz: int
    tile_cnt: Optional[jnp.ndarray] = None

    def tree_flatten(self):
        return (self.rows_local, self.cols_local, self.vals, self.tile_rb,
                self.tile_cb, self.tile_init, self.tile_cnt), (
            self.n_rows, self.n_cols, self.block_rows, self.block_cols, self.tile_nnz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children[:6], *aux, tile_cnt=children[6])

    @property
    def n_tiles(self) -> int:
        return int(self.tile_rb.shape[0])

    @property
    def n_row_blocks(self) -> int:
        return -(-self.n_rows // self.block_rows)

    @property
    def n_col_blocks(self) -> int:
        return -(-self.n_cols // self.block_cols)


def to_bbcsr(csr: CSR, *, block_rows: int = 256, block_cols: int = 512,
             tile_nnz: int = 512) -> BBCSR:
    """Host-side conversion CSR -> BBCSR (see BBCSR docstring)."""
    cols = np.asarray(csr.indices, np.int64)
    vals = np.asarray(csr.values) if csr.values is not None else np.ones_like(cols, np.float32)
    rows = np.asarray(csr.row_ids(), np.int64)
    rb = rows // block_rows
    cb = cols // block_cols
    order = np.lexsort((rows, cb, rb))
    rows, cols, vals, rb, cb = rows[order], cols[order], vals[order], rb[order], cb[order]

    n_rb = -(-csr.n_rows // block_rows)
    tiles_r, tiles_c, tiles_v, tiles_rb, tiles_cb = [], [], [], [], []
    tiles_m = []
    key = rb * (1 << 32) + cb
    if rows.size:
        starts = np.concatenate([[0], np.nonzero(key[1:] != key[:-1])[0] + 1,
                                 [rows.shape[0]]])
    else:
        starts = np.array([0, 0])
    seen_rb = set()
    for gi in range(starts.shape[0] - 1):
        s, e = int(starts[gi]), int(starts[gi + 1])
        if e <= s:
            continue
        g_rb, g_cb = int(rb[s]), int(cb[s])
        seen_rb.add(g_rb)
        cnt = e - s
        n_t = -(-cnt // tile_nnz)
        pad = n_t * tile_nnz - cnt
        r = np.concatenate([rows[s:e] - g_rb * block_rows, np.zeros(pad, np.int64)])
        c = np.concatenate([cols[s:e] - g_cb * block_cols, np.zeros(pad, np.int64)])
        v = np.concatenate([vals[s:e], np.zeros(pad, np.float32)])
        # padding sits at each tile's tail: full tiles, then the remainder
        m = np.full(n_t, tile_nnz, np.int64)
        m[-1] = cnt - (n_t - 1) * tile_nnz
        tiles_r.append(r.reshape(n_t, tile_nnz))
        tiles_c.append(c.reshape(n_t, tile_nnz))
        tiles_v.append(v.reshape(n_t, tile_nnz))
        tiles_m.append(m)
        tiles_rb.append(np.full(n_t, g_rb, np.int64))
        tiles_cb.append(np.full(n_t, g_cb, np.int64))
    for b in range(n_rb):
        if b not in seen_rb:  # all-padding tile so the output block gets zeroed
            tiles_r.append(np.zeros((1, tile_nnz), np.int64))
            tiles_c.append(np.zeros((1, tile_nnz), np.int64))
            tiles_v.append(np.zeros((1, tile_nnz), np.float32))
            tiles_m.append(np.zeros(1, np.int64))
            tiles_rb.append(np.full(1, b, np.int64))
            tiles_cb.append(np.zeros(1, np.int64))
    t_r = np.concatenate(tiles_r)
    t_c = np.concatenate(tiles_c)
    t_v = np.concatenate(tiles_v)
    t_m = np.concatenate(tiles_m)
    t_rb = np.concatenate(tiles_rb)
    t_cb = np.concatenate(tiles_cb)
    order = np.argsort(t_rb, kind="stable")
    t_r, t_c, t_v, t_m, t_rb, t_cb = (
        a[order] for a in (t_r, t_c, t_v, t_m, t_rb, t_cb))
    init = np.ones(t_rb.shape[0], np.int64)
    init[1:] = t_rb[1:] != t_rb[:-1]
    return BBCSR(
        jnp.asarray(t_r, jnp.int32), jnp.asarray(t_c, jnp.int32),
        jnp.asarray(t_v, jnp.float32), jnp.asarray(t_rb, jnp.int32),
        jnp.asarray(t_cb, jnp.int32), jnp.asarray(init, jnp.int32),
        csr.n_rows, csr.n_cols, block_rows, block_cols, tile_nnz,
        tile_cnt=jnp.asarray(t_m, jnp.int32),
    )
