"""PIUMA core: DGAS + ATT, offload engines, graph substrate, algorithms."""
from . import dgas, graph, offload, traffic
from .dgas import ATT, interleave_rule, block_rule, degree_balanced_rule
from .graph import CSR, BBCSR, rmat, uniform_random_graph, to_padded_ell, to_bbcsr
