"""PIUMA core: DGAS + ATT, offload engines, graph substrate, algorithms,
and the graph query service (batched multi-source serving)."""
from . import dgas, graph, offload, traffic
from .dgas import ATT, interleave_rule, block_rule, degree_balanced_rule
from .graph import (CSR, BBCSR, rmat, uniform_random_graph, to_padded_ell,
                    to_bbcsr, DeltaLog, GraphHandle, UpdateReport)
from .service import (GraphService, ServiceStats, Reachability, Distance,
                      PPRTopK, NeighborSample)
