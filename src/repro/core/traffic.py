"""Analytical byte-traffic / throughput model reproducing Table I & II structure.

PIUMA hardware does not exist outside Intel; the paper's numbers come from a
cycle simulator plus an analytical scale-out model.  We reproduce the *model
level*: a machine is (bandwidth, DRAM latency, threads, cores, access
granularity), a workload version is (DRAM bytes, uncached loads, issued
instructions, network bytes) per nonzero/edge, and

    time/elem = max( mem bytes/BW,
                     uncached_loads * latency / threads + instrs / (cores*ipc),
                     net bytes / net_BW )

Machine parameters are the paper's disclosed specs (>16K threads/node, 256
blocks/node, power parity with a 4-socket Xeon 6140); the *emergent* ratios are
then compared against Table I (10x / 19.8x / 29.2x) and Table II in
benchmarks/table1_spmv.py and benchmarks/table2_apps.py — that comparison is
the reproduction, the constants are not fitted per-row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

__all__ = ["Machine", "XEON", "PIUMA_NODE", "AccessProfile", "SPMV_PROFILES",
           "APP_PROFILES", "time_per_elem", "speedup", "multinode_time_per_elem",
           "ROUTE_PAYLOAD_BYTES", "CONTRACT_PAYLOAD_BYTES",
           "push_level_route_bytes", "batched_payload_bytes",
           "flush_route_bytes", "level_collectives", "RouteByteCounter"]


@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    dram_bw: float          # B/s per node
    dram_latency: float     # s
    threads: int            # latency-hiding contexts per node
    cores: int              # instruction issue pipes per node
    ipc: float              # issue rate per core
    line_bytes: int         # DRAM access granularity
    net_bw: float           # B/s per node injection bandwidth
    net_latency: float      # s, cross-node
    bw_efficiency: float    # achievable fraction of peak DRAM bw


# 4-socket Xeon Gold 6140: 4 x 6ch DDR4-2666 = 512 GB/s peak; 144 HW threads,
# 72 cores, ~4-wide issue but graph IPC ~1; 64 B lines; ~100 GbE-class fabric.
XEON = Machine("xeon-4s-6140", dram_bw=512e9, dram_latency=90e-9, threads=144,
               cores=72, ipc=1.5, line_bytes=64, net_bw=12.5e9,
               net_latency=2e-6, bw_efficiency=0.75)

# PIUMA node: 256 blocks, >16K threads ("more than 16K"), in-order MTCs,
# 8-byte native DRAM access, network BW exceeds local DRAM BW (paper §III.D).
PIUMA_NODE = Machine("piuma-node", dram_bw=2.0e12, dram_latency=100e-9,
                     threads=16384, cores=1024, ipc=1.0, line_bytes=8,
                     net_bw=2.5e12, net_latency=500e-9, bw_efficiency=0.95)


@dataclasses.dataclass(frozen=True)
class AccessProfile:
    """Per-element (nonzero or edge) costs of one implementation version."""
    name: str
    dram_bytes: float       # bytes that actually cross the DRAM pins
    uncached_loads: float   # loads the pipeline must wait on (latency-bound term)
    instrs: float           # issued instructions per element
    remote_frac: float = 0.0  # fraction of accesses that cross the network (multi-node)
    net_bytes: float = 0.0    # bytes/elem on the network when distributed


def _xeon_bytes(useful: float, sparse_accesses: float, wasted_prefetch: float = 0.2):
    """Cacheline machine: each sparse access drags a full line; prefetchers add
    ~20% dead lines (Fig. 2's zero-reuse fraction)."""
    return (useful + sparse_accesses * (XEON.line_bytes - 8)) * (1 + wasted_prefetch)


# SpMV versions of Table I.  Per nonzero: matrix value (8 B) + column index
# (4 B) stream; one sparse access into the dense vector; ~1/avg_deg row
# bookkeeping (amortized away here).
SPMV_PROFILES: Dict[str, AccessProfile] = {
    # Xeon: streams matrix (prefetched lines, fully used) + 64 B per vector access.
    "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(12.0 + 8.0, 1.0),
                          uncached_loads=0.0, instrs=10.0),
    # PIUMA base: everything uncached 8 B (3 stalled loads: val, idx, vec elem).
    "piuma_base": AccessProfile("piuma_base", dram_bytes=24.0, uncached_loads=3.0,
                                instrs=10.0),
    # cache-everything pathology: vector access now drags a 64 B line on a
    # machine sized for 8 B flows -> traffic blows up (paper: slower than base).
    "piuma_cache_all": AccessProfile("piuma_cache_all", dram_bytes=12.0 + 64.0,
                                     uncached_loads=0.0, instrs=10.0),
    # selective caching: matrix cached (streamed, full utilization), vector 8 B.
    "piuma_selective": AccessProfile("piuma_selective", dram_bytes=12.0 + 8.0,
                                     uncached_loads=1.0, instrs=10.0),
    # + DMA gather to SPAD: the engine fetches vector elements in the
    # background; the core only multiplies-accumulates out of SPAD/cache.
    "piuma_dma": AccessProfile("piuma_dma", dram_bytes=12.0 + 8.0,
                               uncached_loads=0.0, instrs=4.0),
}


# ---------------------------------------------------------------------------
# Owner-routed exchange byte model (the engine's `offload._route` traffic)
# ---------------------------------------------------------------------------

# one routed push item: int32 local index + f32 value + validity flag
ROUTE_PAYLOAD_BYTES = 4 + 4 + 1

# one routed contraction edge: coarse src + coarse dst ids + f32 summed weight
CONTRACT_PAYLOAD_BYTES = 4 + 4 + 4


def batched_payload_bytes(n_lanes: int, *, packed: bool = False) -> int:
    """Bytes of one routed item in a *batched* push level.

    A batched frontier routes one item per active edge carrying **all B
    lanes**: int32 local index + validity flag + the lane payload — 4 B per
    lane for valued programs, or ``ceil(B/32)`` uint32 words for bit-packed
    boolean frontiers.  The amortization PIUMA's concurrent traversals buy is
    visible directly here: B single-source runs route B full items per edge
    (B * ROUTE_PAYLOAD_BYTES), the batch routes one item of this size.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    lane_bytes = 4 * (-(-n_lanes // 32)) if packed else 4 * n_lanes
    return 4 + 1 + lane_bytes


def push_level_route_bytes(n_shards: int, per_peer_capacity: int,
                           payload_bytes: int = ROUTE_PAYLOAD_BYTES) -> int:
    """Bytes one shard injects per push level through `offload._route`.

    The routed exchange is a fixed-capacity all_to_all: every level each
    shard sends `capacity` slots to each of the S peers whether or not the
    slots hold live items — so the level's network bytes are set by the
    *capacity*, not the frontier.  That is exactly why the engine's compacted
    sparse push (`engine.frontier_edge_capacity`) pays off: shrinking the
    per-peer capacity shrinks this number linearly while full-capacity
    routing pins it at m_per_shard.
    """
    return n_shards * per_peer_capacity * payload_bytes


def flush_route_bytes(n_shards: int, per_shard: int, elem_bytes: int) -> int:
    """Bytes one shard injects per async buffered flush.

    The async placement's outbox (`offload.buffered_flush`) is a dense
    ``(S * per_shard,)`` combine buffer, so one flush ships ``per_shard``
    elements to each of the S peers regardless of how many micro-steps of
    messages it absorbed — the ledger prices *flushes*, not levels.  Dense in
    the residents, so a flush costs about what a full-capacity push level
    does; the async win is doing K levels of work per flush, not shrinking
    any one exchange.
    """
    return n_shards * per_shard * elem_bytes


def level_collectives(*, placement: str, compact: bool = True,
                      program_collectives: int = 0) -> int:
    """Global reductions/exchanges one engine body (level or sync step) costs.

    sync push level: overflow psum (compacted only) + 3 routed all_to_alls
    (index, value, validity planes of `offload._route`) + the termination
    psum, plus any program-issued collectives (e.g. delta-stepping's two
    global-min pmins per level).  async sync step: one buffered flush + the
    termination psum — the program runs shard-local between checks, so
    program collectives don't multiply.
    """
    if placement == "async":
        return 2
    return (1 if compact else 0) + 3 + 1 + program_collectives


@dataclasses.dataclass
class RouteByteCounter:
    """Per-level routed-byte ledger for an engine run (analytical counter).

    The engine's routing capacities are static per mode, so a run's traffic
    is reconstructed exactly from its per-level direction trace: call
    `push_level(capacity)` once per sparse level (with the level's routing
    capacity) and `pull_level(gather_bytes)` for dense levels.
    """

    n_shards: int
    payload_bytes: int = ROUTE_PAYLOAD_BYTES
    total_bytes: int = 0
    levels: int = 0

    def push_level(self, per_peer_capacity: int,
                   payload_bytes: Optional[int] = None) -> int:
        """One sparse level; ``payload_bytes`` overrides the counter's default
        per-item size (e.g. `batched_payload_bytes(B)` for a batched level)."""
        b = push_level_route_bytes(
            self.n_shards, per_peer_capacity,
            self.payload_bytes if payload_bytes is None else payload_bytes)
        self.total_bytes += b
        self.levels += 1
        return b

    def pull_level(self, gather_bytes: int) -> int:
        self.total_bytes += int(gather_bytes)
        self.levels += 1
        return int(gather_bytes)

    def flush_level(self, per_shard: int, elem_bytes: int = 4) -> int:
        """One async buffered flush (`offload.buffered_flush`): the dense
        per-resident outbox changes hands, priced by `flush_route_bytes`."""
        b = flush_route_bytes(self.n_shards, per_shard, elem_bytes)
        self.total_bytes += b
        self.levels += 1
        return b

    def contract_level(self, n_routed_edges: int,
                       payload_bytes: int = CONTRACT_PAYLOAD_BYTES) -> int:
        """One multi-level contraction: `n_routed_edges` locally pre-reduced
        coarse edges change owner shard (unlike the fixed-capacity push
        exchange, contraction ships exactly the surviving edges — the
        between-levels repartition is host-driven, not a static all_to_all).

        Streaming ingest (DESIGN.md §16) prices through the same call: an
        `apply_updates` batch reships the touched partitions' edge lists
        (every partition on compaction) as (src, dst, weight) contract
        payloads — same item shape, same host-driven repartition."""
        b = int(n_routed_edges) * payload_bytes
        self.total_bytes += b
        self.levels += 1
        return b


def time_per_elem(m: Machine, p: AccessProfile) -> float:
    mem = p.dram_bytes / (m.dram_bw * m.bw_efficiency)
    lat = p.uncached_loads * m.dram_latency / m.threads + p.instrs / (m.cores * m.ipc * 1e9)
    return max(mem, lat)


def speedup(p_piuma: AccessProfile, p_xeon: AccessProfile = SPMV_PROFILES["xeon"],
            piuma: Machine = PIUMA_NODE, xeon: Machine = XEON) -> float:
    return time_per_elem(xeon, p_xeon) / time_per_elem(piuma, p_piuma)


def multinode_time_per_elem(m: Machine, p: AccessProfile, n_nodes: int) -> float:
    """Scale-out model: local work shrinks 1/n, remote accesses ride the network.

    Remote fraction grows as (n-1)/n of the uniformly-distributed accesses
    (DGAS interleave); network term includes per-node injection bandwidth and
    a latency term hidden by the thread pool.
    """
    if n_nodes == 1:
        return time_per_elem(m, p)
    rf = p.remote_frac * (n_nodes - 1) / n_nodes
    mem = p.dram_bytes / (m.dram_bw * m.bw_efficiency)
    net = (p.net_bytes * rf) / m.net_bw
    lat = (p.uncached_loads * ((1 - rf) * m.dram_latency + rf * m.net_latency) / m.threads
           + p.instrs / (m.cores * m.ipc * 1e9))
    return max(mem, net, lat) / n_nodes


# Table II applications: per-edge access profiles (PIUMA implementation) and a
# Xeon counterpart.  Derived from each algorithm's inner loop; see
# benchmarks/table2_apps.py for the comparison against the paper's column.
APP_PROFILES: Dict[str, Dict[str, AccessProfile]] = {
    "SpMV": {
        "piuma": dataclasses.replace(SPMV_PROFILES["piuma_dma"], remote_frac=1.0, net_bytes=16.0),
        "xeon": SPMV_PROFILES["xeon"],
    },
    "SpMSpV": {
        # sparse x sparse: tiny useful stream per touched edge; Xeon still drags lines
        "piuma": AccessProfile("piuma", dram_bytes=20.0, uncached_loads=0.0, instrs=6.0,
                               remote_frac=1.0, net_bytes=16.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(12.0, 2.0), uncached_loads=0.0,
                              instrs=25.0),
    },
    "Breadth-first Search": {
        "piuma": AccessProfile("piuma", dram_bytes=20.0, uncached_loads=1.0, instrs=8.0,
                               remote_frac=1.0, net_bytes=16.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(12.0, 1.0), uncached_loads=0.0,
                              instrs=12.0),
    },
    "Random Walks": {
        # pure pointer chasing: two dependent uncached loads per step, ~zero locality
        "piuma": AccessProfile("piuma", dram_bytes=16.0, uncached_loads=2.0, instrs=6.0,
                               remote_frac=1.0, net_bytes=16.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(8.0, 2.0), uncached_loads=2.0,
                              instrs=8.0),
    },
    "PageRank": {
        "piuma": AccessProfile("piuma", dram_bytes=20.0, uncached_loads=0.0, instrs=5.0,
                               remote_frac=1.0, net_bytes=16.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(20.0, 1.0), uncached_loads=0.0,
                              instrs=10.0),
    },
    "Louvain Community": {
        "piuma": AccessProfile("piuma", dram_bytes=24.0, uncached_loads=1.0, instrs=12.0,
                               remote_frac=1.0, net_bytes=24.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(16.0, 2.0), uncached_loads=0.0,
                              instrs=30.0),
    },
    "TIES Sampler": {
        "piuma": AccessProfile("piuma", dram_bytes=16.0, uncached_loads=1.0, instrs=8.0,
                               remote_frac=1.0, net_bytes=16.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(8.0, 2.0), uncached_loads=1.0,
                              instrs=12.0),
    },
    "Graph Sage": {
        # dense per-vertex GEMMs dominate -> smallest PIUMA edge (paper: 3.1x)
        "piuma": AccessProfile("piuma", dram_bytes=80.0, uncached_loads=0.5, instrs=120.0,
                               remote_frac=0.3, net_bytes=32.0),
        "xeon": AccessProfile("xeon", dram_bytes=_xeon_bytes(80.0, 0.5), uncached_loads=0.0,
                              instrs=150.0),
    },
}
