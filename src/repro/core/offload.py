"""Offload engines: DMA gather/scatter, remote atomics, queues, collectives.

PIUMA blocks contain engines that execute memory operations *in the background,
where the data lives*:

* DMA engine      — (strided) copy / gather / scatter between memory and SPAD
* remote atomics  — atomic update at the owning memory controller
* queue engine    — shared work queues (work stealing / dynamic partitioning)
* collective eng. — system-wide barriers and reductions

On a TPU mesh the analogues are (a) local fused gathers/segment-reductions for
the in-node case and (b) `shard_map` + `all_to_all` *owner-routed* exchanges
for the remote case: requests travel to the owner shard, the owner performs the
gather or the commutative update locally, and only the requested/accepted words
cross the network.  This is the paper's fine-grained-access model; the
conventional-architecture baseline ("fetch the whole cache line") is an
`all_gather` of the full remote array, kept for comparison in the algorithms
and benchmarks.

All remote primitives consult an ATT (see `core.dgas`) so distribution rules
stay programmable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from .dgas import ATT
from .. import compat

AxisName = Union[str, Sequence[str]]

__all__ = [
    "dma_gather", "dma_scatter_add", "dma_strided_copy",
    "axis_size", "my_shard",
    "dgas_gather", "remote_scatter_add", "remote_scatter_combine",
    "all_gather_gather",
    "QueueState", "queue_make", "queue_balance",
    "hierarchical_psum", "barrier", "prefix_scan",
]


# ---------------------------------------------------------------------------
# Local (in-node) DMA engine ops
# ---------------------------------------------------------------------------

def dma_gather(table: jnp.ndarray, idx: jnp.ndarray, *, fill: float = 0.0) -> jnp.ndarray:
    """Gather rows/elements; out-of-range indices return `fill` (padding-safe)."""
    valid = (idx >= 0) & (idx < table.shape[0])
    safe = jnp.where(valid, idx, 0)
    out = jnp.take(table, safe, axis=0)
    mask = valid.reshape(valid.shape + (1,) * (out.ndim - valid.ndim))
    return jnp.where(mask, out, jnp.asarray(fill, out.dtype))


def dma_scatter_add(dest: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter-add with padding indices (<0 or >=n) dropped."""
    valid = (idx >= 0) & (idx < dest.shape[0])
    safe = jnp.where(valid, idx, 0)
    mask = valid.reshape(valid.shape + (1,) * (vals.ndim - valid.ndim))
    return dest.at[safe].add(jnp.where(mask, vals, 0).astype(dest.dtype))


def dma_strided_copy(src: jnp.ndarray, start: int, stride: int, count: int) -> jnp.ndarray:
    return lax.dynamic_slice_in_dim(src, start, 1 + (count - 1) * stride)[::stride]


# ---------------------------------------------------------------------------
# Axis helpers (work with a single axis name or a tuple of axis names)
# ---------------------------------------------------------------------------

def axis_size(axis_name: AxisName) -> int:
    return compat.axis_size(axis_name)


def my_shard(axis_name: AxisName) -> jnp.ndarray:
    """Flattened linear shard index across (possibly) multiple mesh axes."""
    if isinstance(axis_name, (tuple, list)):
        idx = jnp.int32(0)
        for a in axis_name:
            idx = idx * compat.axis_size(a) + lax.axis_index(a)
        return idx
    return lax.axis_index(axis_name)


def _all_to_all(x: jnp.ndarray, axis_name: AxisName) -> jnp.ndarray:
    """all_to_all over leading axis of size = axis size (possibly tuple axes)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


# ---------------------------------------------------------------------------
# Owner routing (shared by dgas_gather / remote_scatter_add / queues)
# ---------------------------------------------------------------------------

def _owner_slots(dest: jnp.ndarray, n_shards: int, capacity: int):
    """Assign each item a slot in its destination bucket.

    Returns (flat, valid): flat = dest*capacity + slot for valid items, and
    valid = slot < capacity.  Deterministic (stable sort order).
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = jnp.take(dest, order)
    starts = jnp.searchsorted(sorted_dest, jnp.arange(n_shards, dtype=dest.dtype))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.take(starts, sorted_dest).astype(jnp.int32)
    slot = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    valid = (slot < capacity) & (dest >= 0) & (dest < n_shards)
    flat = jnp.where(valid, dest.astype(jnp.int32) * capacity + slot, 0)
    return flat, valid


def _route(payload, dest: jnp.ndarray, axis_name: AxisName, capacity: int):
    """Send each payload row to shard `dest[i]` (fixed per-peer capacity).

    payload: pytree of arrays with leading dim n.
    Returns (recv_payload, recv_valid, flat, valid):
      recv_payload: pytree with leading dims (S*capacity,) — grouped by source peer
      recv_valid:   (S*capacity,) bool
      flat, valid:  sender-side slot bookkeeping (for reply unscatter).
    """
    S = axis_size(axis_name)
    flat, valid = _owner_slots(dest, S, capacity)

    def scatter_one(x):
        buf = jnp.zeros((S * capacity,) + x.shape[1:], x.dtype)
        vmask = valid.reshape((-1,) + (1,) * (x.ndim - 1))
        return buf.at[flat].add(jnp.where(vmask, x, jnp.zeros((), x.dtype)))

    send = jax.tree.map(scatter_one, payload)
    sendv = jnp.zeros((S * capacity,), jnp.bool_).at[flat].max(valid)

    def a2a(x):
        return _all_to_all(x.reshape((S, capacity) + x.shape[1:]), axis_name).reshape(
            (S * capacity,) + x.shape[1:])

    recv = jax.tree.map(a2a, send)
    recvv = a2a(sendv.astype(jnp.int8)).astype(jnp.bool_)
    return recv, recvv, flat, valid


def _reply(reply_payload, flat: jnp.ndarray, valid: jnp.ndarray, axis_name: AxisName,
           capacity: int, fill=0):
    """Return per-request answers computed at the owner back to the requesters."""
    S = axis_size(axis_name)

    def a2a(x):
        return _all_to_all(x.reshape((S, capacity) + x.shape[1:]), axis_name).reshape(
            (S * capacity,) + x.shape[1:])

    back = jax.tree.map(a2a, reply_payload)

    def unscatter(x):
        out = jnp.take(x, flat, axis=0)
        vmask = valid.reshape(valid.shape + (1,) * (out.ndim - 1))
        return jnp.where(vmask, out, jnp.asarray(fill, out.dtype))

    return jax.tree.map(unscatter, back)


# ---------------------------------------------------------------------------
# DGAS remote access primitives
# ---------------------------------------------------------------------------

def dgas_gather(local: jnp.ndarray, gidx: jnp.ndarray, att: ATT, axis_name: AxisName,
                *, capacity: Optional[int] = None, fill: float = 0.0) -> jnp.ndarray:
    """PIUMA fine-grained remote gather (DMA gather across the DGAS).

    Each shard holds `local` (its rows of the global array, per `att`); `gidx`
    are *global* ids to fetch.  Only the index requests (8 B) and the fetched
    elements travel the network — never whole array replicas.

    capacity: max requests any single peer pair exchanges; defaults to
      2*ceil(n/S) (fine for interleaved/balanced rules; raise for skew —
      overflowing requests return `fill`).
    """
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    owner = att.owner(gidx).astype(jnp.int32)
    local_idx = att.local(gidx).astype(jnp.int32)
    local_idx = jnp.where((gidx >= 0) & (gidx < att.n_global), local_idx, -1)
    recv, recvv, flat, valid = _route(local_idx, owner, axis_name, C)
    answers = dma_gather(local, jnp.where(recvv, recv, -1), fill=fill)
    return _reply(answers, flat, valid, axis_name, C, fill=fill)


def remote_scatter_add(local: jnp.ndarray, gidx: jnp.ndarray, vals: jnp.ndarray,
                       att: ATT, axis_name: AxisName, *,
                       capacity: Optional[int] = None) -> jnp.ndarray:
    """PIUMA remote atomic add: the update executes at the owner shard.

    Routes (local index, value) pairs to the owning shard which applies a
    single fused segment update — the batched bulk-synchronous equivalent of
    per-word remote atomics (commutative ops only; see DESIGN.md §2).
    """
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    owner = att.owner(gidx).astype(jnp.int32)
    local_idx = att.local(gidx).astype(jnp.int32)
    local_idx = jnp.where((gidx >= 0) & (gidx < att.n_global), local_idx, -1)
    (ridx, rvals), recvv, _, _ = _route((local_idx, vals), owner, axis_name, C)
    ridx = jnp.where(recvv, ridx, -1)
    return dma_scatter_add(local, ridx, rvals)


def remote_scatter_combine(local: jnp.ndarray, gidx: jnp.ndarray,
                           vals: jnp.ndarray, att: ATT, axis_name: AxisName, *,
                           combine: str, identity,
                           capacity: Optional[int] = None) -> jnp.ndarray:
    """Remote atomic min/max (the non-additive PIUMA remote atomics).

    Same routing as `remote_scatter_add`; the owner applies a fused
    scatter-{min,max}.  Dropped/padding slots carry `identity` so they are
    no-ops at the owner.
    """
    if combine not in ("min", "max"):
        raise ValueError(f"combine must be 'min' or 'max', got {combine!r}")
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    owner = att.owner(gidx).astype(jnp.int32)
    local_idx = att.local(gidx).astype(jnp.int32)
    local_idx = jnp.where((gidx >= 0) & (gidx < att.n_global), local_idx, -1)
    neutral = jnp.asarray(identity, vals.dtype)
    # each routed slot holds exactly one item, so values arrive unchanged;
    # empty slots are zero-filled by _route and masked to `identity` here.
    (ridx, rvals), recvv, _, _ = _route((local_idx, vals), owner, axis_name, C)
    ridx = jnp.where(recvv, ridx, -1)
    rvals = jnp.where(recvv, rvals, neutral)
    valid = (ridx >= 0) & (ridx < local.shape[0])
    safe = jnp.where(valid, ridx, 0)
    masked = jnp.where(valid, rvals.astype(local.dtype),
                       jnp.asarray(identity, local.dtype))
    if combine == "min":
        return local.at[safe].min(masked)
    return local.at[safe].max(masked)


def all_gather_gather(local: jnp.ndarray, gidx: jnp.ndarray, att: ATT,
                      axis_name: AxisName, *, fill: float = 0.0) -> jnp.ndarray:
    """Conventional-architecture baseline: replicate the whole array, then index.

    This is the 'move the cache line (here: the entire remote array)' strategy
    GSPMD produces by default; kept to quantify PIUMA's advantage.
    Requires a contiguous or interleaved rule to reassemble the global order.
    """
    g = lax.all_gather(local, axis_name, tiled=False)  # (S, rows_per_shard, ...)
    S = g.shape[0]
    if att.kind == "interleave":
        # global id g -> (g % S, g // S): reassemble by transposing
        full = jnp.swapaxes(g, 0, 1).reshape((-1,) + g.shape[2:])[: att.n_global]
    else:
        full = g.reshape((-1,) + g.shape[2:])[: att.n_global]
    return dma_gather(full, gidx, fill=fill)


# ---------------------------------------------------------------------------
# Queue engine
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QueueState:
    """Fixed-capacity distributed work queue (one buffer per shard)."""

    items: jnp.ndarray  # (capacity,) int32, padding = -1
    count: jnp.ndarray  # () int32 — valid prefix length

    def tree_flatten(self):
        return (self.items, self.count), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def queue_make(capacity: int) -> QueueState:
    return QueueState(jnp.full((capacity,), -1, jnp.int32), jnp.zeros((), jnp.int32))


def queue_balance(q: QueueState, axis_name: AxisName) -> QueueState:
    """Rebalance queued items evenly across shards (hardware work stealing).

    Every item gets a global rank via a device prefix scan; item with rank r
    moves to shard r % S (interleave), so post-balance counts differ by <=1.
    """
    S = axis_size(axis_name)
    cap = q.items.shape[0]
    offset = prefix_scan(q.count, axis_name)
    rank = offset + jnp.arange(cap, dtype=jnp.int32)
    is_item = jnp.arange(cap) < q.count
    dest = jnp.where(is_item, rank % S, -1)
    recv, recvv, _, _ = _route(q.items, dest.astype(jnp.int32), axis_name, cap)
    recv = jnp.where(recvv, recv, -1)
    # compact received items to a prefix
    order = jnp.argsort(~recvv, stable=True)  # valid first
    items = jnp.take(recv, order)
    return QueueState(items, recvv.sum().astype(jnp.int32))


# ---------------------------------------------------------------------------
# Collective engine
# ---------------------------------------------------------------------------

def hierarchical_psum(x, axes: Sequence[AxisName]):
    """Reduce one mesh level at a time (intra-block -> intra-pod -> cross-pod),
    matching the HyperX hierarchy; XLA can then schedule each stage on its own
    link class."""
    for a in axes:
        x = lax.psum(x, a)
    return x


def barrier(axis_name: AxisName) -> jnp.ndarray:
    """System-wide barrier (semantic, via a 1-word reduction)."""
    return lax.psum(jnp.ones((), jnp.int32), axis_name)


def prefix_scan(x: jnp.ndarray, axis_name: AxisName) -> jnp.ndarray:
    """Exclusive prefix sum across shards (collective-engine scan)."""
    g = lax.all_gather(x, axis_name, tiled=False)  # (S, ...)
    csum = jnp.cumsum(g, axis=0) - g
    return jnp.take(csum, my_shard(axis_name), axis=0)
