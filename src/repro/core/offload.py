"""Offload engines: DMA gather/scatter, remote atomics, queues, collectives.

PIUMA blocks contain engines that execute memory operations *in the background,
where the data lives*:

* DMA engine      — (strided) copy / gather / scatter between memory and SPAD
* remote atomics  — atomic update at the owning memory controller
* queue engine    — shared work queues (work stealing / dynamic partitioning)
* collective eng. — system-wide barriers and reductions

On a TPU mesh the analogues are (a) local fused gathers/segment-reductions for
the in-node case and (b) `shard_map` + `all_to_all` *owner-routed* exchanges
for the remote case: requests travel to the owner shard, the owner performs the
gather or the commutative update locally, and only the requested/accepted words
cross the network.  This is the paper's fine-grained-access model; the
conventional-architecture baseline ("fetch the whole cache line") is an
`all_gather` of the full remote array, kept for comparison in the algorithms
and benchmarks.

All remote primitives consult an ATT (see `core.dgas`) so distribution rules
stay programmable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from .dgas import ATT
from .. import compat

AxisName = Union[str, Sequence[str]]

__all__ = [
    "dma_gather", "dma_scatter_add", "dma_strided_copy",
    "axis_size", "my_shard",
    "segment_argmax", "segment_weighted_mode", "compact_labels", "run_starts",
    "segment_or",
    "dgas_gather", "remote_scatter_add", "remote_scatter_combine",
    "remote_scatter_weighted_mode", "remote_scatter_or",
    "buffered_flush",
    "all_gather_gather",
    "QueueState", "queue_make", "queue_balance",
    "hierarchical_psum", "barrier", "prefix_scan",
]

# payload sentinel that sorts after every real label / vertex id
LABEL_PAD = 2 ** 30


# ---------------------------------------------------------------------------
# Local (in-node) DMA engine ops
# ---------------------------------------------------------------------------

def dma_gather(table: jnp.ndarray, idx: jnp.ndarray, *, fill: float = 0.0) -> jnp.ndarray:
    """Gather rows/elements; out-of-range indices return `fill` (padding-safe)."""
    valid = (idx >= 0) & (idx < table.shape[0])
    safe = jnp.where(valid, idx, 0)
    out = jnp.take(table, safe, axis=0)
    mask = valid.reshape(valid.shape + (1,) * (out.ndim - valid.ndim))
    return jnp.where(mask, out, jnp.asarray(fill, out.dtype))


def dma_scatter_add(dest: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter-add with padding indices (<0 or >=n) dropped."""
    valid = (idx >= 0) & (idx < dest.shape[0])
    safe = jnp.where(valid, idx, 0)
    mask = valid.reshape(valid.shape + (1,) * (vals.ndim - valid.ndim))
    return dest.at[safe].add(jnp.where(mask, vals, 0).astype(dest.dtype))


def dma_strided_copy(src: jnp.ndarray, start: int, stride: int, count: int) -> jnp.ndarray:
    return lax.dynamic_slice_in_dim(src, start, 1 + (count - 1) * stride)[::stride]


# ---------------------------------------------------------------------------
# Structured segment combines (the engine's argmax / sample reductions; also
# executed at the owner shard by the remote variants below)
# ---------------------------------------------------------------------------

def segment_argmax(idx: jnp.ndarray, score: jnp.ndarray, payload: jnp.ndarray,
                   n: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-destination (score, payload)-packed segment max.

    For each destination v: best[v] = max score over items with idx==v, and
    pick[v] = the payload of a maximizing item (ties broken toward the
    *smaller* payload, deterministically).  Items with idx outside [0, n) or
    score == -inf are ignored; destinations with no items get (-inf, -1).

    HBM has no native packed max, so the pack is expressed as a two-plane
    lexicographic scatter: scatter-max the score plane, then scatter-min the
    payload plane masked to score winners.
    """
    valid = (idx >= 0) & (idx < n)
    safe = jnp.where(valid, idx, 0)
    neg = jnp.asarray(-jnp.inf, score.dtype)
    s = jnp.where(valid, score, neg)
    best = jnp.full((n,), neg, score.dtype).at[safe].max(s)
    is_best = valid & (s == jnp.take(best, safe)) & (s > neg)
    pad = jnp.int32(LABEL_PAD)
    cand = jnp.where(is_best, payload.astype(jnp.int32), pad)
    pick = jnp.full((n,), pad, jnp.int32).at[safe].min(cand)
    return best, jnp.where(pick == pad, -1, pick)


def run_starts(*sorted_keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run detection over a lex-sorted key stream (nonempty): returns
    (is_start (m,) bool, run_id (m,) — the exclusive count of starts).  The
    shared reduction behind :func:`segment_weighted_mode`,
    :func:`compact_labels` and `graph.contract`: a run is a maximal stretch
    where every key matches its predecessor."""
    neq = None
    for k in sorted_keys:
        d = k[1:] != k[:-1]
        neq = d if neq is None else (neq | d)
    is_start = jnp.concatenate([jnp.ones((1,), bool), neq])
    return is_start, jnp.cumsum(is_start) - 1


def segment_weighted_mode(idx: jnp.ndarray, labels: jnp.ndarray,
                          weights: jnp.ndarray, n: int
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-destination weighted mode: argmax_l sum(weights | idx==v, labels==l).

    Returns (best_w, best_label): the winning label's total weight and the
    label itself, ties toward the smaller label.  Items with idx outside
    [0, n) or labels < 0 are ignored; destinations with no items get
    (-inf, -1).  This is the two-stage structured combine: weights are first
    summed per (destination, label) run — the stream is sorted by that pair so
    the sums are one fused segment reduction — then the (sum, label) pack goes
    through :func:`segment_argmax`.
    """
    m = int(idx.shape[0])
    if m == 0:
        return (jnp.full((n,), -jnp.inf, weights.dtype),
                jnp.full((n,), -1, jnp.int32))
    valid = (idx >= 0) & (idx < n) & (labels >= 0)
    si = jnp.where(valid, idx, n).astype(jnp.int32)
    sl = jnp.where(valid, labels, LABEL_PAD).astype(jnp.int32)
    order = jnp.lexsort((sl, si))
    si, sl = jnp.take(si, order), jnp.take(sl, order)
    sw = jnp.where(jnp.take(valid, order),
                   jnp.take(weights, order), jnp.zeros((), weights.dtype))
    is_start, run_id = run_starts(si, sl)
    run_w = jax.ops.segment_sum(sw, run_id, num_segments=m)
    rep_idx = jnp.where(is_start & (si < n), si, -1)
    return segment_argmax(rep_idx, jnp.take(run_w, run_id), sl, n)


def segment_or(idx: jnp.ndarray, words: jnp.ndarray, n: int, *,
               presorted: bool = False) -> jnp.ndarray:
    """Per-destination bitwise OR of packed lane words (MS-BFS's combine).

    ``idx`` (m,) int32 destinations (out-of-range ignored), ``words`` (m, W)
    uint32 bit-packed lane payloads.  Returns (n, W) uint32 with out[v] = OR
    of all words whose idx == v (0 where no items land).

    HBM scatters have no native OR, and bit-packed words cannot ride the
    add/min/max scatters (carries / monotonicity), so the reduction is a
    *segmented scan*: sort by destination (skipped when the caller's stream
    is already destination-sorted, e.g. the engine's host-presorted pull
    stream), run a segmented inclusive OR-scan — the collective engine's
    prefix-scan machinery applied within runs — and keep each run's last
    element.  O(m log m) work, fully vectorized over the W lane words.
    """
    m = int(idx.shape[0])
    W = int(words.shape[1])
    if m == 0:
        return jnp.zeros((n, W), jnp.uint32)
    valid = (idx >= 0) & (idx < n)
    key = jnp.where(valid, idx, n).astype(jnp.int32)
    w = jnp.where(valid[:, None], words.astype(jnp.uint32), jnp.uint32(0))
    if not presorted:
        order = jnp.argsort(key)  # OR is commutative: stability not needed
        key = jnp.take(key, order)
        w = jnp.take(w, order, axis=0)
    first = jnp.concatenate([jnp.ones((1,), bool), key[1:] != key[:-1]])

    def op(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[..., None], vb, va | vb)

    _, scanned = lax.associative_scan(op, (first, w), axis=0)
    is_end = jnp.concatenate([key[1:] != key[:-1], jnp.ones((1,), bool)])
    # one writer per run: scatter the run totals; the n sentinel (and any
    # non-end position) is dropped by the out-of-bounds scatter rule
    end_key = jnp.where(is_end, key, n)
    return jnp.zeros((n, W), jnp.uint32).at[end_key].set(scanned)


def compact_labels(labels: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Renumber arbitrary int labels into dense ids [0, n_c), order-preserving.

    The graph-contraction step (collapse communities into supernodes) needs
    community ids that double as coarse vertex ids.  Same run-detection
    machinery as :func:`segment_weighted_mode`: sort, mark run starts, prefix
    sum the starts — a segment scan, not a host-side unique.  Returns
    (dense (n,) int32, n_c () int32); the smallest original label maps to 0,
    so the renumbering is deterministic and monotone in the original ids.
    """
    n = int(labels.shape[0])
    if n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32)
    order = jnp.argsort(labels, stable=True)
    sl = jnp.take(labels, order)
    _, run_id = run_starts(sl)
    rank = run_id.astype(jnp.int32)
    dense = jnp.zeros((n,), jnp.int32).at[order].set(rank)
    return dense, rank[-1] + 1


# ---------------------------------------------------------------------------
# Axis helpers (work with a single axis name or a tuple of axis names)
# ---------------------------------------------------------------------------

def axis_size(axis_name: AxisName) -> int:
    return compat.axis_size(axis_name)


def my_shard(axis_name: AxisName) -> jnp.ndarray:
    """Flattened linear shard index across (possibly) multiple mesh axes."""
    if isinstance(axis_name, (tuple, list)):
        idx = jnp.int32(0)
        for a in axis_name:
            idx = idx * compat.axis_size(a) + lax.axis_index(a)
        return idx
    return lax.axis_index(axis_name)


def _all_to_all(x: jnp.ndarray, axis_name: AxisName) -> jnp.ndarray:
    """all_to_all over leading axis of size = axis size (possibly tuple axes)."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


# ---------------------------------------------------------------------------
# Owner routing (shared by dgas_gather / remote_scatter_add / queues)
# ---------------------------------------------------------------------------

def _owner_slots(dest: jnp.ndarray, n_shards: int, capacity: int):
    """Assign each item a slot in its destination bucket.

    Returns (flat, valid): flat = dest*capacity + slot for valid items, and
    valid = slot < capacity.  Deterministic (stable sort order).
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = jnp.take(dest, order)
    starts = jnp.searchsorted(sorted_dest, jnp.arange(n_shards, dtype=dest.dtype))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.take(starts, sorted_dest).astype(jnp.int32)
    slot = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    valid = (slot < capacity) & (dest >= 0) & (dest < n_shards)
    flat = jnp.where(valid, dest.astype(jnp.int32) * capacity + slot, 0)
    return flat, valid


def _route(payload, dest: jnp.ndarray, axis_name: AxisName, capacity: int):
    """Send each payload row to shard `dest[i]` (fixed per-peer capacity).

    payload: pytree of arrays with leading dim n.
    Returns (recv_payload, recv_valid, flat, valid):
      recv_payload: pytree with leading dims (S*capacity,) — grouped by source peer
      recv_valid:   (S*capacity,) bool
      flat, valid:  sender-side slot bookkeeping (for reply unscatter).
    """
    S = axis_size(axis_name)
    flat, valid = _owner_slots(dest, S, capacity)

    def scatter_one(x):
        buf = jnp.zeros((S * capacity,) + x.shape[1:], x.dtype)
        vmask = valid.reshape((-1,) + (1,) * (x.ndim - 1))
        return buf.at[flat].add(jnp.where(vmask, x, jnp.zeros((), x.dtype)))

    send = jax.tree.map(scatter_one, payload)
    sendv = jnp.zeros((S * capacity,), jnp.bool_).at[flat].max(valid)

    def a2a(x):
        return _all_to_all(x.reshape((S, capacity) + x.shape[1:]), axis_name).reshape(
            (S * capacity,) + x.shape[1:])

    recv = jax.tree.map(a2a, send)
    recvv = a2a(sendv.astype(jnp.int8)).astype(jnp.bool_)
    return recv, recvv, flat, valid


def _reply(reply_payload, flat: jnp.ndarray, valid: jnp.ndarray, axis_name: AxisName,
           capacity: int, fill=0):
    """Return per-request answers computed at the owner back to the requesters."""
    S = axis_size(axis_name)

    def a2a(x):
        return _all_to_all(x.reshape((S, capacity) + x.shape[1:]), axis_name).reshape(
            (S * capacity,) + x.shape[1:])

    back = jax.tree.map(a2a, reply_payload)

    def unscatter(x):
        out = jnp.take(x, flat, axis=0)
        vmask = valid.reshape(valid.shape + (1,) * (out.ndim - 1))
        return jnp.where(vmask, out, jnp.asarray(fill, out.dtype))

    return jax.tree.map(unscatter, back)


# ---------------------------------------------------------------------------
# DGAS remote access primitives
# ---------------------------------------------------------------------------

def dgas_gather(local: jnp.ndarray, gidx: jnp.ndarray, att: ATT, axis_name: AxisName,
                *, capacity: Optional[int] = None, fill: float = 0.0) -> jnp.ndarray:
    """PIUMA fine-grained remote gather (DMA gather across the DGAS).

    Each shard holds `local` (its rows of the global array, per `att`); `gidx`
    are *global* ids to fetch.  Only the index requests (8 B) and the fetched
    elements travel the network — never whole array replicas.

    capacity: max requests any single peer pair exchanges; defaults to
      2*ceil(n/S) (fine for interleaved/balanced rules; raise for skew —
      overflowing requests return `fill`).
    """
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    owner = att.owner(gidx).astype(jnp.int32)
    local_idx = att.local(gidx).astype(jnp.int32)
    local_idx = jnp.where((gidx >= 0) & (gidx < att.n_global), local_idx, -1)
    recv, recvv, flat, valid = _route(local_idx, owner, axis_name, C)
    answers = dma_gather(local, jnp.where(recvv, recv, -1), fill=fill)
    return _reply(answers, flat, valid, axis_name, C, fill=fill)


def remote_scatter_add(local: jnp.ndarray, gidx: jnp.ndarray, vals: jnp.ndarray,
                       att: ATT, axis_name: AxisName, *,
                       capacity: Optional[int] = None) -> jnp.ndarray:
    """PIUMA remote atomic add: the update executes at the owner shard.

    Routes (local index, value) pairs to the owning shard which applies a
    single fused segment update — the batched bulk-synchronous equivalent of
    per-word remote atomics (commutative ops only; see DESIGN.md §2).
    """
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    owner = att.owner(gidx).astype(jnp.int32)
    local_idx = att.local(gidx).astype(jnp.int32)
    local_idx = jnp.where((gidx >= 0) & (gidx < att.n_global), local_idx, -1)
    (ridx, rvals), recvv, _, _ = _route((local_idx, vals), owner, axis_name, C)
    ridx = jnp.where(recvv, ridx, -1)
    return dma_scatter_add(local, ridx, rvals)


def remote_scatter_combine(local: jnp.ndarray, gidx: jnp.ndarray,
                           vals: jnp.ndarray, att: ATT, axis_name: AxisName, *,
                           combine: str, identity,
                           capacity: Optional[int] = None) -> jnp.ndarray:
    """Remote atomic min/max (the non-additive PIUMA remote atomics).

    Same routing as `remote_scatter_add`; the owner applies a fused
    scatter-{min,max}.  Dropped/padding slots carry `identity` so they are
    no-ops at the owner.
    """
    if combine not in ("min", "max"):
        raise ValueError(f"combine must be 'min' or 'max', got {combine!r}")
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    owner = att.owner(gidx).astype(jnp.int32)
    local_idx = att.local(gidx).astype(jnp.int32)
    local_idx = jnp.where((gidx >= 0) & (gidx < att.n_global), local_idx, -1)
    neutral = jnp.asarray(identity, vals.dtype)
    # each routed slot holds exactly one item, so values arrive unchanged;
    # empty slots are zero-filled by _route and masked to `identity` here.
    (ridx, rvals), recvv, _, _ = _route((local_idx, vals), owner, axis_name, C)
    ridx = jnp.where(recvv, ridx, -1)
    trail = (1,) * (rvals.ndim - 1)  # vals may carry lanes: (m, B) and beyond
    rvals = jnp.where(recvv.reshape((-1,) + trail), rvals, neutral)
    valid = (ridx >= 0) & (ridx < local.shape[0])
    safe = jnp.where(valid, ridx, 0)
    masked = jnp.where(valid.reshape((-1,) + trail), rvals.astype(local.dtype),
                       jnp.asarray(identity, local.dtype))
    if combine == "min":
        return local.at[safe].min(masked)
    return local.at[safe].max(masked)


def remote_scatter_or(per_shard_n: int, gidx: jnp.ndarray, words: jnp.ndarray,
                      att: ATT, axis_name: AxisName, *,
                      capacity: Optional[int] = None) -> jnp.ndarray:
    """Remote atomic OR of bit-packed lane words, executed at the owner.

    The batched engine's push step for bitwise (MS-BFS-style) programs: each
    shard contributes (global vertex, (W,) uint32 lane words) pairs; the
    owner reduces arrivals with :func:`segment_or`.  One routed item carries
    all B lanes in ceil(B/32) words — the amortization PIUMA's concurrent
    traversals exploit, `traffic.batched_payload_bytes` charges it.
    Returns the (per_shard_n, W) uint32 OR-accumulator.
    """
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    in_range = (gidx >= 0) & (gidx < att.n_global)
    owner = jnp.where(in_range, att.owner(jnp.maximum(gidx, 0)), -1).astype(jnp.int32)
    local_idx = jnp.where(in_range, att.local(jnp.maximum(gidx, 0)), -1).astype(jnp.int32)
    (ridx, rwords), recvv, _, _ = _route(
        (local_idx, words.astype(jnp.uint32)), owner, axis_name, C)
    ridx = jnp.where(recvv, ridx, -1)
    return segment_or(ridx, rwords, per_shard_n)


def remote_scatter_weighted_mode(per_shard_n: int, gidx: jnp.ndarray,
                                 labels: jnp.ndarray, weights: jnp.ndarray,
                                 att: ATT, axis_name: AxisName, *,
                                 capacity: Optional[int] = None
                                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Remote structured combine: weighted label mode executed at the owner.

    Each shard contributes (global vertex, label, weight) votes; the triples
    are owner-routed *raw* (no sender-side pre-reduction) so the owner's
    :func:`segment_weighted_mode` sums each (vertex, label) pair over every
    contributing shard before taking the argmax — the reduction is correct
    even when votes for one pair arrive from many shards.  Returns the
    per-local-vertex (best_w, best_label); vertices with no votes get
    (-inf, -1).
    """
    n = gidx.shape[0]
    S = axis_size(axis_name)
    C = capacity if capacity is not None else min(n, 2 * (-(-n // S)))
    in_range = (gidx >= 0) & (gidx < att.n_global)
    owner = jnp.where(in_range, att.owner(jnp.maximum(gidx, 0)), -1).astype(jnp.int32)
    local_idx = jnp.where(in_range, att.local(jnp.maximum(gidx, 0)), -1).astype(jnp.int32)
    (ridx, rlab, rw), recvv, _, _ = _route(
        (local_idx, labels.astype(jnp.int32), weights), owner, axis_name, C)
    ridx = jnp.where(recvv, ridx, -1)
    rlab = jnp.where(recvv, rlab, -1)
    return segment_weighted_mode(ridx, rlab, rw, per_shard_n)


def buffered_flush(outbox: jnp.ndarray, axis_name: AxisName, *,
                   combine: str) -> jnp.ndarray:
    """Deliver a dense deferred-message buffer to its owner shards.

    The async placement's exchange primitive (DESIGN.md §14): between global
    checks each shard folds remote contributions into a dense ``(S*per, ...)``
    outbox addressed by flat slot ``owner * per + local`` (`ATT.flat_slot`)
    using the program's combine, so arbitrarily many local micro-steps of
    traffic collapse into one fixed-size buffer.  At the sync point this
    single collective transposes the buffers — peer p's slice lands on shard
    p — and the S inbound slices are folded with the same combine.  Because
    the combine is associative and commutative, delivery order (i.e. the
    staleness window) cannot change the merged value.

    outbox: (S * per, ...) with identity-filled empty slots
      (0 for 'add'/'or', +inf for 'min', -inf for 'max').
    combine: 'add' | 'min' | 'max' | 'or' ('or' expects uint32 lane words).
    Returns the (per, ...) merged arrivals for this shard's residents.
    """
    S = axis_size(axis_name)
    lead = outbox.shape[0]
    if lead % S != 0:
        raise ValueError(
            f"outbox leading dim {lead} is not divisible by {S} shards")
    box = outbox.reshape((S, lead // S) + outbox.shape[1:])
    arrived = _all_to_all(box, axis_name)  # [p] = peer p's messages for me
    if combine == "add":
        return arrived.sum(axis=0)
    if combine == "min":
        return arrived.min(axis=0)
    if combine == "max":
        return arrived.max(axis=0)
    if combine == "or":
        out = arrived[0]
        for i in range(1, S):  # static: S is a compile-time mesh size
            out = out | arrived[i]
        return out
    raise ValueError(f"unsupported combine {combine!r} for buffered_flush")


def all_gather_gather(local: jnp.ndarray, gidx: jnp.ndarray, att: ATT,
                      axis_name: AxisName, *, fill: float = 0.0) -> jnp.ndarray:
    """Conventional-architecture baseline: replicate the whole array, then index.

    This is the 'move the cache line (here: the entire remote array)' strategy
    GSPMD produces by default; kept to quantify PIUMA's advantage.
    Requires a contiguous or interleaved rule to reassemble the global order.
    """
    g = lax.all_gather(local, axis_name, tiled=False)  # (S, rows_per_shard, ...)
    S = g.shape[0]
    if att.kind == "interleave":
        # global id g -> (g % S, g // S): reassemble by transposing
        full = jnp.swapaxes(g, 0, 1).reshape((-1,) + g.shape[2:])[: att.n_global]
    else:
        full = g.reshape((-1,) + g.shape[2:])[: att.n_global]
    return dma_gather(full, gidx, fill=fill)


# ---------------------------------------------------------------------------
# Queue engine
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QueueState:
    """Fixed-capacity distributed work queue (one buffer per shard)."""

    items: jnp.ndarray  # (capacity,) int32, padding = -1
    count: jnp.ndarray  # () int32 — valid prefix length

    def tree_flatten(self):
        return (self.items, self.count), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def queue_make(capacity: int) -> QueueState:
    return QueueState(jnp.full((capacity,), -1, jnp.int32), jnp.zeros((), jnp.int32))


def queue_balance(q: QueueState, axis_name: AxisName, payload=None):
    """Rebalance queued items evenly across shards (hardware work stealing).

    Every item gets a global rank via a device prefix scan; item with rank r
    moves to shard r % S (interleave), so post-balance counts differ by <=1.
    Since the global item count never exceeds S * capacity, the balanced
    per-shard count fits the original capacity and the returned queue keeps
    the input buffer size (a fixed point for iterated balancing).

    payload: optional pytree with leading dim == capacity, routed alongside
      the items (a queue entry's companion data — e.g. a walker's current
      vertex); rows without an item are zeroed.  Returns (QueueState, payload)
      when given, else just the QueueState.
    """
    S = axis_size(axis_name)
    cap = q.items.shape[0]
    offset = prefix_scan(q.count, axis_name)
    rank = offset + jnp.arange(cap, dtype=jnp.int32)
    is_item = jnp.arange(cap) < q.count
    dest = jnp.where(is_item, rank % S, -1)
    pl_leaves, pl_def = (jax.tree.flatten(payload) if payload is not None
                         else ((), None))
    recv, recvv, _, _ = _route((q.items,) + tuple(pl_leaves),
                               dest.astype(jnp.int32), axis_name, cap)
    # compact received items to a prefix, back into the original capacity
    order = jnp.argsort(~recvv, stable=True)[:cap]  # valid first
    kept = jnp.take(recvv, order)
    items = jnp.where(kept, jnp.take(recv[0], order), -1)
    out_q = QueueState(items, recvv.sum().astype(jnp.int32))
    if payload is None:
        return out_q
    out_pl = []
    for x in recv[1:]:
        xs = jnp.take(x, order, axis=0)
        mask = kept.reshape((-1,) + (1,) * (xs.ndim - 1))
        out_pl.append(jnp.where(mask, xs, jnp.zeros((), xs.dtype)))
    return out_q, jax.tree.unflatten(pl_def, out_pl)


# ---------------------------------------------------------------------------
# Collective engine
# ---------------------------------------------------------------------------

def hierarchical_psum(x, axes: Sequence[AxisName]):
    """Reduce one mesh level at a time (intra-block -> intra-pod -> cross-pod),
    matching the HyperX hierarchy; XLA can then schedule each stage on its own
    link class."""
    for a in axes:
        x = lax.psum(x, a)
    return x


def barrier(axis_name: AxisName) -> jnp.ndarray:
    """System-wide barrier (semantic, via a 1-word reduction)."""
    return lax.psum(jnp.ones((), jnp.int32), axis_name)


def prefix_scan(x: jnp.ndarray, axis_name: AxisName) -> jnp.ndarray:
    """Exclusive prefix sum across shards (collective-engine scan)."""
    g = lax.all_gather(x, axis_name, tiled=False)  # (S, ...)
    csum = jnp.cumsum(g, axis=0) - g
    return jnp.take(csum, my_shard(axis_name), axis=0)
