"""Incremental recompute for monotone programs (DESIGN.md §16).

After a batch of edge inserts, the previous fixpoint of a monotone
(min-combining) program is still a *feasible labeling*: it satisfies the
relaxation inequality on every old edge and can violate it only on the new
edges — whose source endpoints we know (``UpdateReport.changed_sources``).
Re-running the engine with the old fixpoint as ``state0`` and the changed
endpoints as ``frontier0`` is therefore pure label-correcting repair: the
sparse push relaxes outward from the touched region only, and because the
fixpoint of a monotone min-combine is schedule-independent (the same
argument that makes the async placement bit-identical to sync, DESIGN.md
§14), the repaired labels equal a from-scratch run **bit for bit** — f32
min never rounds, and every candidate value is a path evaluation both
schedules generate.

Deletions (and weight *increases*, which are delete+insert in disguise)
break the feasibility invariant — the old fixpoint may be an unreachable
over-optimistic labeling — so they fall back to full recompute; the
decision is logged on the ``repro.streaming`` logger so a deployment can
see what its update mix costs.

The repair functions take and return the same arrays as their from-scratch
counterparts (``bfs`` levels, ``connected_components`` labels, ``sssp``
distances), so callers can hold one result and fold updates into it.
"""
from __future__ import annotations

import logging
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .. import engine
from ..dgas import ATT
from ..graph import CSR, GraphHandle, UpdateReport
from ...obs import get_registry
from .bfs import _levels_from_dist, bfs_level_program
from .cc import cc_program, symmetrize
from .distgraph import ShardedGraph
from .sssp import auto_delta, sssp_program

__all__ = ["bfs_repair", "cc_repair", "sssp_repair",
           "bfs_repair_distributed", "cc_repair_distributed",
           "repair_or_recompute"]

log = logging.getLogger("repro.streaming")

_INF = jnp.float32(jnp.inf)


def _indicator(n: int, vertices) -> jnp.ndarray:
    v = np.asarray(vertices, np.int64)
    out = np.zeros((n,), np.int32)
    out[v] = 1
    return jnp.asarray(out)


def bfs_repair(csr: CSR, prev_levels, changed, *, mode: str = "auto",
               max_levels: Optional[int] = None) -> jnp.ndarray:
    """Repair BFS levels after an insert-only batch.

    csr: the UPDATED graph.  prev_levels: (n,) int32 levels on the
    pre-update graph (unreachable = -1, i.e. ``bfs`` output).  changed:
    source endpoints of the inserted edges.  Returns levels bit-identical
    to ``bfs(csr, source)`` on the updated graph.

    Repair runs the monotone :func:`bfs_level_program` (min hop distance) —
    the iteration-stamped ``bfs_program`` is order-dependent and cannot be
    warm-started — but both compute the exact hop distance, so the int32
    levels agree exactly.
    """
    n = csr.n_rows
    prev = jnp.asarray(prev_levels)
    dist0 = jnp.where(prev >= 0, prev.astype(jnp.float32), _INF)
    # only changed endpoints the old traversal reached can push improvements
    f0 = _indicator(n, changed) * jnp.isfinite(dist0).astype(jnp.int32)
    state = engine.run(csr, bfs_level_program(), {"dist": dist0}, f0,
                       max_iters=max_levels or n, mode=mode)
    return _levels_from_dist(state["dist"])


def cc_repair(csr: CSR, prev_labels, changed, *, mode: str = "auto",
              symmetrize_input: bool = True,
              max_iters: Optional[int] = None) -> jnp.ndarray:
    """Repair connected-component labels after an insert-only batch.

    csr: the UPDATED graph (symmetrized here by default, matching
    ``connected_components``).  changed: endpoints of inserted edges —
    pass BOTH sides (``UpdateReport.changed_vertices``): components are
    undirected, so either endpoint's label can be the one that shrinks.
    Labels are always finite (every vertex labels itself), so every changed
    endpoint seeds the frontier.
    """
    g = symmetrize(csr) if symmetrize_input else csr
    n = g.n_rows
    state0 = {"label": jnp.asarray(prev_labels).astype(jnp.int32)}
    f0 = _indicator(n, changed)
    state = engine.run(g, cc_program(), state0, f0,
                       max_iters=max_iters if max_iters is not None else n,
                       mode=mode)
    return state["label"]


def sssp_repair(csr: CSR, prev_dist, changed, *,
                max_iters: Optional[int] = None,
                mode: str = "auto") -> jnp.ndarray:
    """Repair SSSP distances after a batch of inserts / weight decreases.

    csr: the UPDATED graph.  prev_dist: (n,) f32 distances on the
    pre-update graph (unreachable = +inf).  changed: source endpoints of
    the changed edges.  Returns distances bit-identical to
    ``sssp(csr, source)`` — the (min, +) fixpoint is schedule-independent,
    so the repair wave's Bellman–Ford-style schedule (bound = inf: every
    pending vertex stays active, no bucket pacing — repair regions are
    small, so delta-stepping's re-relaxation bound buys nothing) lands on
    the same f32 values as scratch delta-stepping.
    """
    n = csr.n_rows
    dist0 = jnp.asarray(prev_dist, jnp.float32)
    seeds = _indicator(n, changed) * jnp.isfinite(dist0).astype(jnp.int32)
    state0 = {"dist": dist0, "pending": seeds.astype(bool), "bound": _INF}
    state = engine.run(csr, sssp_program(float("inf")), state0, seeds,
                       max_iters=max_iters if max_iters is not None else 4 * n,
                       mode=mode)
    return state["dist"]


def bfs_repair_distributed(g: ShardedGraph, att: ATT, prev_levels, changed,
                           mesh: Mesh, *, axis=None, max_levels: int = 64,
                           placement: str = "sync",
                           sync_interval: Optional[int] = None) -> jnp.ndarray:
    """Distributed :func:`bfs_repair`: prev_levels stacked (S, per) under
    `att` (``bfs_distributed`` output), `g` the UPDATED sharded graph.
    Returns repaired levels in the same stacked layout."""
    S, per = att.n_shards, att.per_shard
    prev = jnp.asarray(prev_levels)
    dist0 = jnp.where(prev >= 0, prev.astype(jnp.float32), _INF)
    ch = np.asarray(changed, np.int64)
    f0 = np.zeros((S, per), np.int32)
    if ch.size:
        chj = jnp.asarray(ch, jnp.int32)
        f0[np.asarray(att.owner(chj)), np.asarray(att.local(chj))] = 1
    f0 = jnp.asarray(f0) * jnp.isfinite(dist0).astype(jnp.int32)
    state = engine.run_distributed(
        g, att, mesh, bfs_level_program(), {"dist": dist0}, f0, axis=axis,
        max_iters=max_levels * (int(sync_interval or 8)
                                if placement == "async" else 1),
        mode="push", placement=placement, sync_interval=sync_interval)
    return _levels_from_dist(state["dist"])


def cc_repair_distributed(g: ShardedGraph, att: ATT, prev_labels, changed,
                          mesh: Mesh, *, axis=None, max_iters: int = 256,
                          placement: str = "sync",
                          sync_interval: Optional[int] = None) -> jnp.ndarray:
    """Distributed :func:`cc_repair`: `g` must hold the UPDATED *symmetric*
    edge set (build from ``symmetrize(csr)``), prev_labels stacked (S, per).
    changed: both endpoints of the inserted edges (global ids)."""
    S, per = att.n_shards, att.per_shard
    state0 = {"label": jnp.asarray(prev_labels).astype(jnp.int32)}
    ch = np.asarray(changed, np.int64)
    f0 = np.zeros((S, per), np.int32)
    if ch.size:
        chj = jnp.asarray(ch, jnp.int32)
        f0[np.asarray(att.owner(chj)), np.asarray(att.local(chj))] = 1
    state = engine.run_distributed(
        g, att, mesh, cc_program(), state0, jnp.asarray(f0), axis=axis,
        max_iters=max_iters, mode="push", placement=placement,
        sync_interval=sync_interval)
    return state["label"]


def repair_or_recompute(kind: str, handle: GraphHandle, prev,
                        report: UpdateReport, *, source: int = 0,
                        mode: str = "auto"):
    """Dispatch: incremental repair when the batch was monotone-safe, else
    the logged full-recompute fallback (DESIGN.md §16 deletion policy).

    kind: 'bfs' | 'cc' | 'sssp'.  prev: the pre-update result for `kind`
    (ignored on fallback).  Returns the post-update result either way.
    """
    from .bfs import bfs
    from .cc import connected_components
    from .sssp import sssp

    csr = handle.csr
    if report.monotone_safe:
        log.info("epoch %d: %s repair from %d changed endpoints "
                 "(+%d edges, %d upserts)", report.epoch, kind,
                 report.changed_sources.size, report.n_inserted,
                 report.n_upserted)
        if kind == "bfs":
            return bfs_repair(csr, prev, report.changed_sources, mode=mode)
        if kind == "cc":
            return cc_repair(csr, prev, report.changed_vertices, mode=mode)
        if kind == "sssp":
            return sssp_repair(csr, prev, report.changed_sources, mode=mode)
        raise ValueError(f"unknown repair kind {kind!r}")
    log.info("epoch %d: %s full recompute fallback (%d deletes, "
             "weight increases=%s — old fixpoint not feasible)",
             report.epoch, kind, report.n_deleted, not report.monotone_safe
             and report.n_deleted == 0)
    get_registry().counter("streaming.full_recompute_fallback").inc()
    if kind == "bfs":
        return bfs(csr, source, mode=mode)
    if kind == "cc":
        return connected_components(csr, mode=mode)
    if kind == "sssp":
        return sssp(csr, source, delta=auto_delta(csr), mode=mode)
    raise ValueError(f"unknown repair kind {kind!r}")
