"""Single-source shortest paths — delta-stepping-style, on the frontier engine.

The vertex program is the (min, +) semiring: active vertices emit their
tentative distance, every edge adds its weight, destinations keep the min.
On top of that the update rule implements delta-stepping's bucket discipline:
only *pending* vertices (improved since last expanded) whose distance falls
inside the current bucket ``[0, bound)`` join the frontier; when the bucket
drains, ``bound`` advances to ``min(pending dist) + delta``.  ``delta=inf``
degenerates to Bellman–Ford with a frontier (every pending vertex active),
small ``delta`` approaches Dijkstra's settled order — the classic knob
between work-efficiency and parallelism.

Distributed, the relaxations are PIUMA remote atomic *min* ops at the owner
(`offload.remote_scatter_combine`), with the bucket bound agreed globally via
a collective-engine reduction.

Weights must be non-negative (as delta-stepping requires); an unweighted
graph relaxes with unit weights (== BFS distances).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from .. import engine
from ..dgas import ATT
from ..graph import CSR
from .distgraph import ShardedGraph

__all__ = ["sssp", "sssp_distributed", "sssp_program", "auto_delta",
           "sssp_batched", "sssp_batched_distributed"]

_INF = jnp.float32(jnp.inf)


def sssp_program(delta: float, *, global_min=None) -> engine.VertexProgram:
    """(min, +) relaxation with bucketed frontier admission.

    global_min: optional f(x)->x reduction so the distributed engine agrees
      on `min(pending dist)` across shards (identity for the local engine).
    """
    gmin = global_min if global_min is not None else (lambda x: x)

    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, state["dist"], _INF)

    def update_fn(state, acc, frontier, it):
        dist, pending, bound = state["dist"], state["pending"], state["bound"]
        relaxed = acc < dist
        dist = jnp.minimum(dist, acc)
        pending = (pending & (frontier == 0)) | relaxed
        in_bucket = pending & (dist <= bound)
        minpend = gmin(jnp.min(jnp.where(pending, dist, _INF)))
        # global-min of (0 if in bucket else 1) is 0 iff ANY shard has one
        bucket_empty = gmin(jnp.min(jnp.where(in_bucket, 0.0, 1.0))) > 0.5
        bound = jnp.where(jnp.isfinite(minpend) & bucket_empty,
                          minpend + delta, bound)
        new_frontier = pending & (dist <= bound)
        return ({"dist": dist, "pending": pending, "bound": bound},
                new_frontier.astype(jnp.int32))

    return engine.VertexProgram(edge_op="add", combine="min",
                                msg_fn=msg_fn, update_fn=update_fn)


def auto_delta(csr: CSR, *, bins: int = 64, light_edges_per_vertex: float = 4.0,
               scaled: bool = True) -> float:
    """Delta from the weight histogram (DESIGN.md §8).

    Pick delta at the weight quantile where the expected number of sub-delta
    ("light") edges per vertex reaches ``light_edges_per_vertex``:
    delta ≈ quantile(w, q) with q = min(1, target / avg_degree), read off a
    ``bins``-bin histogram CDF (the histogram, not a full sort, is what a
    PIUMA-side autotuner would keep as a running statistic).  Small targets
    degenerate toward Dijkstra's serial bucket order (many near-empty
    expansions); very large ones re-relax heavy chains Bellman-Ford-style.
    The default target 4.0 comes from the `bench_engine.py --sweep-delta`
    sweep on RMAT and uniform-weight graphs (DESIGN.md §8): on this
    bulk-synchronous engine, iteration count dominates, and the 4-light-edge
    quantile sits within ~10% of the best fixed delta on both graph classes
    while keeping the bucket discipline that bounds re-relaxation work.

    scaled: multiply the histogram quantile by the tuned ``sssp.delta_scale``
    for this backend and graph scale (``repro.tune``, DESIGN.md §18) — the
    autotuner sweeps the multiplier by measured iteration count and passes
    ``scaled=False`` to read the raw quantile it scales.  Unweighted (and
    empty) graphs always return exactly 1.0: unit-weight distances are
    integers, one BFS level per bucket, and there is no quantile to scale.
    """
    if csr.values is None:
        return 1.0
    w = np.asarray(csr.values)
    if w.size == 0:
        return 1.0
    from ... import tune
    mul = (tune.resolve("sssp.delta_scale", n=csr.n_rows) if scaled else 1.0)
    avg_deg = max(1.0, csr.nnz / max(1, csr.n_rows))
    hist, edges = np.histogram(w, bins=bins)
    cdf = np.cumsum(hist) / max(1, w.size)
    q = min(1.0, light_edges_per_vertex / avg_deg)
    return float(max(edges[min(int(np.searchsorted(cdf, q)) + 1,
                               len(edges) - 1)], 1e-6)) * mul


def sssp(csr: CSR, source: int, *, delta: Optional[float] = None,
         max_iters: Optional[int] = None, mode: str = "auto",
         return_stats: bool = False, trace: bool = False,
         trace_len: Optional[int] = None):
    """Returns (n,) float32 distances; unreachable = +inf.

    delta: bucket width; None auto-tunes from the weight histogram
      (:func:`auto_delta`).
    trace: with return_stats, record the per-level engine trace into
      ``stats['trace']`` (obs.decode_level_trace reads it back).
    """
    n = csr.n_rows
    delta = delta if delta is not None else auto_delta(csr)
    max_iters = max_iters if max_iters is not None else 4 * n
    state0 = {
        "dist": jnp.full((n,), _INF).at[source].set(0.0),
        "pending": jnp.zeros((n,), bool).at[source].set(True),
        "bound": jnp.float32(delta),
    }
    frontier0 = jnp.zeros((n,), jnp.int32).at[source].set(1)
    out = engine.run(csr, sssp_program(delta), state0, frontier0,
                     max_iters=max_iters, mode=mode, return_stats=return_stats,
                     trace=trace, trace_len=trace_len)
    if return_stats:
        state, stats = out
        return state["dist"], stats
    return out["dist"]


def sssp_batched(csr: CSR, sources, *, delta: Optional[float] = None,
                 max_iters: Optional[int] = None, mode: str = "auto",
                 kernel_bb=None, return_stats: bool = False,
                 trace: bool = False, trace_len: Optional[int] = None):
    """Distances (B, n) float32 for B concurrent single-source runs.

    The *same* ``sssp_program`` drives every lane (the engine vmaps it), so
    row b is bit-identical to ``sssp(csr, sources[b], delta=delta)`` — each
    lane keeps its own bucket bound and drains independently while the
    (min, +) relaxations of all lanes ride one shared edge scan.  ``delta``
    must be shared across the batch (it is a graph-level constant under
    :func:`auto_delta` anyway — the service layer's compatibility rule).
    kernel_bb: optional weighted BBCSR of A^T (``engine.build_pull_operand``)
      to run the relaxations on the Pallas masked-select min combine.
    """
    n = csr.n_rows
    src = jnp.asarray(sources, jnp.int32)
    B = int(src.shape[0])
    delta = delta if delta is not None else auto_delta(csr)
    max_iters = max_iters if max_iters is not None else 4 * n
    lanes = jnp.arange(B)
    state0 = {
        "dist": jnp.full((B, n), _INF).at[lanes, src].set(0.0),
        "pending": jnp.zeros((B, n), bool).at[lanes, src].set(True),
        "bound": jnp.full((B,), delta, jnp.float32),
    }
    frontier0 = jnp.zeros((B, n), jnp.int32).at[lanes, src].set(1)
    out = engine.run_batched(csr, sssp_program(delta), state0, frontier0,
                             max_iters=max_iters, mode=mode,
                             kernel_bb=kernel_bb, return_stats=return_stats,
                             trace=trace, trace_len=trace_len)
    if return_stats:
        state, stats = out
        return state["dist"], stats
    return out["dist"]


def sssp_batched_distributed(g: ShardedGraph, att: ATT, sources, mesh: Mesh,
                             *, axis=None, delta: float = 1.0,
                             max_iters: int = 256,
                             return_stats: bool = False,
                             placement: str = "sync",
                             sync_interval: Optional[int] = None,
                             trace: bool = False,
                             trace_len: Optional[int] = None):
    """Batched distances stacked (S, B, per_shard) under `att`; slice
    ``[:, b, :]`` matches ``sssp_distributed(g, att, sources[b], mesh,
    delta=delta)`` — all B lanes' remote atomic-min relaxations share each
    level's compacted exchange, and the per-lane bucket bounds are agreed
    with one (lane-batched) collective min.  ``return_stats`` adds the
    engine's {'iters', 'pushes', 'pulls', 'fallbacks'} trace (the service
    layer's route-byte model input)."""
    axis = axis if axis is not None else mesh.axis_names[0]
    ax = axis if isinstance(axis, str) else tuple(axis)
    S, per = att.n_shards, att.per_shard
    src = jnp.asarray(sources, jnp.int32)
    B = int(src.shape[0])
    owner = att.owner(src)
    local = att.local(src)
    lanes = jnp.arange(B)
    # async: per-shard bucket pacing — each shard advances its own bound
    # from its local pending set (exactly the local engine's rule); the
    # (min, +) fixpoint is schedule-independent, so distances still match
    # the sync placement bit-for-bit while the two pmin collectives per
    # level disappear from the micro-stepped path.
    prog = sssp_program(delta) if placement == "async" else \
        sssp_program(delta, global_min=lambda x: lax.pmin(x, ax))
    state0 = {
        "dist": jnp.full((S, B, per), _INF).at[owner, lanes, local].set(0.0),
        "pending": jnp.zeros((S, B, per), bool).at[owner, lanes, local].set(True),
        "bound": jnp.full((S, B), delta, jnp.float32),
    }
    frontier0 = jnp.zeros((S, B, per), jnp.int32).at[owner, lanes, local].set(1)
    out = engine.run_batched_distributed(g, att, mesh, prog, state0,
                                         frontier0, axis=axis,
                                         max_iters=max_iters,
                                         return_stats=return_stats,
                                         placement=placement,
                                         sync_interval=sync_interval,
                                         trace=trace, trace_len=trace_len)
    if return_stats:
        state, stats = out
        return state["dist"], stats
    return out["dist"]


def sssp_distributed(g: ShardedGraph, att: ATT, source: int, mesh: Mesh, *,
                     axis=None, delta: float = 1.0, max_iters: int = 256,
                     placement: str = "sync",
                     sync_interval: Optional[int] = None) -> jnp.ndarray:
    """Distances stacked (S, per_shard) under `att`; remote atomic-min push.

    placement='async': bounded-staleness pacing with per-shard bucket
    bounds (local gmin — PIUMA's own per-block bucket model); the (min, +)
    fixpoint is schedule-independent so distances match 'sync' exactly.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    ax = axis if isinstance(axis, str) else tuple(axis)
    S, per = att.n_shards, att.per_shard
    owner = int(att.owner(jnp.asarray(source)))
    local = int(att.local(jnp.asarray(source)))

    prog = sssp_program(delta) if placement == "async" else \
        sssp_program(delta, global_min=lambda x: lax.pmin(x, ax))
    state0 = {
        "dist": jnp.full((S, per), _INF).at[owner, local].set(0.0),
        "pending": jnp.zeros((S, per), bool).at[owner, local].set(True),
        "bound": jnp.full((S, 1), delta, jnp.float32),
    }
    frontier0 = jnp.zeros((S, per), jnp.int32).at[owner, local].set(1)
    state = engine.run_distributed(g, att, mesh, prog, state0, frontier0,
                                   axis=axis, max_iters=max_iters, mode="push",
                                   placement=placement,
                                   sync_interval=sync_interval)
    return state["dist"]
