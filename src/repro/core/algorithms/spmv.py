"""SpMV — the paper's §V.A case study, in every PIUMA flavor.

Local versions:
  spmv        — fine-grained gather + owner-side reduction (paper's base loop)
  spmv_ell    — padded-row vectorized variant
  spmv_bbcsr  — the Pallas DMA-gather kernel (selective caching + SPAD), see
                kernels/spmv_dma.py

Distributed version (shard_map):
  spmv_distributed(mode="dgas")      — PIUMA: fine-grained remote gather of
                                       exactly the needed vector elements
  spmv_distributed(mode="allgather") — conventional baseline: replicate x
                                       (the "move whole cache lines" analogue)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..dgas import ATT
from ..graph import CSR, BBCSR
from .. import offload
from .distgraph import ShardedGraph

__all__ = ["spmv", "spmv_ell", "spmv_bbcsr", "spmv_distributed"]


def spmv(csr: CSR, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x via fine-grained gather + segment reduction."""
    vals = csr.values if csr.values is not None else jnp.ones_like(csr.indices, x.dtype)
    gathered = offload.dma_gather(x, csr.indices)
    contrib = vals * gathered
    return jax.ops.segment_sum(contrib, csr.row_ids(), num_segments=csr.n_rows)


def spmv_ell(cols: jnp.ndarray, vals: jnp.ndarray, mask: jnp.ndarray,
             x: jnp.ndarray) -> jnp.ndarray:
    """Padded-ELL SpMV: (n_rows, k) layout, one masked gather + row reduce."""
    gathered = offload.dma_gather(x, cols)
    return jnp.sum(jnp.where(mask, vals * gathered, 0.0), axis=1)


def spmv_bbcsr(bb: BBCSR, x: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    from ...kernels import ops as kops
    return kops.spmv_dma(bb, x, interpret=interpret)


# ---------------------------------------------------------------------------
# Distributed
# ---------------------------------------------------------------------------

def spmv_distributed(g: ShardedGraph, x_sharded: jnp.ndarray, x_att: ATT,
                     row_att: ATT, mesh: Mesh, *, axis=None,
                     mode: str = "dgas") -> jnp.ndarray:
    """y = A @ x with rows owned per `row_att` and x distributed per `x_att`.

    One pull step of the frontier engine: the row owner dgas-gathers exactly
    the x elements its nonzeros name ("dgas"), or takes the replicate-x
    baseline ("allgather").  Returns y stacked (S, per_shard) under `row_att`.
    """
    if mode not in ("dgas", "allgather"):
        raise KeyError(mode)
    from .. import engine
    return engine.spmv_pass(g, x_sharded, x_att, row_att, mesh, axis=axis,
                            mode=mode)
