"""SpMV — the paper's §V.A case study, in every PIUMA flavor.

Local versions:
  spmv        — fine-grained gather + owner-side reduction (paper's base loop)
  spmv_ell    — padded-row vectorized variant
  spmv_bbcsr  — the Pallas DMA-gather kernel (selective caching + SPAD), see
                kernels/spmv_dma.py

Distributed version (shard_map):
  spmv_distributed(mode="dgas")      — PIUMA: fine-grained remote gather of
                                       exactly the needed vector elements
  spmv_distributed(mode="allgather") — conventional baseline: replicate x
                                       (the "move whole cache lines" analogue)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..dgas import ATT, block_rule
from ..graph import CSR, BBCSR
from .. import offload
from .distgraph import ShardedGraph

__all__ = ["spmv", "spmv_ell", "spmv_bbcsr", "spmv_distributed"]


def spmv(csr: CSR, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x via fine-grained gather + segment reduction."""
    vals = csr.values if csr.values is not None else jnp.ones_like(csr.indices, x.dtype)
    gathered = offload.dma_gather(x, csr.indices)
    contrib = vals * gathered
    return jax.ops.segment_sum(contrib, csr.row_ids(), num_segments=csr.n_rows)


def spmv_ell(cols: jnp.ndarray, vals: jnp.ndarray, mask: jnp.ndarray,
             x: jnp.ndarray) -> jnp.ndarray:
    """Padded-ELL SpMV: (n_rows, k) layout, one masked gather + row reduce."""
    gathered = offload.dma_gather(x, cols)
    return jnp.sum(jnp.where(mask, vals * gathered, 0.0), axis=1)


def spmv_bbcsr(bb: BBCSR, x: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    from ...kernels import ops as kops
    return kops.spmv_dma(bb, x, interpret=interpret)


# ---------------------------------------------------------------------------
# Distributed
# ---------------------------------------------------------------------------

def _spmv_shard_dgas(src, dst, val, x_local, *, x_att: ATT, row_att: ATT, axis):
    src, dst, val, x_local = src[0], dst[0], val[0], x_local[0]
    xg = offload.dgas_gather(x_local, jnp.where(dst >= 0, dst, -1), x_att, axis,
                             capacity=dst.shape[0])
    contrib = jnp.where(src >= 0, val * xg, 0.0)
    local_rows = jnp.where(src >= 0, row_att.local(jnp.maximum(src, 0)), -1)
    y = jnp.zeros((row_att.per_shard,), x_local.dtype)
    return offload.dma_scatter_add(y, local_rows, contrib)[None]


def _spmv_shard_allgather(src, dst, val, x_local, *, x_att: ATT, row_att: ATT, axis):
    src, dst, val, x_local = src[0], dst[0], val[0], x_local[0]
    xg = offload.all_gather_gather(x_local, jnp.where(dst >= 0, dst, -1), x_att, axis)
    contrib = jnp.where(src >= 0, val * xg, 0.0)
    local_rows = jnp.where(src >= 0, row_att.local(jnp.maximum(src, 0)), -1)
    y = jnp.zeros((row_att.per_shard,), x_local.dtype)
    return offload.dma_scatter_add(y, local_rows, contrib)[None]


def spmv_distributed(g: ShardedGraph, x_sharded: jnp.ndarray, x_att: ATT,
                     row_att: ATT, mesh: Mesh, *, axis=None,
                     mode: str = "dgas") -> jnp.ndarray:
    """y = A @ x with rows owned per `row_att` and x distributed per `x_att`.

    Returns y stacked (S, per_shard) under `row_att` layout.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    fn = {"dgas": _spmv_shard_dgas, "allgather": _spmv_shard_allgather}[mode]
    fn = partial(fn, x_att=x_att, row_att=row_att, axis=axis)
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    mapped = shard_map(fn, mesh=mesh,
                       in_specs=(spec, spec, spec, spec), out_specs=spec)
    return mapped(g.src, g.dst, g.val, x_sharded)
