"""Community detection (paper: "Louvain Community", 41x / 555x).

Implemented as weighted label propagation — one-level Louvain local-move
sweeps: every vertex adopts the label with maximal incident edge weight.
The access pattern (gather all neighbor labels, weighted vote, atomic label
update) is exactly the remote-atomic-heavy loop the paper benchmarks; full
multi-level coarsening is out of scope (DESIGN.md §9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..graph import CSR, to_padded_ell
from .. import offload

__all__ = ["label_propagation", "modularity"]

_PAD = jnp.int32(2**30)


def _weighted_mode(labels: jnp.ndarray, weights: jnp.ndarray, fallback: jnp.ndarray):
    """Row-wise argmax_l sum(weights[labels==l]). labels padded with _PAD/w=0.

    (n, k) -> (n,). Ties break toward the smaller label (deterministic).
    """
    n, k = labels.shape
    order = jnp.argsort(labels, axis=1)
    sl = jnp.take_along_axis(labels, order, 1)
    sw = jnp.take_along_axis(weights, order, 1)
    is_start = jnp.concatenate(
        [jnp.ones((n, 1), bool), sl[:, 1:] != sl[:, :-1]], axis=1)
    run_id = jnp.cumsum(is_start, axis=1) - 1                     # (n,k) in [0,k)
    seg = (jnp.arange(n)[:, None] * k + run_id).reshape(-1)
    run_w = jax.ops.segment_sum(sw.reshape(-1), seg, num_segments=n * k).reshape(n, k)
    run_l = jnp.full((n * k,), _PAD, jnp.int32).at[seg].min(sl.reshape(-1)).reshape(n, k)
    run_w = jnp.where(run_l == _PAD, -1.0, run_w)
    best = jnp.argmax(run_w, axis=1)
    lab = jnp.take_along_axis(run_l, best[:, None], 1)[:, 0]
    has_any = jnp.max(run_w, axis=1) > 0
    return jnp.where(has_any, lab, fallback)


def label_propagation(csr: CSR, *, iters: int = 10,
                      max_deg: int | None = None) -> jnp.ndarray:
    """Returns (n,) int32 community labels."""
    cols, vals, mask = to_padded_ell(csr, max_deg)
    n = csr.n_rows

    def body(_, labels):
        nl = offload.dma_gather(labels, jnp.where(mask, cols, -1), fill=0)
        nl = jnp.where(mask, nl, _PAD).astype(jnp.int32)
        w = jnp.where(mask, vals, 0.0)
        return _weighted_mode(nl, w, labels)

    labels0 = jnp.arange(n, dtype=jnp.int32)
    return jax.lax.fori_loop(0, iters, body, labels0)


def modularity(csr: CSR, labels: jnp.ndarray) -> jnp.ndarray:
    """Newman modularity Q of a labeling (directed form)."""
    vals = csr.values if csr.values is not None else jnp.ones_like(csr.indices, jnp.float32)
    rows = csr.row_ids()
    m = jnp.sum(vals)
    same = (offload.dma_gather(labels, rows) == offload.dma_gather(labels, csr.indices))
    e_in = jnp.sum(jnp.where(same, vals, 0.0)) / m
    deg_out = jax.ops.segment_sum(vals, rows, num_segments=csr.n_rows)
    deg_in = jax.ops.segment_sum(vals, csr.indices, num_segments=csr.n_cols)
    c_out = jax.ops.segment_sum(deg_out, labels, num_segments=csr.n_rows)
    c_in = jax.ops.segment_sum(deg_in, labels, num_segments=csr.n_rows)
    return e_in - jnp.sum(c_out * c_in) / (m * m)
