"""Community detection (paper: "Louvain Community", 41x / 555x).

Implemented as weighted label propagation — one-level Louvain local-move
sweeps: every vertex adopts the label with maximal incident edge weight.
Since PR 2 the sweep is an engine program: the per-vertex weighted vote is
the engine's ``combine='argmax_weighted'`` structured combine (DESIGN.md §4),
so this module holds only the two-line message/update rules.  Votes come
from a vertex's *out*-neighbors, and the engine combines over in-edges, so
the program runs on the transposed adjacency.

Distributed, the votes are owner-routed raw and reduced at the destination
owner (`offload.remote_scatter_weighted_mode` — the remote-atomic-heavy loop
the paper benchmarks); full multi-level coarsening is out of scope
(DESIGN.md §9).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from .. import engine, offload
from ..dgas import ATT, block_rule
from ..graph import CSR
from .distgraph import shard_graph, shard_vertex_array

__all__ = ["label_propagation", "label_propagation_distributed",
           "lpa_program", "modularity"]


def lpa_program() -> engine.VertexProgram:
    """Weighted label propagation as an argmax-combine engine program.

    Messages are current labels; the edge value is the vote weight; the
    engine's structured combine returns (winning label's total weight,
    winning label) with ties toward the smaller label.  Vertices with no
    positive incident vote keep their label.  Every vertex stays active
    every sweep (classic synchronous LPA), so the frontier never drains and
    the engine runs exactly ``max_iters`` sweeps.
    """

    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, state["label"], -1)

    def update_fn(state, acc, frontier, it):
        best_w, best_l = acc
        label = jnp.where(best_w > 0, best_l, state["label"])
        return {"label": label}, jnp.ones_like(frontier)

    return engine.VertexProgram(edge_op="mul", combine="argmax_weighted",
                                msg_fn=msg_fn, update_fn=update_fn)


def label_propagation(csr: CSR, *, iters: int = 10,
                      mode: str = "pull") -> jnp.ndarray:
    """Returns (n,) int32 community labels.

    Defaults to mode='pull': the frontier is all-ones every sweep, so the
    sparse/push machinery (and its max-degree gather budget) would be dead
    weight under 'auto'.
    """
    n = csr.n_rows
    state0 = {"label": jnp.arange(n, dtype=jnp.int32)}
    frontier0 = jnp.ones((n,), jnp.int32)
    # votes flow out-neighbor -> voter: run the program over A^T's edges
    state = engine.run(csr.transpose(), lpa_program(), state0, frontier0,
                       max_iters=iters, mode=mode)
    return state["label"]


def label_propagation_distributed(csr: CSR, mesh: Mesh, *,
                                  att: Optional[ATT] = None, axis=None,
                                  iters: int = 10) -> jnp.ndarray:
    """Distributed LPA; labels returned stacked (S, per) under `att`.

    Shards the transposed edge list by vote-source owner and pushes each
    sweep through the engine: (voter, label, weight) triples are owner-routed
    and reduced with the remote weighted-mode combine at the voter's owner.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    names = [axis] if isinstance(axis, str) else list(axis)
    S = 1
    for a in names:
        S *= int(mesh.shape[a])
    att = att if att is not None else block_rule(csr.n_rows, S)
    g_t, _ = shard_graph(csr.transpose(), S, row_att=att)
    labels0 = shard_vertex_array(jnp.arange(csr.n_rows, dtype=jnp.int32), att)
    state0 = {"label": labels0}
    frontier0 = jnp.ones((S, att.per_shard), jnp.int32)
    # LPA's frontier is all-ones every sweep: compacted push would always
    # overflow and fall back, so disable it and skip the per-sweep check
    state = engine.run_distributed(g_t, att, mesh, lpa_program(), state0,
                                   frontier0, axis=axis, max_iters=iters,
                                   mode="push", push_edge_capacity=0)
    return state["label"]


def modularity(csr: CSR, labels: jnp.ndarray) -> jnp.ndarray:
    """Newman modularity Q of a labeling (directed form)."""
    vals = csr.values if csr.values is not None else jnp.ones_like(csr.indices, jnp.float32)
    rows = csr.row_ids()
    m = jnp.sum(vals)
    same = (offload.dma_gather(labels, rows) == offload.dma_gather(labels, csr.indices))
    e_in = jnp.sum(jnp.where(same, vals, 0.0)) / m
    deg_out = jax.ops.segment_sum(vals, rows, num_segments=csr.n_rows)
    deg_in = jax.ops.segment_sum(vals, csr.indices, num_segments=csr.n_cols)
    c_out = jax.ops.segment_sum(deg_out, labels, num_segments=csr.n_rows)
    c_in = jax.ops.segment_sum(deg_in, labels, num_segments=csr.n_rows)
    return e_in - jnp.sum(c_out * c_in) / (m * m)
