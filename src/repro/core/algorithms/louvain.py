"""Community detection (paper: "Louvain Community", 41x / 555x).

The local-move sweep is weighted label propagation: every vertex adopts the
label with maximal incident edge weight.  Since PR 2 the sweep is an engine
program: the per-vertex weighted vote is the engine's
``combine='argmax_weighted'`` structured combine (DESIGN.md §4), so this
module holds only the two-line message/update rules.  Votes come from a
vertex's *out*-neighbors, and the engine combines over in-edges, so the
program runs on the transposed adjacency.

Since PR 3 the sweeps compose into **multi-level Louvain** (DESIGN.md §11).
Raw LPA maximizes incident weight with no null-model penalty, which on
low-structure graphs merges past the modularity optimum — so the multilevel
local move splits the sweep in two: the engine's argmax combine still picks
each vertex's heaviest neighbor community, but only as a *candidate*
(:func:`louvain_candidate_program`), and a vectorized gain gate accepts the
move only when the exact directed-modularity delta is positive (and the
target label is smaller — synchronous moves with a strictly decreasing label
order cannot swap-cycle).  :func:`multilevel` runs gated sweeps until
modularity stalls, contracts the communities (`graph.contract` — supernodes,
intra-community weight into self-loops), and repeats on
`engine.run_multilevel`'s level pipeline, accepting a level only while
modularity keeps improving.  Distributed, the sweep keeps votes at the
voter's owner (edges are sharded by source), reads remote labels with
`dgas_gather`, accumulates in-side sums with the `remote_scatter_add` remote
atomic, modularity is a pair of psum'd segment reductions
(:func:`modularity_distributed`), and contraction reshards each level's
surviving coarse edges to their new owner with `RouteByteCounter` accounting
(:func:`contract_distributed`).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from ...compat import shard_map
from .. import engine, offload, traffic
from ..dgas import ATT, block_rule
from ..graph import CSR, contract
from .distgraph import (ShardedGraph, shard_graph, shard_vertex_array,
                        unshard_vertex_array)

__all__ = ["label_propagation", "label_propagation_distributed",
           "lpa_program", "louvain_candidate_program",
           "modularity", "modularity_distributed",
           "multilevel", "multilevel_distributed", "contract_distributed",
           "partition_equal"]


# trace-safe: host-side test/driver helper comparing two *concrete*
# labelings — repro-lint: disable=host-sync
def partition_equal(a, b) -> bool:
    """True iff two labelings induce the same partition (bijective label
    correspondence) — the equivalence the distributed drivers promise, since
    renumbering order is the only freedom they have."""
    m1, m2 = {}, {}
    for x, y in zip(np.asarray(a).tolist(), np.asarray(b).tolist()):
        if m1.setdefault(x, y) != y or m2.setdefault(y, x) != x:
            return False
    return True


def lpa_program() -> engine.VertexProgram:
    """Weighted label propagation as an argmax-combine engine program.

    Messages are current labels; the edge value is the vote weight; the
    engine's structured combine returns (winning label's total weight,
    winning label) with ties toward the smaller label.  Vertices with no
    positive incident vote keep their label.  Every vertex stays active
    every sweep (classic synchronous LPA), so the frontier never drains and
    the engine runs exactly ``max_iters`` sweeps.
    """

    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, state["label"], -1)

    def update_fn(state, acc, frontier, it):
        best_w, best_l = acc
        label = jnp.where(best_w > 0, best_l, state["label"])
        return {"label": label}, jnp.ones_like(frontier)

    return engine.VertexProgram(edge_op="mul", combine="argmax_weighted",
                                msg_fn=msg_fn, update_fn=update_fn)


def label_propagation(csr: CSR, *, iters: int = 10,
                      mode: str = "pull") -> jnp.ndarray:
    """Returns (n,) int32 community labels.

    Defaults to mode='pull': the frontier is all-ones every sweep, so the
    sparse/push machinery (and its max-degree gather budget) would be dead
    weight under 'auto'.
    """
    n = csr.n_rows
    state0 = {"label": jnp.arange(n, dtype=jnp.int32)}
    frontier0 = jnp.ones((n,), jnp.int32)
    # votes flow out-neighbor -> voter: run the program over A^T's edges
    state = engine.run(csr.transpose(), lpa_program(), state0, frontier0,
                       max_iters=iters, mode=mode)
    return state["label"]


def label_propagation_distributed(csr: CSR, mesh: Mesh, *,
                                  att: Optional[ATT] = None, axis=None,
                                  iters: int = 10) -> jnp.ndarray:
    """Distributed LPA; labels returned stacked (S, per) under `att`.

    Shards the transposed edge list by vote-source owner and pushes each
    sweep through the engine: (voter, label, weight) triples are owner-routed
    and reduced with the remote weighted-mode combine at the voter's owner.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    names = [axis] if isinstance(axis, str) else list(axis)
    S = 1
    for a in names:
        S *= int(mesh.shape[a])
    att = att if att is not None else block_rule(csr.n_rows, S)
    g_t, _ = shard_graph(csr.transpose(), S, row_att=att)
    labels0 = shard_vertex_array(jnp.arange(csr.n_rows, dtype=jnp.int32), att)
    state0 = {"label": labels0}
    frontier0 = jnp.ones((S, att.per_shard), jnp.int32)
    # LPA's frontier is all-ones every sweep: compacted push would always
    # overflow and fall back, so disable it and skip the per-sweep check
    state = engine.run_distributed(g_t, att, mesh, lpa_program(), state0,
                                   frontier0, axis=axis, max_iters=iters,
                                   mode="push", push_edge_capacity=0)
    return state["label"]


def modularity(csr: CSR, labels: jnp.ndarray) -> jnp.ndarray:
    """Newman modularity Q of a labeling (directed form)."""
    vals = csr.values if csr.values is not None else jnp.ones_like(csr.indices, jnp.float32)
    rows = csr.row_ids()
    m = jnp.sum(vals)
    same = (offload.dma_gather(labels, rows) == offload.dma_gather(labels, csr.indices))
    e_in = jnp.sum(jnp.where(same, vals, 0.0)) / m
    deg_out = jax.ops.segment_sum(vals, rows, num_segments=csr.n_rows)
    deg_in = jax.ops.segment_sum(vals, csr.indices, num_segments=csr.n_cols)
    c_out = jax.ops.segment_sum(deg_out, labels, num_segments=csr.n_rows)
    c_in = jax.ops.segment_sum(deg_in, labels, num_segments=csr.n_rows)
    return e_in - jnp.sum(c_out * c_in) / (m * m)


# Compiled shard_map callables are cached per structural signature: the
# multilevel drivers call these once per sweep, and re-tracing/compiling an
# identical program every sweep dominates wall clock on a forced-multi-device
# host.  The cache itself is the ExecutionCore's (`engine.cached_mapped`,
# DESIGN.md §14) — one keying scheme (mesh, axis, ATT semantics, structural
# signature) for the engine's distributed placements and these sweeps alike.
# `louvain._MAPPED_CACHE` resolves to the shared store (kept for §11 docs and
# tooling; lazy because engine is mid-import when this module loads).
def __getattr__(name):
    if name == "_MAPPED_CACHE":
        return engine._MAPPED_CACHE
    raise AttributeError(name)


def _cached_mapped(kind: str, mesh, axis, att: ATT, m: int, build):
    return engine.cached_mapped(
        (kind, engine._mesh_key(mesh), engine._axis_key(axis),
         engine._att_key(att), m), build)


def modularity_distributed(g: ShardedGraph, att: ATT, mesh: Mesh,
                           labels: jnp.ndarray, *, axis=None) -> jnp.ndarray:
    """Distributed Newman modularity (directed form), psum'd across shards.

    `g` is edge-sharded by source owner under `att` and `labels` is the
    stacked (S, per) vertex labeling (global label ids in [0, n)).  Each
    shard reads its sources' labels locally, fetches destination labels with
    the fine-grained `dgas_gather`, reduces its partial (intra-community
    weight, per-community out/in degree) sums, and three psums assemble the
    global quantities — every shard returns the same Q.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    axes = [axis] if isinstance(axis, str) else list(axis)
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    n = att.n_global
    m_edges = g.edges_per_shard

    def shard_fn(src, dst, val, lab):
        src, dst, val, lab = src[0], dst[0], val[0], lab[0]
        local_src = jnp.where(src >= 0, att.local(jnp.maximum(src, 0)), -1)
        lab_src = offload.dma_gather(lab, local_src, fill=-1)
        lab_dst = offload.dgas_gather(lab, jnp.where(src >= 0, dst, -1), att,
                                      axis, capacity=m_edges, fill=-1)
        valid = (src >= 0) & (lab_src >= 0) & (lab_dst >= 0)
        w = jnp.where(valid, val, 0.0)
        m_tot = offload.hierarchical_psum(jnp.sum(w), axes)
        e_in = offload.hierarchical_psum(
            jnp.sum(jnp.where(lab_src == lab_dst, w, 0.0)), axes)
        c_out = offload.dma_scatter_add(jnp.zeros((n,), jnp.float32),
                                        jnp.where(valid, lab_src, -1), w)
        c_in = offload.dma_scatter_add(jnp.zeros((n,), jnp.float32),
                                       jnp.where(valid, lab_dst, -1), w)
        c_out = offload.hierarchical_psum(c_out, axes)
        c_in = offload.hierarchical_psum(c_in, axes)
        q = e_in / m_tot - jnp.sum(c_out * c_in) / (m_tot * m_tot)
        return q[None]

    mapped = _cached_mapped(
        "modularity", mesh, axis, att, m_edges,
        lambda: jax.jit(shard_map(shard_fn, mesh=mesh, in_specs=(spec,) * 4,
                                  out_specs=spec, check_rep=False)))
    return mapped(g.src, g.dst, g.val, labels.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Multi-level Louvain (gain-gated local moves + contraction on the pipeline)
# ---------------------------------------------------------------------------

def louvain_candidate_program() -> engine.VertexProgram:
    """Record (not adopt) each vertex's heaviest neighbor-community candidate.

    Same ``argmax_weighted`` combine as :func:`lpa_program`, but the update
    *stores* the (weight, label) winner in the state instead of switching to
    it — the modularity gain gate outside the engine decides the move
    (phase 1 of true Louvain, DESIGN.md §11).  One recording pass per sweep:
    ``max_iters=1`` with a drained next frontier.
    """

    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, state["label"], -1)

    def update_fn(state, acc, frontier, it):
        cand_w, cand_l = acc
        return ({"label": state["label"], "cand_w": cand_w, "cand_l": cand_l},
                jnp.zeros_like(frontier))

    return engine.VertexProgram(edge_op="mul", combine="argmax_weighted",
                                msg_fn=msg_fn, update_fn=update_fn)


# trace-safe: pre-trace host prep on concrete graph structure, once per
# level (engine._dst_sorted_stream's pattern) — repro-lint: disable=host-sync
def _vote_transpose(csr: CSR) -> CSR:
    """A^T of the self-loop-free voting graph (host prep, once per level).

    Self-loops stay in the *level graph* (they carry contracted
    intra-community weight and feed modularity / degrees) but must not vote:
    a supernode's self-vote is the 'stay' option, whose gain is zero by
    definition in the gate."""
    rows = np.asarray(csr.row_ids())
    cols = np.asarray(csr.indices)
    vals = (np.asarray(csr.values) if csr.values is not None
            else np.ones_like(cols, np.float32))
    keep = rows != cols
    return CSR.from_coo(cols[keep], rows[keep], vals[keep],
                        csr.n_rows, csr.n_cols)


def _gate_moves(lab, cand_w, cand_l, w_in_b, w_out_same, w_in_same,
                kout, kin, out_c, in_c, w_tot, down_only):
    """The shared gain-gate tail (see :func:`_gain_gate` for the math).  The
    local and distributed sweeps both end here, which is what keeps their
    move decisions — and therefore `multilevel` vs `multilevel_distributed`
    partitions — in lock-step."""
    w_to_b = jnp.where(cand_l >= 0, cand_w, 0.0)
    b = jnp.where(cand_l >= 0, cand_l, lab)
    d_e = (w_to_b + w_in_b) - (w_out_same + w_in_same)
    d_pen = (kout * (jnp.take(in_c, b) - jnp.take(in_c, lab))
             + kin * (jnp.take(out_c, b) - jnp.take(out_c, lab))
             + 2.0 * kout * kin)
    dq = d_e / w_tot - d_pen / (w_tot * w_tot)
    # `down_only` is traced so one compiled sweep serves both parities
    move = (b != lab) & (dq > 0) & ((down_only == 0) | (b < lab))
    return jnp.where(move, b, lab)


def _gain_gate(csr: CSR, lab: jnp.ndarray, cand_w: jnp.ndarray,
               cand_l: jnp.ndarray, kout: jnp.ndarray, kin: jnp.ndarray,
               w_tot, down_only) -> jnp.ndarray:
    """Accept candidate moves whose exact directed-modularity delta is > 0.

    For v moving from community A to B the delta of
    ``Q = sum_c e_c/W - sum_c out_c*in_c/W^2`` is::

        d_e   = [w(v->B) + w(B->v)] - [w(v->A\\v) + w(A\\v->v)]
        d_pen = kout_v*(in_B - in_A) + kin_v*(out_B - out_A) + 2*kout_v*kin_v
        dQ    = d_e/W - d_pen/W^2

    (self-loops cancel — they stay intra-community either way; A's aggregates
    include v).  All terms are edge-parallel segment reductions; w(v->B) is
    the candidate's vote weight straight from the engine combine.

    ``down_only`` restricts moves to B < A: under a strictly decreasing
    label order, simultaneous (synchronous) moves cannot swap-cycle, so the
    sweep is safe without coloring.  The local-move phase alternates
    down-only sweeps with free ones (which can undo a down-move that walled
    a vertex off from its best community); free sweeps *can* oscillate, but
    the phase keeps a sweep only if measured modularity improves, so an
    oscillation is discarded rather than applied.
    """
    n = csr.n_rows
    rows, cols = csr.row_ids(), csr.indices
    vals = (csr.values if csr.values is not None
            else jnp.ones_like(cols, jnp.float32))
    ns = rows != cols
    lab_r, lab_c = jnp.take(lab, rows), jnp.take(lab, cols)
    same = ns & (lab_r == lab_c)
    w_same = jnp.where(same, vals, 0.0)
    w_out_same = jax.ops.segment_sum(w_same, rows, num_segments=n)
    w_in_same = jax.ops.segment_sum(w_same, cols, num_segments=n)
    bl_safe = jnp.where(cand_l >= 0, cand_l, -2)
    to_b = ns & (lab_r == jnp.take(bl_safe, cols))
    w_in_b = jax.ops.segment_sum(jnp.where(to_b, vals, 0.0), cols,
                                 num_segments=n)
    out_c = jax.ops.segment_sum(kout, lab, num_segments=n)
    in_c = jax.ops.segment_sum(kin, lab, num_segments=n)
    return _gate_moves(lab, cand_w, cand_l, w_in_b, w_out_same, w_in_same,
                       kout, kin, out_c, in_c, w_tot, down_only)


@jax.jit
def _sweep_jit(vote_t: CSR, csr: CSR, lab, kout, kin, w_tot, down_only):
    """One compiled local-move sweep.  Module-level (graphs ride in as pytree
    arguments, their shapes/aux as the jit cache key) so repeated multilevel
    runs over the same level shapes reuse the compilation."""
    n = csr.n_rows
    state0 = {"label": lab, "cand_w": jnp.zeros((n,), jnp.float32),
              "cand_l": jnp.full((n,), -1, jnp.int32)}
    st = engine.run(vote_t, louvain_candidate_program(), state0,
                    jnp.ones((n,), jnp.int32), max_iters=1, mode="pull")
    return _gain_gate(csr, lab, st["cand_w"], st["cand_l"], kout, kin,
                      w_tot, down_only)


# trace-safe: deliberately host-driven — accept/stall control flow needs the
# score on host each step — repro-lint: disable=host-sync
def _hill_climb(step_fn, score_fn, x0, q0, max_steps: int, tol: float):
    """Greedy improving-only loop shared by the local and distributed sweep
    phases: ``step_fn(x, s)`` proposes, ``score_fn(cand)`` measures, a
    proposal is kept only if it improves by more than ``tol``, and the climb
    stops once two proposals in a row fail (the sweeps alternate down-only /
    free parity, so both must stall).  Returns ``(x, best_score)``."""
    x, q_best, stale = x0, q0, 0
    for s in range(max_steps):
        cand = step_fn(x, s)
        q = float(score_fn(cand))
        if np.isfinite(q) and q > q_best + tol:
            x, q_best, stale = cand, q, 0
        else:
            stale += 1
            if stale >= 2:
                break
    return x, q_best


# trace-safe: host driver around jitted sweeps (see _hill_climb) —
# repro-lint: disable=host-sync
def louvain_local_moves(csr: CSR, *, max_sweeps: int = 30,
                        sweep_tol: float = 1e-6):
    """Louvain phase 1 on one (coarse) graph: gain-gated local moves until
    modularity stalls.

    Each sweep runs the engine candidate program (one argmax-combine pass on
    the voting transpose) and the :func:`_gain_gate` — even sweeps down-only,
    odd sweeps free; the :func:`_hill_climb` keeps a sweep only if it
    improves :func:`modularity` by more than ``sweep_tol``, so the phase is
    a monotone climb from the singleton labeling.  Returns ``(labels, q)``.
    """
    n = csr.n_rows
    vote_t = _vote_transpose(csr)
    rows, cols = csr.row_ids(), csr.indices
    vals = (csr.values if csr.values is not None
            else jnp.ones_like(cols, jnp.float32))
    kout = jax.ops.segment_sum(vals, rows, num_segments=n)
    kin = jax.ops.segment_sum(vals, cols, num_segments=n)
    w_tot = jnp.sum(vals)

    lab0 = jnp.arange(n, dtype=jnp.int32)
    return _hill_climb(
        lambda lab, s: _sweep_jit(vote_t, csr, lab, kout, kin, w_tot,
                                  jnp.int32(s % 2 == 0)),
        lambda lab: modularity(csr, lab),
        lab0, float(modularity(csr, lab0)), max_sweeps, sweep_tol)


def multilevel(csr: CSR, *, max_levels: int = 10, max_sweeps: int = 30,
               tol: float = 1e-4, sweep_tol: float = 1e-6):
    """Multi-level Louvain: gain-gated engine sweeps + community contraction
    until modularity stalls.

    Each level runs :func:`louvain_local_moves` on the current (coarse)
    graph, contracts the resulting communities with `graph.contract` and
    scores the assignment with :func:`modularity` — which contraction leaves
    invariant, so a level's score *is* the level-0 modularity of the
    projected labels.  `engine.run_multilevel` owns the loop and the stall
    criterion (a level is kept only if it improves Q by more than ``tol``),
    so the returned score trace is strictly increasing.

    Returns ``(labels, scores)``: the (n,) int32 community labels on the
    original graph and the accepted levels' modularity trace.
    """

    def level_fn(g, level):
        return louvain_local_moves(g, max_sweeps=max_sweeps,
                                   sweep_tol=sweep_tol)[0]

    labels, _, scores = engine.run_multilevel(
        csr, level_fn, contract, modularity, max_levels=max_levels, tol=tol)
    return labels, scores


# trace-safe: host-driven between-levels contraction — coarse shapes are
# data-dependent, so the readbacks are the point — repro-lint: disable=host-sync
def contract_distributed(g: ShardedGraph, att: ATT, labels, *,
                         counter: Optional[traffic.RouteByteCounter] = None):
    """Contract an edge-sharded graph along a global labeling, routing each
    surviving coarse edge to its new owner shard.

    Per shard: relabel the owned edge partition ((u, v, w) ->
    (label[u], label[v], w)) and pre-reduce duplicate coarse pairs locally
    (the sender-side segment combine), then ship every pre-reduced edge whose
    coarse source falls under a *different* owner in the coarse block rule —
    only those cross the network, and ``counter.contract_level`` charges them
    at `traffic.CONTRACT_PAYLOAD_BYTES` apiece.  The repartition itself is
    host work (coarse shapes are data-dependent), like `shard_graph`.

    Returns ``(coarse_csr, coarse_g, coarse_att, renumber, n_routed)``.
    """
    S = g.n_shards
    lab = jnp.asarray(labels).astype(jnp.int32)
    dense_dev, n_c_dev = offload.compact_labels(lab)
    dense = np.asarray(dense_dev)
    n_c = int(n_c_dev)
    coarse_att = block_rule(n_c, S)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    val = np.asarray(g.val)
    n_routed = 0
    parts = []
    for s in range(S):
        live = src[s] >= 0
        csrc, cdst = dense[src[s][live]], dense[dst[s][live]]
        w = val[s][live]
        # sender-side pre-reduction: one summed weight per coarse pair
        key = csrc.astype(np.int64) * n_c + cdst
        uniq, inv = np.unique(key, return_inverse=True)
        w_red = np.bincount(inv, weights=w, minlength=uniq.size)
        usrc = (uniq // n_c).astype(np.int64)
        udst = (uniq % n_c).astype(np.int64)
        new_owner = np.asarray(coarse_att.owner(jnp.asarray(usrc)))
        n_routed += int((new_owner != s).sum())
        parts.append((usrc, udst, w_red))
    if counter is not None:
        counter.contract_level(n_routed)
    rows = np.concatenate([p[0] for p in parts])
    cols = np.concatenate([p[1] for p in parts])
    vals = np.concatenate([p[2] for p in parts]).astype(np.float32)
    coarse = CSR.from_coo(rows, cols, vals, n_c, n_c, sum_duplicates=True)
    coarse_g, _ = shard_graph(coarse, S, row_att=coarse_att)
    return coarse, coarse_g, coarse_att, dense_dev, n_routed


def _louvain_sweep_distributed(g: ShardedGraph, att: ATT, mesh: Mesh,
                               labels: jnp.ndarray, kout: jnp.ndarray,
                               kin: jnp.ndarray, w_tot: jnp.ndarray, *,
                               axis=None,
                               down_only: bool = True) -> jnp.ndarray:
    """One distributed gain-gated local-move sweep; labels stacked (S, per).

    Edges are sharded by *voter* (source) owner, so the candidate vote is a
    local :func:`offload.segment_weighted_mode` — only the label reads cross
    the network (`dgas_gather`) and the in-side weight sums return via the
    `remote_scatter_add` remote atomic; the community aggregates (out_c,
    in_c) are psum-replicated.  The level-invariant degree operands ride in
    pre-sharded (``kout``/``kin`` stacked (S, per), ``w_tot`` (S,)) so the
    sweep loop does not re-route them every sweep.  Same gate and
    ``down_only`` move order as the local :func:`_gain_gate`, so the sweep
    is value-equivalent shard count aside.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    axes = [axis] if isinstance(axis, str) else list(axis)
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    n = att.n_global
    per = att.per_shard
    m = g.edges_per_shard

    def shard_fn(src, dst, val, lab, down, kout, kin, w_tot):
        src, dst, val, lab = src[0], dst[0], val[0], lab[0]
        down, kout, kin, w_tot = down[0], kout[0], kin[0], w_tot[0]
        live = src >= 0
        local_src = jnp.where(live, att.local(jnp.maximum(src, 0)), -1)
        lab_src = offload.dma_gather(lab, local_src, fill=-1)
        gdst = jnp.where(live, dst, -1)
        lab_dst = offload.dgas_gather(lab, gdst, att, axis, capacity=m,
                                      fill=-1)
        ns = live & (src != dst)
        # candidate: heaviest neighbor community, reduced at the voter
        cand_w, cand_l = offload.segment_weighted_mode(
            jnp.where(ns, local_src, -1), lab_dst, val, per)
        same = ns & (lab_src == lab_dst)
        w_same = jnp.where(same, val, 0.0)
        zeros = jnp.zeros((per,), jnp.float32)
        w_out_same = offload.dma_scatter_add(
            zeros, jnp.where(same, local_src, -1), w_same)
        w_in_same = offload.remote_scatter_add(
            zeros, jnp.where(same, dst, -1), w_same, att, axis, capacity=m)
        cl_dst = offload.dgas_gather(cand_l, gdst, att, axis, capacity=m,
                                     fill=-2)
        to_b = ns & (lab_src == cl_dst)
        w_in_b = offload.remote_scatter_add(
            zeros, jnp.where(to_b, dst, -1), jnp.where(to_b, val, 0.0),
            att, axis, capacity=m)
        out_c = offload.hierarchical_psum(
            offload.dma_scatter_add(jnp.zeros((n,), jnp.float32), lab, kout),
            axes)
        in_c = offload.hierarchical_psum(
            offload.dma_scatter_add(jnp.zeros((n,), jnp.float32), lab, kin),
            axes)
        return _gate_moves(lab, cand_w, cand_l, w_in_b, w_out_same,
                           w_in_same, kout, kin, out_c, in_c, w_tot,
                           down)[None]

    mapped = _cached_mapped(
        "sweep", mesh, axis, att, m,
        lambda: jax.jit(shard_map(shard_fn, mesh=mesh, in_specs=(spec,) * 8,
                                  out_specs=spec, check_rep=False)))
    down = jnp.full((att.n_shards,), int(down_only), jnp.int32)
    return mapped(g.src, g.dst, g.val, labels.astype(jnp.int32), down,
                  kout, kin, w_tot)


# trace-safe: host-driven level pipeline (engine.run_multilevel's shape) —
# per-level shapes depend on readbacks — repro-lint: disable=host-sync
def multilevel_distributed(csr: CSR, mesh: Mesh, *, axis=None,
                           max_levels: int = 10, max_sweeps: int = 30,
                           tol: float = 1e-4, sweep_tol: float = 1e-6,
                           counter: Optional[traffic.RouteByteCounter] = None):
    """Distributed multi-level Louvain: `engine.run_multilevel`'s exact level
    pipeline with every stage a sharded closure.

    ``level_fn`` is the :func:`_hill_climb` over
    :func:`_louvain_sweep_distributed` scored by
    :func:`modularity_distributed`; ``contract_fn`` is
    :func:`contract_distributed` (installing the coarse shards for the next
    level and charging `counter` with the routed edges); ``score_fn`` is the
    psum'd modularity.  Because the loop, gate and stall rules are literally
    the single-device ones, the result matches :func:`multilevel` labels
    (same partition; float reduction order is the only freedom).

    Returns ``(labels, scores)`` with global (n,) labels on the input graph.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    names = [axis] if isinstance(axis, str) else list(axis)
    S = 1
    for a in names:
        S *= int(mesh.shape[a])

    cur = {}

    def prepare(g):
        if cur.get("g") is not g:
            att = block_rule(g.n_rows, S)
            gsh, _ = shard_graph(g, S, row_att=att)
            cur.update(g=g, att=att, gsh=gsh)

    def score_fn(g, labels):
        prepare(g)
        lab_sh = shard_vertex_array(np.asarray(labels), cur["att"])
        return float(np.asarray(modularity_distributed(
            cur["gsh"], cur["att"], mesh, lab_sh, axis=axis))[0])

    def level_fn(g, level):
        prepare(g)
        gsh, att = cur["gsh"], cur["att"]
        # level-invariant degree operands, hoisted out of the sweep loop
        rows, cols = g.row_ids(), g.indices
        vals = (g.values if g.values is not None
                else jnp.ones_like(cols, jnp.float32))
        kout = shard_vertex_array(np.asarray(
            jax.ops.segment_sum(vals, rows, num_segments=g.n_rows)), att)
        kin = shard_vertex_array(np.asarray(
            jax.ops.segment_sum(vals, cols, num_segments=g.n_rows)), att)
        w_tot = jnp.full((S,), float(jnp.sum(vals)), jnp.float32)
        lab0 = shard_vertex_array(np.arange(g.n_rows, dtype=np.int32), att)
        lab_sh, _ = _hill_climb(
            lambda lab, s: _louvain_sweep_distributed(
                gsh, att, mesh, lab, kout, kin, w_tot, axis=axis,
                down_only=s % 2 == 0),
            lambda lab: np.asarray(modularity_distributed(
                gsh, att, mesh, lab, axis=axis))[0],
            lab0,
            float(np.asarray(modularity_distributed(
                gsh, att, mesh, lab0, axis=axis))[0]),
            max_sweeps, sweep_tol)
        return unshard_vertex_array(lab_sh, att)

    def contract_fn(g, assign):
        prepare(g)
        coarse, gsh, att, renumber, _ = contract_distributed(
            cur["gsh"], cur["att"], jnp.asarray(assign), counter=counter)
        cur.update(g=coarse, att=att, gsh=gsh)
        return coarse, renumber

    labels, _, scores = engine.run_multilevel(
        csr, level_fn, contract_fn, score_fn, max_levels=max_levels, tol=tol)
    return labels, scores
