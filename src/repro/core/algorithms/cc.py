"""Connected components via min-label propagation, on the frontier engine.

Every vertex starts labeled with its own id; active vertices broadcast their
label and destinations keep the minimum — the (min, copy) instance of the
engine's semiring.  A vertex whose label shrinks re-enters the frontier, so
work decays to the slowly-converging boundary vertices exactly where the
direction-optimizing switch pays off (dense first sweeps, sparse tail).

Components are defined on the *undirected* structure; by default the input
is symmetrized host-side (A + A^T pattern).  Distributed, the label pushes
are PIUMA remote atomic *min* ops at the destination owner, and the caller
is expected to hand in an already-symmetric sharded graph.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .. import engine
from ..dgas import ATT
from ..graph import CSR
from .distgraph import ShardedGraph

__all__ = ["connected_components", "connected_components_distributed",
           "cc_program", "symmetrize"]

_PAD_LABEL = 2 ** 30


def symmetrize(csr: CSR) -> CSR:
    """Host-side A + A^T pattern (unweighted)."""
    indptr = np.asarray(csr.indptr)
    rows = np.repeat(np.arange(csr.n_rows), np.diff(indptr))
    cols = np.asarray(csr.indices)
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    return CSR.from_coo(r, c, None, csr.n_rows, csr.n_cols,
                        sum_duplicates=True)


def cc_program() -> engine.VertexProgram:
    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, state["label"],
                         jnp.int32(_PAD_LABEL))

    def update_fn(state, acc, frontier, it):
        label = state["label"]
        changed = acc < label
        return ({"label": jnp.minimum(label, acc)},
                changed.astype(jnp.int32))

    return engine.VertexProgram(edge_op="copy", combine="min",
                                msg_fn=msg_fn, update_fn=update_fn,
                                identity=_PAD_LABEL)


def connected_components(csr: CSR, *, max_iters: Optional[int] = None,
                         symmetrize_input: bool = True,
                         mode: str = "auto", return_stats: bool = False):
    """Returns (n,) int32 — each vertex's component id (its min member id).
    ``return_stats`` adds the ExecutionCore's {'iters', 'pushes', 'pulls'}
    direction trace (dense first sweeps, sparse boundary tail)."""
    g = symmetrize(csr) if symmetrize_input else csr
    n = g.n_rows
    max_iters = max_iters if max_iters is not None else n
    state0 = {"label": jnp.arange(n, dtype=jnp.int32)}
    frontier0 = jnp.ones((n,), jnp.int32)
    out = engine.run(g, cc_program(), state0, frontier0,
                     max_iters=max_iters, mode=mode,
                     return_stats=return_stats)
    if return_stats:
        state, stats = out
        return state["label"], stats
    return out["label"]


def connected_components_distributed(g: ShardedGraph, att: ATT, mesh: Mesh, *,
                                     axis=None, max_iters: int = 256,
                                     placement: str = "sync",
                                     sync_interval: Optional[int] = None
                                     ) -> jnp.ndarray:
    """Labels stacked (S, per_shard) under `att`.  `g` must already hold the
    symmetric edge set (build from `symmetrize(csr)`).

    The min-label program is monotone, so placement='async' (bounded-
    staleness pacing, `sync_interval` local sweeps per global check) reaches
    the identical label fixpoint with no program changes.
    """
    S, per = att.n_shards, att.per_shard
    shards = jnp.arange(S, dtype=jnp.int32)[:, None]
    locals_ = jnp.arange(per, dtype=jnp.int32)[None, :]
    gids = att.to_global(shards, locals_).astype(jnp.int32)  # (S, per)
    state0 = {"label": gids}
    frontier0 = jnp.ones((S, per), jnp.int32)
    state = engine.run_distributed(g, att, mesh, cc_program(), state0,
                                   frontier0, axis=axis, max_iters=max_iters,
                                   mode="push", placement=placement,
                                   sync_interval=sync_interval)
    return state["label"]
