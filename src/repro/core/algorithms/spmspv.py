"""SpMSpV: sparse-matrix x sparse-vector (paper Table II: 111x / 1387x).

y = A^T x_s for a sparse input vector x_s = {(id_i, val_i)}.  Work is
proportional to the edges of *active* vertices only, so the whole benefit
comes from fine-grained row gathers + scatter-adds (no dense pass).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..graph import CSR, to_padded_ell
from .. import offload

__all__ = ["spmspv", "spmspv_ell"]


def spmspv(csr: CSR, ids: jnp.ndarray, vals: jnp.ndarray, *,
           max_deg: int | None = None) -> jnp.ndarray:
    """Dense output y (n_cols,). `ids` padded with -1, `vals` 0 on padding.

    Gathers each active row's (padded) adjacency and scatter-adds
    contributions — O(nnz(active rows)) fine-grained traffic.
    """
    # per-active-row slices out of CSR, padded to k
    k = int(max_deg if max_deg is not None else jnp.max(csr.degrees()))
    safe_ids = jnp.maximum(ids, 0)
    start = offload.dma_gather(csr.indptr, safe_ids)
    deg = offload.dma_gather(csr.indptr, safe_ids + 1) - start
    offs = start[:, None] + jnp.arange(k)[None, :]
    valid = (jnp.arange(k)[None, :] < deg[:, None]) & (ids >= 0)[:, None]
    cols = offload.dma_gather(csr.indices, jnp.where(valid, offs, -1))
    mvals = (offload.dma_gather(csr.values, jnp.where(valid, offs, -1))
             if csr.values is not None else jnp.where(valid, 1.0, 0.0))
    contrib = mvals * vals[:, None]
    y = jnp.zeros((csr.n_cols,), jnp.float32)
    return offload.dma_scatter_add(y, jnp.where(valid, cols, -1), contrib)


def spmspv_ell(ell_cols: jnp.ndarray, ell_vals: jnp.ndarray, ell_mask: jnp.ndarray,
               n_cols: int, ids: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Same, but from a prebuilt padded-ELL matrix (kernel-friendly layout)."""
    safe = jnp.maximum(ids, 0)
    cols = offload.dma_gather(ell_cols, safe)            # (k_active, k)
    mv = offload.dma_gather(ell_vals, safe)
    mask = offload.dma_gather(ell_mask, safe) & (ids >= 0)[:, None]
    contrib = jnp.where(mask, mv * vals[:, None], 0.0)
    y = jnp.zeros((n_cols,), jnp.float32)
    return offload.dma_scatter_add(y, jnp.where(mask, cols, -1), contrib)
