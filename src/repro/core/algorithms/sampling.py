"""Graph sampling: TIES (paper Table II) and layered neighbor sampling (GNN).

TIES = Totally Induced Edge Sampling (Ahmed et al.): sample edges uniformly,
keep the induced subgraph on their endpoints.

`neighbor_sample` is the GraphSAGE-style layered fanout sampler required by
the `minibatch_lg` GNN shape — with-replacement sampling straight out of CSR.
Each layer is one `engine.sample_neighbors` pass (the push-compacted
``combine='sample'`` step: DMA-gathered adjacency rows + a keyed reservoir
pick per query slot, a PIUMA fine-grained pattern); this module keeps only
the layered fanout shape.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import CSR
from .. import engine, offload

__all__ = ["ties_sample", "neighbor_sample", "neighbor_sample_np"]


def ties_sample(csr: CSR, n_edges_sample: int, max_nodes: int, key: jax.Array):
    """Returns (node_set (max_nodes,) padded with -1, n_nodes, induced_edge_mask (nnz,))."""
    nnz = int(csr.indices.shape[0])
    rows = csr.row_ids()
    eids = jax.random.randint(key, (n_edges_sample,), 0, nnz)
    srcs = offload.dma_gather(rows, eids)
    dsts = offload.dma_gather(csr.indices, eids)
    cand = jnp.concatenate([srcs, dsts]).astype(jnp.int32)
    cand = jnp.sort(cand)
    keep = jnp.concatenate([jnp.array([True]), cand[1:] != cand[:-1]])
    # compact unique ids to a prefix, pad with -1
    order = jnp.argsort(~keep, stable=True)
    uniq = jnp.where(jnp.arange(cand.shape[0]) < keep.sum(),
                     jnp.take(cand, order), -1)
    n_nodes = keep.sum()
    node_set = uniq[:max_nodes]
    # induced edges: both endpoints in the (sorted-prefix) node set
    sorted_set = jnp.sort(jnp.where(node_set >= 0, node_set, jnp.int32(2**30)))

    def member(v):
        pos = jnp.searchsorted(sorted_set, v)
        pos = jnp.clip(pos, 0, max_nodes - 1)
        return jnp.take(sorted_set, pos) == v

    mask = member(rows) & member(csr.indices)
    return node_set, jnp.minimum(n_nodes, max_nodes), mask


def neighbor_sample(csr: CSR, seeds: jnp.ndarray, fanouts: Sequence[int],
                    key: jax.Array):
    """Layered with-replacement fanout sampling.

    Returns a list of node-id arrays: [seeds (B,), (B,f1), (B,f1,f2), ...].
    Sink nodes self-sample (id repeated), keeping shapes static.  Each layer
    replicates every query f times — one independent reservoir slot per draw —
    and runs one engine sampling step over the flattened slots.
    """
    layers = [seeds.astype(jnp.int32)]
    cur = seeds.astype(jnp.int32)
    for f in fanouts:
        key, sub = jax.random.split(key)
        flat = jnp.repeat(cur.reshape(-1), f)
        nbr = engine.sample_neighbors(csr, flat, sub)
        nxt = nbr.reshape(cur.shape + (f,))
        layers.append(nxt)
        cur = nxt
    return layers


def neighbor_sample_np(indptr: np.ndarray, indices: np.ndarray, seeds: np.ndarray,
                       fanouts: Sequence[int], rng: np.random.Generator):
    """Host-side (data pipeline) version of neighbor_sample."""
    layers = [seeds.astype(np.int32)]
    cur = seeds.astype(np.int64)
    for f in fanouts:
        flat = cur.reshape(-1)
        start = indptr[flat]
        deg = indptr[flat + 1] - start
        r = rng.integers(0, 1 << 30, (flat.shape[0], f))
        off = start[:, None] + r % np.maximum(deg, 1)[:, None]
        nbr = indices[np.minimum(off, indices.shape[0] - 1)]
        nbr = np.where(deg[:, None] > 0, nbr, flat[:, None])
        nxt = nbr.reshape(cur.shape + (f,)).astype(np.int32)
        layers.append(nxt)
        cur = nxt
    return layers
