"""PageRank — push-style along out-edges (the paper's Fig. 1 motivating pattern).

Local: power iteration with fine-grained scatter-adds.
Distributed: every push is a PIUMA *remote atomic add* at the owner of the
destination vertex (`offload.remote_scatter_add`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..dgas import ATT
from ..graph import CSR
from .. import offload
from .distgraph import ShardedGraph

__all__ = ["pagerank", "pagerank_distributed"]


def pagerank(csr: CSR, *, damping: float = 0.85, iters: int = 20) -> jnp.ndarray:
    n = csr.n_rows
    deg = csr.degrees().astype(jnp.float32)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)
    rows = csr.row_ids()
    cols = csr.indices

    def body(_, x):
        push = offload.dma_gather(x * inv_deg, rows)          # value each edge carries
        y = jax.ops.segment_sum(push, cols, num_segments=n)    # scatter-add at dst
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))         # redistribute sinks
        return (1 - damping) / n + damping * (y + dangling / n)

    x0 = jnp.full((n,), 1.0 / n, jnp.float32)
    return jax.lax.fori_loop(0, iters, body, x0)


def _pr_shard(src, dst, val, x, inv_deg, deg, *, att: ATT, damping, axis):
    src, dst, x, inv_deg, deg = src[0], dst[0], x[0], inv_deg[0], deg[0]
    n = att.n_global
    local_src = jnp.where(src >= 0, att.local(jnp.maximum(src, 0)), 0)
    push = jnp.where(src >= 0, offload.dma_gather(x * inv_deg, local_src), 0.0)
    y = jnp.zeros_like(x)
    # PIUMA remote atomic add at the dst owner
    y = offload.remote_scatter_add(y, jnp.where(src >= 0, dst, -1), push, att, axis,
                                   capacity=dst.shape[0])
    dangling = offload.hierarchical_psum(
        jnp.sum(jnp.where(deg > 0, 0.0, x)), [axis] if isinstance(axis, str) else list(axis))
    out = (1 - damping) / n + damping * (y + dangling / n)
    return out[None]


def pagerank_distributed(g: ShardedGraph, att: ATT, mesh: Mesh, *, axis=None,
                         damping: float = 0.85, iters: int = 20) -> jnp.ndarray:
    """x sharded by `att` (same rule owns vertex data and src rows).

    Returns stacked (S, per_shard) pagerank vector.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    n, S, per = att.n_global, att.n_shards, att.per_shard

    # degrees, sharded by att
    def _deg_shard(src, *, att, axis):
        d = jnp.zeros((att.per_shard,), jnp.float32)
        ones = jnp.where(src[0] >= 0, 1.0, 0.0)
        return offload.remote_scatter_add(d, src[0], ones, att, axis,
                                          capacity=src.shape[1])[None]

    deg = shard_map(partial(_deg_shard, att=att, axis=axis), mesh=mesh,
                    in_specs=(spec,), out_specs=spec)(g.src)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1.0), 0.0)

    step = shard_map(partial(_pr_shard, att=att, damping=damping, axis=axis),
                     mesh=mesh, in_specs=(spec,) * 6, out_specs=spec)

    # mask padded vertex slots out of the initial mass
    x = jnp.full((S, per), 1.0 / n, jnp.float32)
    # zero out padding slots (local ids beyond the shard's span)
    spans = jnp.asarray(
        [min(per, max(0, att.shard_slice(s)[1])) if att.kind != "interleave"
         else (n - s + S - 1) // S for s in range(S)], jnp.int32)
    x = jnp.where(jnp.arange(per)[None, :] < spans[:, None], x, 0.0)

    def body(_, x):
        return step(g.src, g.dst, g.val, x, inv_deg, deg)

    return jax.lax.fori_loop(0, iters, body, x)
