"""PageRank — push-style along out-edges (the paper's Fig. 1 motivating
pattern), as a dense-frontier :mod:`repro.core.engine` vertex program.

The frontier never shrinks (every vertex pushes mass every iteration), so the
engine runs the dense direction throughout; what PageRank gains from the
engine is the shared machinery: locally the edge-parallel segment reduction,
distributed the shard_map wiring with every push a PIUMA *remote atomic add*
at the owner of the destination vertex.
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from ...compat import shard_map
from .. import engine
from ..dgas import ATT
from ..graph import CSR
from .. import offload
from .distgraph import ShardedGraph

__all__ = ["pagerank", "pagerank_distributed", "ppr", "ppr_batched",
           "ppr_topk", "ppr_program"]


def pagerank(csr: CSR, *, damping: float = 0.85, iters: int = 20) -> jnp.ndarray:
    n = csr.n_rows
    deg = csr.degrees().astype(jnp.float32)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)

    def msg_fn(state, frontier):
        return state["x"] * inv_deg

    def update_fn(state, acc, frontier, it):
        x = state["x"]
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))  # redistribute sinks
        x = (1 - damping) / n + damping * (acc + dangling / n)
        return {"x": x}, frontier

    prog = engine.VertexProgram(edge_op="copy", combine="add",
                                msg_fn=msg_fn, update_fn=update_fn)
    state0 = {"x": jnp.full((n,), 1.0 / n, jnp.float32)}
    frontier0 = jnp.ones((n,), jnp.int32)
    return engine.run(csr, prog, state0, frontier0, max_iters=iters,
                      mode="pull")["x"]


def ppr_program(csr: CSR, damping: float) -> engine.VertexProgram:
    """Personalized PageRank: the restart vector rides in ``state['r']`` (so
    the batched engine's lane vmap personalizes it per source); dangling mass
    also restarts to r — the random surfer teleports home, not uniformly."""
    deg = csr.degrees().astype(jnp.float32)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1), 0.0)

    def msg_fn(state, frontier):
        return state["x"] * inv_deg

    def update_fn(state, acc, frontier, it):
        x, r = state["x"], state["r"]
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))
        x = (1 - damping) * r + damping * (acc + dangling * r)
        return {"x": x, "r": r}, frontier

    return engine.VertexProgram(edge_op="copy", combine="add",
                                msg_fn=msg_fn, update_fn=update_fn)


def ppr(csr: CSR, source: int, *, damping: float = 0.85,
        iters: int = 20) -> jnp.ndarray:
    """Personalized PageRank from one source; (n,) float32 scores."""
    n = csr.n_rows
    r = jnp.zeros((n,), jnp.float32).at[source].set(1.0)
    state0 = {"x": r, "r": r}
    frontier0 = jnp.ones((n,), jnp.int32)
    return engine.run(csr, ppr_program(csr, damping), state0, frontier0,
                      max_iters=iters, mode="pull")["x"]


def ppr_batched(csr: CSR, sources, *, damping: float = 0.85,
                iters: int = 20, return_stats: bool = False,
                trace: bool = False, trace_len=None):
    """Personalized PageRank for B sources in one engine pass; (B, n) f32.

    Row b is bit-identical to ``ppr(csr, sources[b])``: the vmapped lanes
    share each dense edge scan (PageRank never leaves the pull regime) but
    personalize the restart vector per lane via the state.  ``return_stats``
    adds the ExecutionCore's {'iters', 'pushes', 'pulls'} trace.
    """
    n = csr.n_rows
    src = jnp.asarray(sources, jnp.int32)
    B = int(src.shape[0])
    r = jnp.zeros((B, n), jnp.float32).at[jnp.arange(B), src].set(1.0)
    state0 = {"x": r, "r": r}
    frontier0 = jnp.ones((B, n), jnp.int32)
    out = engine.run_batched(csr, ppr_program(csr, damping), state0,
                             frontier0, max_iters=iters, mode="pull",
                             return_stats=return_stats,
                             trace=trace, trace_len=trace_len)
    if return_stats:
        state, stats = out
        return state["x"], stats
    return out["x"]


def ppr_topk(csr: CSR, sources, k: int, *, damping: float = 0.85,
             iters: int = 20,
             return_stats: bool = False,
             trace: bool = False, trace_len=None):
    """Top-k PPR per source: (scores (B, k), vertex ids (B, k)) — the
    service layer's PPR query shape.  ``return_stats`` appends the
    ExecutionCore's level trace (all pulls: PPR never leaves the dense
    regime), so the serving ledger can price PPR batches from the measured
    run like the traversal kinds."""
    out = ppr_batched(csr, sources, damping=damping, iters=iters,
                      return_stats=return_stats,
                      trace=trace, trace_len=trace_len)
    x, stats = out if return_stats else (out, None)
    vals, idx = lax.top_k(x, k)
    if return_stats:
        return vals, idx.astype(jnp.int32), stats
    return vals, idx.astype(jnp.int32)


def pagerank_distributed(g: ShardedGraph, att: ATT, mesh: Mesh, *, axis=None,
                         damping: float = 0.85, iters: int = 20) -> jnp.ndarray:
    """x sharded by `att` (same rule owns vertex data and src rows).

    Returns stacked (S, per_shard) pagerank vector.
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    n, S, per = att.n_global, att.n_shards, att.per_shard
    axes = [axis] if isinstance(axis, str) else list(axis)

    # degrees, sharded by att
    def _deg_shard(src, *, att, axis):
        d = jnp.zeros((att.per_shard,), jnp.float32)
        ones = jnp.where(src[0] >= 0, 1.0, 0.0)
        return offload.remote_scatter_add(d, src[0], ones, att, axis,
                                          capacity=src.shape[1])[None]

    deg = shard_map(partial(_deg_shard, att=att, axis=axis), mesh=mesh,
                    in_specs=(spec,), out_specs=spec)(g.src)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1.0), 0.0)

    def msg_fn(state, frontier):
        return state["x"] * state["inv_deg"]

    def update_fn(state, acc, frontier, it):
        x, dg = state["x"], state["deg"]
        dangling = offload.hierarchical_psum(
            jnp.sum(jnp.where(dg > 0, 0.0, x)), axes)
        x = (1 - damping) / n + damping * (acc + dangling / n)
        return {"x": x, "inv_deg": state["inv_deg"], "deg": dg}, frontier

    prog = engine.VertexProgram(edge_op="copy", combine="add",
                                msg_fn=msg_fn, update_fn=update_fn)

    # mask padded vertex slots out of the initial mass
    x = jnp.full((S, per), 1.0 / n, jnp.float32)
    # zero out padding slots (local ids beyond the shard's span)
    spans = jnp.asarray(
        [min(per, max(0, att.shard_slice(s)[1])) if att.kind != "interleave"
         else (n - s + S - 1) // S for s in range(S)], jnp.int32)
    x = jnp.where(jnp.arange(per)[None, :] < spans[:, None], x, 0.0)

    state0 = {"x": x, "inv_deg": inv_deg, "deg": deg}
    frontier0 = jnp.ones((S, per), jnp.int32)
    state = engine.run_distributed(g, att, mesh, prog, state0, frontier0,
                                   axis=axis, max_iters=iters, mode="push")
    return state["x"]
