"""Distributed (DGAS-partitioned) graph representation.

Host-side: partition CSR rows with a programmable ATT rule (default: the
paper's degree-balanced rule), producing *stacked* per-shard COO arrays with
identical padding so they drop straight into `shard_map` (leading dim = shard).

Vertex data (x vectors, levels, labels, ...) is sharded with its own ATT rule
— the two rules need not agree; all cross-references go through the offload
engines.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dgas import ATT, block_rule, degree_balanced_rule
from ..graph import CSR

__all__ = ["ShardedGraph", "shard_graph", "shard_vertex_array", "unshard_vertex_array"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Stacked per-shard edge lists. Padding entries have src=dst=-1, val=0."""

    src: jnp.ndarray   # (S, m) int32 global src vertex (owned by the shard)
    dst: jnp.ndarray   # (S, m) int32 global dst vertex
    val: jnp.ndarray   # (S, m) f32
    n_vertices: int
    n_shards: int

    def tree_flatten(self):
        return (self.src, self.dst, self.val), (self.n_vertices, self.n_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def edges_per_shard(self) -> int:
        return int(self.src.shape[1])


def shard_graph(csr: CSR, n_shards: int, row_att: Optional[ATT] = None) -> tuple[ShardedGraph, ATT]:
    """Partition edges by *source-row ownership* under `row_att`.

    Default rule is the paper's degree-balanced contiguous partition ("rows are
    partitioned ... based on the number of non-zeros").
    """
    indptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = (np.asarray(csr.values) if csr.values is not None
            else np.ones_like(cols, np.float32))
    rows = np.asarray(csr.row_ids())
    if row_att is None:
        row_att = degree_balanced_rule(indptr, n_shards)
    owner = np.asarray(row_att.owner(jnp.asarray(rows)))
    counts = np.bincount(owner, minlength=n_shards)
    m = int(counts.max()) if counts.size else 1
    m = max(m, 1)
    S = n_shards
    src_b = np.full((S, m), -1, np.int32)
    dst_b = np.full((S, m), -1, np.int32)
    val_b = np.zeros((S, m), np.float32)
    for s in range(S):
        sel = owner == s
        k = int(sel.sum())
        src_b[s, :k] = rows[sel]
        dst_b[s, :k] = cols[sel]
        val_b[s, :k] = vals[sel]
    g = ShardedGraph(jnp.asarray(src_b), jnp.asarray(dst_b), jnp.asarray(val_b),
                     csr.n_rows, S)
    return g, row_att


def shard_vertex_array(x: np.ndarray, att: ATT) -> jnp.ndarray:
    """Host-side: lay a global vertex array out as (S, per_shard) under `att`."""
    x = np.asarray(x)
    S, per = att.n_shards, att.per_shard
    out = np.zeros((S, per) + x.shape[1:], x.dtype)
    gid = np.arange(att.n_global)
    owner = np.asarray(att.owner(jnp.asarray(gid)))
    local = np.asarray(att.local(jnp.asarray(gid)))
    out[owner, local] = x
    return jnp.asarray(out)


def unshard_vertex_array(xs: jnp.ndarray, att: ATT) -> jnp.ndarray:
    """Inverse of shard_vertex_array ((S, per, ...) -> (n_global, ...))."""
    xs = np.asarray(xs)
    gid = np.arange(att.n_global)
    owner = np.asarray(att.owner(jnp.asarray(gid)))
    local = np.asarray(att.local(jnp.asarray(gid)))
    return jnp.asarray(xs[owner, local])
