"""Distributed (DGAS-partitioned) graph representation.

Host-side: partition CSR rows with a programmable ATT rule (default: the
paper's degree-balanced rule), producing *stacked* per-shard COO arrays with
identical padding so they drop straight into `shard_map` (leading dim = shard).

Vertex data (x vectors, levels, labels, ...) is sharded with its own ATT rule
— the two rules need not agree; all cross-references go through the offload
engines.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dgas import ATT, block_rule, degree_balanced_rule
from ..graph import CSR

__all__ = ["ShardedGraph", "shard_graph", "update_shards",
           "shard_vertex_array", "unshard_vertex_array"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Stacked per-shard edge lists. Padding entries have src=dst=-1, val=0."""

    src: jnp.ndarray   # (S, m) int32 global src vertex (owned by the shard)
    dst: jnp.ndarray   # (S, m) int32 global dst vertex
    val: jnp.ndarray   # (S, m) f32
    n_vertices: int
    n_shards: int

    def tree_flatten(self):
        return (self.src, self.dst, self.val), (self.n_vertices, self.n_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def edges_per_shard(self) -> int:
        return int(self.src.shape[1])


def shard_graph(csr: CSR, n_shards: int, row_att: Optional[ATT] = None) -> tuple[ShardedGraph, ATT]:
    """Partition edges by *source-row ownership* under `row_att`.

    Default rule is the paper's degree-balanced contiguous partition ("rows are
    partitioned ... based on the number of non-zeros").
    """
    indptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = (np.asarray(csr.values) if csr.values is not None
            else np.ones_like(cols, np.float32))
    rows = np.asarray(csr.row_ids())
    if row_att is None:
        row_att = degree_balanced_rule(indptr, n_shards)
    owner = np.asarray(row_att.owner(jnp.asarray(rows)))
    counts = np.bincount(owner, minlength=n_shards)
    m = int(counts.max()) if counts.size else 1
    m = max(m, 1)
    S = n_shards
    src_b = np.full((S, m), -1, np.int32)
    dst_b = np.full((S, m), -1, np.int32)
    val_b = np.zeros((S, m), np.float32)
    for s in range(S):
        sel = owner == s
        k = int(sel.sum())
        src_b[s, :k] = rows[sel]
        dst_b[s, :k] = cols[sel]
        val_b[s, :k] = vals[sel]
    g = ShardedGraph(jnp.asarray(src_b), jnp.asarray(dst_b), jnp.asarray(val_b),
                     csr.n_rows, S)
    return g, row_att


def update_shards(gsh: ShardedGraph, csr: CSR, att: ATT,
                  shards) -> Optional[ShardedGraph]:
    """Rebuild only `shards`' rows of the stacked edge arrays from the
    (updated) `csr` — the streaming-ingest reshard (DESIGN.md §16): an
    update batch whose changed edges all live in a few partitions only
    reships those partitions' edge lists, not the world.

    Returns the patched ShardedGraph, or ``None`` when any touched shard's
    new edge count exceeds the existing padding capacity
    (``edges_per_shard``) — the caller must then fall back to a full
    ``shard_graph`` reshard (the streaming layer treats that as a
    compaction event and prices it accordingly).
    """
    shards = sorted({int(s) for s in np.asarray(shards).reshape(-1)})
    if not shards:
        return gsh
    m = gsh.edges_per_shard
    indptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = (np.asarray(csr.values) if csr.values is not None
            else np.ones_like(cols, np.float32))
    rows = np.repeat(np.arange(csr.n_rows), np.diff(indptr))
    owner = np.asarray(att.owner(jnp.asarray(rows)))
    src_b = np.asarray(gsh.src).copy()
    dst_b = np.asarray(gsh.dst).copy()
    val_b = np.asarray(gsh.val).copy()
    for s in shards:
        sel = owner == s
        k = int(sel.sum())
        if k > m:
            return None
        src_b[s, :k] = rows[sel]
        dst_b[s, :k] = cols[sel]
        val_b[s, :k] = vals[sel]
        src_b[s, k:] = -1
        dst_b[s, k:] = -1
        val_b[s, k:] = 0.0
    return ShardedGraph(jnp.asarray(src_b), jnp.asarray(dst_b),
                        jnp.asarray(val_b), csr.n_rows, gsh.n_shards)


def shard_vertex_array(x: np.ndarray, att: ATT) -> jnp.ndarray:
    """Host-side: lay a global vertex array out as (S, per_shard) under `att`."""
    x = np.asarray(x)
    S, per = att.n_shards, att.per_shard
    out = np.zeros((S, per) + x.shape[1:], x.dtype)
    gid = np.arange(att.n_global)
    owner = np.asarray(att.owner(jnp.asarray(gid)))
    local = np.asarray(att.local(jnp.asarray(gid)))
    out[owner, local] = x
    return jnp.asarray(out)


def unshard_vertex_array(xs: jnp.ndarray, att: ATT) -> jnp.ndarray:
    """Inverse of shard_vertex_array ((S, per, ...) -> (n_global, ...))."""
    xs = np.asarray(xs)
    gid = np.arange(att.n_global)
    owner = np.asarray(att.owner(jnp.asarray(gid)))
    local = np.asarray(att.local(jnp.asarray(gid)))
    return jnp.asarray(xs[owner, local])
