"""Random walks — the paper's highest-speedup workload (279x / 2606x).

Pure pointer chasing: every step is two dependent fine-grained reads
(degree/offset from indptr, then the sampled neighbor from the edge array).
Since PR 2 both variants run on shared engine machinery instead of bespoke
traversal code:

* locally each step is :func:`engine.sample_neighbors` — the push-compacted
  ``combine='sample'`` step (keyed reservoir pick over the DMA-gathered
  adjacency row); this module keeps only the scan over steps.
* distributed, a walker is a *queue entry*, not a frontier bit: the walk runs
  on :func:`engine.run_queue`, so walker load-balancing across shards comes
  from the shared queue engine (`offload.queue_balance` work stealing) and
  the per-step reads stay DGAS remote gathers against *different* ATT rules
  (vertex space vs edge space) — the pattern conventional caches are worst
  at and PIUMA is built for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .. import engine, offload
from ..dgas import ATT, block_rule
from ..graph import CSR
from .distgraph import shard_vertex_array

__all__ = ["random_walks", "random_walks_distributed", "walk_queue_program"]


def random_walks(csr: CSR, starts: jnp.ndarray, n_steps: int,
                 key: jax.Array) -> jnp.ndarray:
    """Uniform random walks. Returns (n_walkers, n_steps+1) int32 node ids.

    Walkers at a sink (deg 0) stay in place.  Each walker slot draws
    independently, so walkers colliding on a vertex stay uncorrelated.
    """
    def body(cur, step_key):
        nxt = engine.sample_neighbors(csr, cur, step_key)
        return nxt, nxt

    keys = jax.random.split(key, n_steps)
    _, path = jax.lax.scan(body, starts.astype(jnp.int32), keys)
    return jnp.concatenate([starts[None].astype(jnp.int32), path], axis=0).T


def walk_queue_program(v_att: ATT, e_att: ATT, axis, cap: int) -> engine.QueueProgram:
    """One walk step as a queue program: items are walker ids, the payload is
    each walker's current vertex.  Both reads are fine-grained DGAS gathers;
    the sampled move is the classic two-dependent-load pointer chase."""

    def step_fn(operands, items, cur, state, it, key):
        indptr_sh, indices_sh = operands
        valid = items >= 0
        q = jnp.where(valid, cur, -1)
        start = offload.dgas_gather(indptr_sh, q, v_att, axis,
                                    capacity=cap).astype(jnp.int32)
        end = offload.dgas_gather(indptr_sh, jnp.where(valid, cur + 1, -1),
                                  v_att, axis, capacity=cap).astype(jnp.int32)
        deg = end - start
        r = jax.random.randint(key, items.shape, 0, 1 << 30)
        off = start + r % jnp.maximum(deg, 1)
        nbr = offload.dgas_gather(indices_sh,
                                  jnp.where(valid & (deg > 0), off, -1),
                                  e_att, axis, capacity=cap).astype(jnp.int32)
        nxt = jnp.where(valid, jnp.where(deg > 0, nbr, cur), -1)
        return items, nxt, state, (items, nxt)

    return engine.QueueProgram(step_fn)


def random_walks_distributed(csr: CSR, starts: jnp.ndarray, n_steps: int,
                             key: jax.Array, mesh: Mesh, *, axis=None) -> jnp.ndarray:
    """Walker-parallel distributed walks; graph arrays DGAS-sharded.

    indptr is sharded by a vertex-space block ATT; indices (edge array) by an
    edge-space block ATT.  Walkers start at their start vertex's owner shard
    and are rebalanced every step by the queue engine.  Returns
    (n_walkers, n_steps+1).
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    names = [axis] if isinstance(axis, str) else list(axis)
    S = 1
    for a in names:
        S *= int(mesh.shape[a])
    v_att = block_rule(csr.n_rows + 1, S)
    e_att = block_rule(int(csr.indices.shape[0]), S)
    indptr_sh = shard_vertex_array(np.asarray(csr.indptr), v_att)
    indices_sh = shard_vertex_array(np.asarray(csr.indices), e_att)

    starts_np = np.asarray(starts, np.int32)
    W = starts_np.shape[0]
    # natural DGAS placement: a walker enqueues at its start vertex's owner;
    # capacity covers both that initial skew and the balanced ceil(W/S)
    owner = np.asarray(block_rule(csr.n_rows, S).owner(jnp.asarray(starts_np)))
    counts = np.bincount(owner, minlength=S)
    cap = max(1, int(counts.max()), -(-W // S))
    items0 = np.full((S, cap), -1, np.int32)
    cur0 = np.zeros((S, cap), np.int32)
    for s in range(S):
        sel = np.nonzero(owner == s)[0]
        items0[s, :sel.size] = sel
        cur0[s, :sel.size] = starts_np[sel]

    prog = walk_queue_program(v_att, e_att, axis, cap)
    _, (out_ids, out_v) = engine.run_queue(
        mesh, prog, jnp.asarray(items0), jnp.asarray(cur0),
        (indptr_sh, indices_sh), n_iters=n_steps, axis=axis, key=key)

    # stitch the per-(shard, step) snapshots back into per-walker paths
    out_ids = np.asarray(out_ids)   # (S, n_steps, cap)
    out_v = np.asarray(out_v)
    walks = np.zeros((W, n_steps + 1), np.int32)
    walks[:, 0] = starts_np
    for t in range(n_steps):
        ids = out_ids[:, t, :].reshape(-1)
        vs = out_v[:, t, :].reshape(-1)
        sel = ids >= 0
        walks[ids[sel], t + 1] = vs[sel]
    return jnp.asarray(walks)
