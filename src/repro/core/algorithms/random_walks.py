"""Random walks — the paper's highest-speedup workload (279x / 2606x).

Pure pointer chasing: every step is two dependent fine-grained reads
(degree/offset from indptr, then the sampled neighbor from the edge array).
The distributed version issues both as DGAS remote gathers against *different*
ATT rules (vertex space vs edge space) — the pattern conventional caches are
worst at and PIUMA is built for.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..dgas import ATT, block_rule
from ..graph import CSR
from .. import offload
from .distgraph import shard_vertex_array

__all__ = ["random_walks", "random_walks_distributed"]


def random_walks(csr: CSR, starts: jnp.ndarray, n_steps: int,
                 key: jax.Array) -> jnp.ndarray:
    """Uniform random walks. Returns (n_walkers, n_steps+1) int32 node ids.

    Walkers at a sink (deg 0) stay in place.
    """
    n_walkers = starts.shape[0]

    def step(cur, key):
        start = offload.dma_gather(csr.indptr, cur)
        end = offload.dma_gather(csr.indptr, cur + 1)
        deg = end - start
        r = jax.random.randint(key, (n_walkers,), 0, 1 << 30)
        off = start + r % jnp.maximum(deg, 1)
        nbr = offload.dma_gather(csr.indices, off)
        return jnp.where(deg > 0, nbr, cur)

    keys = jax.random.split(key, n_steps)

    def body(cur, k):
        nxt = step(cur, k)
        return nxt, nxt

    _, path = jax.lax.scan(body, starts.astype(jnp.int32), keys)
    return jnp.concatenate([starts[None].astype(jnp.int32), path], axis=0).T


def _rw_shard(indptr_sh, indices_sh, cur, keys, *, v_att: ATT, e_att: ATT, axis):
    indptr_sh, indices_sh, cur = indptr_sh[0], indices_sh[0], cur[0]
    n_walkers = cur.shape[0]

    def step(cur, key):
        start = offload.dgas_gather(indptr_sh, cur, v_att, axis,
                                    capacity=n_walkers).astype(jnp.int32)
        end = offload.dgas_gather(indptr_sh, cur + 1, v_att, axis,
                                  capacity=n_walkers).astype(jnp.int32)
        deg = end - start
        r = jax.random.randint(key, (n_walkers,), 0, 1 << 30)
        off = start + r % jnp.maximum(deg, 1)
        nbr = offload.dgas_gather(indices_sh, off, e_att, axis,
                                  capacity=n_walkers).astype(jnp.int32)
        return jnp.where(deg > 0, nbr, cur)

    def body(cur, k):
        nxt = step(cur, k)
        return nxt, nxt

    _, path = jax.lax.scan(body, cur, keys[0])
    return jnp.concatenate([cur[None], path], axis=0).T[None]


def random_walks_distributed(csr: CSR, starts: jnp.ndarray, n_steps: int,
                             key: jax.Array, mesh: Mesh, *, axis=None) -> jnp.ndarray:
    """Walker-parallel distributed walks; graph arrays DGAS-sharded.

    indptr sharded by a vertex-space block ATT; indices (edge array) by an
    edge-space block ATT. Walkers sharded evenly. Returns (n_walkers, n_steps+1).
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    S = int(np_prod([mesh.shape[a] for a in ([axis] if isinstance(axis, str) else axis)]))
    v_att = block_rule(csr.n_rows + 1, S)
    e_att = block_rule(int(csr.indices.shape[0]), S)
    indptr_sh = shard_vertex_array(jnp.asarray(csr.indptr), v_att)
    indices_sh = shard_vertex_array(jnp.asarray(csr.indices), e_att)
    n_walkers = starts.shape[0]
    assert n_walkers % S == 0, "walkers must divide across shards"
    cur = starts.astype(jnp.int32).reshape(S, n_walkers // S)
    keys = jax.random.split(key, (S, n_steps))
    fn = partial(_rw_shard, v_att=v_att, e_att=e_att, axis=axis)
    mapped = shard_map(fn, mesh=mesh, in_specs=(spec,) * 4, out_specs=spec)
    out = mapped(indptr_sh, indices_sh, cur, keys)
    return out.reshape(n_walkers, n_steps + 1)


def np_prod(xs):
    out = 1
    for x in xs:
        out *= int(x)
    return out
