"""Breadth-first search, as a :mod:`repro.core.engine` vertex program.

The program: active vertices emit an indicator along out-edges; a destination
combining a positive count for the first time is assigned the next level and
joins the frontier.  Direction optimization (push the sparse frontier, pull
once it saturates) is the engine's job, not BFS's — locally ``mode='auto'``
switches on the frontier population count (Beamer's heuristic); distributed,
push expands through PIUMA remote atomics at the dst owner and pull gathers
via fine-grained dgas reads over the reversed edge shards.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax.sharding import Mesh

from .. import engine
from ..dgas import ATT
from ..graph import CSR, BBCSR
from .distgraph import ShardedGraph

__all__ = ["bfs", "bfs_distributed", "bfs_program"]


def bfs_program() -> engine.VertexProgram:
    """Levels in state['level'], int32 frontier indicator as the message."""

    def msg_fn(state, frontier):
        return frontier.astype(jnp.int32)

    def update_fn(state, acc, frontier, it):
        new = (acc > 0) & (state["level"] < 0)
        level = jnp.where(new, it + 1, state["level"])
        return {"level": level}, new.astype(jnp.int32)

    return engine.VertexProgram(edge_op="copy", combine="add",
                                msg_fn=msg_fn, update_fn=update_fn)


def bfs(csr: CSR, source: int, *, max_levels: int | None = None,
        mode: str = "auto", kernel_bb: Optional[BBCSR] = None) -> jnp.ndarray:
    """Returns level array (n,) int32; unreachable = -1.

    mode: 'auto' (direction-optimizing, default) | 'push' | 'pull'.
    kernel_bb: optional BBCSR of A^T to run both directions on the Pallas
      SpMV/SpMSpV kernels; must be unit-valued — build it with
      engine.build_pull_operand(csr, unit_values=True) (the engine rejects a
      weighted operand, since the kernel multiplies by stored values).
    """
    n = csr.n_rows
    max_levels = max_levels or n
    state0 = {"level": jnp.full((n,), -1, jnp.int32).at[source].set(0)}
    frontier0 = jnp.zeros((n,), jnp.int32).at[source].set(1)
    state = engine.run(csr, bfs_program(), state0, frontier0,
                       max_iters=max_levels, mode=mode, kernel_bb=kernel_bb)
    return state["level"]


def bfs_distributed(g: ShardedGraph, att: ATT, source: int, mesh: Mesh, *,
                    axis=None, max_levels: int = 64,
                    g_rev: Optional[ShardedGraph] = None,
                    mode: str = "push") -> jnp.ndarray:
    """Returns level array stacked (S, per_shard) under `att` layout.

    mode='push' reproduces the seed behavior exactly; pass `g_rev`
    (engine.reverse_graph) with mode='auto' for the direction-optimizing
    variant.
    """
    S, per = att.n_shards, att.per_shard
    owner = int(att.owner(jnp.asarray(source)))
    local = int(att.local(jnp.asarray(source)))
    state0 = {"level": jnp.full((S, per), -1, jnp.int32).at[owner, local].set(0)}
    frontier0 = jnp.zeros((S, per), jnp.int32).at[owner, local].set(1)
    state = engine.run_distributed(g, att, mesh, bfs_program(), state0,
                                   frontier0, axis=axis, max_iters=max_levels,
                                   g_rev=g_rev, mode=mode)
    return state["level"]
