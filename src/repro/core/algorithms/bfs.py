"""Breadth-first search.

Local: dense-frontier level synchronous BFS (edge-parallel, scatter-max).
Distributed: per level, each shard expands its locally-owned frontier rows and
marks destinations with a PIUMA remote atomic (max) at the owner; the queue
engine rebalances a sparse frontier when it is small (work stealing).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..dgas import ATT
from ..graph import CSR
from .. import offload
from .distgraph import ShardedGraph

__all__ = ["bfs", "bfs_distributed"]


def bfs(csr: CSR, source: int, *, max_levels: int | None = None) -> jnp.ndarray:
    """Returns level array (n,) int32; unreachable = -1."""
    n = csr.n_rows
    rows, cols = csr.row_ids(), csr.indices
    max_levels = max_levels or n

    def cond(state):
        level, frontier, i = state
        return jnp.logical_and(jnp.any(frontier), i < max_levels)

    def body(state):
        level, frontier, i = state
        active = offload.dma_gather(frontier.astype(jnp.int32), rows)  # per edge
        reached = jnp.zeros((n,), jnp.int32).at[cols].max(active).astype(jnp.bool_)
        new = reached & (level < 0)
        level = jnp.where(new, i + 1, level)
        return level, new, i + 1

    level0 = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    frontier0 = jnp.zeros((n,), jnp.bool_).at[source].set(True)
    level, _, _ = jax.lax.while_loop(cond, body, (level0, frontier0, jnp.int32(0)))
    return level


def _bfs_shard(src, dst, x_unused, level, frontier, *, att: ATT, axis, max_levels):
    src, dst, level, frontier = src[0], dst[0], level[0], frontier[0]

    def cond(state):
        level, frontier, i = state
        any_frontier = offload.hierarchical_psum(
            frontier.sum(), [axis] if isinstance(axis, str) else list(axis))
        return jnp.logical_and(any_frontier > 0, i < max_levels)

    def body(state):
        level, frontier, i = state
        local_src = jnp.where(src >= 0, att.local(jnp.maximum(src, 0)), 0)
        active = jnp.where(src >= 0,
                           offload.dma_gather(frontier.astype(jnp.int32), local_src), 0)
        reached = jnp.zeros((att.per_shard,), jnp.int32)
        # remote atomic max == scatter-add of indicator then clamp (idempotent mark)
        reached = offload.remote_scatter_add(
            reached, jnp.where(active > 0, dst, -1), active.astype(jnp.int32),
            att, axis, capacity=dst.shape[0])
        new = (reached > 0) & (level < 0)
        level = jnp.where(new, i + 1, level)
        return level, new.astype(jnp.int32), i + 1

    level, _, _ = jax.lax.while_loop(cond, body, (level, frontier, jnp.int32(0)))
    return level[None]


def bfs_distributed(g: ShardedGraph, att: ATT, source: int, mesh: Mesh, *,
                    axis=None, max_levels: int = 64) -> jnp.ndarray:
    """Returns level array stacked (S, per_shard) under `att` layout."""
    axis = axis if axis is not None else mesh.axis_names[0]
    spec = P(axis) if isinstance(axis, str) else P(tuple(axis))
    S, per = att.n_shards, att.per_shard
    owner = int(att.owner(jnp.asarray(source)))
    local = int(att.local(jnp.asarray(source)))
    level0 = jnp.full((S, per), -1, jnp.int32).at[owner, local].set(0)
    frontier0 = jnp.zeros((S, per), jnp.int32).at[owner, local].set(1)
    fn = partial(_bfs_shard, att=att, axis=axis, max_levels=max_levels)
    mapped = shard_map(fn, mesh=mesh, in_specs=(spec,) * 5, out_specs=spec)
    return mapped(g.src, g.dst, g.val, level0, frontier0)
