"""Breadth-first search, as a :mod:`repro.core.engine` vertex program.

The program: active vertices emit an indicator along out-edges; a destination
combining a positive count for the first time is assigned the next level and
joins the frontier.  Direction optimization (push the sparse frontier, pull
once it saturates) is the engine's job, not BFS's — locally ``mode='auto'``
switches on the frontier population count (Beamer's heuristic); distributed,
push expands through PIUMA remote atomics at the dst owner and pull gathers
via fine-grained dgas reads over the reversed edge shards.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .. import engine
from ..dgas import ATT
from ..graph import CSR, BBCSR
from .distgraph import ShardedGraph

__all__ = ["bfs", "bfs_distributed", "bfs_program", "bfs_level_program",
           "msbfs", "msbfs_distributed", "msbfs_program"]

_INF = jnp.float32(jnp.inf)


def bfs_program() -> engine.VertexProgram:
    """Levels in state['level'], int32 frontier indicator as the message."""

    def msg_fn(state, frontier):
        return frontier.astype(jnp.int32)

    def update_fn(state, acc, frontier, it):
        new = (acc > 0) & (state["level"] < 0)
        level = jnp.where(new, it + 1, state["level"])
        return {"level": level}, new.astype(jnp.int32)

    return engine.VertexProgram(edge_op="copy", combine="add",
                                msg_fn=msg_fn, update_fn=update_fn)


def bfs(csr: CSR, source: int, *, max_levels: int | None = None,
        mode: str = "auto", kernel_bb: Optional[BBCSR] = None) -> jnp.ndarray:
    """Returns level array (n,) int32; unreachable = -1.

    mode: 'auto' (direction-optimizing, default) | 'push' | 'pull'.
    kernel_bb: optional BBCSR of A^T to run both directions on the Pallas
      SpMV/SpMSpV kernels; must be unit-valued — build it with
      engine.build_pull_operand(csr, unit_values=True) (the engine rejects a
      weighted operand, since the kernel multiplies by stored values).
    """
    n = csr.n_rows
    max_levels = max_levels or n
    state0 = {"level": jnp.full((n,), -1, jnp.int32).at[source].set(0)}
    frontier0 = jnp.zeros((n,), jnp.int32).at[source].set(1)
    state = engine.run(csr, bfs_program(), state0, frontier0,
                       max_iters=max_levels, mode=mode, kernel_bb=kernel_bb)
    return state["level"]


def bfs_level_program() -> engine.VertexProgram:
    """Monotone min-level BFS — the async placement's BFS program.

    :func:`bfs_program` stamps a destination's level from the iteration
    counter the first time it is touched, which is order-*dependent* under
    the async placement's deferred message delivery.  This variant is
    label-correcting instead: the state is a float distance (levels are
    small ints, exact in f32), active vertices emit ``dist + 1``, and
    destinations keep the **min** — the unit-weight (min, +) semiring, whose
    unique fixpoint is the hop distance no matter in which order (or how
    stale) messages arrive.  Convert with
    ``where(isfinite(dist), dist, -1).astype(int32)`` to match
    :func:`bfs_program` levels exactly.
    """

    def msg_fn(state, frontier):
        return jnp.where(frontier > 0, state["dist"] + 1.0, _INF)

    def update_fn(state, acc, frontier, it):
        better = acc < state["dist"]
        return ({"dist": jnp.minimum(state["dist"], acc)},
                better.astype(jnp.int32))

    return engine.VertexProgram(edge_op="copy", combine="min",
                                msg_fn=msg_fn, update_fn=update_fn)


def _levels_from_dist(dist: jnp.ndarray) -> jnp.ndarray:
    """f32 min-level fixpoint -> int32 levels, unreachable = -1."""
    return jnp.where(jnp.isfinite(dist), dist, -1.0).astype(jnp.int32)


def msbfs_program(n_lanes: int) -> engine.VertexProgram:
    """Multi-source BFS (MS-BFS, Then et al.): one bit lane per source.

    The frontier is the bit-packed (n, W) uint32 word array; ``seen`` is the
    OR-accumulated visited mask, and a destination's new lanes are
    ``acc & ~seen`` — B traversals advance per edge scan.  Levels are kept
    unpacked (B, n) so they read out exactly like B separate `bfs` runs.
    """

    def msg_fn(state, frontier):
        return frontier

    def update_fn(state, acc, frontier, it):
        new = acc & ~state["seen"]
        newb = engine.unpack_lanes(new, n_lanes)
        level = jnp.where(newb > 0, it + 1, state["level"])
        return {"seen": state["seen"] | new, "level": level}, new

    return engine.VertexProgram(edge_op="copy", combine="or",
                                msg_fn=msg_fn, update_fn=update_fn)


def msbfs(csr: CSR, sources, *, max_levels: int | None = None,
          mode: str = "auto", return_stats: bool = False,
          trace: bool = False, trace_len: Optional[int] = None):
    """Levels (B, n) int32 for B concurrent BFS traversals; unreachable = -1.

    Row b is bit-identical to ``bfs(csr, sources[b])`` — the lanes share
    every edge scan but never interact.  Duplicate sources are allowed (their
    lanes evolve identically).  ``trace`` (with ``return_stats``) records the
    per-level engine trace into ``stats['trace']`` (obs.decode_level_trace).
    """
    n = csr.n_rows
    src = jnp.asarray(sources, jnp.int32)
    B = int(src.shape[0])
    max_levels = max_levels or n
    lanes = jnp.arange(B)
    bits0 = jnp.zeros((B, n), jnp.int32).at[lanes, src].set(1)
    f0 = engine.pack_lanes(bits0)
    state0 = {"seen": f0,
              "level": jnp.full((B, n), -1, jnp.int32).at[lanes, src].set(0)}
    out = engine.run_batched(csr, msbfs_program(B), state0, f0,
                             max_iters=max_levels, mode=mode,
                             return_stats=return_stats,
                             trace=trace, trace_len=trace_len)
    if return_stats:
        state, stats = out
        return state["level"], stats
    return out["level"]


def msbfs_distributed(g: ShardedGraph, att: ATT, sources, mesh: Mesh, *,
                      axis=None, max_levels: int = 64,
                      push_edge_capacity: Optional[int] = None,
                      return_stats: bool = False, placement: str = "sync",
                      sync_interval: Optional[int] = None,
                      trace: bool = False, trace_len: Optional[int] = None):
    """Batched-lane BFS on the distributed push pipeline.

    Returns levels stacked (S, B, per_shard) under the `att` layout — slice
    ``[:, b, :]`` is bit-identical to ``bfs_distributed(g, att, sources[b],
    mesh)``.  One compacted exchange per level carries all B lanes as packed
    words (`offload.remote_scatter_or`).

    placement='async' runs the monotone :func:`bfs_level_program` on vmapped
    valued lanes instead (the first-touch level stamp of the packed program
    is order-dependent under deferred delivery; the min-level fixpoint is
    not), with `sync_interval` local micro-steps per global check — same
    levels, ≥K× fewer global reductions.
    """
    S, per = att.n_shards, att.per_shard
    src = jnp.asarray(sources, jnp.int32)
    B = int(src.shape[0])
    W = engine.lane_words(B)
    owner = att.owner(src)
    local = att.local(src)
    lanes = jnp.arange(B)
    if placement == "async":
        k = int(sync_interval) if sync_interval is not None else 8
        dist0 = jnp.full((S, B, per), _INF) \
            .at[owner, lanes, local].set(0.0)
        f0 = jnp.zeros((S, B, per), jnp.int32) \
            .at[owner, lanes, local].set(1)
        out = engine.run_batched_distributed(
            g, att, mesh, bfs_level_program(), {"dist": dist0}, f0,
            axis=axis, max_iters=max_levels * k,
            push_edge_capacity=push_edge_capacity,
            return_stats=return_stats, placement="async", sync_interval=k,
            trace=trace, trace_len=trace_len)
        if return_stats:
            state, stats = out
            return _levels_from_dist(state["dist"]), stats
        return _levels_from_dist(out["dist"])
    # traceable init (sources may be a jit argument — the service's padded
    # batches): lanes occupy disjoint bits of their word, so the scatter-add
    # is the bitwise OR even when sources collide on a (shard, vertex, word)
    bits = jnp.uint32(1) << (lanes % 32).astype(jnp.uint32)
    words0 = jnp.zeros((S, per, W), jnp.uint32) \
        .at[owner, local, lanes // 32].add(bits)
    level0 = jnp.full((S, B, per), -1, jnp.int32) \
        .at[owner, lanes, local].set(0)
    state0 = {"seen": words0, "level": level0}
    out = engine.run_batched_distributed(
        g, att, mesh, msbfs_program(B), state0, words0,
        axis=axis, max_iters=max_levels,
        push_edge_capacity=push_edge_capacity, return_stats=return_stats,
        trace=trace, trace_len=trace_len)
    if return_stats:
        state, stats = out
        return state["level"], stats
    return out["level"]


def bfs_distributed(g: ShardedGraph, att: ATT, source: int, mesh: Mesh, *,
                    axis=None, max_levels: int = 64,
                    g_rev: Optional[ShardedGraph] = None,
                    mode: str = "push", placement: str = "sync",
                    sync_interval: Optional[int] = None) -> jnp.ndarray:
    """Returns level array stacked (S, per_shard) under `att` layout.

    mode='push' reproduces the seed behavior exactly; pass `g_rev`
    (engine.reverse_graph) with mode='auto' for the direction-optimizing
    variant.  placement='async' (push-only) runs the monotone
    :func:`bfs_level_program` with bounded-staleness pacing — identical
    levels, `sync_interval`× fewer global reductions.
    """
    S, per = att.n_shards, att.per_shard
    owner = int(att.owner(jnp.asarray(source)))
    local = int(att.local(jnp.asarray(source)))
    frontier0 = jnp.zeros((S, per), jnp.int32).at[owner, local].set(1)
    if placement == "async":
        k = int(sync_interval) if sync_interval is not None else 8
        dist0 = jnp.full((S, per), _INF).at[owner, local].set(0.0)
        state = engine.run_distributed(
            g, att, mesh, bfs_level_program(), {"dist": dist0}, frontier0,
            axis=axis, max_iters=max_levels * k, mode=mode,
            placement="async", sync_interval=k)
        return _levels_from_dist(state["dist"])
    state0 = {"level": jnp.full((S, per), -1, jnp.int32).at[owner, local].set(0)}
    state = engine.run_distributed(g, att, mesh, bfs_program(), state0,
                                   frontier0, axis=axis, max_iters=max_levels,
                                   g_rev=g_rev, mode=mode)
    return state["level"]
