from .spmv import spmv, spmv_ell, spmv_bbcsr, spmv_distributed
from .spmspv import spmspv, spmspv_ell
from .pagerank import pagerank, pagerank_distributed
from .bfs import bfs, bfs_distributed, bfs_program
from .sssp import sssp, sssp_distributed, sssp_program
from .cc import (connected_components, connected_components_distributed,
                 cc_program, symmetrize)
from .random_walks import random_walks, random_walks_distributed
from .louvain import label_propagation, modularity
from .sampling import ties_sample, neighbor_sample

__all__ = [
    "spmv", "spmv_ell", "spmv_bbcsr", "spmv_distributed",
    "spmspv", "spmspv_ell",
    "pagerank", "pagerank_distributed",
    "bfs", "bfs_distributed", "bfs_program",
    "sssp", "sssp_distributed", "sssp_program",
    "connected_components", "connected_components_distributed",
    "cc_program", "symmetrize",
    "random_walks", "random_walks_distributed",
    "label_propagation", "modularity",
    "ties_sample", "neighbor_sample",
]
