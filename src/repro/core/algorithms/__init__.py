from .spmv import spmv, spmv_ell, spmv_bbcsr, spmv_distributed
from .spmspv import spmspv, spmspv_ell
from .pagerank import (pagerank, pagerank_distributed, ppr, ppr_batched,
                       ppr_topk)
from .bfs import (bfs, bfs_distributed, bfs_program, bfs_level_program,
                  msbfs, msbfs_distributed, msbfs_program)
from .sssp import (sssp, sssp_distributed, sssp_program, auto_delta,
                   sssp_batched, sssp_batched_distributed)
from .cc import (connected_components, connected_components_distributed,
                 cc_program, symmetrize)
from .random_walks import (random_walks, random_walks_distributed,
                           walk_queue_program)
from .louvain import (label_propagation, label_propagation_distributed,
                      lpa_program, modularity, modularity_distributed,
                      multilevel, multilevel_distributed, contract_distributed)
from .sampling import ties_sample, neighbor_sample
from .incremental import (bfs_repair, cc_repair, sssp_repair,
                          bfs_repair_distributed, cc_repair_distributed,
                          repair_or_recompute)

__all__ = [
    "spmv", "spmv_ell", "spmv_bbcsr", "spmv_distributed",
    "spmspv", "spmspv_ell",
    "pagerank", "pagerank_distributed", "ppr", "ppr_batched", "ppr_topk",
    "bfs", "bfs_distributed", "bfs_program", "bfs_level_program",
    "msbfs", "msbfs_distributed", "msbfs_program",
    "sssp", "sssp_distributed", "sssp_program", "auto_delta",
    "sssp_batched", "sssp_batched_distributed",
    "connected_components", "connected_components_distributed",
    "cc_program", "symmetrize",
    "random_walks", "random_walks_distributed", "walk_queue_program",
    "label_propagation", "label_propagation_distributed", "lpa_program",
    "modularity", "modularity_distributed",
    "multilevel", "multilevel_distributed", "contract_distributed",
    "ties_sample", "neighbor_sample",
    "bfs_repair", "cc_repair", "sssp_repair",
    "bfs_repair_distributed", "cc_repair_distributed", "repair_or_recompute",
]
