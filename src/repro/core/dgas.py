"""DGAS: distributed global address space, and the ATT (address translation table).

PIUMA exposes one flat address space across all nodes; *programmable* ATT rules
decide where each application address physically lives (interleaved, block
partitioned, ...).  On a TPU mesh the physical location is the device shard, so
the ATT here is the programmable map

    global element id  ->  (owner shard, local offset)

used consistently by the graph partitioner, the offload engines and the
distributed algorithms.  Because every primitive consults the ATT (instead of
hard-coding ``id % n`` or ``id // per``), the *same* algorithm code runs under
any distribution rule — the paper's "application code does not need to change
for multinode execution".
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ATT",
    "interleave_rule",
    "block_rule",
    "custom_boundary_rule",
    "degree_balanced_rule",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ATT:
    """Address translation table: global id -> (owner, local offset).

    ``boundaries`` is only used by boundary-based rules; for the closed-form
    rules it is a size-1 placeholder so the pytree structure is static.

    Attributes:
      kind: 'interleave' | 'block' | 'boundaries'.
      n_global: size of the global id space.
      n_shards: number of owners (devices along the sharded axis).
      boundaries: (n_shards+1,) int32 — shard s owns [boundaries[s], boundaries[s+1]).
    """

    kind: str
    n_global: int
    n_shards: int
    boundaries: jnp.ndarray  # (n_shards+1,) for 'boundaries', else (1,)

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.boundaries,), (self.kind, self.n_global, self.n_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, n_global, n_shards = aux
        return cls(kind, n_global, n_shards, children[0])

    # -- core queries -------------------------------------------------------
    @property
    def per_shard(self) -> int:
        """Padded local capacity (max elements any shard owns)."""
        if self.kind == "interleave":
            return -(-self.n_global // self.n_shards)
        if self.kind == "block":
            return -(-self.n_global // self.n_shards)
        # boundary rule: static upper bound = n_global (callers should use
        # local_capacity computed at build time instead); we store it densely.
        return int(self._max_span)

    @property
    def _max_span(self):
        b = np.asarray(self.boundaries)
        if b.shape[0] <= 1:
            return -(-self.n_global // self.n_shards)
        return int(np.max(b[1:] - b[:-1]))

    def owner(self, gid: jnp.ndarray) -> jnp.ndarray:
        """Owner shard of each global id."""
        if self.kind == "interleave":
            return gid % self.n_shards
        if self.kind == "block":
            per = -(-self.n_global // self.n_shards)
            return gid // per
        # boundaries: owner s satisfies boundaries[s] <= gid < boundaries[s+1]
        return jnp.clip(
            jnp.searchsorted(self.boundaries, gid, side="right") - 1,
            0,
            self.n_shards - 1,
        )

    def local(self, gid: jnp.ndarray) -> jnp.ndarray:
        """Local offset of each global id within its owner shard."""
        if self.kind == "interleave":
            return gid // self.n_shards
        if self.kind == "block":
            per = -(-self.n_global // self.n_shards)
            return gid % per
        return gid - jnp.take(self.boundaries, self.owner(gid))

    def to_global(self, shard: jnp.ndarray, local: jnp.ndarray) -> jnp.ndarray:
        """Inverse translation: (owner, local) -> global id."""
        if self.kind == "interleave":
            return local * self.n_shards + shard
        if self.kind == "block":
            per = -(-self.n_global // self.n_shards)
            return shard * per + local
        return jnp.take(self.boundaries, shard) + local

    def flat_slot(self, gid: jnp.ndarray) -> jnp.ndarray:
        """Dense outbox address of each global id: owner * per_shard + local.

        The async placement's deferred-message buffers (offload.buffered_flush)
        are laid out as (n_shards * per_shard, ...) so that a plain reshape
        splits them per destination peer; this is the slot a message for
        ``gid`` occupies in such a buffer.
        """
        return self.owner(gid) * self.per_shard + self.local(gid)

    def shard_slice(self, shard: int) -> tuple[int, int]:
        """Host-side: (start, count) of globally-contiguous ids owned by `shard`.

        Only meaningful for contiguous rules ('block' / 'boundaries').
        """
        if self.kind == "block":
            per = -(-self.n_global // self.n_shards)
            start = shard * per
            return start, max(0, min(per, self.n_global - start))
        if self.kind == "boundaries":
            b = np.asarray(self.boundaries)
            return int(b[shard]), int(b[shard + 1] - b[shard])
        raise ValueError("interleave rule has no contiguous shard slice")


def interleave_rule(n_global: int, n_shards: int) -> ATT:
    """PIUMA 'address interleaved' rule: id % n_shards."""
    return ATT("interleave", n_global, n_shards, jnp.zeros((1,), jnp.int32))


def block_rule(n_global: int, n_shards: int) -> ATT:
    """PIUMA 'block partitioned' rule: contiguous equal blocks."""
    return ATT("block", n_global, n_shards, jnp.zeros((1,), jnp.int32))


def custom_boundary_rule(boundaries: np.ndarray, n_global: int) -> ATT:
    """Arbitrary contiguous partition given explicit boundaries (n_shards+1,)."""
    b = jnp.asarray(np.asarray(boundaries, dtype=np.int32))
    return ATT("boundaries", n_global, int(b.shape[0]) - 1, b)


def degree_balanced_rule(indptr: np.ndarray, n_shards: int) -> ATT:
    """Contiguous row partition balancing *nonzeros* (the paper's SpMV rule:

    "rows are partitioned across the threads based on the number of
    non-zeros for a balanced execution").
    """
    indptr = np.asarray(indptr)
    n_rows = indptr.shape[0] - 1
    nnz = int(indptr[-1])
    targets = (np.arange(1, n_shards) * (nnz / n_shards)).astype(np.int64)
    cuts = np.searchsorted(indptr, targets, side="left")
    boundaries = np.concatenate([[0], cuts, [n_rows]]).astype(np.int32)
    boundaries = np.maximum.accumulate(boundaries)  # monotone under ties
    return custom_boundary_rule(boundaries, n_rows)
