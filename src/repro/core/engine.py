"""Direction-optimizing vertex-program execution engine.

Every frontier algorithm in this repo (BFS, PageRank, SpMV-as-one-step, SSSP,
connected components) is the same loop: per-vertex *messages* flow along edges
and are combined at the destination, then a per-vertex *update* produces the
next state and the next frontier.  This module owns that loop **once** — as
the :class:`ExecutionCore` (DESIGN.md §14): a single stepping loop
(:func:`_core_loop`) parameterized by two orthogonal axes,

* **lane representation** — ``scalar`` (one traversal), ``valued`` (B
  concurrent traversals as vmapped (B, n) lanes), ``packed`` (B boolean
  traversals bit-packed into (n, ceil(B/32)) uint32 words, MS-BFS style);
* **placement** — ``local`` (one device), shard_map-``distributed``
  (stacked (S, ...) operands, owner-routed exchanges, globally-agreed
  branches, one global reduction per level), or ``async`` (the distributed
  placement with bounded-staleness shard pacing: each shard runs up to
  ``sync_interval`` collective-free local micro-steps against its resident
  partition, deferring remote contributions into a dense combine outbox
  that one ``offload.buffered_flush`` exchange delivers at each global
  convergence check — the PIUMA fine-grained-asynchrony model, bit-identical
  to sync for monotone combines); all share one ``_MAPPED_CACHE`` keying
  scheme with the algorithm layer (louvain's compiled sweeps).

The five public runners — :func:`run`, :func:`run_batched`,
:func:`run_distributed`, :func:`run_batched_distributed`, :func:`run_queue` —
are thin wrappers that pick a point in that grid (``run_queue`` is the
queue-program family: its per-iteration body is its own, but it shares the
compaction, routing and shard_map plumbing).  The algorithms supply only the
little per-edge/per-vertex functions — the paper's "programmable offload"
story: the hardware-ish machinery (DMA gather, remote atomics, collectives,
queues) is shared.

Semiring-lite model.  A program computes, per iteration::

    msg  = msg_fn(state, frontier)            # (n,) — identity on inactive
    acc[v] = combine_{(u,v) in E} edge_op(msg[u], w_uv)
    state, frontier = update_fn(state, acc, frontier, it)

with ``edge_op`` in {mul, add, copy} and ``combine`` in {add, min, max} — plus
two *structured* combines that extend the semiring with non-scalar reductions
(DESIGN.md §4):

* ``combine='argmax_weighted'`` — per-destination weighted label mode: the
  message is an int label, the edge value is the vote weight, and ``acc`` is
  the pair (winning label's total weight, winning label).  Weighted label
  propagation (Louvain local moves) is this combine plus a two-line update.
* ``combine='sample'`` — per-destination keyed reservoir pick: every edge
  draws a random priority from the iteration key (Efraimidis–Spirakis when
  ``edge_op='mul'`` weights the draw) and ``acc`` is the pair (best priority,
  sampled source payload).  Random walks and neighbor sampling are one-step
  programs on this combine, via :func:`sample_neighbors`.

Frontier masking is folded into ``msg_fn`` (inactive vertices emit the combine
identity — ``-1`` for structured payloads), which is what makes push and pull
produce the same ``acc``.

Direction optimization (Beamer-style, re-expressed for bulk arrays):

* **sparse / push** — extract the frontier as an index list (static capacity
  ``C``), gather only those vertices' adjacency rows and scatter-combine their
  contributions: work ∝ edges of *active* vertices.
* **dense / pull** — one full edge-parallel pass (gather msg at src, segment
  combine at dst): work ∝ |E| but with no compaction overhead and perfectly
  vectorized.

The switch is a ``lax.cond`` on the frontier population count — carried
through the core loop, and globally reduced with
:func:`offload.hierarchical_psum` under the distributed placement so all
shards take the same branch.

When the program's combine is ``add``, both directions can instead run on the
BBCSR Pallas machinery (``kernels/spmv_dma.py``): the dense step is the SpMV
kernel, and the sparse step is the new SpMSpV variant that skips every tile
whose column block contains no active vertex (PIUMA's "only touch the data
the sparse frontier names").
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map
from .. import tune as _tune
from . import offload
from .dgas import ATT
from .graph import CSR, BBCSR, to_bbcsr
from .algorithms.distgraph import ShardedGraph

AxisName = Union[str, Sequence[str]]

__all__ = [
    "VertexProgram", "ExecutionCore", "run", "run_distributed", "spmv_pass",
    "build_pull_operand", "tile_active", "sample_neighbors",
    "QueueProgram", "run_queue", "frontier_edge_capacity",
    "Hierarchy", "run_multilevel",
    "run_batched", "run_batched_distributed",
    "lane_words", "pack_lanes", "unpack_lanes",
    "cached_mapped",
]

_COMBINE_IDENTITY = {"add": 0.0, "min": float("inf"), "max": float("-inf"),
                     "or": 0}
_STRUCTURED_COMBINES = ("argmax_weighted", "sample")
_LANE_REPS = ("scalar", "valued", "packed")


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """One frontier algorithm, reduced to its per-edge/per-vertex pieces.

    Attributes:
      edge_op:   how a message meets the edge weight: 'mul' | 'add' | 'copy'.
      combine:   destination-side reduction: 'add' | 'min' | 'max', or a
                 structured combine 'argmax_weighted' | 'sample' (the message
                 is then an int32 payload, -1 = inactive, and `acc` is the
                 (score, payload) pair — see the module docstring), or the
                 batched-only bitwise combine 'or' (messages are bit-packed
                 uint32 lane words, :func:`run_batched`; edge_op 'copy').
      msg_fn:    (state, frontier) -> (n,) messages; MUST emit `identity` for
                 vertices outside the frontier (that makes push == pull).
      update_fn: (state, acc, frontier, it) -> (state, next_frontier).
      identity:  combine identity (defaults per combine).
    """

    edge_op: str
    combine: str
    msg_fn: Callable[[Any, jnp.ndarray], jnp.ndarray]
    update_fn: Callable[[Any, jnp.ndarray, jnp.ndarray, jnp.ndarray], tuple]
    identity: Optional[float] = None

    def __post_init__(self):
        if self.edge_op not in ("mul", "add", "copy"):
            raise ValueError(f"unknown edge_op {self.edge_op!r}")
        if (self.combine not in _COMBINE_IDENTITY
                and self.combine not in _STRUCTURED_COMBINES):
            raise ValueError(f"unknown combine {self.combine!r}")
        if self.structured and self.edge_op == "add":
            raise ValueError(f"combine {self.combine!r} takes its weight from "
                             "the edge value: edge_op must be 'mul' (weighted)"
                             " or 'copy' (unit)")
        if self.combine == "or" and self.edge_op != "copy":
            raise ValueError("combine 'or' reduces bit-packed lane words — "
                             "edge values cannot weigh in: edge_op must be "
                             "'copy'")

    @property
    def structured(self) -> bool:
        """True for the non-scalar combines whose acc is a (score, payload)
        pair rather than a single reduced value."""
        return self.combine in _STRUCTURED_COMBINES

    @property
    def ident(self):
        if self.identity is not None:
            return self.identity
        if self.structured:
            return float("-inf")  # score identity; payload identity is -1
        return _COMBINE_IDENTITY[self.combine]


def _apply_edge(em: jnp.ndarray, ev: jnp.ndarray, edge_op: str) -> jnp.ndarray:
    if edge_op == "mul":
        return em * ev
    if edge_op == "add":
        return em + ev
    return em


def _scatter_combine(dest: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                     combine: str, identity) -> jnp.ndarray:
    """Scatter-{add,min,max} with out-of-range indices dropped.  ``vals`` may
    carry trailing lane dims beyond ``idx`` (the batched engine's (m, B)
    payloads); ``dest`` then carries the same trailing shape."""
    valid = (idx >= 0) & (idx < dest.shape[0])
    safe = jnp.where(valid, idx, 0)
    neutral = jnp.asarray(identity, dest.dtype)
    vmask = valid.reshape(valid.shape + (1,) * (vals.ndim - valid.ndim))
    masked = jnp.where(vmask, vals.astype(dest.dtype), neutral)
    if combine == "add":
        return dest.at[safe].add(masked)
    if combine == "min":
        return dest.at[safe].min(masked)
    return dest.at[safe].max(masked)


# ---------------------------------------------------------------------------
# Bit-packed lanes (batched boolean frontiers, MS-BFS style)
# ---------------------------------------------------------------------------

def lane_words(n_lanes: int) -> int:
    """uint32 words needed to bit-pack ``n_lanes`` boolean lanes."""
    return -(-n_lanes // 32)


def pack_lanes(bits: jnp.ndarray) -> jnp.ndarray:
    """(B, n) lane indicators -> (n, W) uint32 words; lane b lives at bit
    b % 32 of word b // 32."""
    B, n = bits.shape
    W = lane_words(B)
    b = (jnp.asarray(bits) != 0).astype(jnp.uint32)
    b = jnp.pad(b, ((0, W * 32 - B), (0, 0))).reshape(W, 32, n)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    # lanes occupy disjoint bits, so the sum is the OR
    return (b << shifts).sum(axis=1, dtype=jnp.uint32).T


def unpack_lanes(words: jnp.ndarray, n_lanes: int) -> jnp.ndarray:
    """(n, W) uint32 words -> (B, n) int32 {0, 1} lane indicators."""
    lanes = jnp.arange(n_lanes)
    bits = (words[:, lanes // 32] >> (lanes % 32).astype(jnp.uint32)) & 1
    return bits.T.astype(jnp.int32)


def _acc_init(n: int, prog: VertexProgram, dtype) -> jnp.ndarray:
    return jnp.full((n,), prog.ident, dtype)


# ---------------------------------------------------------------------------
# Kernel (BBCSR / Pallas) operands
# ---------------------------------------------------------------------------

def build_pull_operand(csr: CSR, *, unit_values: bool = False,
                       combine: str = "add", **bb_kwargs) -> BBCSR:
    """BBCSR of A^T — rows are *destinations*, columns are *sources* — so
    ``spmv_dma(bb, msg)`` computes exactly the engine's dense step for an
    'add' program (and ``spmspv_dma`` its sparse step).

    The tile geometry defaults to the tuned config for ``combine``'s kernel
    family on this backend and graph scale (``repro.tune``, DESIGN.md §18);
    explicit ``block_rows=`` / ``block_cols=`` / ``tile_nnz=`` kwargs win
    per key."""
    family = "bbcsr_min" if combine in ("min", "max") else "bbcsr_add"
    params = {k: _tune.resolve(f"kernels.{family}.{k}",
                               explicit=bb_kwargs.get(k), n=csr.n_rows)
              for k in ("block_rows", "block_cols", "tile_nnz")}
    params.update({k: v for k, v in bb_kwargs.items()
                   if k not in ("block_rows", "block_cols", "tile_nnz")})
    t = csr.transpose()
    if unit_values:
        t = CSR(t.indptr, t.indices, None, t.n_rows, t.n_cols)
    return to_bbcsr(t, **params)


def tile_active(bb: BBCSR, frontier: jnp.ndarray) -> jnp.ndarray:
    """(n_tiles,) int32 flags: 1 iff the tile's column block holds any active
    source vertex.  Scalar-prefetched by the SpMSpV kernel."""
    ncb = bb.n_col_blocks
    f = frontier.astype(jnp.int32)
    pad = ncb * bb.block_cols - f.shape[0]
    f = jnp.pad(f, (0, pad))
    blk = f.reshape(ncb, bb.block_cols).max(axis=1)
    return jnp.take(blk, bb.tile_cb)


# ---------------------------------------------------------------------------
# Per-level step primitives (local placement)
# ---------------------------------------------------------------------------

def _gather_rows(indptr, indices, vals, ids, k):
    """DMA-gather up to ``k`` adjacency entries per id (padding id = -1).

    Returns (cols (C, k), w (C, k) f32 — edge values or unit, valid (C, k),
    deg (C,)); the shared expansion behind the push step and the compacted
    sampling step.
    """
    safe = jnp.maximum(ids, 0)
    start = jnp.take(indptr, safe)
    deg = jnp.take(indptr, safe + 1) - start
    offs = start[:, None] + jnp.arange(k, dtype=indptr.dtype)[None, :]
    valid = (jnp.arange(k)[None, :] < deg[:, None]) & (ids >= 0)[:, None]
    cols = offload.dma_gather(indices, jnp.where(valid, offs, -1))
    if vals is not None:
        w = offload.dma_gather(vals, jnp.where(valid, offs, -1))
    else:
        w = jnp.ones((ids.shape[0], k), jnp.float32)
    return cols, w, valid, deg


def _es_scores(u: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Efraimidis–Spirakis reservoir priorities: the max of u_i^(1/w_i) picks
    item i with probability w_i / sum(w); non-positive weights never win."""
    return jnp.where(w > 0, u ** (1.0 / jnp.maximum(w, 1e-30)), -jnp.inf)


def _structured_combine(idx, payload, w, n, prog: VertexProgram, key):
    """Edge-stream entry to the structured combines: idx = destination per
    item (-1 ignored), payload = int message, w = edge weight."""
    idx = jnp.where(payload >= 0, idx, -1)
    if prog.combine == "argmax_weighted":
        return offload.segment_weighted_mode(idx, payload, w, n)
    # 'sample': keyed reservoir pick — iid priorities, so the per-destination
    # argmax is a uniform pick; Efraimidis–Spirakis exponents weight the draw
    # by the edge value when edge_op='mul'.
    u = jax.random.uniform(key, idx.shape, minval=1e-7, maxval=1.0)
    score = _es_scores(u, w) if prog.edge_op == "mul" else u
    return offload.segment_argmax(idx, score, payload, n)


def _dense_step(rows, cols, vals, msg, n, prog: VertexProgram, key=None):
    """Pull direction: one edge-parallel pass over every edge."""
    if prog.structured:
        payload = jnp.take(msg, rows).astype(jnp.int32)
        w = vals if vals is not None else jnp.ones_like(rows, jnp.float32)
        return _structured_combine(cols, payload, w, n, prog, key)
    em = jnp.take(msg, rows)
    ev = _apply_edge(em, vals, prog.edge_op)
    if prog.combine == "add":
        return jax.ops.segment_sum(ev.astype(msg.dtype), cols, num_segments=n)
    return _scatter_combine(_acc_init(n, prog, msg.dtype), cols, ev,
                            prog.combine, prog.ident)


def _sparse_step(indptr, indices, vals, msg, frontier, n, C, k,
                 prog: VertexProgram, key=None):
    """Push direction: expand only the ≤C active vertices' adjacency rows."""
    ids, = jnp.nonzero(frontier, size=C, fill_value=-1)
    cols, ev, valid, _ = _gather_rows(indptr, indices, vals, ids, k)
    safe = jnp.maximum(ids, 0)
    if prog.structured:
        payload = jnp.broadcast_to(
            jnp.take(msg, safe).astype(jnp.int32)[:, None], (C, k))
        idx = jnp.where(valid, cols, -1).reshape(-1)
        return _structured_combine(idx, payload.reshape(-1),
                                   ev.astype(jnp.float32).reshape(-1), n,
                                   prog, key)
    ev = ev.astype(msg.dtype)
    em = jnp.take(msg, safe)[:, None]
    contrib = _apply_edge(em, ev, prog.edge_op)
    contrib = jnp.where(valid, contrib, jnp.asarray(prog.ident, msg.dtype))
    acc = _acc_init(n, prog, msg.dtype)
    return _scatter_combine(acc, jnp.where(valid, cols, -1).reshape(-1),
                            contrib.reshape(-1), prog.combine, prog.ident)


# trace-safe: validation runs at dispatch time on a concrete BBCSR operand,
# before any trace begins — repro-lint: disable=host-sync
def _check_kernel_operand(prog: VertexProgram, kernel_bb: BBCSR) -> None:
    """Validate a Pallas operand against the program's semiring: 'add'
    accumulates val*msg on the MXU; 'min'/'max' relax msg + w with the
    masked-select tile combine ((min,+)/(max,+) — the distance semirings)."""
    if prog.combine == "add":
        if prog.edge_op == "add":
            raise ValueError("the 'add'-combine kernels compute val*msg; "
                             "edge_op 'add' has no kernel path")
        if prog.edge_op == "copy":
            v = np.asarray(kernel_bb.vals)
            if not bool(np.all((v == 0) | (v == 1))):
                raise ValueError(
                    "edge_op 'copy' needs a unit-valued kernel operand — "
                    "build it with build_pull_operand(csr, unit_values=True)")
    elif prog.combine in ("min", "max"):
        if prog.edge_op != "add":
            raise ValueError("the min/max tile combines relax msg + w: "
                             "edge_op must be 'add'")
        if kernel_bb.tile_cnt is None:
            raise ValueError("min/max tile combines need the BBCSR per-tile "
                             "padding counts — rebuild the operand with "
                             "to_bbcsr")
    else:
        raise ValueError(f"no kernel path for combine {prog.combine!r}")


# trace-safe: indptr is graph structure, concrete by the engine's contract —
# the pull happens once, pre-trace, to derive a *static* gather budget
def _max_degree(indptr) -> int:  # repro-lint: disable=host-sync
    # static max degree for gather budgets; derived with numpy from the
    # (concrete) indptr so the callers stay usable under jit
    indptr_np = np.asarray(indptr)
    k = int((indptr_np[1:] - indptr_np[:-1]).max()) if indptr_np.size > 1 else 1
    return max(k, 1)


def sample_neighbors(csr: CSR, queries: jnp.ndarray, key: jax.Array, *,
                     weighted: bool = False,
                     k: Optional[int] = None) -> jnp.ndarray:
    """One push-compacted step of a ``combine='sample'`` program.

    For every query slot (duplicates allowed — each slot draws independently,
    so colliding walkers stay uncorrelated) the engine picks one out-neighbor
    of that vertex.  The unweighted pick lowers the reservoir to the
    equivalent inverse-CDF draw — one random offset into the row, O(1) DMA
    per slot instead of a max-degree-padded row gather (same uniform
    distribution, and the pointer-chase access pattern the paper's random
    walks measure).  ``weighted=True`` keeps the full keyed reservoir: the
    row is DMA-gathered and the per-slot argmax of Efraimidis–Spirakis
    priorities draws proportionally to edge values.  Sinks return the query
    itself (walkers stay put, shapes stay static).

    Random walks and layered neighbor sampling are scans/loops over this one
    step — the offload machinery (DMA gather, keyed pick) is the engine's,
    the algorithms keep only their loop shape.
    """
    q = queries.astype(jnp.int32)
    safe = jnp.maximum(q, 0)
    if not weighted:
        start = jnp.take(csr.indptr, safe)
        deg = jnp.take(csr.indptr, safe + 1) - start
        r = jax.random.randint(key, q.shape, 0, 1 << 30)
        off = start + r % jnp.maximum(deg, 1)
        nbr = offload.dma_gather(csr.indices, jnp.where(deg > 0, off, -1))
        return jnp.where((deg > 0) & (q >= 0), nbr, q)
    if k is None:
        k = _max_degree(csr.indptr)
    cols, w, valid, deg = _gather_rows(csr.indptr, csr.indices, csr.values,
                                       q, k)
    u = jax.random.uniform(key, cols.shape, minval=1e-7, maxval=1.0)
    score = jnp.where(valid, _es_scores(u, w), -jnp.inf)
    pick = jnp.argmax(score, axis=1)
    nbr = jnp.take_along_axis(cols, pick[:, None], 1)[:, 0]
    return jnp.where((deg > 0) & (q >= 0), nbr, q)


# ---------------------------------------------------------------------------
# ExecutionCore: THE stepping loop (DESIGN.md §14)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionCore:
    """One fully-lowered point of the (lane representation × placement) grid.

    The public runners *plan* (build these four callables), the core *steps*
    (the single :func:`_core_loop` below), the runners *finish* (unpack
    state/stats).  Fields:

      msg:    (state, frontier) -> messages — lane-axis handling baked in
              (vmapped for valued lanes, direct for scalar/packed).
      step:   (msg, frontier, alive, it_key) -> (acc, was_push, fallback) —
              the per-level direction decision plus the placement's
              dense/sparse (local) or push/pull (distributed) step.
              ``alive`` is the carried global active count, so the decision
              is one comparison, not a fresh reduction.
      update: (state, acc, frontier, it) -> (state, next_frontier).
      count:  frontier -> int32 global active count (psum'd under the
              distributed placement so every shard agrees).
      pace:   optional (state, frontier, it) -> (state, frontier, it) — the
              async placement's bounded-staleness hook, run at the top of
              every loop body: advance up to sync_interval-1 collective-free
              local micro-steps (deferring remote traffic into the outbox
              carried inside ``state``) before the body's globally-checked
              step.  None (the default) keeps the body fully synchronous.
    """

    msg: Callable
    step: Callable
    update: Callable
    count: Callable
    pace: Optional[Callable] = None


def _lane_ops(prog: VertexProgram, lanes: str):
    """The lane-representation axis: how msg/update see the lane dim and how
    a frontier collapses to the per-vertex union indicator."""
    if lanes not in _LANE_REPS:
        raise ValueError(f"unknown lane representation {lanes!r}")
    if lanes == "valued":
        return (jax.vmap(prog.msg_fn),
                jax.vmap(prog.update_fn, in_axes=(0, 0, 0, None)),
                lambda f: (f > 0).any(axis=0))
    if lanes == "packed":
        return prog.msg_fn, prog.update_fn, lambda f: (f != 0).any(axis=-1)
    return prog.msg_fn, prog.update_fn, lambda f: f


def _core_loop(core: ExecutionCore, state0: Any, frontier0: jnp.ndarray, *,
               max_iters: int, key: Optional[jax.Array] = None,
               trace_len: int = 0, trace_flush: bool = False):
    """Run an :class:`ExecutionCore` to frontier exhaustion (or `max_iters`).

    This is the engine's only stepping loop (`scripts/check_single_core.py`
    guards that it stays that way): every public frontier runner lowers to
    it.  The carry holds the active-population count so the level's
    direction decision and the termination test share one reduction per
    iteration.  Returns ``(state, stats)`` with stats =
    {'iters', 'pushes', 'pulls', 'fallbacks'} (int32 scalars; wrappers drop
    the keys their placement cannot produce).

    trace_len > 0 additionally carries a ``(trace_len, 4)`` int32 per-level
    trace (DESIGN.md §17): one row ``[frontier, was_push, fallback, flush]``
    per body iteration, written on device with a drop-mode scatter (levels
    past the buffer are dropped, never clamp-overwritten) and returned as
    ``stats['trace']``.  ``frontier`` is the carried global active count
    entering the level — no new reduction — and ``flush`` mirrors
    ``was_push`` only when ``trace_flush`` is set (the async placement,
    where the globally-checked step's "push" IS the outbox flush).  The
    trace rides its own carry slot and never feeds state/frontier, so
    results are bit-identical with tracing on or off; with ``trace_len=0``
    the carry (and the compiled loop) is exactly the untraced one.
    """
    traced = int(trace_len) > 0

    def cond(carry):
        it, alive = carry[2], carry[3]
        return jnp.logical_and(alive > 0, it < max_iters)

    def body(carry):
        state, frontier, it, alive, (n_push, n_pull, n_fb) = carry[:5]
        if core.pace is not None:  # async: local micro-steps first
            state, frontier, it = core.pace(state, frontier, it)
        msg = core.msg(state, frontier)
        it_key = jax.random.fold_in(key, it) if key is not None else None
        acc, was_push, fb = core.step(msg, frontier, alive, it_key)
        state, frontier = core.update(state, acc, frontier, it)
        out = (state, frontier, it + 1, core.count(frontier),
               (n_push + was_push, n_pull + (1 - was_push), n_fb + fb))
        if traced:
            tr, row = carry[5]
            rec = jnp.stack([alive, was_push, fb,
                             was_push if trace_flush else jnp.int32(0)])
            # row, not it: async pacing advances `it` by sync_interval per
            # body call, the trace records one row per global check
            out += ((tr.at[row].set(rec, mode="drop"), row + 1),)
        return out

    zero = jnp.int32(0)
    carry0 = (state0, frontier0, zero, core.count(frontier0),
              (zero, zero, zero))
    if traced:
        carry0 += ((jnp.zeros((int(trace_len), 4), jnp.int32), zero),)
    fin = lax.while_loop(cond, body, carry0)
    state, it, (n_push, n_pull, n_fb) = fin[0], fin[2], fin[4]
    stats = {"iters": it, "pushes": n_push, "pulls": n_pull,
             "fallbacks": n_fb}
    if traced:
        stats["trace"] = fin[5][0]
    return state, stats


def _scan_steps(body, carry, xs):
    """The engine's ONE fixed-length scan call site.  Both fixed-length
    iteration shapes — `run_queue`'s per-iteration body and the async
    placement's collective-free micro-step pacing — lower to this helper, so
    the `single-core` rule's ≤1-scan budget keeps a second stepping loop from
    regrowing unnoticed (the exhaustion loop stays `_core_loop`'s
    while_loop)."""
    return lax.scan(body, carry, xs)


def _direction_step(dense, sparse, mode: str, threshold):
    """The shared direction-switch plumbing: 'pull' always takes the dense
    step; 'push'/'auto' take the sparse step while the carried active count
    fits ``threshold`` (a frontier over the push capacity would be silently
    truncated by the size=C nonzero, so oversized levels fall back to dense —
    with push's default capacity C=n the fallback never fires)."""
    if mode == "pull":
        def step(msg, frontier, alive, it_key):
            return dense(msg, frontier, it_key), jnp.int32(0), jnp.int32(0)
        return step

    def step(msg, frontier, alive, it_key):
        small = alive <= threshold
        acc = lax.cond(small, lambda: sparse(msg, frontier, it_key),
                       lambda: dense(msg, frontier, it_key))
        return acc, small.astype(jnp.int32), jnp.int32(0)
    return step


# ---------------------------------------------------------------------------
# Local placement
# ---------------------------------------------------------------------------

_DST_SORTED_CACHE: dict = {}


# trace-safe: deliberate pre-trace CSR host pull — indptr/indices are
# concrete graph structure and the sorted stream is memoized per graph
def _dst_sorted_stream(csr: CSR):  # repro-lint: disable=host-sync
    """(src, dst) edge stream sorted by destination — the packed dense step's
    presorted segment_or input.  Graph-only data, so the O(m log m) host sort
    is memoized per CSR (eager callers would otherwise pay it every call);
    derived from indptr/indices with numpy — concrete even under jit, like
    _max_degree's indptr (row_ids() would trace).  The cache holds *numpy*
    arrays: device arrays materialized inside a jit trace are constants of
    that trace, and caching those leaks tracers into later traces.  Keyed by
    object identity with a weakref guard, so entries die with their graph
    and a recycled id cannot alias."""
    key = id(csr)
    hit = _DST_SORTED_CACHE.get(key)
    if hit is None or hit[0]() is not csr:
        indptr_np = np.asarray(csr.indptr)
        cols_np = np.asarray(csr.indices)
        rows_np = np.repeat(np.arange(csr.n_rows, dtype=np.int32),
                            np.diff(indptr_np))
        order = np.argsort(cols_np, kind="stable")
        hit = (None, rows_np[order], cols_np[order].astype(np.int32))
        try:
            ref = weakref.ref(csr,
                              lambda _, k=key: _DST_SORTED_CACHE.pop(k, None))
            hit = (ref,) + hit[1:]
            _DST_SORTED_CACHE[key] = hit
        except TypeError:
            pass  # un-weakrefable: skip caching rather than leak
    return jnp.asarray(hit[1]), jnp.asarray(hit[2])


def _local_core(csr: CSR, prog: VertexProgram, lanes: str, *, mode: str,
                C: int, k: int, kernel_bb: Optional[BBCSR],
                interpret) -> ExecutionCore:
    """Plan the local placement: lower (prog, lanes, mode) to an
    :class:`ExecutionCore` whose dense/sparse steps run on this device."""
    msg_of, update, union = _lane_ops(prog, lanes)
    n = csr.n_rows
    rows, cols = csr.row_ids(), csr.indices
    vals = csr.values
    if prog.edge_op == "copy":
        vals = None
    elif vals is None:
        vals = jnp.ones_like(csr.indices, jnp.float32)
    if lanes == "packed":
        p_src, p_dst = _dst_sorted_stream(csr)

    if lanes == "scalar":
        def dense(msg, frontier, it_key):
            if kernel_bb is not None:
                from ..kernels import ops as kops
                if prog.combine == "add":
                    return kops.spmv_dma(kernel_bb, msg,
                                         interpret=interpret)[:n]
                # min/max: the SpMSpV kernel with every tile active is the
                # dense pass (there is no separate dense-combine kernel)
                all_active = jnp.ones((kernel_bb.n_tiles,), jnp.int32)
                return kops.spmspv_dma(kernel_bb, msg, all_active,
                                       combine=prog.combine,
                                       interpret=interpret)[:n]
            return _dense_step(rows, cols, vals, msg, n, prog, it_key)

        def sparse(msg, frontier, it_key):
            if kernel_bb is not None:
                from ..kernels import ops as kops
                return kops.spmspv_dma(kernel_bb, msg,
                                       tile_active(kernel_bb, frontier),
                                       combine=prog.combine,
                                       interpret=interpret)[:n]
            return _sparse_step(csr.indptr, csr.indices, vals, msg, frontier,
                                n, C, k, prog, it_key)

    elif lanes == "packed":
        def dense(msg, frontier, it_key):
            return offload.segment_or(p_dst, jnp.take(msg, p_src, axis=0), n,
                                      presorted=True)

        def sparse(msg, frontier, it_key):
            ids, = jnp.nonzero(union(frontier), size=C, fill_value=-1)
            ecols, _, valid, _ = _gather_rows(csr.indptr, csr.indices, vals,
                                              ids, k)
            safe = jnp.maximum(ids, 0)
            idx = jnp.where(valid, ecols, -1).reshape(-1)       # (C*k,)
            em = jnp.take(msg, safe, axis=0)                    # (C, W)
            words = jnp.broadcast_to(em[:, None, :], (C, k, em.shape[1]))
            return offload.segment_or(idx, words.reshape(C * k, -1), n)

    else:  # valued
        def dense(msg, frontier, it_key):
            if kernel_bb is not None:
                return _kernel_lanes(kernel_bb, msg, prog,
                                     jnp.ones((kernel_bb.n_tiles,), jnp.int32),
                                     interpret)
            em = jnp.take(msg, rows, axis=1)                    # (B, m)
            ev = _apply_edge(em, vals[None, :], prog.edge_op) \
                if vals is not None else em
            if prog.combine == "add":
                return jax.ops.segment_sum(ev.T, cols, num_segments=n).T
            acc = jnp.full((n, ev.shape[0]), prog.ident, msg.dtype)
            return _scatter_combine(acc, cols, ev.T, prog.combine,
                                    prog.ident).T

        def sparse(msg, frontier, it_key):
            if kernel_bb is not None:
                uf = union(frontier).astype(jnp.int32)
                return _kernel_lanes(kernel_bb, msg, prog,
                                     tile_active(kernel_bb, uf), interpret)
            ids, = jnp.nonzero(union(frontier), size=C, fill_value=-1)
            ecols, w, valid, _ = _gather_rows(csr.indptr, csr.indices, vals,
                                              ids, k)
            safe = jnp.maximum(ids, 0)
            idx = jnp.where(valid, ecols, -1).reshape(-1)       # (C*k,)
            em = jnp.take(msg, safe, axis=1)                    # (B, C)
            contrib = _apply_edge(em[:, :, None], w[None, :, :], prog.edge_op) \
                if prog.edge_op != "copy" else jnp.broadcast_to(
                    em[:, :, None], (em.shape[0], C, k))
            contrib = jnp.where(valid[None, :, :], contrib,
                                jnp.asarray(prog.ident, msg.dtype))
            B = em.shape[0]
            acc = jnp.full((n, B), prog.ident, msg.dtype)
            return _scatter_combine(acc, idx, contrib.reshape(B, C * k).T,
                                    prog.combine, prog.ident).T

    return ExecutionCore(
        msg=msg_of, step=_direction_step(dense, sparse, mode, C),
        update=update,
        count=lambda f: union(f).astype(jnp.int32).sum())


def _trace_len_of(trace: bool, trace_len, max_iters, return_stats: bool) -> int:
    """Resolve the runners' (trace, trace_len) opt-in to a static buffer
    length (0 = tracing off).  The trace rides the stats dict, so tracing
    requires return_stats; the default buffer covers min(max_iters, 512)
    levels (per-level rows, so even async runs — whose `max_iters` counts
    micro-steps — rarely drop rows)."""
    if not trace:
        if trace_len is not None:
            raise ValueError("trace_len is only meaningful with trace=True")
        return 0
    if not return_stats:
        raise ValueError("trace=True returns stats['trace']: pass "
                         "return_stats=True as well")
    n = int(trace_len) if trace_len is not None else min(int(max_iters), 512)
    if n < 1:
        raise ValueError(f"trace_len must be >= 1, got {n}")
    return n


def _run_local(csr: CSR, prog: VertexProgram, lanes: str, state0, frontier0,
               *, max_iters, mode, push_capacity, kernel_bb, interpret, key,
               return_stats, trace_len: int = 0):
    """Shared local wrapper: validate, plan a local ExecutionCore, loop."""
    if mode not in ("auto", "push", "pull"):
        raise ValueError(f"mode must be 'auto', 'push' or 'pull', got {mode!r}")
    n = csr.n_rows
    k = _max_degree(csr.indptr) if mode != "pull" else 1
    if push_capacity is None:
        push_capacity = n if mode == "push" else max(1, n // 32)
    C = min(push_capacity, n)
    if kernel_bb is not None:
        _check_kernel_operand(prog, kernel_bb)
    core = _local_core(csr, prog, lanes, mode=mode, C=C, k=k,
                       kernel_bb=kernel_bb, interpret=interpret)
    state, stats = _core_loop(core, state0, frontier0, max_iters=max_iters,
                              key=key, trace_len=trace_len)
    if return_stats:
        keys = ("iters", "pushes", "pulls") + \
            (("trace",) if trace_len else ())
        return state, {k_: stats[k_] for k_ in keys}
    return state


def run(csr: CSR, prog: VertexProgram, state0: Any, frontier0: jnp.ndarray, *,
        max_iters: int, mode: str = "auto", push_capacity: Optional[int] = None,
        kernel_bb: Optional[BBCSR] = None, interpret: Optional[bool] = None,
        key: Optional[jax.Array] = None, return_stats: bool = False,
        trace: bool = False, trace_len: Optional[int] = None):
    """Run `prog` to frontier exhaustion (or `max_iters`).

    The (scalar lanes, local placement) point of the ExecutionCore grid.

    Warm-start contract (the streaming-repair seed, DESIGN.md §16):
    `state0` need not be the program's cold initial state — any *feasible*
    labeling works, with `frontier0` marking the vertices whose outgoing
    relaxations might still fire.  For a monotone (min-combining) program
    the fixpoint is schedule-independent, so running from an old fixpoint
    plus a changed-endpoint frontier lands bit-identically on the
    from-scratch result (`algorithms.incremental` builds on exactly this).

    mode: 'auto' (direction-optimizing), 'push' (always sparse), 'pull'
      (always dense).  'auto' switches on the frontier population count:
      sparse while it fits `push_capacity` (default n/32), dense otherwise.
    kernel_bb: BBCSR of A^T (see `build_pull_operand`) — routes both
      directions through the Pallas SpMV/SpMSpV kernels (combine='add' only).
    key: PRNG key, required for combine='sample' (folded per iteration).
    return_stats: also return {'iters', 'pushes', 'pulls'} taken.
    trace: with return_stats, also record the fixed-length per-level trace
      (``stats['trace']``, decoded by `repro.obs.decode_level_trace`) —
      results are bit-identical trace on or off.  trace_len overrides the
      default min(max_iters, 512)-row buffer.
    """
    if prog.combine == "or":
        raise ValueError("combine='or' is the batched bitwise combine: run it "
                         "through run_batched")
    if prog.combine == "sample" and key is None:
        raise ValueError("combine='sample' draws keyed priorities: pass key=")
    return _run_local(csr, prog, "scalar", state0, frontier0,
                      max_iters=max_iters, mode=mode,
                      push_capacity=push_capacity, kernel_bb=kernel_bb,
                      interpret=interpret, key=key, return_stats=return_stats,
                      trace_len=_trace_len_of(trace, trace_len, max_iters,
                                              return_stats))


def run_batched(csr: CSR, prog: VertexProgram, state0: Any,
                frontier0: jnp.ndarray, *, max_iters: int, mode: str = "auto",
                push_capacity: Optional[int] = None,
                kernel_bb: Optional[BBCSR] = None,
                interpret: Optional[bool] = None, return_stats: bool = False,
                trace: bool = False, trace_len: Optional[int] = None):
    """Run ``prog`` for a *batch* of sources in one pass over the graph.

    The (valued | packed lanes, local placement) points of the ExecutionCore
    grid.  PIUMA hides latency by keeping many traversals in flight per core;
    the bulk-array re-expression is MS-BFS-style lane batching: per iteration
    the engine scans the edges touched by the **union frontier** once and
    carries all B lanes' payloads through that single scan, so the
    irregular-access cost (gathers, compaction, routing) is amortized B ways.
    Two lane representations:

    * ``combine='or'`` — **bit-packed boolean lanes**: frontier and messages
      are (n, W) uint32 words, W = ceil(B/32); the destination combine is a
      bitwise OR (:func:`offload.segment_or`).  The program is written
      against packed words (state may keep unpacked per-lane planes — see
      ``bfs.msbfs_program``).
    * any scalar combine — **vmapped valued lanes**: frontier/state leaves
      are (B, n) and ``msg_fn``/``update_fn`` are the *single-source*
      functions, vmapped over the lane axis; the per-edge work is one fused
      (m, B) pass.  Results are bit-identical to B separate :func:`run`
      calls: each lane sees the same per-edge arithmetic, and lanes whose
      frontier has emptied emit combine identities (no-ops) until the whole
      batch drains.

    mode: as :func:`run`; 'auto' switches on the union frontier's population
      count.  kernel_bb routes the valued dense/sparse steps through the
      Pallas kernels (combine 'add', or 'min'/'max' via the masked-select
      tile combine), one lane per kernel launch under ``lax.map`` with the
      union-frontier tile schedule shared across lanes.
    Returns the final state (leaves (B, n)); ``return_stats`` adds
    {'iters', 'pushes', 'pulls'}; ``trace``/``trace_len`` as :func:`run`
    (the per-level rows describe the shared union-frontier scan).
    """
    if prog.structured:
        raise NotImplementedError(
            "structured combines are not lane-batched: sampling is already "
            "batch-shaped (sample_neighbors), label modes are one-shot")
    packed = prog.combine == "or"
    if kernel_bb is not None and packed:
        raise ValueError("the Pallas path carries f32 payloads: bit-packed"
                         " 'or' lanes have no kernel combine")
    return _run_local(csr, prog, "packed" if packed else "valued", state0,
                      frontier0, max_iters=max_iters, mode=mode,
                      push_capacity=push_capacity, kernel_bb=kernel_bb,
                      interpret=interpret, key=None, return_stats=return_stats,
                      trace_len=_trace_len_of(trace, trace_len, max_iters,
                                              return_stats))


def _kernel_lanes(bb: BBCSR, msg: jnp.ndarray, prog: VertexProgram,
                  tile_sched: jnp.ndarray, interpret) -> jnp.ndarray:
    """One Pallas SpMV/SpMSpV launch per lane (lax.map keeps it a single
    compilation), sharing the union-frontier tile schedule: a tile inactive
    for every lane is skipped for all of them, and lanes inactive on an
    active tile contribute combine identities."""
    from ..kernels import ops as kops
    n = bb.n_rows

    def one(msg_b):
        return kops.spmspv_dma(bb, msg_b, tile_sched, combine=prog.combine,
                               interpret=interpret)[:n]

    return lax.map(one, msg)


# ---------------------------------------------------------------------------
# Multi-level pipeline (hierarchy of coarsened graphs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """A chain of coarsening maps from a :func:`run_multilevel` run.

    ``maps[l]`` is the (n_l,) int32 map from level-l vertex to its level-(l+1)
    supernode, so the hierarchy is itself a graph-of-graphs: the level-(l+1)
    graph is the level-l graph contracted along ``maps[l]``.
    """

    maps: tuple

    @property
    def n_levels(self) -> int:
        return len(self.maps)

    def project(self, top: jnp.ndarray) -> jnp.ndarray:
        """Pull a per-vertex array at the *top* (coarsest) level down to level
        0 by composing the maps: out[v] = top[maps[-1][... maps[0][v]]]."""
        x = jnp.asarray(top)
        for m in reversed(self.maps):
            x = jnp.take(x, jnp.asarray(m), axis=0)
        return x


# trace-safe: deliberately host-driven — each level's shapes depend on the
# previous level's readback, so the float() syncs ARE the control flow
def run_multilevel(csr: CSR, level_fn: Callable, contract_fn: Callable,  # repro-lint: disable=host-sync
                   score_fn: Callable, *, max_levels: int = 10,
                   tol: float = 1e-4):
    """Generic cluster-then-contract level pipeline (multi-level Louvain's
    loop shape, program-agnostic — the distributed driver runs this same
    loop with sharded closures).

    Per level: ``level_fn(g, level) -> (n_l,) assignment`` (typically an
    engine :func:`run` whose VertexProgram state re-seeds from the coarse
    identity labeling — the level pipeline reuses one program across every
    level), ``score_fn(g, assign) -> float`` scores the raw assignment (for
    Louvain: modularity, invariant to both label renumbering and
    contraction, so the per-level score *is* the level-0 score of the
    projected labels), and — only if the level is accepted —
    ``contract_fn(g, assign) -> (coarse_g, renumber)`` collapses it.

    Stall criterion: a level is **accepted only if it improves the score by
    more than ``tol``** over the previous accepted level (level 0 must beat
    the singleton baseline ``score_fn(csr, arange)``); the first
    non-improving level is discarded — without paying for its contraction —
    and the loop stops, so the returned per-level score trace is strictly
    increasing by construction.  Also stops when a level no longer shrinks
    the graph.

    Host-driven loop (each level's shapes are data-dependent); the per-level
    work inside ``level_fn`` stays jitted engine machinery.

    Returns ``(labels0, hierarchy, scores)``: the level-0 projection of the
    final clustering, the :class:`Hierarchy` of accepted coarsening maps, and
    the accepted levels' scores.
    """
    g = csr
    maps, scores = [], []
    q_prev = float(score_fn(g, jnp.arange(g.n_rows, dtype=jnp.int32)))
    for level in range(max_levels):
        assign = level_fn(g, level)
        q = float(score_fn(g, assign))
        if not np.isfinite(q) or q <= q_prev + tol:
            break
        coarse, renumber = contract_fn(g, assign)
        maps.append(renumber)
        scores.append(q)
        q_prev = q
        no_shrink = coarse.n_rows >= g.n_rows
        g = coarse
        if no_shrink:
            break
    hier = Hierarchy(tuple(maps))
    n_top = g.n_rows if maps else csr.n_rows
    labels0 = hier.project(jnp.arange(n_top, dtype=jnp.int32))
    return labels0, hier, scores


# ---------------------------------------------------------------------------
# Distributed placement (owns the shard_map/ATT boilerplate, once)
# ---------------------------------------------------------------------------

def _axes_list(axis: AxisName):
    return [axis] if isinstance(axis, str) else list(axis)


def _spec(axis: AxisName) -> P:
    return P(axis) if isinstance(axis, str) else P(tuple(axis))


def _axis_key(axis: AxisName):
    return axis if isinstance(axis, str) else tuple(axis)


def _mesh_key(mesh):
    try:
        hash(mesh)
        return mesh
    except TypeError:
        return id(mesh)


# trace-safe: ATT boundaries are concrete placement metadata fixed at mesh
# setup; the pull makes them a hashable compile-cache key component
def _att_key(att: ATT):  # repro-lint: disable=host-sync
    return (att.kind, att.n_global, att.n_shards,
            tuple(np.asarray(att.boundaries).tolist()))


_MAPPED_CACHE: dict = {}
_MAPPED_CACHE_MAX = 64


def cached_mapped(key, build, *, ident=None):
    """One keying scheme for every compiled ``shard_map`` wrapper in the repo
    — the engine's distributed placements and the algorithm layer's sweeps
    (louvain) share this cache, so re-tracing never dominates wall clock on
    forced multi-device hosts.

    ``key`` must capture every *structural* input baked into the closure
    (mesh, axis, ATT rule, capacities, operand shapes/dtypes, flags);
    ``ident`` guards the parts a tuple key cannot: the entry only hits while
    ``ident`` is the *same object* (e.g. the VertexProgram whose closures the
    shard function captured — a rebuilt program rebuilds the wrapper, so a
    different delta/damping baked into an update_fn can never be served
    stale).  Entries hold strong refs, bounded FIFO at ``_MAPPED_CACHE_MAX``.
    """
    hit = _MAPPED_CACHE.get(key)
    if hit is not None and hit[0] is ident:
        return hit[1]
    while len(_MAPPED_CACHE) >= _MAPPED_CACHE_MAX:
        _MAPPED_CACHE.pop(next(iter(_MAPPED_CACHE)))
    fn = build()
    _MAPPED_CACHE[key] = (ident, fn)
    return fn


def _shard_apply(mesh: Mesh, axis: AxisName, shard_fn, operands, *,
                 check_rep: bool = False, cache_key=None, ident=None):
    """The shard_map boilerplate, owned once: spec construction, the
    stacked-operand in_specs, pytree-broadcast out_specs, and (optionally)
    the `cached_mapped` wrapper reuse."""
    spec = _spec(axis)

    def build():
        # check_rep=False (default): this jax has no replication rule for
        # while_loop with a psum in its cond; outputs are per-shard anyway.
        return shard_map(shard_fn, mesh=mesh, in_specs=(spec,) * len(operands),
                         out_specs=spec, check_rep=check_rep)

    mapped = build() if cache_key is None else cached_mapped(cache_key, build,
                                                             ident=ident)
    return mapped(*operands)


def frontier_edge_capacity(m: int, switch_frac: float, *,
                           slack: Optional[float] = None,
                           n: Optional[int] = None) -> int:
    """Per-peer routing capacity for the compacted sparse push.

    While the engine is in the push regime the frontier holds at most
    ``switch_frac * n`` vertices, so with edges spread uniformly a shard sees
    ≈ ``switch_frac * m`` active edges; ``slack`` covers degree skew.  Levels
    that overflow this capacity fall back to full-capacity routing at
    runtime, so the rule trades traffic (capacity shrinks with the frontier
    bound) against fallback frequency — see DESIGN.md §7 and
    `traffic.push_level_route_bytes` for the byte model the capacity feeds.

    slack: None takes the tuned ``engine.push_slack`` (``repro.tune``) for
    this backend and graph scale; ``n`` (global vertex count) keys that
    lookup when the caller knows it.
    """
    slack = _tune.resolve("engine.push_slack", explicit=slack, n=n)
    return max(1, min(m, int(m * switch_frac * slack)))


def _active_edge_mask(src, frontier, att: ATT):
    """Per-shard mask of edges whose (owned) source is in the frontier —
    computed once per level and shared by the overflow count and the
    compaction (they sit on opposite sides of a `lax.cond`, so CSE across
    the boundary is not guaranteed)."""
    local_src = jnp.where(src >= 0, att.local(jnp.maximum(src, 0)), 0)
    return (src >= 0) & (jnp.take(frontier, local_src) > 0)


def _compact_slots(valid, cap: int):
    """Stable compaction order shared by the frontier push (active edges into
    the routing capacity) and the queue runner (live entries to a prefix):
    positions of the True entries, in order, padded with -1 to ``cap``."""
    slots, = jnp.nonzero(valid, size=cap, fill_value=-1)
    return slots


def _compact_active_edges(src, dst, val, active, cap: int):
    """Frontier-proportional payload: keep only the edges the `active` mask
    names, compacted into ``cap`` slots (the distributed analogue of the
    local push step's frontier extraction).
    Returns (src, dst, val) of length ``cap``, padded with src = dst = -1.
    """
    slots = _compact_slots(active, cap)
    ssafe = jnp.maximum(slots, 0)
    keep = slots >= 0
    return (jnp.where(keep, jnp.take(src, ssafe), -1),
            jnp.where(keep, jnp.take(dst, ssafe), -1),
            jnp.where(keep, jnp.take(val, ssafe), 0.0))


def _push_step_shard(src, dst, val, msg, att: ATT, axis, prog: VertexProgram,
                     capacity: int):
    """Scalar-lane push: owner of src computes contributions locally,
    remote-combines at the dst owner (PIUMA remote atomic).  ``capacity`` is
    the per-peer routing budget — ``_route`` moves O(S * capacity) bytes, so
    a compacted edge list with a small capacity makes the level's traffic
    proportional to the active frontier instead of the full edge partition."""
    local_src = jnp.where(src >= 0, att.local(jnp.maximum(src, 0)), 0)
    gidx = jnp.where(src >= 0, dst, -1)
    if prog.structured:
        payload = offload.dma_gather(msg, local_src, fill=-1).astype(jnp.int32)
        payload = jnp.where(src >= 0, payload, -1)
        w = val if prog.edge_op == "mul" else jnp.ones_like(val)
        if prog.combine != "argmax_weighted":
            raise NotImplementedError(
                "distributed combine='sample' is queue-shaped work: run it "
                "through run_queue / sample_neighbors instead")
        return offload.remote_scatter_weighted_mode(
            att.per_shard, gidx, payload, w, att, axis, capacity=capacity)
    em = offload.dma_gather(msg, local_src, fill=prog.ident)
    em = jnp.where(src >= 0, em, jnp.asarray(prog.ident, msg.dtype))
    ev = _apply_edge(em, val, prog.edge_op) if prog.edge_op != "copy" else em
    ev = jnp.where(src >= 0, ev, jnp.asarray(prog.ident, msg.dtype))
    acc = _acc_init(att.per_shard, prog, msg.dtype)
    if prog.combine == "add":
        return offload.remote_scatter_add(acc, gidx, ev, att, axis,
                                          capacity=capacity)
    return offload.remote_scatter_combine(acc, gidx, ev, att, axis,
                                          combine=prog.combine,
                                          identity=prog.ident,
                                          capacity=capacity)


def _batched_push_step(att: ATT, axis, prog: VertexProgram, packed: bool):
    """Lane-batched push: one routed item per active edge carries **all** B
    lanes (packed words or a trailing valued lane dim) to the dst owner."""

    def push_step(csrc, cdst, cval, msg, cap):
        gidx = jnp.where(csrc >= 0, cdst, -1)
        lsrc = jnp.where(csrc >= 0, att.local(jnp.maximum(csrc, 0)), -1)
        if packed:
            em = offload.dma_gather(msg, lsrc, fill=0).astype(jnp.uint32)
            return offload.remote_scatter_or(att.per_shard, gidx, em,
                                             att, axis, capacity=cap)
        em = offload.dma_gather(msg.T, lsrc, fill=prog.ident)  # (m, B)
        ev = _apply_edge(em, cval[:, None], prog.edge_op) \
            if prog.edge_op != "copy" else em
        ev = jnp.where((csrc >= 0)[:, None], ev,
                       jnp.asarray(prog.ident, em.dtype))
        B = msg.shape[0]
        if prog.combine == "add":
            acc = offload.remote_scatter_add(
                jnp.zeros((att.per_shard, B), msg.dtype), gidx, ev,
                att, axis, capacity=cap)
        else:
            acc = offload.remote_scatter_combine(
                jnp.full((att.per_shard, B), prog.ident, msg.dtype),
                gidx, ev, att, axis, combine=prog.combine,
                identity=prog.ident, capacity=cap)
        return acc.T                                           # (B, per)

    return push_step


def _push_dispatch(push_step, src, dst, val, att: ATT, axes, union,
                   edge_cap: int, m_fwd: int, compact: bool):
    """The §7 compaction plumbing, shared by every distributed lane rep:
    when the globally-agreed active-edge count fits ``edge_cap`` the level
    routes a compacted edge list at that small capacity; overflowing levels
    fall back to full-capacity routing (the fallback counter's numerator)."""

    def push(msg, frontier):
        if not compact:
            return push_step(src, dst, val, msg, m_fwd), jnp.int32(0)
        active = _active_edge_mask(src, union(frontier), att)
        # every shard must take the same branch: reduce the overflow flag
        over = offload.hierarchical_psum(
            (active.astype(jnp.int32).sum() > edge_cap
             ).astype(jnp.int32), axes)

        def compacted():
            csrc, cdst, cval = _compact_active_edges(src, dst, val, active,
                                                     edge_cap)
            return push_step(csrc, cdst, cval, msg, edge_cap)

        acc = lax.cond(over == 0, compacted,
                       lambda: push_step(src, dst, val, msg, m_fwd))
        return acc, (over > 0).astype(jnp.int32)

    return push


def _pull_step_shard(own, remote, val, msg, att_in: ATT, att_out: ATT, axis,
                     prog: VertexProgram, capacity: int, gather_mode: str):
    """Pull: owner of the *output* vertex fetches messages from the input
    owners (fine-grained dgas_gather, or the all_gather baseline) and reduces
    locally."""
    gidx = jnp.where(remote >= 0, remote, -1)
    if prog.structured:
        if prog.combine != "argmax_weighted":
            raise NotImplementedError(
                "distributed combine='sample' is queue-shaped work: run it "
                "through run_queue / sample_neighbors instead")
        payload = offload.dgas_gather(msg, gidx, att_in, axis,
                                      capacity=capacity, fill=-1)
        payload = payload.astype(jnp.int32)
        w = val if prog.edge_op == "mul" else jnp.ones_like(val)
        local_own = jnp.where(own >= 0, att_out.local(jnp.maximum(own, 0)), -1)
        return offload.segment_weighted_mode(local_own, payload, w,
                                             att_out.per_shard)
    if gather_mode == "dgas":
        em = offload.dgas_gather(msg, gidx, att_in, axis, capacity=capacity,
                                 fill=prog.ident)
    else:
        em = offload.all_gather_gather(msg, gidx, att_in, axis, fill=prog.ident)
    ev = _apply_edge(em, val, prog.edge_op) if prog.edge_op != "copy" else em
    ev = jnp.where(own >= 0, ev, jnp.asarray(prog.ident, msg.dtype))
    local_own = jnp.where(own >= 0, att_out.local(jnp.maximum(own, 0)), -1)
    acc = _acc_init(att_out.per_shard, prog, msg.dtype)
    if prog.combine == "add":
        return offload.dma_scatter_add(acc, local_own, ev)
    return _scatter_combine(acc, local_own, ev, prog.combine, prog.ident)


def _async_split(src, dst, val, att: ATT, axis, prog: VertexProgram,
                 lanes: str):
    """Plan the async placement's *split* push pass (DESIGN.md §14).

    Every resident edge is either **local** (destination owned by this shard
    — its contribution is applied to the local accumulator immediately) or
    **remote** (its contribution is folded into the dense ``(S*per, ...)``
    outbox at `ATT.flat_slot`, to be delivered by the next
    `offload.buffered_flush`).  A pass is completely collective-free, which
    is what lets the pacing scan run K of them between global checks.

    Returns ``(pass_, orient, merge, outbox0)``:
      pass_(msg, outbox) -> (acc, outbox) — acc is the (per[, lanes]) local
        accumulator in scatter layout, outbox the updated deferred buffer.
      orient(acc) — scatter layout -> the update_fn's lane layout
        (transpose for valued lanes, identity otherwise).
      merge(a, b) — the program's combine, elementwise (folds flushed
        arrivals into the local accumulator; both in scatter layout).
      outbox0(msg_aval) -> identity-filled outbox for the msg shape/dtype.
    """
    per = att.per_shard
    me = offload.my_shard(axis)
    in_range = src >= 0
    lsrc = jnp.where(in_range, att.local(jnp.maximum(src, 0)), -1)
    d_safe = jnp.maximum(dst, 0)
    is_local = in_range & (att.owner(d_safe) == me)
    lidx = jnp.where(is_local, att.local(d_safe), -1)
    ridx = jnp.where(in_range & ~is_local, att.flat_slot(d_safe), -1)

    if lanes == "packed":
        def pass_(msg, outbox):
            em = offload.dma_gather(msg, lsrc, fill=0).astype(jnp.uint32)
            acc = offload.segment_or(lidx, em, per)
            outbox = outbox | offload.segment_or(ridx, em, outbox.shape[0])
            return acc, outbox

        orient = lambda a: a
        merge = jnp.bitwise_or
    else:
        def pass_(msg, outbox):
            flat = msg.T if lanes == "valued" else msg       # gather by row
            em = offload.dma_gather(flat, lsrc, fill=prog.ident)
            ev = val if lanes == "scalar" else val[:, None]
            ev = _apply_edge(em, ev, prog.edge_op) \
                if prog.edge_op != "copy" else em
            mask = in_range if lanes == "scalar" else in_range[:, None]
            ev = jnp.where(mask, ev, jnp.asarray(prog.ident, em.dtype))
            acc0 = jnp.full((per,) + ev.shape[1:], prog.ident, em.dtype)
            acc = _scatter_combine(acc0, lidx, ev, prog.combine, prog.ident)
            outbox = _scatter_combine(outbox, ridx, ev, prog.combine,
                                      prog.ident)
            return acc, outbox

        orient = (lambda a: a.T) if lanes == "valued" else (lambda a: a)
        merge = {"add": jnp.add, "min": jnp.minimum,
                 "max": jnp.maximum}[prog.combine]

    def outbox0(msg_aval):
        if lanes == "packed":
            return jnp.zeros((att.n_shards * per,) + tuple(msg_aval.shape[1:]),
                             jnp.uint32)
        trail = (msg_aval.shape[0],) if lanes == "valued" else ()
        return jnp.full((att.n_shards * per,) + trail, prog.ident,
                        msg_aval.dtype)

    return pass_, orient, merge, outbox0


def reverse_graph(csr: CSR, att: ATT) -> ShardedGraph:
    """Shard the *transposed* edge list by destination owner (= `att`, the
    vertex rule) for the distributed pull direction."""
    from .algorithms.distgraph import shard_graph
    g_rev, _ = shard_graph(csr.transpose(), att.n_shards, row_att=att)
    return g_rev


def _run_distributed(g: ShardedGraph, att: ATT, mesh: Mesh,
                     prog: VertexProgram, state0: Any, frontier0: jnp.ndarray,
                     *, lanes: str, axis, max_iters: int, mode: str,
                     switch_frac: Optional[float], push_edge_capacity,
                     g_rev, return_stats: bool, placement: str = "sync",
                     sync_interval: int = 1, trace_len: int = 0):
    """Shared distributed wrapper: plan a sharded ExecutionCore and run the
    single stepping loop inside one shard_map (cached via `cached_mapped`).

    placement 'sync' is the per-level bulk-synchronous engine; 'async' is the
    bounded-staleness variant: sync_interval-1 collective-free local
    micro-steps (`_async_split` + the pacing scan) between global checks,
    each check being one `offload.buffered_flush` + the termination psum.
    """
    if placement not in ("sync", "async"):
        raise ValueError(
            f"placement must be 'sync' or 'async', got {placement!r}")
    if placement == "async":
        if prog.structured:
            raise NotImplementedError(
                "the async placement defers messages in a dense combine "
                "outbox: structured combines (argmax_weighted/sample) have "
                "no identity-mergeable buffer entry")
        if mode != "push":
            raise ValueError("the async placement paces the split "
                             "local/remote push pass: mode must be 'push'")
        if sync_interval < 1:
            raise ValueError(f"sync_interval must be >= 1, got {sync_interval}")
    axis = axis if axis is not None else mesh.axis_names[0]
    axes = _axes_list(axis)
    # tuned-config funnel (DESIGN.md §18): a caller's explicit switch_frac /
    # push_edge_capacity wins; None consults TUNED.json for this backend and
    # graph scale, then the hand-picked default
    switch_frac = _tune.resolve("engine.switch_frac",
                                explicit=switch_frac, n=att.n_global)
    switch_count = max(1, int(att.n_global * switch_frac))
    state_leaves, state_def = jax.tree.flatten(state0)
    n_state = len(state_leaves)
    use_rev = g_rev is not None
    m_fwd = g.edges_per_shard
    m_rev = g_rev.edges_per_shard if use_rev else 0
    if push_edge_capacity is None:
        edge_cap = frontier_edge_capacity(m_fwd, switch_frac,
                                          n=att.n_global)
    else:
        edge_cap = int(push_edge_capacity)
    compact = mode != "pull" and 0 < edge_cap < m_fwd

    def shard_fn(src, dst, val, rsrc, rdst, rval, frontier, *leaves):
        src, dst, val = src[0], dst[0], val[0]
        rsrc, rdst, rval = rsrc[0], rdst[0], rval[0]
        frontier = frontier[0]
        state = jax.tree.unflatten(state_def, [l[0] for l in leaves])
        msg_of, update, union = _lane_ops(prog, lanes)

        def count(f):
            return offload.hierarchical_psum(
                union(f).astype(jnp.int32).sum(), axes)

        if placement == "async":
            # Bounded-staleness pacing: the carried state is (state, outbox).
            # Each loop body = sync_interval-1 collective-free split passes
            # (pace), then one globally-checked step: split pass + one
            # buffered_flush delivering every deferred remote contribution +
            # the termination psum.  The outbox is always fully drained
            # before `count`, so alive == 0 means global quiescence.
            pass_, orient, merge, outbox0 = _async_split(src, dst, val, att,
                                                         axis, prog, lanes)
            box_id = outbox0(jax.eval_shape(msg_of, state, frontier))

            def amsg(wrapped, f):
                st, box = wrapped
                return msg_of(st, f), box

            def astep(msg_box, f, alive, it_key):
                m, box = msg_box
                acc, box = pass_(m, box)
                arrived = offload.buffered_flush(box, axis,
                                                 combine=prog.combine)
                # stats: 'pushes' counts flushes under the async placement
                return orient(merge(acc, arrived)), jnp.int32(1), jnp.int32(0)

            def aupdate(wrapped, acc, f, it):
                st, _ = wrapped
                st, f = update(st, acc, f, it)
                return (st, box_id), f  # flushed: fresh identity outbox

            def apace(wrapped, f, it):
                st, box = wrapped

                def micro(carry, step_it):
                    st_, box_, f_ = carry
                    acc_, box_ = pass_(msg_of(st_, f_), box_)
                    st_, f_ = update(st_, orient(acc_), f_, step_it)
                    return (st_, box_, f_), None

                (st, box, f), _ = _scan_steps(
                    micro, (st, box, f), it + jnp.arange(sync_interval - 1))
                return (st, box), f, it + jnp.int32(sync_interval - 1)

            core = ExecutionCore(
                msg=amsg, step=astep, update=aupdate, count=count,
                pace=apace if sync_interval > 1 else None)
            state = (state, box_id)
        else:
            if lanes == "scalar":
                def push_step(s, d, v, msg, cap):
                    return _push_step_shard(s, d, v, msg, att, axis, prog,
                                            capacity=cap)
            else:
                push_step = _batched_push_step(att, axis, prog,
                                               packed=lanes == "packed")
            push = _push_dispatch(push_step, src, dst, val, att, axes, union,
                                  edge_cap, m_fwd, compact)

            def pull(msg):
                # g_rev rows: src = output vertex (owned here), dst = input
                return _pull_step_shard(rsrc, rdst, rval, msg, att, att, axis,
                                        prog, capacity=m_rev,
                                        gather_mode="dgas")

            if mode == "push":
                def step(msg, frontier, alive, it_key):
                    acc, fb = push(msg, frontier)
                    return acc, jnp.int32(1), fb
            elif mode == "pull":
                def step(msg, frontier, alive, it_key):
                    return pull(msg), jnp.int32(0), jnp.int32(0)
            else:
                def step(msg, frontier, alive, it_key):
                    def do_push():
                        acc, fb = push(msg, frontier)
                        return acc, jnp.int32(1), fb
                    return lax.cond(
                        alive <= switch_count, do_push,
                        lambda: (pull(msg), jnp.int32(0), jnp.int32(0)))

            core = ExecutionCore(msg=msg_of, step=step, update=update,
                                 count=count)
        state, stats = _core_loop(core, state, frontier, max_iters=max_iters,
                                  trace_len=trace_len,
                                  trace_flush=placement == "async")
        if placement == "async":
            state = state[0]  # drop the (drained) outbox
        out = tuple(l[None] for l in jax.tree.leaves(state))
        if return_stats:
            out = out + tuple(stats[k][None] for k in
                              ("iters", "pushes", "pulls", "fallbacks"))
            if trace_len:   # rows are globally-agreed: every shard identical
                out = out + (stats["trace"][None],)
        return out

    if not use_rev:  # placeholder operands keep the shard_map arity static
        z = jnp.full((att.n_shards, 1), -1, jnp.int32)
        rsrc, rdst, rval = z, z, jnp.zeros((att.n_shards, 1), jnp.float32)
    else:
        rsrc, rdst, rval = g_rev.src, g_rev.dst, g_rev.val

    operands = (g.src, g.dst, g.val, rsrc, rdst, rval, frontier0,
                *state_leaves)
    cache_key = ("core", _mesh_key(mesh), _axis_key(axis), _att_key(att),
                 (lanes, mode, int(max_iters), float(switch_frac), edge_cap,
                  compact, use_rev, m_fwd, m_rev, return_stats, state_def,
                  placement, int(sync_interval), int(trace_len)),
                 tuple((tuple(x.shape), str(x.dtype)) for x in operands))
    out = _shard_apply(mesh, axis, shard_fn, operands, cache_key=cache_key,
                       ident=prog)
    state = jax.tree.unflatten(state_def, list(out[:n_state]))
    if return_stats:
        keys = ("iters", "pushes", "pulls", "fallbacks")
        stats = dict(zip(keys, out[n_state:n_state + len(keys)]))
        if trace_len:
            stats["trace"] = out[n_state + len(keys)]
        return state, stats
    return state


def run_distributed(g: ShardedGraph, att: ATT, mesh: Mesh,
                    prog: VertexProgram, state0: Any, frontier0: jnp.ndarray,
                    *, axis: Optional[AxisName] = None, max_iters: int,
                    g_rev: Optional[ShardedGraph] = None, mode: str = "push",
                    switch_frac: Optional[float] = None,
                    push_edge_capacity: Optional[int] = None,
                    return_stats: bool = False, placement: str = "sync",
                    sync_interval: Optional[int] = None,
                    trace: bool = False, trace_len: Optional[int] = None):
    """Distributed loop; `state0`/`frontier0` are stacked (S, per) per `att`.

    The (scalar lanes, distributed placement) point of the ExecutionCore
    grid.

    mode: 'push' (every level scatters via remote atomics — the seed
      behavior), 'pull' (requires `g_rev`; every level gathers via dgas), or
      'auto' (push while the globally-psum'd frontier is below
      `switch_frac * n`, pull once it saturates — Beamer's heuristic).
    switch_frac: the 'auto' switch threshold (and the capacity derivation's
      frontier bound).  None resolves the tuned value for this backend and
      graph scale, then the hand-picked 1/32 (``repro.tune``, DESIGN.md §18).
    placement: 'sync' (one global reduction per level) or 'async'
      (bounded-staleness pacing: each shard runs `sync_interval` local
      micro-steps per global check, deferring cross-shard messages into a
      dense combine outbox flushed once per check — requires mode='push' and
      a non-structured combine; fixpoints are bit-identical to 'sync' for
      monotone programs, see DESIGN.md §14).
    sync_interval: local micro-steps per global check under 'async'
      (default 8; 1 = flush every step, which reproduces the sync schedule).
    push_edge_capacity: per-peer routing capacity for the *compacted* push
      step.  When a level's globally-agreed active-edge count fits, the shard
      compacts active edges with nonzero-into-capacity and routes at this
      small capacity, so sparse levels move O(active edges) bytes instead of
      the full edge partition; overflowing levels fall back to full-capacity
      routing.  None derives `frontier_edge_capacity(m, switch_frac)`; 0
      disables compaction (the seed behavior).
    return_stats: also return {'iters', 'pushes', 'pulls', 'fallbacks'} —
      (S,) int32 arrays, identical on every shard (globally reduced);
      'fallbacks' counts the push levels whose active-edge count overflowed
      the compacted capacity (the §7 fallback rate's numerator).
    trace: with return_stats, record the per-level device trace
      (``stats['trace']``, stacked (S, trace_len, 4) and identical on every
      shard — the rows are built from globally-agreed quantities); under
      'async' each row is one global check and the flush column fires.
    Returns the final state pytree, stacked (S, per).
    """
    if mode not in ("auto", "push", "pull"):
        raise ValueError(f"mode must be 'auto', 'push' or 'pull', got {mode!r}")
    if prog.combine == "or":
        raise ValueError("combine='or' is the batched bitwise combine: run it "
                         "through run_batched_distributed")
    if mode in ("pull", "auto") and g_rev is None:
        raise ValueError(f"mode={mode!r} needs g_rev (see reverse_graph)")
    if sync_interval is None:
        sync_interval = 8 if placement == "async" else 1
    return _run_distributed(g, att, mesh, prog, state0, frontier0,
                            lanes="scalar", axis=axis, max_iters=max_iters,
                            mode=mode, switch_frac=switch_frac,
                            push_edge_capacity=push_edge_capacity,
                            g_rev=g_rev, return_stats=return_stats,
                            placement=placement,
                            sync_interval=int(sync_interval),
                            trace_len=_trace_len_of(trace, trace_len,
                                                    max_iters, return_stats))


def run_batched_distributed(g: ShardedGraph, att: ATT, mesh: Mesh,
                            prog: VertexProgram, state0: Any,
                            frontier0: jnp.ndarray, *,
                            axis: Optional[AxisName] = None, max_iters: int,
                            switch_frac: Optional[float] = None,
                            push_edge_capacity: Optional[int] = None,
                            return_stats: bool = False,
                            placement: str = "sync",
                            sync_interval: Optional[int] = None,
                            trace: bool = False,
                            trace_len: Optional[int] = None):
    """Distributed batched loop: B concurrent traversals, one push pipeline.

    The (valued | packed lanes, distributed placement) points of the
    ExecutionCore grid.  Lane layouts (leading dim S = shard, matching
    :func:`run_batched`):

    * packed (``combine='or'``): frontier0 is (S, per, W) uint32 words and
      the program operates on per-shard (per, W) words directly; the remote
      combine is :func:`offload.remote_scatter_or` at the dst owner.
    * valued: frontier0 is (S, B, per) int32, state leaves (S, B, ...), and
      the single-source ``msg_fn``/``update_fn`` are vmapped over the lane
      axis (collectives inside the program — e.g. SSSP's global bucket min —
      batch elementwise across lanes).

    Every level runs the push direction with the §7 active-edge compaction
    driven by the **union** frontier, so one compacted exchange carries all
    B lanes: a routed item is (idx, validity, B-lane payload) —
    `traffic.batched_payload_bytes` is the byte model, vs B single-source
    exchanges at `ROUTE_PAYLOAD_BYTES` each.  Levels whose active-edge count
    overflows the capacity fall back to full-capacity routing (counted in
    ``stats['fallbacks']``), exactly as in :func:`run_distributed`.

    placement/sync_interval: as :func:`run_distributed` — 'async' paces each
    shard through `sync_interval` local micro-steps per global check (the
    batched engine is already push-only, so every batched program with a
    monotone combine qualifies; under 'async' stats count micro-steps in
    'iters' and buffered flushes in 'pushes').

    Returns the final state pytree stacked (S, ...); ``return_stats`` adds
    {'iters', 'pushes', 'pulls', 'fallbacks'} ((S,) int32, identical on
    every shard; 'pulls' is always 0 — the batched distributed engine is
    push-only).  ``trace``/``trace_len`` as :func:`run_distributed`.
    """
    if prog.structured:
        raise NotImplementedError(
            "structured combines are not lane-batched: sampling is already "
            "batch-shaped (sample_neighbors / run_queue)")
    packed = prog.combine == "or"
    if sync_interval is None:
        sync_interval = 8 if placement == "async" else 1
    return _run_distributed(g, att, mesh, prog, state0, frontier0,
                            lanes="packed" if packed else "valued",
                            axis=axis, max_iters=max_iters, mode="push",
                            switch_frac=switch_frac,
                            push_edge_capacity=push_edge_capacity,
                            g_rev=None, return_stats=return_stats,
                            placement=placement,
                            sync_interval=int(sync_interval),
                            trace_len=_trace_len_of(trace, trace_len,
                                                    max_iters, return_stats))


def spmv_pass(g: ShardedGraph, x_sharded: jnp.ndarray, x_att: ATT,
              row_att: ATT, mesh: Mesh, *, axis: Optional[AxisName] = None,
              mode: str = "dgas") -> jnp.ndarray:
    """One distributed engine pull step == y = A @ x (rows per `row_att`,
    x per `x_att`).  `spmv_distributed` delegates here; kept in the engine so
    SpMV shares the exact same shard step as every frontier algorithm."""
    axis = axis if axis is not None else mesh.axis_names[0]
    prog = VertexProgram(edge_op="mul", combine="add",
                         msg_fn=lambda s, f: s, update_fn=None)

    def shard_fn(src, dst, val, x_local):
        return _pull_step_shard(src[0], dst[0], val[0], x_local[0],
                                x_att, row_att, axis, prog,
                                capacity=g.edges_per_shard,
                                gather_mode=mode)[None]

    return _shard_apply(mesh, axis, shard_fn, (g.src, g.dst, g.val, x_sharded),
                        check_rep=True)


# ---------------------------------------------------------------------------
# Queue-driven programs (the second program family: work entries, not bitmaps)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueueProgram:
    """An algorithm whose unit of work is a queue entry, not a frontier bit.

    step_fn: (operands, items, payload, state, it, key)
             -> (items, payload, state, out)
      items:   (cap,) int32 queue entries, -1 = empty slot; setting an entry
               to -1 retires it (the runner re-compacts before balancing).
      payload: pytree of (cap, ...) companion data aligned with the items —
               it migrates with them through the balancer.
      out:     anything to stack per iteration (see run_queue).
    """

    step_fn: Callable


def run_queue(mesh: Mesh, prog: QueueProgram, items0: jnp.ndarray,
              payload0: Any, operands: Any, *, n_iters: int,
              axis: Optional[AxisName] = None,
              key: Optional[jax.Array] = None, state0: Any = (),
              sync_interval: int = 1):
    """Queue-driven distributed runner — shard_map plumbing owned once.

    Frontier programs are bitmap-shaped; walker / sampler workloads are a bag
    of work entries that migrate between shards — a different program family,
    so its per-iteration body is its own (a fixed-length `lax.scan`, not the
    frontier core's exhaustion loop), but the machinery around that body is
    the shared ExecutionCore plumbing: `_compact_slots` stable compaction,
    `offload.queue_balance` owner routing (the hardware queue engine's work
    stealing), `_shard_apply` shard_map wiring, per-(shard, iteration) key
    folding.

    items0:   (S, cap) int32 stacked queues, -1 = empty slot.
    payload0: pytree of (S, cap, ...) companion data riding with the items.
    operands: pytree of (S, ...) sharded arrays handed to every step
              (graph shards, lookup tables, ...).
    sync_interval: rebalance cadence (the async placement's knob for queue
      work): the queue-engine steal/balance — the body's only collective —
      runs every sync_interval-th iteration, so shards proceed at their own
      pace in between (entries still read remote data through dgas_gather
      by global id, so results stay valid; only load placement and the
      per-(shard, it) key stream differ from cadence 1).  Default 1 keeps
      the fully-balanced schedule.
    Returns (state, outs) with each `out` leaf stacked (S, n_iters, ...).
    """
    axis = axis if axis is not None else mesh.axis_names[0]
    key = key if key is not None else jax.random.PRNGKey(0)
    pl_leaves, pl_def = jax.tree.flatten(payload0)
    op_leaves, op_def = jax.tree.flatten(operands)
    st_leaves, st_def = jax.tree.flatten(state0)
    n_pl, n_op = len(pl_leaves), len(op_leaves)

    def shard_fn(items, *rest):
        items = items[0]
        payload = jax.tree.unflatten(pl_def, [l[0] for l in rest[:n_pl]])
        ops = jax.tree.unflatten(op_def, [l[0] for l in rest[n_pl:n_pl + n_op]])
        state = jax.tree.unflatten(st_def, [l[0] for l in rest[n_pl + n_op:]])
        shard_key = jax.random.fold_in(key, offload.my_shard(axis))
        cap = items.shape[0]

        def body(carry, it):
            items, payload, state = carry
            # retired entries may sit anywhere in the buffer: compact live
            # ones to a stable prefix (same machinery as the push step's
            # active-edge compaction) before balancing
            slots = _compact_slots(items >= 0, cap)
            safe = jnp.maximum(slots, 0)
            items = jnp.where(slots >= 0, jnp.take(items, safe), -1)
            payload = jax.tree.map(lambda x: jnp.take(x, safe, axis=0),
                                   payload)
            q = offload.QueueState(items,
                                   (items >= 0).sum().astype(jnp.int32))

            def balance(args):
                q_, pl_ = args
                if pl_leaves:
                    return offload.queue_balance(q_, axis, pl_)
                return offload.queue_balance(q_, axis), pl_

            if sync_interval > 1:
                # `it` is the scan index — identical on every shard — so the
                # branch is globally uniform and the collective inside the
                # cond is trace-safe (same pattern as _push_dispatch).
                q, payload = lax.cond((it % sync_interval) == 0, balance,
                                      lambda args: args, (q, payload))
            else:
                q, payload = balance((q, payload))
            items, payload, state, out = prog.step_fn(
                ops, q.items, payload, state, it,
                jax.random.fold_in(shard_key, it))
            return (items, payload, state), out

        (items, payload, state), outs = _scan_steps(
            body, (items, payload, state), jnp.arange(n_iters))
        return jax.tree.map(lambda l: l[None], (state, outs))

    return _shard_apply(mesh, axis, shard_fn,
                        (items0, *pl_leaves, *op_leaves, *st_leaves))
