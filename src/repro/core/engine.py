"""Direction-optimizing vertex-program execution engine.

Every frontier algorithm in this repo (BFS, PageRank, SpMV-as-one-step, SSSP,
connected components) is the same loop: per-vertex *messages* flow along edges
and are combined at the destination, then a per-vertex *update* produces the
next state and the next frontier.  This module owns that loop once — frontier
representation, push/pull direction choice, and (for the distributed case) the
``shard_map``/ATT plumbing — so the algorithms shrink to small
:class:`VertexProgram` definitions, the paper's "programmable offload" story:
the hardware-ish machinery (DMA gather, remote atomics, collectives, queues)
is shared and the application supplies only the little per-edge/per-vertex
functions.

Semiring-lite model.  A program computes, per iteration::

    msg  = msg_fn(state, frontier)            # (n,) — identity on inactive
    acc[v] = combine_{(u,v) in E} edge_op(msg[u], w_uv)
    state, frontier = update_fn(state, acc, frontier, it)

with ``edge_op`` in {mul, add, copy} and ``combine`` in {add, min, max}.
Frontier masking is folded into ``msg_fn`` (inactive vertices emit the combine
identity), which is what makes push and pull produce the same ``acc``.

Direction optimization (Beamer-style, re-expressed for bulk arrays):

* **sparse / push** — extract the frontier as an index list (static capacity
  ``C``), gather only those vertices' adjacency rows and scatter-combine their
  contributions: work ∝ edges of *active* vertices.
* **dense / pull** — one full edge-parallel pass (gather msg at src, segment
  combine at dst): work ∝ |E| but with no compaction overhead and perfectly
  vectorized.

The switch is a ``lax.cond`` on the frontier population count — globally
reduced with :func:`offload.hierarchical_psum` in the distributed engine so
all shards take the same branch.

When the program's combine is ``add``, both directions can instead run on the
BBCSR Pallas machinery (``kernels/spmv_dma.py``): the dense step is the SpMV
kernel, and the sparse step is the new SpMSpV variant that skips every tile
whose column block contains no active vertex (PIUMA's "only touch the data
the sparse frontier names").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import offload
from .dgas import ATT
from .graph import CSR, BBCSR, to_bbcsr
from .algorithms.distgraph import ShardedGraph

AxisName = Union[str, Sequence[str]]

__all__ = [
    "VertexProgram", "run", "run_distributed", "spmv_pass",
    "build_pull_operand", "tile_active",
]

_COMBINE_IDENTITY = {"add": 0.0, "min": float("inf"), "max": float("-inf")}


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """One frontier algorithm, reduced to its per-edge/per-vertex pieces.

    Attributes:
      edge_op:   how a message meets the edge weight: 'mul' | 'add' | 'copy'.
      combine:   destination-side reduction: 'add' | 'min' | 'max'.
      msg_fn:    (state, frontier) -> (n,) messages; MUST emit `identity` for
                 vertices outside the frontier (that makes push == pull).
      update_fn: (state, acc, frontier, it) -> (state, next_frontier).
      identity:  combine identity (defaults per combine).
    """

    edge_op: str
    combine: str
    msg_fn: Callable[[Any, jnp.ndarray], jnp.ndarray]
    update_fn: Callable[[Any, jnp.ndarray, jnp.ndarray, jnp.ndarray], tuple]
    identity: Optional[float] = None

    def __post_init__(self):
        if self.edge_op not in ("mul", "add", "copy"):
            raise ValueError(f"unknown edge_op {self.edge_op!r}")
        if self.combine not in _COMBINE_IDENTITY:
            raise ValueError(f"unknown combine {self.combine!r}")

    @property
    def ident(self):
        if self.identity is not None:
            return self.identity
        return _COMBINE_IDENTITY[self.combine]


def _apply_edge(em: jnp.ndarray, ev: jnp.ndarray, edge_op: str) -> jnp.ndarray:
    if edge_op == "mul":
        return em * ev
    if edge_op == "add":
        return em + ev
    return em


def _scatter_combine(dest: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                     combine: str, identity) -> jnp.ndarray:
    """Scatter-{add,min,max} with out-of-range indices dropped."""
    valid = (idx >= 0) & (idx < dest.shape[0])
    safe = jnp.where(valid, idx, 0)
    neutral = jnp.asarray(identity, dest.dtype)
    masked = jnp.where(valid, vals.astype(dest.dtype), neutral)
    if combine == "add":
        return dest.at[safe].add(masked)
    if combine == "min":
        return dest.at[safe].min(masked)
    return dest.at[safe].max(masked)


def _acc_init(n: int, prog: VertexProgram, dtype) -> jnp.ndarray:
    return jnp.full((n,), prog.ident, dtype)


# ---------------------------------------------------------------------------
# Kernel (BBCSR / Pallas) operands
# ---------------------------------------------------------------------------

def build_pull_operand(csr: CSR, *, unit_values: bool = False,
                       **bb_kwargs) -> BBCSR:
    """BBCSR of A^T — rows are *destinations*, columns are *sources* — so
    ``spmv_dma(bb, msg)`` computes exactly the engine's dense step for an
    'add' program (and ``spmspv_dma`` its sparse step)."""
    t = csr.transpose()
    if unit_values:
        t = CSR(t.indptr, t.indices, None, t.n_rows, t.n_cols)
    return to_bbcsr(t, **bb_kwargs)


def tile_active(bb: BBCSR, frontier: jnp.ndarray) -> jnp.ndarray:
    """(n_tiles,) int32 flags: 1 iff the tile's column block holds any active
    source vertex.  Scalar-prefetched by the SpMSpV kernel."""
    ncb = bb.n_col_blocks
    f = frontier.astype(jnp.int32)
    pad = ncb * bb.block_cols - f.shape[0]
    f = jnp.pad(f, (0, pad))
    blk = f.reshape(ncb, bb.block_cols).max(axis=1)
    return jnp.take(blk, bb.tile_cb)


# ---------------------------------------------------------------------------
# Local engine
# ---------------------------------------------------------------------------

def _dense_step(rows, cols, vals, msg, n, prog: VertexProgram):
    """Pull direction: one edge-parallel pass over every edge."""
    em = jnp.take(msg, rows)
    ev = _apply_edge(em, vals, prog.edge_op)
    if prog.combine == "add":
        return jax.ops.segment_sum(ev.astype(msg.dtype), cols, num_segments=n)
    return _scatter_combine(_acc_init(n, prog, msg.dtype), cols, ev,
                            prog.combine, prog.ident)


def _sparse_step(indptr, indices, vals, msg, frontier, n, C, k,
                 prog: VertexProgram):
    """Push direction: expand only the ≤C active vertices' adjacency rows."""
    ids, = jnp.nonzero(frontier, size=C, fill_value=-1)
    safe = jnp.maximum(ids, 0)
    start = jnp.take(indptr, safe)
    deg = jnp.take(indptr, safe + 1) - start
    offs = start[:, None] + jnp.arange(k, dtype=indptr.dtype)[None, :]
    valid = (jnp.arange(k)[None, :] < deg[:, None]) & (ids >= 0)[:, None]
    cols = offload.dma_gather(indices, jnp.where(valid, offs, -1))
    if vals is not None:
        ev = offload.dma_gather(vals, jnp.where(valid, offs, -1))
    else:
        ev = jnp.ones((C, k), msg.dtype)
    em = jnp.take(msg, safe)[:, None]
    contrib = _apply_edge(em, ev, prog.edge_op)
    contrib = jnp.where(valid, contrib, jnp.asarray(prog.ident, msg.dtype))
    acc = _acc_init(n, prog, msg.dtype)
    return _scatter_combine(acc, jnp.where(valid, cols, -1).reshape(-1),
                            contrib.reshape(-1), prog.combine, prog.ident)


def run(csr: CSR, prog: VertexProgram, state0: Any, frontier0: jnp.ndarray, *,
        max_iters: int, mode: str = "auto", push_capacity: Optional[int] = None,
        kernel_bb: Optional[BBCSR] = None, interpret: Optional[bool] = None,
        return_stats: bool = False):
    """Run `prog` to frontier exhaustion (or `max_iters`).

    mode: 'auto' (direction-optimizing), 'push' (always sparse), 'pull'
      (always dense).  'auto' switches on the frontier population count:
      sparse while it fits `push_capacity` (default n/32), dense otherwise.
    kernel_bb: BBCSR of A^T (see `build_pull_operand`) — routes both
      directions through the Pallas SpMV/SpMSpV kernels (combine='add' only).
    return_stats: also return {'iters', 'pushes', 'pulls'} taken.
    """
    if mode not in ("auto", "push", "pull"):
        raise ValueError(f"mode must be 'auto', 'push' or 'pull', got {mode!r}")
    n = csr.n_rows
    rows, cols = csr.row_ids(), csr.indices
    vals = csr.values
    if prog.edge_op == "copy":
        vals = None
    elif vals is None:
        vals = jnp.ones_like(csr.indices, jnp.float32)
    if mode != "pull":
        # static max degree for the push gather budget; derived with numpy
        # from the (concrete) indptr so `run` stays usable under jit
        indptr_np = np.asarray(csr.indptr)
        k = int((indptr_np[1:] - indptr_np[:-1]).max()) if indptr_np.size > 1 else 1
    else:
        k = 1
    k = max(k, 1)
    if push_capacity is None:
        push_capacity = n if mode == "push" else max(1, n // 32)
    C = min(push_capacity, n)
    if kernel_bb is not None:
        if prog.combine != "add":
            raise ValueError("the Pallas path accumulates on the MXU: combine "
                             "must be 'add'")
        if prog.edge_op == "add":
            raise ValueError("the Pallas kernels compute val*msg; edge_op "
                             "'add' has no kernel path")
        if prog.edge_op == "copy":
            v = np.asarray(kernel_bb.vals)
            if not bool(np.all((v == 0) | (v == 1))):
                raise ValueError(
                    "edge_op 'copy' needs a unit-valued kernel operand — "
                    "build it with build_pull_operand(csr, unit_values=True)")

    def dense(msg, frontier):
        if kernel_bb is not None:
            from ..kernels import ops as kops
            return kops.spmv_dma(kernel_bb, msg, interpret=interpret)[:n]
        return _dense_step(rows, cols, vals, msg, n, prog)

    def sparse(msg, frontier):
        if kernel_bb is not None:
            from ..kernels import ops as kops
            return kops.spmspv_dma(kernel_bb, msg, tile_active(kernel_bb, frontier),
                                   interpret=interpret)[:n]
        return _sparse_step(csr.indptr, csr.indices, vals, msg, frontier,
                            n, C, k, prog)

    def cond(carry):
        state, frontier, it, _, _ = carry
        return jnp.logical_and(jnp.any(frontier), it < max_iters)

    def body(carry):
        state, frontier, it, n_push, n_pull = carry
        msg = prog.msg_fn(state, frontier)
        if mode == "pull":
            acc, was_push = dense(msg, frontier), jnp.int32(0)
        else:
            # 'push' too: a frontier over C would be silently truncated by
            # the size=C nonzero, so oversized levels fall back to dense
            # (with push's default C=n the fallback never fires)
            small = frontier.astype(jnp.int32).sum() <= C
            acc = lax.cond(small, lambda: sparse(msg, frontier),
                           lambda: dense(msg, frontier))
            was_push = small.astype(jnp.int32)
        state, frontier = prog.update_fn(state, acc, frontier, it)
        return state, frontier, it + 1, n_push + was_push, n_pull + (1 - was_push)

    carry0 = (state0, frontier0, jnp.int32(0), jnp.int32(0), jnp.int32(0))
    state, _, it, n_push, n_pull = lax.while_loop(cond, body, carry0)
    if return_stats:
        return state, {"iters": it, "pushes": n_push, "pulls": n_pull}
    return state


# ---------------------------------------------------------------------------
# Distributed engine (owns the shard_map/ATT boilerplate)
# ---------------------------------------------------------------------------

def _axes_list(axis: AxisName):
    return [axis] if isinstance(axis, str) else list(axis)


def _spec(axis: AxisName) -> P:
    return P(axis) if isinstance(axis, str) else P(tuple(axis))


def _push_step_shard(src, dst, val, msg, att: ATT, axis, prog: VertexProgram,
                     capacity: int):
    """Push: owner of src computes contributions locally, remote-combines at
    the dst owner (PIUMA remote atomic)."""
    local_src = jnp.where(src >= 0, att.local(jnp.maximum(src, 0)), 0)
    em = offload.dma_gather(msg, local_src, fill=prog.ident)
    em = jnp.where(src >= 0, em, jnp.asarray(prog.ident, msg.dtype))
    ev = _apply_edge(em, val, prog.edge_op) if prog.edge_op != "copy" else em
    ev = jnp.where(src >= 0, ev, jnp.asarray(prog.ident, msg.dtype))
    acc = _acc_init(att.per_shard, prog, msg.dtype)
    gidx = jnp.where(src >= 0, dst, -1)
    if prog.combine == "add":
        return offload.remote_scatter_add(acc, gidx, ev, att, axis,
                                          capacity=capacity)
    return offload.remote_scatter_combine(acc, gidx, ev, att, axis,
                                          combine=prog.combine,
                                          identity=prog.ident,
                                          capacity=capacity)


def _pull_step_shard(own, remote, val, msg, att_in: ATT, att_out: ATT, axis,
                     prog: VertexProgram, capacity: int, gather_mode: str):
    """Pull: owner of the *output* vertex fetches messages from the input
    owners (fine-grained dgas_gather, or the all_gather baseline) and reduces
    locally."""
    gidx = jnp.where(remote >= 0, remote, -1)
    if gather_mode == "dgas":
        em = offload.dgas_gather(msg, gidx, att_in, axis, capacity=capacity,
                                 fill=prog.ident)
    else:
        em = offload.all_gather_gather(msg, gidx, att_in, axis, fill=prog.ident)
    ev = _apply_edge(em, val, prog.edge_op) if prog.edge_op != "copy" else em
    ev = jnp.where(own >= 0, ev, jnp.asarray(prog.ident, msg.dtype))
    local_own = jnp.where(own >= 0, att_out.local(jnp.maximum(own, 0)), -1)
    acc = _acc_init(att_out.per_shard, prog, msg.dtype)
    if prog.combine == "add":
        return offload.dma_scatter_add(acc, local_own, ev)
    return _scatter_combine(acc, local_own, ev, prog.combine, prog.ident)


def reverse_graph(csr: CSR, att: ATT) -> ShardedGraph:
    """Shard the *transposed* edge list by destination owner (= `att`, the
    vertex rule) for the distributed pull direction."""
    from .algorithms.distgraph import shard_graph
    g_rev, _ = shard_graph(csr.transpose(), att.n_shards, row_att=att)
    return g_rev


def run_distributed(g: ShardedGraph, att: ATT, mesh: Mesh,
                    prog: VertexProgram, state0: Any, frontier0: jnp.ndarray,
                    *, axis: Optional[AxisName] = None, max_iters: int,
                    g_rev: Optional[ShardedGraph] = None, mode: str = "push",
                    switch_frac: float = 1 / 32):
    """Distributed loop; `state0`/`frontier0` are stacked (S, per) per `att`.

    mode: 'push' (every level scatters via remote atomics — the seed
      behavior), 'pull' (requires `g_rev`; every level gathers via dgas), or
      'auto' (push while the globally-psum'd frontier is below
      `switch_frac * n`, pull once it saturates — Beamer's heuristic).
    Returns the final state pytree, stacked (S, per).
    """
    if mode not in ("auto", "push", "pull"):
        raise ValueError(f"mode must be 'auto', 'push' or 'pull', got {mode!r}")
    axis = axis if axis is not None else mesh.axis_names[0]
    spec = _spec(axis)
    axes = _axes_list(axis)
    if mode in ("pull", "auto") and g_rev is None:
        raise ValueError(f"mode={mode!r} needs g_rev (see reverse_graph)")
    switch_count = max(1, int(att.n_global * switch_frac))

    state_leaves, state_def = jax.tree.flatten(state0)
    n_state = len(state_leaves)
    use_rev = g_rev is not None
    m_fwd = g.edges_per_shard
    m_rev = g_rev.edges_per_shard if use_rev else 0

    def shard_fn(src, dst, val, rsrc, rdst, rval, frontier, *leaves):
        src, dst, val = src[0], dst[0], val[0]
        rsrc, rdst, rval = rsrc[0], rdst[0], rval[0]
        frontier = frontier[0]
        state = jax.tree.unflatten(state_def, [l[0] for l in leaves])

        def push(msg):
            return _push_step_shard(src, dst, val, msg, att, axis, prog,
                                    capacity=m_fwd)

        def pull(msg):
            # g_rev rows: src = output vertex (owned here), dst = input vertex
            return _pull_step_shard(rsrc, rdst, rval, msg, att, att, axis,
                                    prog, capacity=m_rev, gather_mode="dgas")

        def count(f):
            # globally-reduced count => every shard sees the same value
            return offload.hierarchical_psum(f.astype(jnp.int32).sum(), axes)

        def cond(carry):
            state, frontier, it, alive = carry
            return jnp.logical_and(alive > 0, it < max_iters)

        def body(carry):
            state, frontier, it, alive = carry
            msg = prog.msg_fn(state, frontier)
            if mode == "push":
                acc = push(msg)
            elif mode == "pull":
                acc = pull(msg)
            else:
                acc = lax.cond(alive <= switch_count,
                               lambda: push(msg), lambda: pull(msg))
            state, frontier = prog.update_fn(state, acc, frontier, it)
            # one collective per level: the new count rides the loop carry
            return state, frontier, it + 1, count(frontier)

        state, frontier, _, _ = lax.while_loop(
            cond, body, (state, frontier, jnp.int32(0), count(frontier)))
        return tuple(l[None] for l in jax.tree.leaves(state))

    if not use_rev:  # placeholder operands keep the shard_map arity static
        z = jnp.full((att.n_shards, 1), -1, jnp.int32)
        rsrc, rdst, rval = z, z, jnp.zeros((att.n_shards, 1), jnp.float32)
    else:
        rsrc, rdst, rval = g_rev.src, g_rev.dst, g_rev.val

    n_in = 7 + n_state
    # check_rep=False: this jax has no replication rule for while_loop with a
    # psum in its cond; outputs are per-shard anyway (out_specs fully sharded).
    mapped = shard_map(shard_fn, mesh=mesh, in_specs=(spec,) * n_in,
                       out_specs=(spec,) * n_state, check_rep=False)
    out = mapped(g.src, g.dst, g.val, rsrc, rdst, rval, frontier0,
                 *state_leaves)
    return jax.tree.unflatten(state_def, list(out))


def spmv_pass(g: ShardedGraph, x_sharded: jnp.ndarray, x_att: ATT,
              row_att: ATT, mesh: Mesh, *, axis: Optional[AxisName] = None,
              mode: str = "dgas") -> jnp.ndarray:
    """One distributed engine pull step == y = A @ x (rows per `row_att`,
    x per `x_att`).  `spmv_distributed` delegates here; kept in the engine so
    SpMV shares the exact same shard step as every frontier algorithm."""
    axis = axis if axis is not None else mesh.axis_names[0]
    spec = _spec(axis)
    prog = VertexProgram(edge_op="mul", combine="add",
                         msg_fn=lambda s, f: s, update_fn=None)

    def shard_fn(src, dst, val, x_local):
        return _pull_step_shard(src[0], dst[0], val[0], x_local[0],
                                x_att, row_att, axis, prog,
                                capacity=g.edges_per_shard,
                                gather_mode=mode)[None]

    mapped = shard_map(shard_fn, mesh=mesh, in_specs=(spec,) * 4,
                       out_specs=spec)
    return mapped(g.src, g.dst, g.val, x_sharded)
