"""Pallas TPU kernels (validated with interpret=True on CPU)."""
from . import ops, ref
