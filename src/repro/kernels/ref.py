"""Pure-jnp oracles for every Pallas kernel (the `assert_allclose` targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.graph import BBCSR

__all__ = ["spmv_bbcsr_ref", "segment_sum_ref", "embedding_bag_ref",
           "flash_attention_ref"]


def spmv_bbcsr_ref(bb: BBCSR, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x straight off the tile arrays (padding vals are 0)."""
    rows = (bb.tile_rb[:, None] * bb.block_rows + bb.rows_local).reshape(-1)
    cols = (bb.tile_cb[:, None] * bb.block_cols + bb.cols_local).reshape(-1)
    vals = bb.vals.reshape(-1)
    x_pad = jnp.pad(x, (0, bb.n_col_blocks * bb.block_cols - x.shape[0]))
    contrib = vals * jnp.take(x_pad, cols)
    y = jax.ops.segment_sum(contrib, rows,
                            num_segments=bb.n_row_blocks * bb.block_rows)
    return y[: bb.n_rows]


def segment_sum_ref(data: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    """Sorted-or-not segment sum; rows with seg<0 are dropped."""
    valid = seg >= 0
    safe = jnp.where(valid, seg, 0)
    w = valid.reshape(valid.shape + (1,) * (data.ndim - 1)).astype(data.dtype)
    return jax.ops.segment_sum(data * w, safe, num_segments=num_segments)


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray, bag: jnp.ndarray,
                      n_bags: int, weights: Optional[jnp.ndarray] = None,
                      mode: str = "sum") -> jnp.ndarray:
    """EmbeddingBag: sum/mean of table rows grouped by bag id (idx<0 = padding)."""
    valid = idx >= 0
    rows = jnp.take(table, jnp.where(valid, idx, 0), axis=0)
    w = jnp.where(valid, 1.0, 0.0) if weights is None else jnp.where(valid, weights, 0.0)
    rows = rows * w[:, None].astype(rows.dtype)
    out = jax.ops.segment_sum(rows, jnp.where(valid, bag, n_bags),
                              num_segments=n_bags + 1)[:n_bags]
    if mode == "mean":
        cnt = jax.ops.segment_sum(w, jnp.where(valid, bag, n_bags),
                                  num_segments=n_bags + 1)[:n_bags]
        out = out / jnp.maximum(cnt, 1e-9)[:, None]
    return out


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    causal alignment assumes q occupies the LAST Sq positions of the kv range.
    window: sliding window — key j visible to query position p iff p-window < j <= p.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32)).astype(q.dtype)
