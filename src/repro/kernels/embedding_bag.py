"""Pallas TPU kernel: EmbeddingBag — the recsys DMA-gather hot path.

JAX has no native EmbeddingBag; this is it, built the PIUMA way: the huge
table stays in HBM, and per grid step the Pallas pipeline DMAs exactly ONE
requested row into VMEM, addressed by a *scalar-prefetched* index (the TPU
equivalent of handing the DMA engine an index list — the engine runs ahead of
compute and only the needed rows ever cross HBM, never whole cache lines /
pages of the table).  Bags are contiguous runs of the (sorted-by-bag) index
stream; the output row is revisited consecutively and accumulated.

For MXU-width efficiency a production variant would fetch `rows_per_step`
rows per step; this kernel keeps one row per step to make the fine-grained
access pattern explicit (ops.py exposes the blocked wrapper).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag_kernel_call"]


def _kernel(idx_ref, bag_ref, init_ref, w_ref, row_ref, out_ref):
    i = pl.program_id(0)
    w = w_ref[0, 0]
    valid = (idx_ref[i] >= 0).astype(jnp.float32)
    row = row_ref[0, :] * w * valid

    @pl.when(init_ref[i] == 1)
    def _init():
        out_ref[0, :] = row

    @pl.when(init_ref[i] == 0)
    def _acc():
        out_ref[0, :] += row


def embedding_bag_kernel_call(table: jnp.ndarray, idx: jnp.ndarray,
                              bag: jnp.ndarray, n_bags: int,
                              weights: Optional[jnp.ndarray] = None,
                              *, interpret: bool = True) -> jnp.ndarray:
    """table (V, d); idx (N,) int32 sorted by bag, -1 = padding; bag (N,) int32
    non-decreasing, every bag in [0, n_bags) present at least once.

    Returns (n_bags, d) float32 sums. (mean handled by the ops wrapper.)
    """
    n = idx.shape[0]
    d = table.shape[1]
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.astype(jnp.float32)).reshape(n, 1)
    init = jnp.concatenate([jnp.ones((1,), jnp.int32),
                            (bag[1:] != bag[:-1]).astype(jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # idx, bag, init
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, idx, bag, ini: (i, 0)),  # weight
            # DMA of exactly the requested row (clamped for padding slots)
            pl.BlockSpec((1, d), lambda i, idx, bag, ini: (jnp.maximum(idx[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx, bag, ini: (bag[i], 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), bag.astype(jnp.int32), init, w,
      table.astype(jnp.float32))
    return out
