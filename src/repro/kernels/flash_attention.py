"""Pallas TPU kernel: flash attention (causal / sliding-window / GQA).

The LM hot path.  Online-softmax accumulation over key blocks with the
running (m, l, acc) state held in VMEM scratch; q/k/v blocks are DMA'd by the
Pallas pipeline (double buffered).  Supports:

* GQA — Hq queries share Hq/Hkv kv heads (k/v BlockSpecs fold the group),
* causal masking with q occupying the LAST Sq positions of the kv range
  (covers both training (Sq == Skv) and decode (Sq == 1)),
* sliding-window masking (Mixtral-style SWA).

Block sizes default to MXU-aligned (128) tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, seq_off: int, n_kblocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + seq_off
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kblocks - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention_kernel_call(q, k, v, *, causal: bool = True,
                                window: Optional[int] = None,
                                scale: Optional[float] = None,
                                block_q: int = 128, block_k: int = 128,
                                interpret: bool = True):
    """q (B,Hq,Sq,D); k,v (B,Hkv,Skv,D). Returns (B,Hq,Sq,D) in q.dtype."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_off=Skv - Sq, n_kblocks=nk)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=(B, Hq, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
                pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // g, j, 0)),
                pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // g, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
