"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` (the
kernel body runs as traced jnp — numerically identical); on a real TPU they
compile to Mosaic.  ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .. import tune as _tune
from ..core.graph import BBCSR
from . import embedding_bag as _eb
from . import flash_attention as _fa
from . import ref as ref
from . import segment_sum as _ss
from . import spmv_dma as _spmv

__all__ = ["spmv_dma", "spmspv_dma", "segment_sum_sorted", "embedding_bag",
           "flash_attention"]

# segment-sum kernel VMEM budget: out (M, d) + onehot (bn, M) in f32
_SEGSUM_VMEM_LIMIT = 4 * 1024 * 1024


def _interp(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def spmv_dma(bb: BBCSR, x: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = A @ x via the DMA-gather/selective-caching kernel."""
    return _spmv.spmv_bbcsr_kernel_call(bb, x, interpret=_interp(interpret))


def spmspv_dma(bb: BBCSR, x: jnp.ndarray, tile_active: jnp.ndarray, *,
               combine: str = "add",
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """y = A ⊕ x for sparse x; tiles whose column block is inactive (per
    `tile_active`, see `core.engine.tile_active`) skip compute.  combine:
    'add' (val * x[col], MXU one-hot path) or 'min' / 'max' (x[col] + val,
    masked-select tile combine — the distance semirings; needs
    bb.tile_cnt)."""
    return _spmv.spmspv_bbcsr_kernel_call(bb, x, tile_active, combine=combine,
                                          interpret=_interp(interpret))


def segment_sum_sorted(data: jnp.ndarray, seg: jnp.ndarray, num_segments: int,
                       *, block_n: Optional[int] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sorted segment sum. Falls back to jax.ops.segment_sum above the VMEM cap.

    block_n: stream tile width; None takes the tuned value (repro.tune,
    explicit kwarg wins)."""
    block_n = int(_tune.resolve("kernels.segment_sum.block_n",
                                explicit=block_n, n=num_segments))
    d = data.shape[-1]
    if 4 * num_segments * (d + block_n) > _SEGSUM_VMEM_LIMIT:
        return ref.segment_sum_ref(data, seg, num_segments)
    return _ss.segment_sum_kernel_call(data, seg, num_segments, block_n=block_n,
                                       interpret=_interp(interpret))


def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray, bag: jnp.ndarray,
                  n_bags: int, weights: Optional[jnp.ndarray] = None,
                  mode: str = "sum", *, presorted: bool = False,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """EmbeddingBag(sum|mean). idx (N,) int32 (-1 pad), bag (N,) int32 in [0, n_bags).

    The kernel needs the stream sorted by bag with every bag present; unless
    `presorted`, this wrapper adds one sentinel per bag and sorts (stable).
    """
    if not presorted:
        sent_idx = jnp.full((n_bags,), -1, jnp.int32)
        sent_bag = jnp.arange(n_bags, dtype=jnp.int32)
        idx_all = jnp.concatenate([idx.astype(jnp.int32), sent_idx])
        bag_all = jnp.concatenate([bag.astype(jnp.int32), sent_bag])
        w_all = (None if weights is None else
                 jnp.concatenate([weights, jnp.zeros((n_bags,), weights.dtype)]))
        order = jnp.argsort(bag_all, stable=True)
        idx_all = jnp.take(idx_all, order)
        bag_all = jnp.take(bag_all, order)
        w_all = None if w_all is None else jnp.take(w_all, order)
    else:
        idx_all, bag_all, w_all = idx, bag, weights
    out = _eb.embedding_bag_kernel_call(table, idx_all, bag_all, n_bags, w_all,
                                        interpret=_interp(interpret))
    if mode == "mean":
        valid = (idx_all >= 0)
        w = valid.astype(jnp.float32) if w_all is None else jnp.where(valid, w_all, 0.0)
        cnt = jax.ops.segment_sum(w, bag_all, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1e-9)[:, None]
    return out


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Flash attention with GQA/causal/sliding-window. See flash_attention.py.

    block_q / block_k: tile shape; None takes the tuned values (repro.tune,
    explicit kwargs win)."""
    seq = q.shape[-2]
    block_q = int(_tune.resolve("kernels.flash_attention.block_q",
                                explicit=block_q, n=seq))
    block_k = int(_tune.resolve("kernels.flash_attention.block_k",
                                explicit=block_k, n=seq))
    return _fa.flash_attention_kernel_call(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_interp(interpret))
